"""Real JAX execution beneath the sandbox abstraction.

A *model instance* is the TPU-serving analogue of the paper's sandbox: a
compiled (prefill, decode) executable pair + resident weights + a KV-cache
slab.  Setting one up costs real time (XLA compile + weight init) — the
moral equivalent of the paper's container start + code download, and in the
same 0.1-10 s range (T3's SNE regime).

``JaxModelExecutor`` plugs into ``SemiGlobalScheduler`` through the
``execute`` hook: invocation -> measured wall seconds.
"""
from __future__ import annotations

import time
import zlib
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.backends import pow2_bucket
from ..core.types import FunctionSpec, Invocation
from ..models import (decode_step, decode_step_ragged, init_cache,
                      init_params, prefill)
from ..models.config import ModelConfig


def batch_seed(inv_ids: Iterable[int]) -> int:
    """Deterministic, order-INDEPENDENT seed for a batched execution.

    The member set alone determines the seed: coalescing order (which
    depends on flush timing) must not change what the batch computes.
    Seeding from ``invs[0].inv_id`` broke that — the same member set
    flushed in a different gather order executed different work."""
    data = b"".join(i.to_bytes(8, "little")
                    for i in sorted(int(i) for i in inv_ids))
    return zlib.crc32(data)


@dataclass
class ServedModel:
    """What a 'function' computes: prefill `prompt_len` tokens, then decode
    `gen_len` tokens, at batch size `batch`."""

    cfg: ModelConfig
    prompt_len: int = 64
    gen_len: int = 8
    batch: int = 1


@dataclass
class ModelInstance:
    """A warm sandbox: compiled executables + weights + cache."""

    served: ServedModel
    params: Any = None
    prefill_fn: Callable = None
    decode_fn: Callable = None
    cache0: Any = None
    setup_seconds: float = 0.0

    def setup(self, seed: int = 0) -> float:
        """Compile + initialize.  Returns real wall time (the sandbox setup
        overhead that Archipelago moves off the critical path)."""
        t0 = time.perf_counter()
        sm = self.served
        cfg = sm.cfg
        key = jax.random.PRNGKey(seed)
        self.params = jax.jit(lambda k: init_params(cfg, k))(key)
        max_len = sm.prompt_len + sm.gen_len
        self.cache0 = init_cache(cfg, sm.batch, max_len)

        def _prefill(params, tokens, cache, frontend=None):
            return prefill(cfg, params, tokens, cache, frontend)

        def _decode(params, cache, tok, t):
            return decode_step(cfg, params, cache, tok, t)

        self.prefill_fn = jax.jit(_prefill)
        self.decode_fn = jax.jit(_decode)
        # trigger compilation (part of setup, exactly like a container build)
        tokens = jnp.zeros((sm.batch, sm.prompt_len), jnp.int32)
        frontend = None
        if cfg.frontend:
            frontend = jnp.zeros((sm.batch, cfg.n_frontend_tokens,
                                  cfg.d_model), cfg.dtype())
            lg, c = self.prefill_fn(self.params, tokens, self.cache0, frontend)
        else:
            lg, c = self.prefill_fn(self.params, tokens, self.cache0)
        tok = jnp.zeros((sm.batch, 1), jnp.int32)
        lg2, _ = self.decode_fn(self.params, c, tok, jnp.int32(sm.prompt_len))
        jax.block_until_ready((lg, lg2))
        self.setup_seconds = time.perf_counter() - t0
        return self.setup_seconds

    def run(self, seed: int = 0) -> float:
        """One request: prefill + gen_len greedy decode steps.  Returns
        measured wall seconds."""
        sm = self.served
        cfg = sm.cfg
        t0 = time.perf_counter()
        key = jax.random.PRNGKey(seed)
        tokens = jax.random.randint(key, (sm.batch, sm.prompt_len), 0,
                                    cfg.vocab_size)
        if cfg.frontend:
            frontend = jnp.zeros((sm.batch, cfg.n_frontend_tokens,
                                  cfg.d_model), cfg.dtype())
            logits, cache = self.prefill_fn(self.params, tokens, self.cache0,
                                            frontend)
        else:
            logits, cache = self.prefill_fn(self.params, tokens, self.cache0)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        for i in range(sm.gen_len):
            logits, cache = self.decode_fn(self.params, cache, tok,
                                           jnp.int32(sm.prompt_len + i))
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(tok)
        return time.perf_counter() - t0


class JaxModelExecutor:
    """Maps function names -> model instances; measures real setup/exec.

    Used two ways:
      * ``calibrate()`` produces FunctionSpecs whose exec_time / setup_time
        are *measured*, so the scheduler operates on real numbers.
      * as the SGS ``execute`` hook, it runs the actual model per invocation.
    """

    def __init__(self, served: Dict[str, ServedModel]):
        self.served = served
        self._instances: Dict[str, ModelInstance] = {}
        self.n_executions = 0

    def ensure_instance(self, fn_name: str) -> ModelInstance:
        inst = self._instances.get(fn_name)
        if inst is None:
            inst = ModelInstance(self.served[fn_name])
            inst.setup()
            self._instances[fn_name] = inst
        return inst

    def calibrate(self, mem_mb: float = 512.0,
                  runs: int = 3) -> Dict[str, FunctionSpec]:
        """Measure setup + exec time per function; build real FunctionSpecs."""
        specs = {}
        for name in self.served:
            inst = self.ensure_instance(name)
            times = [inst.run(seed=i) for i in range(runs)]
            specs[name] = FunctionSpec(
                name=name, exec_time=sorted(times)[len(times) // 2],
                mem_mb=mem_mb, setup_time=inst.setup_seconds)
        return specs

    def execute(self, inv: Invocation) -> float:
        """SGS execute hook: run the real model for this invocation."""
        inst = self.ensure_instance(inv.fn.name)
        self.n_executions += 1
        return inst.run(seed=inv.inv_id)


class BatchingJaxExecutor:
    """Batched data plane: pads concurrently in-flight invocations of the
    same ``ServedModel`` into one real batched execution.

    A *bucket* is a power-of-two batch size; each bucket gets its own
    compiled (prefill, decode) executable pair — all compiled up front in
    ``calibrate`` so sweeps pay XLA compiles exactly once.  At run time the
    coalescer (``repro.core.backends.BatchCoalescer``, which owns the
    time/size flush window) calls ``run_batch`` with the gathered
    invocations; the batch executes once at the smallest bucket that fits
    and every member shares the measured wall time.  Each invocation
    occupies one batch slot (one sequence): the bucket size *replaces* the
    ``ServedModel.batch`` dimension.

    Amortizing weight reads over the whole batch is why this sustains a
    multiple of the per-invocation executor's throughput once batches form
    — see ``benchmarks/bench_serving.py``'s batched-vs-unbatched
    comparison.
    """

    def __init__(self, served: Dict[str, ServedModel], max_batch: int = 8):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.served = served
        self.max_batch = max_batch
        self._instances: Dict[Tuple[str, int], ModelInstance] = {}
        # calibration medians per (fn_name, bucket) — measured batched
        # execution seconds, recorded for reporting/analysis
        self.bucket_exec_s: Dict[Tuple[str, int], float] = {}
        self.n_executions = 0           # real batched runs

    def buckets(self) -> List[int]:
        """The power-of-two batch sizes compiled per model: 1, 2, 4, ...,
        up to the smallest power of two covering ``max_batch``."""
        out, b = [], 1
        top = pow2_bucket(self.max_batch)
        while b <= top:
            out.append(b)
            b *= 2
        return out

    def ensure_instance(self, fn_name: str, bucket: int) -> ModelInstance:
        key = (fn_name, bucket)
        inst = self._instances.get(key)
        if inst is None:
            inst = ModelInstance(replace(self.served[fn_name], batch=bucket))
            inst.setup()
            self._instances[key] = inst
        return inst

    def calibrate(self, mem_mb: float = 512.0,
                  runs: int = 3) -> Dict[str, FunctionSpec]:
        """Compile EVERY bucket executable per function (the whole compile
        bill lands here, off the serving path) and measure each bucket's
        batched execution time.  The returned ``FunctionSpec``s carry the
        batch-1 numbers — what a single invocation costs unbatched — so
        scheduling stays comparable with the per-invocation ``jax``
        backend; per-bucket medians live in ``bucket_exec_s``."""
        specs = {}
        for name in self.served:
            for b in self.buckets():
                inst = self.ensure_instance(name, b)
                times = [inst.run(seed=i) for i in range(runs)]
                self.bucket_exec_s[(name, b)] = sorted(times)[len(times) // 2]
            specs[name] = FunctionSpec(
                name=name, exec_time=self.bucket_exec_s[(name, 1)],
                mem_mb=mem_mb,
                setup_time=self._instances[(name, 1)].setup_seconds)
        return specs

    def run_batch(self, fn_name: str, invs: List[Invocation]) -> float:
        """Execute ``invs`` as ONE padded batch; returns measured wall
        seconds (the shared runtime of every member)."""
        bucket = pow2_bucket(len(invs))
        inst = self.ensure_instance(fn_name, bucket)
        self.n_executions += 1
        return inst.run(seed=batch_seed(inv.inv_id for inv in invs))


@dataclass
class _ContinuousState:
    """Per-function continuous-serving state: resident weights + a slot slab.

    The *slab* is one persistent KV/SSM cache allocated at the padded
    capacity (``pow2_bucket(max_batch)`` sequences); every request owns one
    slot for its lifetime.  ``tok``/``pos`` hold each slot's last sampled
    token and absolute decode position.  Slots not marked active by the
    batcher are never gathered, so stale contents are harmless."""

    served: ServedModel
    cap: int
    params: Any = None
    slab: Any = None
    tok: Any = None                       # (cap, 1) int32
    pos: Any = None                       # (cap,)  int32
    join_fns: Dict[int, Callable] = field(default_factory=dict)
    step_fns: Dict[int, Callable] = field(default_factory=dict)
    setup_seconds: float = 0.0


class ContinuousJaxExecutor:
    """Step-granular data plane: real continuous batching over a slot slab.

    The real twin of ``repro.core.backends.ContinuousBatcher``'s hooks:

    * ``admit(fn, invs, slots)`` — ONE batched prefill of the joiners,
      scattered into their cache slots (plus the first sampled token).
    * ``step(fn, slots)`` — ONE fused ragged decode step for every active
      slot (``repro.models.decode_step_ragged``: per-row positions, so
      requests at different depths share the device step).
    * ``gen_steps(fn)`` — decode steps a request owes after its prefill.

    Batches are padded to power-of-two *buckets*; each bucket gets its own
    jitted (join, step) executable pair, all compiled in ``calibrate`` so
    the serving path never compiles.  Padding duplicates the first member's
    slot: duplicate gather rows compute identical values, so the duplicate
    scatter is deterministic.  Prompts are seeded from the order-independent
    ``batch_seed`` of the joining member set.

    Limitations: models with a modality frontend or an encoder stack
    (``cfg.frontend`` / encdec) keep the windowed data plane — their
    prefill needs per-request frontend frames, which the slot slab does not
    carry yet (see docs/SERVING.md).
    """

    def __init__(self, served: Dict[str, ServedModel], max_batch: int = 8):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        for name, sm in served.items():
            if sm.cfg.frontend or sm.cfg.arch_type == "encdec":
                raise NotImplementedError(
                    f"continuous batching does not support frontend/encdec "
                    f"models yet (function {name!r}, model {sm.cfg.name}); "
                    f"use batching='windowed'")
        self.served = served
        self.max_batch = max_batch
        self._state: Dict[str, _ContinuousState] = {}
        # calibration medians per (fn_name, bucket): batched prefill
        # seconds and per-decode-step seconds (roofline reporting)
        self.bucket_admit_s: Dict[Tuple[str, int], float] = {}
        self.bucket_step_s: Dict[Tuple[str, int], float] = {}
        self.n_executions = 0           # real device dispatches (admit+step)

    def buckets(self) -> List[int]:
        out, b = [], 1
        top = pow2_bucket(self.max_batch)
        while b <= top:
            out.append(b)
            b *= 2
        return out

    def gen_steps(self, fn_name: str) -> int:
        return self.served[fn_name].gen_len

    def _ensure(self, fn_name: str) -> _ContinuousState:
        st = self._state.get(fn_name)
        if st is None:
            st = self._setup(fn_name)
            self._state[fn_name] = st
        return st

    def _setup(self, fn_name: str) -> _ContinuousState:
        t0 = time.perf_counter()
        sm = self.served[fn_name]
        cfg = sm.cfg
        cap = pow2_bucket(self.max_batch)
        max_len = sm.prompt_len + sm.gen_len
        st = _ContinuousState(served=sm, cap=cap)
        st.params = jax.jit(lambda k: init_params(cfg, k))(
            jax.random.PRNGKey(0))
        st.slab = init_cache(cfg, cap, max_len)
        st.tok = jnp.zeros((cap, 1), jnp.int32)
        st.pos = jnp.zeros((cap,), jnp.int32)

        def make_join(b: int) -> Callable:
            def _join(params, slab, tok, pos, tokens, slot_ids):
                cache = init_cache(cfg, b, max_len)
                lg, c = prefill(cfg, params, tokens, cache)
                first = jnp.argmax(lg, axis=-1).astype(jnp.int32)  # (b,1)
                slab = jax.tree.map(
                    lambda s, cn: s.at[:, slot_ids].set(cn.astype(s.dtype)),
                    slab, c)
                tok = tok.at[slot_ids].set(first)
                pos = pos.at[slot_ids].set(
                    jnp.full((b,), sm.prompt_len, jnp.int32))
                return slab, tok, pos
            return jax.jit(_join)

        def make_step(b: int) -> Callable:
            def _step(params, slab, tok, pos, slot_ids):
                sub = jax.tree.map(lambda s: s[:, slot_ids], slab)
                lg, c2 = decode_step_ragged(cfg, params, sub,
                                            tok[slot_ids], pos[slot_ids])
                ntok = jnp.argmax(lg, axis=-1).astype(jnp.int32)   # (b,1)
                slab = jax.tree.map(
                    lambda s, cn: s.at[:, slot_ids].set(cn.astype(s.dtype)),
                    slab, c2)
                tok = tok.at[slot_ids].set(ntok)
                pos = pos.at[slot_ids].set(pos[slot_ids] + 1)
                return slab, tok, pos
            return jax.jit(_step)

        # compile every bucket up front (the whole compile bill is setup,
        # off the serving path — container build, in paper terms)
        for b in self.buckets():
            jf, sf = make_join(b), make_step(b)
            toks = jnp.zeros((b, sm.prompt_len), jnp.int32)
            ids = jnp.arange(b, dtype=jnp.int32)
            slab, tok, pos = jf(st.params, st.slab, st.tok, st.pos, toks, ids)
            slab, tok, pos = sf(st.params, slab, tok, pos, ids)
            jax.block_until_ready(tok)
            st.join_fns[b], st.step_fns[b] = jf, sf
        st.setup_seconds = time.perf_counter() - t0
        return st

    def _pad_slots(self, slots: List[int]) -> Tuple[int, jnp.ndarray]:
        """Pad the slot list to its bucket by repeating the first slot
        (duplicate rows compute identical values — deterministic)."""
        b = pow2_bucket(len(slots))
        pad = b - len(slots)
        return b, jnp.asarray(list(slots) + [slots[0]] * pad, jnp.int32)

    def admit(self, fn_name: str, invs: List[Invocation],
              slots: List[int]) -> float:
        return self._admit_seeded(fn_name,
                                  [inv.inv_id for inv in invs], slots)

    def _admit_seeded(self, fn_name: str, ids: List[int],
                      slots: List[int]) -> float:
        st = self._ensure(fn_name)
        sm = st.served
        t0 = time.perf_counter()
        b, slot_ids = self._pad_slots(slots)
        key = jax.random.PRNGKey(batch_seed(ids))
        toks = jax.random.randint(key, (len(slots), sm.prompt_len), 0,
                                  sm.cfg.vocab_size)
        if b > len(slots):
            toks = jnp.concatenate(
                [toks, jnp.broadcast_to(toks[:1],
                                        (b - len(slots),) + toks.shape[1:])])
        st.slab, st.tok, st.pos = st.join_fns[b](
            st.params, st.slab, st.tok, st.pos, toks, slot_ids)
        jax.block_until_ready(st.tok)
        self.n_executions += 1
        return time.perf_counter() - t0

    def step(self, fn_name: str, slots: List[int]) -> float:
        st = self._ensure(fn_name)
        t0 = time.perf_counter()
        b, slot_ids = self._pad_slots(slots)
        st.slab, st.tok, st.pos = st.step_fns[b](
            st.params, st.slab, st.tok, st.pos, slot_ids)
        jax.block_until_ready(st.tok)
        self.n_executions += 1
        return time.perf_counter() - t0

    def release_slots(self, fn_name: str, slots: List[int]) -> None:
        """Scrub the token/position rows of vacated cache slots.

        Called by the batcher when residents are dropped mid-flight (their
        worker crashed, core.fault): freed slots are never gathered again
        until re-admission overwrites them, so this is slab hygiene rather
        than correctness — it keeps dead requests' sampled tokens out of the
        state a debugger (or a later assertion) would inspect.  Cheap: two
        scatter updates, no cache-slab traffic."""
        st = self._state.get(fn_name)
        if st is None or not slots:
            return
        slot_ids = jnp.asarray(sorted(slots), jnp.int32)
        st.tok = st.tok.at[slot_ids].set(0)
        st.pos = st.pos.at[slot_ids].set(0)

    def calibrate(self, mem_mb: float = 512.0,
                  runs: int = 3) -> Dict[str, FunctionSpec]:
        """Compile every bucket executable per function and measure each
        bucket's batched prefill + per-step decode medians.  The returned
        ``FunctionSpec`` carries the batch-1 full-request time (prefill +
        ``gen_len`` steps) so scheduling stays comparable with the
        windowed/per-invocation backends; per-bucket medians live in
        ``bucket_admit_s`` / ``bucket_step_s``."""
        specs = {}
        for name in self.served:
            st = self._ensure(name)
            for b in self.buckets():
                slots = list(range(b))
                a = sorted(self._admit_seeded(name, slots, slots)
                           for _ in range(runs))
                s = sorted(self.step(name, slots) for _ in range(runs))
                self.bucket_admit_s[(name, b)] = a[runs // 2]
                self.bucket_step_s[(name, b)] = s[runs // 2]
            exec_s = (self.bucket_admit_s[(name, 1)]
                      + st.served.gen_len * self.bucket_step_s[(name, 1)])
            specs[name] = FunctionSpec(name=name, exec_time=exec_s,
                                       mem_mb=mem_mb,
                                       setup_time=st.setup_seconds)
        return specs
