"""Real JAX execution beneath the sandbox abstraction.

A *model instance* is the TPU-serving analogue of the paper's sandbox: a
compiled (prefill, decode) executable pair + resident weights + a KV-cache
slab.  Setting one up costs real time (XLA compile + weight init) — the
moral equivalent of the paper's container start + code download, and in the
same 0.1-10 s range (T3's SNE regime).

``JaxModelExecutor`` plugs into ``SemiGlobalScheduler`` through the
``execute`` hook: invocation -> measured wall seconds.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.backends import pow2_bucket
from ..core.types import FunctionSpec, Invocation
from ..models import decode_step, init_cache, init_params, prefill
from ..models.config import ModelConfig


@dataclass
class ServedModel:
    """What a 'function' computes: prefill `prompt_len` tokens, then decode
    `gen_len` tokens, at batch size `batch`."""

    cfg: ModelConfig
    prompt_len: int = 64
    gen_len: int = 8
    batch: int = 1


@dataclass
class ModelInstance:
    """A warm sandbox: compiled executables + weights + cache."""

    served: ServedModel
    params: Any = None
    prefill_fn: Callable = None
    decode_fn: Callable = None
    cache0: Any = None
    setup_seconds: float = 0.0

    def setup(self, seed: int = 0) -> float:
        """Compile + initialize.  Returns real wall time (the sandbox setup
        overhead that Archipelago moves off the critical path)."""
        t0 = time.perf_counter()
        sm = self.served
        cfg = sm.cfg
        key = jax.random.PRNGKey(seed)
        self.params = jax.jit(lambda k: init_params(cfg, k))(key)
        max_len = sm.prompt_len + sm.gen_len
        self.cache0 = init_cache(cfg, sm.batch, max_len)

        def _prefill(params, tokens, cache, frontend=None):
            return prefill(cfg, params, tokens, cache, frontend)

        def _decode(params, cache, tok, t):
            return decode_step(cfg, params, cache, tok, t)

        self.prefill_fn = jax.jit(_prefill)
        self.decode_fn = jax.jit(_decode)
        # trigger compilation (part of setup, exactly like a container build)
        tokens = jnp.zeros((sm.batch, sm.prompt_len), jnp.int32)
        frontend = None
        if cfg.frontend:
            frontend = jnp.zeros((sm.batch, cfg.n_frontend_tokens,
                                  cfg.d_model), cfg.dtype())
            lg, c = self.prefill_fn(self.params, tokens, self.cache0, frontend)
        else:
            lg, c = self.prefill_fn(self.params, tokens, self.cache0)
        tok = jnp.zeros((sm.batch, 1), jnp.int32)
        lg2, _ = self.decode_fn(self.params, c, tok, jnp.int32(sm.prompt_len))
        jax.block_until_ready((lg, lg2))
        self.setup_seconds = time.perf_counter() - t0
        return self.setup_seconds

    def run(self, seed: int = 0) -> float:
        """One request: prefill + gen_len greedy decode steps.  Returns
        measured wall seconds."""
        sm = self.served
        cfg = sm.cfg
        t0 = time.perf_counter()
        key = jax.random.PRNGKey(seed)
        tokens = jax.random.randint(key, (sm.batch, sm.prompt_len), 0,
                                    cfg.vocab_size)
        if cfg.frontend:
            frontend = jnp.zeros((sm.batch, cfg.n_frontend_tokens,
                                  cfg.d_model), cfg.dtype())
            logits, cache = self.prefill_fn(self.params, tokens, self.cache0,
                                            frontend)
        else:
            logits, cache = self.prefill_fn(self.params, tokens, self.cache0)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        for i in range(sm.gen_len):
            logits, cache = self.decode_fn(self.params, cache, tok,
                                           jnp.int32(sm.prompt_len + i))
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(tok)
        return time.perf_counter() - t0


class JaxModelExecutor:
    """Maps function names -> model instances; measures real setup/exec.

    Used two ways:
      * ``calibrate()`` produces FunctionSpecs whose exec_time / setup_time
        are *measured*, so the scheduler operates on real numbers.
      * as the SGS ``execute`` hook, it runs the actual model per invocation.
    """

    def __init__(self, served: Dict[str, ServedModel]):
        self.served = served
        self._instances: Dict[str, ModelInstance] = {}
        self.n_executions = 0

    def ensure_instance(self, fn_name: str) -> ModelInstance:
        inst = self._instances.get(fn_name)
        if inst is None:
            inst = ModelInstance(self.served[fn_name])
            inst.setup()
            self._instances[fn_name] = inst
        return inst

    def calibrate(self, mem_mb: float = 512.0,
                  runs: int = 3) -> Dict[str, FunctionSpec]:
        """Measure setup + exec time per function; build real FunctionSpecs."""
        specs = {}
        for name in self.served:
            inst = self.ensure_instance(name)
            times = [inst.run(seed=i) for i in range(runs)]
            specs[name] = FunctionSpec(
                name=name, exec_time=sorted(times)[len(times) // 2],
                mem_mb=mem_mb, setup_time=inst.setup_seconds)
        return specs

    def execute(self, inv: Invocation) -> float:
        """SGS execute hook: run the real model for this invocation."""
        inst = self.ensure_instance(inv.fn.name)
        self.n_executions += 1
        return inst.run(seed=inv.inv_id)


class BatchingJaxExecutor:
    """Batched data plane: pads concurrently in-flight invocations of the
    same ``ServedModel`` into one real batched execution.

    A *bucket* is a power-of-two batch size; each bucket gets its own
    compiled (prefill, decode) executable pair — all compiled up front in
    ``calibrate`` so sweeps pay XLA compiles exactly once.  At run time the
    coalescer (``repro.core.backends.BatchCoalescer``, which owns the
    time/size flush window) calls ``run_batch`` with the gathered
    invocations; the batch executes once at the smallest bucket that fits
    and every member shares the measured wall time.  Each invocation
    occupies one batch slot (one sequence): the bucket size *replaces* the
    ``ServedModel.batch`` dimension.

    Amortizing weight reads over the whole batch is why this sustains a
    multiple of the per-invocation executor's throughput once batches form
    — see ``benchmarks/bench_serving.py``'s batched-vs-unbatched
    comparison.
    """

    def __init__(self, served: Dict[str, ServedModel], max_batch: int = 8):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.served = served
        self.max_batch = max_batch
        self._instances: Dict[Tuple[str, int], ModelInstance] = {}
        # calibration medians per (fn_name, bucket) — measured batched
        # execution seconds, recorded for reporting/analysis
        self.bucket_exec_s: Dict[Tuple[str, int], float] = {}
        self.n_executions = 0           # real batched runs

    def buckets(self) -> List[int]:
        """The power-of-two batch sizes compiled per model: 1, 2, 4, ...,
        up to the smallest power of two covering ``max_batch``."""
        out, b = [], 1
        top = pow2_bucket(self.max_batch)
        while b <= top:
            out.append(b)
            b *= 2
        return out

    def ensure_instance(self, fn_name: str, bucket: int) -> ModelInstance:
        key = (fn_name, bucket)
        inst = self._instances.get(key)
        if inst is None:
            inst = ModelInstance(replace(self.served[fn_name], batch=bucket))
            inst.setup()
            self._instances[key] = inst
        return inst

    def calibrate(self, mem_mb: float = 512.0,
                  runs: int = 3) -> Dict[str, FunctionSpec]:
        """Compile EVERY bucket executable per function (the whole compile
        bill lands here, off the serving path) and measure each bucket's
        batched execution time.  The returned ``FunctionSpec``s carry the
        batch-1 numbers — what a single invocation costs unbatched — so
        scheduling stays comparable with the per-invocation ``jax``
        backend; per-bucket medians live in ``bucket_exec_s``."""
        specs = {}
        for name in self.served:
            for b in self.buckets():
                inst = self.ensure_instance(name, b)
                times = [inst.run(seed=i) for i in range(runs)]
                self.bucket_exec_s[(name, b)] = sorted(times)[len(times) // 2]
            specs[name] = FunctionSpec(
                name=name, exec_time=self.bucket_exec_s[(name, 1)],
                mem_mb=mem_mb,
                setup_time=self._instances[(name, 1)].setup_seconds)
        return specs

    def run_batch(self, fn_name: str, invs: List[Invocation]) -> float:
        """Execute ``invs`` as ONE padded batch; returns measured wall
        seconds (the shared runtime of every member)."""
        bucket = pow2_bucket(len(invs))
        inst = self.ensure_instance(fn_name, bucket)
        self.n_executions += 1
        return inst.run(seed=invs[0].inv_id)
