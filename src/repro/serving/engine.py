"""Hardware-in-the-loop serving stack.

The scheduler layers (LBS + SGSs) are the exact objects from ``repro.core``;
time is advanced by the discrete-event engine, but *every execution and every
sandbox setup is a real jitted JAX call whose wall time is measured and fed
back* — queuing, placement, proactive allocation, scaling all operate on
real numbers.  (A fully wall-clock-threaded server adds nothing for a
single-host CPU container; the event engine gives deterministic, auditable
schedules while the data plane stays real.)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from ..core.cluster import ClusterConfig, build_cluster
from ..core.lbs import LBSConfig
from ..core.sgs import SGSConfig
from ..core.types import DagSpec, FunctionSpec, Request
from ..sim.engine import SimEnv
from ..sim.metrics import Metrics
from .executor import JaxModelExecutor, ServedModel


@dataclass
class ServingApp:
    """A tenant: one DAG over served models, with a latency deadline."""

    dag_id: str
    models: Dict[str, ServedModel]          # fn name -> model
    edges: Tuple[Tuple[str, str], ...] = ()
    slack: float = 0.5                      # deadline = critical path + slack


class ServingStack:
    def __init__(self, apps: List[ServingApp],
                 cluster: Optional[ClusterConfig] = None,
                 sgs_cfg: Optional[SGSConfig] = None,
                 lbs_cfg: Optional[LBSConfig] = None):
        served = {}
        for app in apps:
            served.update(app.models)
        self.executor = JaxModelExecutor(served)
        # calibrate: real measured exec/setup times become the FunctionSpecs
        self.fn_specs = self.executor.calibrate()
        self.dags: Dict[str, DagSpec] = {}
        for app in apps:
            fns = tuple(self.fn_specs[n] for n in app.models)
            dag = DagSpec(dag_id=app.dag_id, functions=fns, edges=app.edges,
                          deadline=0.0 or 1.0)
            # set deadline from measured critical path + slack
            cp = dag.critical_path_time()
            self.dags[app.dag_id] = DagSpec(
                dag_id=app.dag_id, functions=fns, edges=app.edges,
                deadline=cp + app.slack)

        self.env = SimEnv()
        self.lbs = build_cluster(self.env, cluster, sgs_cfg, lbs_cfg,
                                 execute=self.executor.execute)
        self.metrics = Metrics()

    def prewarm(self, dag_id: str, n_per_fn: int = 2) -> float:
        """Proactively allocate sandboxes on the DAG's initial SGS before
        traffic arrives (the 'initial DAG upload' step, §3).  Returns the
        time at which they are warm — start traffic after it."""
        dag = self.dags[dag_id]
        sgs = self.lbs.select(Request(dag=dag, arrival_time=0.0), 0.0)
        sgs.preallocate(dag, n_per_fn)
        return max(f.setup_time for f in dag.functions) + 0.1

    def submit_at(self, t: float, dag_id: str) -> None:
        dag = self.dags[dag_id]

        def fire():
            req = Request(dag=dag, arrival_time=self.env.now())
            self.metrics.requests.append(req)
            self.lbs.route(req, self.env.now())

        self.env.call_at(t, fire)

    def run(self, until: float) -> Metrics:
        self.env.every(0.1, lambda: self.lbs.check_scaling(self.env.now()),
                       until=until)
        self.env.run_until(until)
        for s in self.lbs.sgss.values():
            self.metrics.queuing_delays.extend(s.queuing_delays)
            self.metrics.queuing_delay_times.extend(s.queuing_delay_times)
        return self.metrics
