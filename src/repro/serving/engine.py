"""Serving apps as workloads: hardware-in-the-loop through the experiment API.

A :class:`ServingApp` is a tenant — one DAG over :class:`ServedModel`s with a
latency slack.  ``serving_workload`` (registered as the ``"serving_apps"``
workload factory) turns a list of apps into an ordinary
:class:`~repro.sim.workload.WorkloadSpec`, so serving runs route through the
same ``simulate``/``run_sweep`` pipeline, stacks, warmup/drain semantics and
``ExperimentResult`` reporting as every simulation::

    from repro.sim import Experiment, simulate

    r = simulate(Experiment(
        stack="archipelago", backend="jax",
        workload_factory="serving_apps",
        workload_kwargs=dict(apps=[app], duration=20.0, rps=10.0),
        warmup=5.0))

With ``backend="jax"`` the scheduler layers (LBS + SGSs) are the exact
objects from ``repro.core``; time is advanced by the discrete-event engine,
but *every execution and every sandbox setup is a real jitted JAX call whose
wall time is measured and fed back* — queuing, placement, proactive
allocation and scaling all operate on real numbers.  (A fully wall-clock-
threaded server adds nothing for a single-host CPU container; the event
engine gives deterministic, auditable schedules while the data plane stays
real.)  The same workload runs under ``backend="jax-batched"`` (the
batching data plane: concurrently in-flight invocations of one model
coalesce into padded batched executions — ``batch_window``/``max_batch``
are sweepable ``backend_kwargs``), ``"stub"``/``"stub-batched"`` (scripted
times, CI) or ``"modeled"`` (placeholder times) unchanged.

The spec's ``pre_pump`` hook reproduces the paper's "initial DAG upload"
(§3): before traffic, each app's initial SGS proactively allocates
``prewarm`` sandboxes per function.  Set ``Experiment.warmup`` past the
largest measured setup time to report steady-state numbers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional, Sequence, Tuple, Union

from ..core.types import DagSpec, FunctionSpec, Request
from ..sim.experiment import register_workload
from ..sim.workload import ArrivalProcess, ConstantRate, WorkloadSpec
from .executor import ServedModel

# Placeholder costs until a backend resolves real numbers: the jax backend
# replaces them with calibrated measurements, the stub backend with scripted
# values; the modeled backend runs them as-is (cheap structural smoke).
PLACEHOLDER_EXEC = 0.010
PLACEHOLDER_SETUP = 1.0
PLACEHOLDER_MEM_MB = 512.0


@dataclass
class ServingApp:
    """A tenant: one DAG over served models, with a latency deadline.

    ``slack`` is granted on top of the DAG's critical-path execution time —
    with the jax backend that path is *measured*, so the deadline tracks
    real hardware speed.
    """

    dag_id: str
    models: Dict[str, ServedModel]          # fn name -> model
    edges: Tuple[Tuple[str, str], ...] = ()
    slack: float = 0.5                      # deadline = critical path + slack

    def dag(self, fn_specs: Optional[Mapping[str, FunctionSpec]] = None
            ) -> DagSpec:
        """The app as a ``DagSpec``: function specs from ``fn_specs`` where
        given (calibrated/scripted), placeholders otherwise; the deadline is
        derived from the DAG's own critical path via ``with_deadline`` —
        computed once, from whatever specs the DAG actually carries."""
        fn_specs = fn_specs or {}
        fns = tuple(
            fn_specs.get(name) or FunctionSpec(
                name=name, exec_time=PLACEHOLDER_EXEC,
                mem_mb=PLACEHOLDER_MEM_MB, setup_time=PLACEHOLDER_SETUP)
            for name in self.models)
        return DagSpec(dag_id=self.dag_id, functions=fns,
                       edges=self.edges).with_deadline(slack=self.slack)


@dataclass
class ServingWorkloadSpec(WorkloadSpec):
    """A ``WorkloadSpec`` over served models.

    Extra fields ride along through backend re-speccing
    (``dataclasses.replace`` keeps them): ``served`` lets the jax backend
    find the models to calibrate, ``slacks`` re-derives each deadline as
    measured-critical-path + slack, and ``prewarm`` drives the ``pre_pump``
    proactive-allocation hook.
    """

    served: Dict[str, ServedModel] = field(default_factory=dict)
    slacks: Dict[str, float] = field(default_factory=dict)
    prewarm: Dict[str, int] = field(default_factory=dict)   # dag_id -> n/fn

    def pre_pump(self, env, stack) -> None:
        """Prewarm hook, run by ``simulate`` after the stack is built and
        before the first arrival: each app's initial SGS proactively
        allocates ``prewarm[dag_id]`` sandboxes per function (§3 "initial
        DAG upload" / §5.2.3 warm-up).  Stacks without proactive allocation
        (the reactive baselines) simply ignore it — exactly the paper's
        cold-start handicap."""
        lbs = getattr(stack, "lbs", None)
        scheduler = getattr(stack, "scheduler", None)
        for dag, _ in self.tenants:
            n = self.prewarm.get(dag.dag_id, 0)
            if n <= 0:
                continue
            if lbs is not None:
                sgs = lbs.select(Request(dag=dag, arrival_time=0.0), 0.0)
                sgs.preallocate(dag, n)
            elif hasattr(scheduler, "preallocate"):
                scheduler.preallocate(dag, n)


@register_workload("serving_apps")
def serving_workload(apps: Sequence[ServingApp],
                     duration: float = 30.0,
                     rps: Union[float, Mapping[str, float]] = 10.0,
                     arrivals: Optional[Mapping[str, ArrivalProcess]] = None,
                     prewarm_per_fn: int = 2) -> ServingWorkloadSpec:
    """Serving apps as a workload: one tenant per app.

    ``rps`` is a constant Poisson rate (scalar, or a per-``dag_id`` mapping
    that must name every app); ``arrivals`` overrides the arrival process
    per app (any ``ArrivalProcess`` — sinusoidal diurnal load, on/off
    bursts, ...).  ``prewarm_per_fn`` proactive sandboxes per function are
    allocated before traffic via ``pre_pump``.
    """
    arrivals = arrivals or {}
    app_ids = [a.dag_id for a in apps]
    if len(set(app_ids)) != len(app_ids):
        raise ValueError(f"duplicate dag_id(s) across apps: "
                         f"{sorted({i for i in app_ids if app_ids.count(i) > 1})}")
    for label, mapping in (("rps", rps if isinstance(rps, Mapping) else {}),
                           ("arrivals", arrivals)):
        unknown = set(mapping) - set(app_ids)
        if unknown:
            raise ValueError(f"{label} names unknown dag_id(s) "
                             f"{sorted(unknown)}; apps: {sorted(app_ids)}")
    if isinstance(rps, Mapping):
        ambiguous = set(rps) & set(arrivals)
        if ambiguous:
            raise ValueError(f"dag_id(s) {sorted(ambiguous)} appear in both "
                             f"rps and arrivals; specify one")
        missing = [i for i in app_ids if i not in rps and i not in arrivals]
        if missing:
            raise ValueError(f"rps mapping must cover every app; missing: "
                             f"{sorted(missing)}")
    tenants = []
    served: Dict[str, ServedModel] = {}
    slacks: Dict[str, float] = {}
    prewarm: Dict[str, int] = {}
    for app in apps:
        overlap = set(app.models) & set(served)
        if overlap:
            raise ValueError(
                f"function name(s) {sorted(overlap)} served by more than "
                f"one app; names must be unique across apps")
        served.update(app.models)
        slacks[app.dag_id] = app.slack
        prewarm[app.dag_id] = prewarm_per_fn
        proc = arrivals.get(app.dag_id)
        if proc is None:
            r = rps[app.dag_id] if isinstance(rps, Mapping) else float(rps)
            proc = ConstantRate(r)
        tenants.append((app.dag(), proc))
    return ServingWorkloadSpec(tenants=tenants, duration=duration,
                               served=served, slacks=slacks, prewarm=prewarm)
