"""Serving substrate: real JAX execution beneath the Archipelago scheduler."""
from .executor import JaxModelExecutor, ModelInstance, ServedModel
from .engine import ServingApp, ServingStack

__all__ = ["JaxModelExecutor", "ModelInstance", "ServedModel", "ServingApp",
           "ServingStack"]
