"""Serving substrate: real JAX execution beneath the Archipelago scheduler.

Serving runs route through the experiment API: register apps as a workload
(``serving_workload`` / ``workload_factory="serving_apps"``) and
``simulate`` with ``backend="jax"`` — see ``docs/SERVING.md``.
"""
from .executor import (BatchingJaxExecutor, JaxModelExecutor, ModelInstance,
                       ServedModel)
from .engine import ServingApp, ServingWorkloadSpec, serving_workload
from .apps import multitenant_apps, smoke_apps

__all__ = ["BatchingJaxExecutor", "JaxModelExecutor", "ModelInstance",
           "ServedModel", "ServingApp", "ServingWorkloadSpec",
           "serving_workload", "multitenant_apps", "smoke_apps"]
