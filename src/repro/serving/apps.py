"""Reference serving app sets shared by the examples and benchmarks.

One multitenant set spanning architecture families (dense, SSM, MoE, and a
two-stage VLM pipeline exercising DAG-aware scheduling) plus a single-model
smoke set — so ``examples/multitenant_serving.py`` and
``benchmarks/bench_serving.py`` sweep exactly the same tenants.
"""
from __future__ import annotations

from typing import List

from ..configs import get_config
from .engine import ServingApp
from .executor import ServedModel


def _mk(arch: str, **kw) -> ServedModel:
    return ServedModel(get_config(arch, reduced=True), **kw)


def smoke_apps() -> List[ServingApp]:
    """One small, fast-compiling model (CI smoke)."""
    return [ServingApp("chat", {"ssm/gen": _mk("mamba2-370m", prompt_len=16,
                                               gen_len=2)}, slack=0.8)]


def multitenant_apps() -> List[ServingApp]:
    """Four apps across architecture families sharing one cluster."""
    return [
        ServingApp("chat", {"chat/gen": _mk("minicpm-2b", prompt_len=32,
                                            gen_len=3)}, slack=0.8),
        ServingApp("complete", {"ssm/gen": _mk("mamba2-370m", prompt_len=32,
                                               gen_len=2)}, slack=1.2),
        ServingApp("moe", {"moe/gen": _mk("mixtral-8x22b", prompt_len=16,
                                          gen_len=2)}, slack=1.2),
        # two-stage pipeline: vision encode (stub embeds) -> caption decode
        ServingApp("caption",
                   {"vlm/embed": _mk("phi-3-vision-4.2b", prompt_len=16,
                                     gen_len=1),
                    "vlm/decode": _mk("phi3-mini-3.8b", prompt_len=16,
                                      gen_len=2)},
                   edges=(("vlm/embed", "vlm/decode"),), slack=1.5),
    ]
