"""Training substrate: optimizer, schedules, data pipeline, checkpointing,
and the canonical train_step used by the launcher and the dry-run."""
from .optim import (AdamWState, adamw_init, adamw_update, cosine_schedule,
                    make_schedule, wsd_schedule)
from .data import DataConfig, Prefetcher, SyntheticLM
from . import checkpoint
from .steps import make_train_step

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_schedule",
           "make_schedule", "wsd_schedule", "DataConfig", "Prefetcher",
           "SyntheticLM", "checkpoint", "make_train_step"]
