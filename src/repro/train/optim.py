"""Optimizers and LR schedules (no external deps).

Includes the WSD (warmup-stable-decay) schedule MiniCPM introduced
[arXiv:2404.06395] alongside the standard cosine schedule.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

f32 = jnp.float32


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=f32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def adamw_update(grads: Any, state: AdamWState, params: Any, *,
                 lr: jnp.ndarray, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 grad_clip: float = 1.0) -> Tuple[Any, AdamWState]:
    """Returns (new_params, new_state).  Global-norm clipping + decoupled WD."""
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(f32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    t = step.astype(f32)

    def upd(g, m, v, p):
        g = g.astype(f32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** t)
        vhat = v / (1 - b2 ** t)
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(f32)
        return (p.astype(f32) - lr * delta).astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------


def wsd_schedule(peak_lr: float, warmup: int, stable: int, decay: int,
                 floor_frac: float = 0.1) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Warmup-Stable-Decay (MiniCPM): linear warmup, flat plateau, then
    exponential-style decay to floor_frac * peak."""

    def sched(step):
        s = step.astype(f32)
        wu = peak_lr * jnp.minimum(s / max(1, warmup), 1.0)
        in_decay = jnp.clip((s - warmup - stable) / max(1, decay), 0.0, 1.0)
        decay_mult = (1.0 - in_decay) + floor_frac * in_decay
        return jnp.where(s <= warmup + stable, wu, peak_lr * decay_mult)

    return sched


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def sched(step):
        s = step.astype(f32)
        wu = peak_lr * jnp.minimum(s / max(1, warmup), 1.0)
        prog = jnp.clip((s - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(math.pi * prog))
        return jnp.where(s <= warmup, wu, peak_lr * cos)

    return sched


def make_schedule(kind: str, peak_lr: float, total_steps: int
                  ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    warmup = max(1, total_steps // 20)
    if kind == "wsd":
        decay = max(1, total_steps // 10)
        return wsd_schedule(peak_lr, warmup, total_steps - warmup - decay,
                            decay)
    return cosine_schedule(peak_lr, warmup, total_steps)
