"""Synthetic-but-structured data pipeline.

Offline container: no external corpora.  The pipeline still exercises the
real mechanics — deterministic sharded batching, prefetch, pack-to-length —
over a synthetic Zipfian token stream with Markov bigram structure (so a
~100M model's loss visibly drops below the unigram entropy during the
example training run).
"""
from __future__ import annotations

import threading
import queue as _queue
from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    zipf_a: float = 1.2          # unigram skew
    markov_strength: float = 0.8  # P(next in successor set | cur)
    n_successors: int = 8


class SyntheticLM:
    """Deterministic, seekable synthetic token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self.unigram = p / p.sum()
        # each token gets a small successor set (bigram structure)
        self.successors = rng.integers(0, v, size=(v, cfg.n_successors))

    def batch(self, step: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 32) ^ step)
        B, S = cfg.batch_size, cfg.seq_len
        out = np.empty((B, S), np.int32)
        cur = rng.choice(cfg.vocab_size, size=B, p=self.unigram)
        out[:, 0] = cur
        for t in range(1, S):
            use_markov = rng.random(B) < cfg.markov_strength
            succ_pick = self.successors[cur, rng.integers(
                0, cfg.n_successors, size=B)]
            indep = rng.choice(cfg.vocab_size, size=B, p=self.unigram)
            cur = np.where(use_markov, succ_pick, indep).astype(np.int32)
            out[:, t] = cur
        return out

    def iterate(self, start_step: int = 0) -> Iterator[np.ndarray]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (double buffering) over a batch iterator."""

    def __init__(self, it: Iterator[np.ndarray], depth: int = 2):
        self._q: _queue.Queue = _queue.Queue(maxsize=depth)
        self._it = it
        self._stop = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        for item in self._it:
            if self._stop:
                return
            self._q.put(item)

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop = True
