"""Canonical jitted steps: train_step (loss + AdamW) and serve steps.

These are the functions the dry-run lowers for every (arch x shape x mesh).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import lm_loss
from ..models.config import ModelConfig
from .optim import adamw_init, adamw_update, make_schedule


def make_train_step(cfg: ModelConfig, total_steps: int = 10_000,
                    peak_lr: float = 3e-4,
                    ) -> Callable[..., Tuple[Any, Any, jnp.ndarray]]:
    """Returns train_step(params, opt_state, tokens[, frontend]) ->
    (params, opt_state, loss)."""
    sched = make_schedule(cfg.lr_schedule, peak_lr, total_steps)

    def train_step(params, opt_state, tokens, frontend=None):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, tokens, frontend))(params)
        lr = sched(opt_state.step + 1)
        params, opt_state = adamw_update(grads, opt_state, params, lr=lr)
        return params, opt_state, loss

    return train_step
