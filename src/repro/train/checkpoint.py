"""Checkpointing: pure-numpy .npz of a flattened pytree + JSON manifest.

No orbax/flax dependency; supports save/restore of params + optimizer state
with dtype/shape validation on restore.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # npz has no native bf16: stage through float32 (exact superset)
            arr = np.asarray(leaf, dtype=np.float32)
        flat[key] = arr
    return flat


def save(path: str, step: int, params: Any, opt_state: Any = None) -> None:
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, f"step_{step:08d}.npz"),
             **{f"p/{k}": v for k, v in _flatten(params).items()},
             **({f"o/{k}": v for k, v in _flatten(opt_state).items()}
                if opt_state is not None else {}))
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump({"latest_step": step}, f)


def latest_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["latest_step"]


def restore(path: str, step: int, params_like: Any,
            opt_like: Any = None) -> Tuple[Any, Any]:
    data = np.load(os.path.join(path, f"step_{step:08d}.npz"))

    def rebuild(tree, prefix):
        leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
        out = []
        for path, leaf in leaves:
            key = prefix + "/".join(str(getattr(p, "key",
                                                getattr(p, "idx", p)))
                                    for p in path)
            arr = data[key]
            if arr.shape != leaf.shape:
                raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
            out.append(jnp.asarray(arr).astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(tree), out)

    p = rebuild(params_like, "p/")
    o = rebuild(opt_like, "o/") if opt_like is not None else None
    return p, o
