"""Sandbox demand estimation (§4.3.1).

Each SGS continuously records the per-function arrival rate over a fixed
interval (100 ms in the prototype) and maintains an EWMA estimate.  Given the
SLA percentile (e.g. 99%), the number of sandboxes to keep proactively
allocated is the Poisson inverse-CDF at that percentile over the interval,
scaled up when a function's execution time overflows the interval (requests
from interval *k* still occupy sandboxes during interval *k+1*...).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict


def poisson_ppf(p: float, lam: float, max_n: int = 100_000) -> int:
    """Smallest n with  P[X <= n] >= p  for X ~ Poisson(lam).

    Pure-python CDF walk (no scipy dependency); numerically stable via
    multiplicative pmf recurrence pmf(k) = pmf(k-1) * lam / k.
    """
    if not 0.0 <= p < 1.0:
        raise ValueError(f"p must be in [0,1), got {p}")
    if lam < 0:
        raise ValueError(f"lam must be >= 0, got {lam}")
    if lam == 0.0:
        return 0
    if lam > 50:
        # normal approximation with continuity correction for the far tail,
        # refined by an exact walk from the approximate point.
        from statistics import NormalDist

        z = NormalDist().inv_cdf(p)
        n = int(lam + z * math.sqrt(lam) + 0.5)
        n = max(n, 0)
        # refine: walk until CDF crosses p (cheap: few steps)
        return _refine_ppf(p, lam, n, max_n)
    # exact walk from 0
    pmf = math.exp(-lam)
    cdf = pmf
    n = 0
    while cdf < p and n < max_n:
        n += 1
        pmf *= lam / n
        cdf += pmf
    return n


def _poisson_cdf(lam: float, n: int) -> float:
    pmf = math.exp(-lam)
    cdf = pmf
    for k in range(1, n + 1):
        pmf *= lam / k
        cdf += pmf
    return cdf


def _refine_ppf(p: float, lam: float, n0: int, max_n: int) -> int:
    n = max(n0, 0)
    cdf = _poisson_cdf(lam, n)
    if cdf >= p:
        while n > 0 and _poisson_cdf(lam, n - 1) >= p:
            n -= 1
        return n
    while cdf < p and n < max_n:
        n += 1
        cdf = _poisson_cdf(lam, n)
    return n


@dataclass(slots=True)
class RateEstimator:
    """EWMA arrival-rate estimator over fixed measurement intervals."""

    interval: float = 0.100        # 100 ms (§4.3.1)
    alpha: float = 0.3             # EWMA weight on the newest measurement

    _count: int = 0
    _window_start: float = 0.0
    _rate: float = 0.0             # requests / second
    _initialized: bool = False

    def record_arrival(self, now: float) -> None:
        # fast path: no window boundary crossed since the last sample (the
        # overwhelmingly common case on the per-invocation hot path)
        if now - self._window_start >= self.interval:
            self._roll(now)
        self._count += 1

    def rate(self, now: float) -> float:
        """Current EWMA estimate in requests/second."""
        self._roll(now)
        return self._rate

    def _roll(self, now: float) -> None:
        # close out any fully elapsed windows
        while now - self._window_start >= self.interval:
            measured = self._count / self.interval
            if not self._initialized:
                # first window: adopt the measurement directly
                if self._count > 0:
                    self._rate = measured
                    self._initialized = True
            else:
                self._rate = self.alpha * measured + (1 - self.alpha) * self._rate
            self._count = 0
            self._window_start += self.interval


@dataclass
class DemandEstimator:
    """Per-function sandbox demand (Fig. 5): EWMA rate -> Poisson ppf @ SLA."""

    sla: float = 0.99
    interval: float = 0.100
    alpha: float = 0.3
    _rates: Dict[str, RateEstimator] = field(default_factory=dict)

    def _est(self, fn_name: str) -> RateEstimator:
        est = self._rates.get(fn_name)
        if est is None:
            est = self._rates[fn_name] = RateEstimator(self.interval,
                                                       self.alpha)
        return est

    def record_arrival(self, fn_name: str, now: float) -> None:
        # hand-inlined _est + RateEstimator.record_arrival: this runs once
        # per function invocation
        est = self._rates.get(fn_name)
        if est is None:
            est = self._rates[fn_name] = RateEstimator(self.interval,
                                                       self.alpha)
        if now - est._window_start >= est.interval:
            est._roll(now)
        est._count += 1

    def rate(self, fn_name: str, now: float) -> float:
        return self._est(fn_name).rate(now)

    def demand(self, fn_name: str, exec_time: float, now: float) -> int:
        """Minimum number of sandboxes so that, with probability >= sla, every
        request arriving in the next interval finds a sandbox.

        The paper takes the Poisson inverse CDF of the per-interval arrival
        count at the SLA, then scales up for requests that overflow the
        interval (exec_time > T).  The two steps combine into one via
        Little's law: the number of in-flight requests (busy sandboxes) at
        any instant is Poisson with mean  rate * max(T, exec_time), so the
        inverse CDF of *that* distribution is the demand.  (The naive
        ppf(rate*T) * ceil(exec/T) over-counts by up to ~2x at high rates
        because tail mass doesn't scale linearly across windows.)
        """
        occupancy_window = max(self.interval, exec_time)
        lam = self.rate(fn_name, now) * occupancy_window
        return poisson_ppf(self.sla, lam)
