"""Elastic control plane: LBS replica autoscaling from observed load.

The paper argues the LBS is "a scalable service" (§5) but leaves *how many*
replicas to the operator; our xl tier exposed the consequence — at ~26k rps
the default 4 replicas (190us per routing decision ≈ 21k rps of capacity)
saturate, and the benchmark hand-tuned ``n_lbs=16``.  This module replaces
the hand tuning with a feedback controller over the M/D/1 decision clocks:

* **Signal.** Per ``interval``, utilization is measured as
  ``decisions x lb_cost / (replicas x interval)`` (offered decision work
  over pool capacity) plus the worst clock backlog (``busy_until - now`` —
  queueing that has already formed).
* **Scale-out.** When utilization exceeds ``target_utilization`` — or any
  backlog exceeds ``backlog_threshold`` — the pool grows multiplicatively
  to the size that would put the *observed* load at the target
  (``ceil(n x util / target)``), reacting within one interval; flash
  crowds are a doubling or two, not a +1 crawl.
* **Scale-in.** Hysteresis: utilization must sit below
  ``scale_in_utilization`` with zero backlog for ``scale_in_patience``
  consecutive intervals, and actions respect a ``cooldown`` — replicas
  retire one per decision (the most idle clock), so diurnal troughs shed
  capacity without oscillating.

Every decision is recorded as a typed :class:`ScalingEvent`; together with
the per-DAG SGS scaling log (``LoadBalancer.scaling_log``) these flow into
``ExperimentResult.scaling_events`` (lossless JSON round-trip), and
``Metrics.window`` views give during-event latency (docs/SCENARIOS.md).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional

__all__ = ["AutoscaleConfig", "ScalingEvent", "LBSReplicaAutoscaler",
           "scaling_summary"]


@dataclass(frozen=True)
class AutoscaleConfig:
    """Knobs for the LBS replica autoscaler — carried on
    ``Experiment.autoscale`` (frozen: hashable, picklable, sweepable via
    ``run_sweep`` dotted paths like ``"autoscale.target_utilization"``)."""

    min_replicas: int = 2
    max_replicas: int = 256
    interval: float = 0.1           # observation/decision cadence (s)
    target_utilization: float = 0.6
    scale_in_utilization: float = 0.25
    backlog_threshold: float = 0.01  # seconds of formed queue forcing growth
    cooldown: float = 0.5           # min seconds between scale-ins
    scale_in_patience: int = 5      # consecutive quiet intervals to shrink

    def to_dict(self) -> Dict[str, Any]:
        return {"min_replicas": self.min_replicas,
                "max_replicas": self.max_replicas,
                "interval": self.interval,
                "target_utilization": self.target_utilization,
                "scale_in_utilization": self.scale_in_utilization,
                "backlog_threshold": self.backlog_threshold,
                "cooldown": self.cooldown,
                "scale_in_patience": self.scale_in_patience}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "AutoscaleConfig":
        return cls(**dict(d))


@dataclass
class ScalingEvent:
    """One control-plane scaling decision (LBS replica pool or per-DAG SGS
    set), JSON round-trippable through ``to_dict``/``from_dict``."""

    t: float
    component: str                  # "lbs" | "sgs"
    action: str                     # "scale_out" | "scale_in"
    n_before: int
    n_after: int
    metric: float                   # utilization (lbs) / slack-normalized
    #                                 queuing delay (sgs) that triggered it
    detail: Dict[str, Any] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {"t": self.t, "component": self.component,
                "action": self.action, "n_before": self.n_before,
                "n_after": self.n_after, "metric": self.metric,
                "detail": dict(self.detail)}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ScalingEvent":
        return cls(t=d["t"], component=d["component"], action=d["action"],
                   n_before=d["n_before"], n_after=d["n_after"],
                   metric=d["metric"], detail=dict(d.get("detail", {})))


class LBSReplicaAutoscaler:
    """Grows/shrinks a live list of LBS decision clocks (see module
    docstring for the control law).

    The stack's submit closure round-robins over the *same list object* and
    bumps :attr:`n_routed` per routed request, so the controller observes
    exactly the work the clocks absorbed; ``tick`` mutates the list in
    place.  ``make_clock`` injects the clock type (``_ServiceClock`` — a
    factory argument keeps ``core.autoscale`` import-free of
    ``core.stacks``)."""

    def __init__(self, clocks: List[Any], lb_cost: float,
                 cfg: Optional[AutoscaleConfig] = None, *,
                 make_clock: Callable[[], Any]):
        self.clocks = clocks
        self.lb_cost = lb_cost
        self.cfg = cfg or AutoscaleConfig()
        self.make_clock = make_clock
        self.n_routed = 0               # bumped by the submit hot path
        self.events: List[ScalingEvent] = []
        self._last_action = -math.inf
        self._quiet = 0

    @property
    def n_replicas(self) -> int:
        return len(self.clocks)

    def tick(self, now: float) -> None:
        """One control decision: read the window's routed count, measure
        utilization + backlog, and resize the pool."""
        cfg = self.cfg
        n, self.n_routed = self.n_routed, 0
        clocks = self.clocks
        k = len(clocks)
        util = (n * self.lb_cost) / (k * cfg.interval)
        backlog = max(0.0, max(c.busy_until for c in clocks) - now)
        if ((util > cfg.target_utilization
             or backlog > cfg.backlog_threshold)
                and k < cfg.max_replicas):
            want = max(k + 1, math.ceil(k * util / cfg.target_utilization))
            want = min(cfg.max_replicas, want)
            for _ in range(want - k):
                c = self.make_clock()
                c.busy_until = now      # fresh replica: idle from now
                clocks.append(c)
            self.events.append(ScalingEvent(
                t=round(now, 6), component="lbs", action="scale_out",
                n_before=k, n_after=want, metric=round(util, 6),
                detail={"backlog_s": round(backlog, 6)}))
            self._last_action = now
            self._quiet = 0
        elif (util < cfg.scale_in_utilization and backlog <= 1e-9
                and k > cfg.min_replicas):
            self._quiet += 1
            if (self._quiet >= cfg.scale_in_patience
                    and now - self._last_action >= cfg.cooldown):
                # retire the most idle replica (smallest busy_until)
                idx = min(range(k), key=lambda i: clocks[i].busy_until)
                clocks.pop(idx)
                self.events.append(ScalingEvent(
                    t=round(now, 6), component="lbs", action="scale_in",
                    n_before=k, n_after=k - 1, metric=round(util, 6),
                    detail={}))
                self._last_action = now
                self._quiet = 0
        else:
            self._quiet = 0


def scaling_summary(events: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Compact per-run digest of an ``ExperimentResult.scaling_events``
    list, for benchmark rows: action counts per component plus the LBS
    replica trajectory's peak/final sizes."""
    out: Dict[str, Any] = {"n_events": len(events)}
    lbs = [e for e in events if e["component"] == "lbs"]
    sgs = [e for e in events if e["component"] == "sgs"]
    out["lbs_scale_outs"] = sum(e["action"] == "scale_out" for e in lbs)
    out["lbs_scale_ins"] = sum(e["action"] == "scale_in" for e in lbs)
    out["sgs_scale_outs"] = sum(e["action"] == "scale_out" for e in sgs)
    out["sgs_scale_ins"] = sum(e["action"] == "scale_in" for e in sgs)
    if lbs:
        out["lbs_peak_replicas"] = max(e["n_after"] for e in lbs)
        out["lbs_final_replicas"] = lbs[-1]["n_after"]
    return out
