"""Pluggable scheduler-stack registry for the experiment API.

The paper's evaluation (§7) is a matrix of *scheduler stacks* × workloads ×
cluster shapes.  A stack bundles everything between "a request arrived" and
"a scheduler object accepted it": cluster construction, control-plane
service clocks (the §7.4 per-decision costs), routing, and background loops.
``repro.sim.experiment.simulate`` drives any registered stack through ONE
generic arrival-pump loop, so adding a scheduler is a one-class job:

    from repro.core.stacks import register_stack, FlatWorkerStack

    @register_stack("my-scheduler")
    class MyStack(FlatWorkerStack):
        def make_scheduler(self, workers, env, exp):
            return MyScheduler(workers, env, **exp.params)

Built-in stacks: ``archipelago`` (LBS → SGSs, §4-§5), ``fifo`` (centralized
FIFO + keep-alive, §2.4 baseline, alias ``baseline``), ``sparrow``
(power-of-two probing, Fig. 2d), and ``pull`` — a worker-initiated
pull-based scheduler in the spirit of Hiku [Akbari & Hauswirth 2025],
registered purely through this module as the extensibility proof.
"""
from __future__ import annotations

import itertools
import random
from dataclasses import dataclass
from typing import (TYPE_CHECKING, Callable, Dict, List, Optional, Protocol,
                    Type)

from .backends import ExecutionBackend
from .baselines import CentralizedFIFO, SparrowScheduler
from .cluster import build_cluster, build_flat_workers
from .lbs import LoadBalancer
from .sandbox import Worker
from .types import Request, Sandbox

if TYPE_CHECKING:       # pragma: no cover - typing only, avoids a core->sim cycle
    from ..sim.experiment import Experiment
    from ..sim.metrics import Metrics
    from ..sim.workload import WorkloadSpec

# §7.4 measured control-plane decision costs (Go prototype medians)
LB_DECISION_COST = 190e-6
SGS_DECISION_COST = 241e-6


@dataclass(slots=True)
class _ServiceClock:
    """Serializes work through one control-plane component (M/D/1 server).

    The paper's measured per-decision costs (§7.4): LBS routing ~190us,
    SGS scheduling ~241us.  A single centralized scheduler at several
    thousand RPS approaches rho=1 and its queue explodes — exactly the
    §2.4 scalability argument; Archipelago spreads this cost over many
    SGSs.
    """

    busy_until: float = 0.0

    def acquire(self, now: float, service: float) -> float:
        start = self.busy_until
        if now > start:
            start = now
        self.busy_until = start + service
        return self.busy_until


def make_archipelago_submit(lb_clocks: List[_ServiceClock],
                            sgs_clocks: Dict[int, _ServiceClock],
                            select, call_at, lb_cost: float, sgs_cost: float,
                            scaler=None, deliver=None):
    """Build the Archipelago per-arrival hot-path closure.

    The two-hop control-plane arithmetic (LBS routing clock → SGS decision
    clock, both hand-inlined M/D/1 acquires) is shared by four variants:

    * ``scaler is None`` — static LB replica pool, round-robin via
      ``itertools.cycle`` (the historical hot path, byte-identical to the
      equivalence goldens); otherwise the elastic pool re-reads the live
      clock-list length and counts routed requests for the autoscaler.
    * ``deliver is None`` — in-process submission: the routed request
      becomes an event (``call_at(t_sched, sgs.submit_request, req)``).
      A sharded coordinator (``repro.sim.shard``) instead passes
      ``deliver(t_sched, sgs_id, req)`` to route the submission into the
      owning shard's outbox; ``select`` then returns SGS *proxies* and
      ``call_at`` is unused.

    Each variant is its own flat closure so the dominant sequential path
    pays zero extra call frames or branches per arrival.
    """
    if deliver is None:
        if scaler is None:
            # static pool: round-robin over the LB replicas without a
            # counter/modulo.  This closure is the historical hot path —
            # byte-identical decisions to the equivalence goldens.
            next_lb_clock = itertools.cycle(lb_clocks).__next__

            def submit(req: Request, now: float) -> None:
                # hop 1: LBS routing decision (a scalable service: many
                # LBs).  Both clock acquires are hand-inlined M/D/1
                # waits (identical arithmetic to _ServiceClock.acquire).
                c = next_lb_clock()
                t = c.busy_until
                if now > t:
                    t = now
                c.busy_until = t_routed = t + lb_cost
                sgs = select(req, now)
                # hop 2: SGS scheduling decision, serialized per SGS
                c = sgs_clocks[sgs.sgs_id]
                t = c.busy_until
                if t_routed > t:
                    t = t_routed
                c.busy_until = t_sched = \
                    t + sgs_cost * req.dag._n_fns
                call_at(t_sched, sgs.submit_request, req)
        else:
            # elastic pool: the autoscaler grows/shrinks `clocks` in
            # place between arrivals, so round-robin with a cursor that
            # re-reads the live length, and count routed requests for
            # the utilization signal
            clocks = lb_clocks
            cursor = [0]

            def submit(req: Request, now: float) -> None:
                i = cursor[0]
                if i >= len(clocks):
                    i = 0
                cursor[0] = i + 1
                c = clocks[i]
                t = c.busy_until
                if now > t:
                    t = now
                c.busy_until = t_routed = t + lb_cost
                scaler.n_routed += 1
                sgs = select(req, now)
                c = sgs_clocks[sgs.sgs_id]
                t = c.busy_until
                if t_routed > t:
                    t = t_routed
                c.busy_until = t_sched = \
                    t + sgs_cost * req.dag._n_fns
                call_at(t_sched, sgs.submit_request, req)
    elif scaler is None:
        next_lb_clock = itertools.cycle(lb_clocks).__next__

        def submit(req: Request, now: float) -> None:
            c = next_lb_clock()
            t = c.busy_until
            if now > t:
                t = now
            c.busy_until = t_routed = t + lb_cost
            sgs = select(req, now)
            c = sgs_clocks[sgs.sgs_id]
            t = c.busy_until
            if t_routed > t:
                t = t_routed
            c.busy_until = t_sched = \
                t + sgs_cost * req.dag._n_fns
            deliver(t_sched, sgs.sgs_id, req)
    else:
        clocks = lb_clocks
        cursor = [0]

        def submit(req: Request, now: float) -> None:
            i = cursor[0]
            if i >= len(clocks):
                i = 0
            cursor[0] = i + 1
            c = clocks[i]
            t = c.busy_until
            if now > t:
                t = now
            c.busy_until = t_routed = t + lb_cost
            scaler.n_routed += 1
            sgs = select(req, now)
            c = sgs_clocks[sgs.sgs_id]
            t = c.busy_until
            if t_routed > t:
                t = t_routed
            c.busy_until = t_sched = \
                t + sgs_cost * req.dag._n_fns
            deliver(t_sched, sgs.sgs_id, req)

    return submit


class Stack(Protocol):
    """What ``simulate``'s generic pump loop needs from a scheduler stack.

    Lifecycle: ``build`` once (against the resolved execution backend —
    stacks thread the backend's asynchronous ``submit`` seam (falling back
    to the legacy ``execute`` hook) into their schedulers so *what runs an
    invocation* is orthogonal to *where it runs*, see ``core.backends``),
    ``submit`` per arrival (called inside the pump at the request's arrival
    instant), ``start_background`` once after the first arrival is scheduled
    (periodic scaling passes etc.), and ``collect`` after the run drains
    (fold per-scheduler samples into the run's Metrics).

    ``submit`` is the per-arrival hot path: the built-in stacks rebind
    ``self.submit`` in ``build`` to a closure over locals (clocks, costs,
    ``env.call_at``) so the pump pays no attribute lookups per arrival —
    exactly like the pre-registry drivers.  Subclasses that override
    ``submit`` as a plain method keep working (the rebinding is skipped).
    """

    name: str
    lbs: Optional[LoadBalancer]
    scheduler: object

    def build(self, env, exp: "Experiment", spec: "WorkloadSpec",
              backend: ExecutionBackend) -> None: ...
    def submit(self, req: Request, now: float) -> None: ...
    def start_background(self) -> None: ...
    def collect(self, metrics: "Metrics") -> None: ...
    def counters(self) -> Dict[str, int]: ...

    # Optional: wire the run's flat metrics plane into every scheduler's
    # ``on_complete`` hook (``Metrics.record_completion``) and return True.
    # Stacks without it — or whose schedulers lack the hook — make the pump
    # fall back to the legacy per-object request list.
    # def attach_metrics(self, metrics: "Metrics") -> bool: ...


_STACKS: Dict[str, Type] = {}


def register_stack(name: str, *aliases: str) -> Callable[[Type], Type]:
    """Class decorator: make a stack constructible by name through
    ``Experiment(stack=name)``.  Raises on duplicate registration."""

    def deco(cls: Type) -> Type:
        names = (name, *aliases)
        taken = [n for n in names if n in _STACKS]
        if taken:       # validate before inserting: no partial registration
            raise ValueError(f"stack {taken[0]!r} is already registered")
        for n in names:
            _STACKS[n] = cls
        cls.name = name
        return cls

    return deco


def get_stack(name: str) -> Type:
    try:
        return _STACKS[name]
    except KeyError:
        raise ValueError(
            f"unknown stack {name!r}; registered stacks: "
            f"{', '.join(sorted(_STACKS))}") from None


def available_stacks() -> List[str]:
    return sorted(_STACKS)


# ---------------------------------------------------------------------------
# Built-in stacks
# ---------------------------------------------------------------------------


@register_stack("archipelago")
class ArchipelagoStack:
    """Full paper stack: scalable LBS tier → semi-global schedulers (§4-§5).

    ``params``: ``n_lbs`` (parallel LB replicas, default 4; with
    ``Experiment.autoscale`` set it is only the *initial* pool size —
    default ``min_replicas`` — and the LBS replica autoscaler grows/shrinks
    the pool from observed decision-clock utilization, ``core.autoscale``).

    Straggler mitigation (docs/FAULTS.md "Hedged retries"):
    ``hedge_timeout`` — per-invocation dispatch timeout as a multiple of
    the invocation's expected ``exec_time`` (None/0 = off, the default); a
    dispatched copy that has not completed by ``setup + hedge_timeout ×
    exec_time`` gets a speculative duplicate enqueued, first completion
    wins.  ``hedge_jitter`` — seeded uniform fraction (default 0.25) the
    timeout is stretched by, so co-batched stragglers do not hedge in
    lockstep.
    """

    PARAMS = frozenset({"n_lbs", "hedge_timeout", "hedge_jitter"})

    lbs: Optional[LoadBalancer] = None
    scheduler: object = None
    _autoscaler = None

    def build(self, env, exp: "Experiment", spec: "WorkloadSpec",
              backend: ExecutionBackend) -> None:
        self.env = env
        self.exp = exp
        self.spec = spec
        self.lbs = build_cluster(env, exp.cluster, exp.sgs, exp.lbs,
                                 execute=backend.execute,
                                 backend_submit=backend.submit)
        # batching data planes expose a dead-member release hook: a worker
        # crash mid-batch must free the victims' pending/slot state
        drop = getattr(backend, "drop_invocations", None)
        # hedged-retry knobs (validated Experiment.params; zero-fault runs
        # leave them unset, so the SGS hot path stays decision-identical)
        hedge = exp.params.get("hedge_timeout")
        hedge = float(hedge) if hedge else None
        if hedge is not None and hedge <= 0.0:
            hedge = None
        jitter = float(exp.params.get("hedge_jitter", 0.25))
        if drop is not None or hedge is not None:
            for sid, s in self.lbs.sgss.items():
                s.backend_drop = drop
                if hedge is not None:
                    s._hedge_timeout = hedge
                    s._hedge_jitter = jitter
                    # seeded per-SGS stream, independent of the workload rng
                    s._hedge_rng = random.Random((exp.seed << 20) ^ sid)
        auto = getattr(exp, "autoscale", None)
        if auto is not None:
            n_lb = int(exp.params.get("n_lbs", auto.min_replicas))
            n_lb = max(1, max(auto.min_replicas,
                              min(n_lb, auto.max_replicas)))
        else:
            n_lb = max(1, int(exp.params.get("n_lbs", 4)))
        self._n_lb = n_lb
        self._lb_clocks = [_ServiceClock() for _ in range(n_lb)]
        self._sgs_clocks = {sid: _ServiceClock() for sid in self.lbs.sgss}
        self._arrival_no = 0
        if auto is not None:
            from .autoscale import LBSReplicaAutoscaler
            self._autoscaler = LBSReplicaAutoscaler(
                self._lb_clocks, exp.lb_cost, auto, make_clock=_ServiceClock)
        if type(self).submit is ArchipelagoStack.submit:
            # hot path: close over locals so the pump pays zero attribute
            # lookups per arrival (same constants as the pre-registry driver)
            self.submit = make_archipelago_submit(
                self._lb_clocks, self._sgs_clocks, self.lbs.select,
                env.call_at, exp.lb_cost, exp.sgs_cost,
                scaler=self._autoscaler)

    def submit(self, req: Request, now: float) -> None:
        # hop 1: LBS routing decision (LBS is a scalable service: many LBs)
        i = self._arrival_no
        self._arrival_no = i + 1
        t_routed = self._lb_clocks[i % self._n_lb].acquire(
            now, self.exp.lb_cost)
        sgs = self.lbs.select(req, now)
        # hop 2: SGS scheduling decision, serialized per SGS
        t_sched = self._sgs_clocks[sgs.sgs_id].acquire(
            t_routed, self.exp.sgs_cost * len(req.dag.functions))
        self.env.call_at(t_sched, sgs.submit_request, req)

    def start_background(self) -> None:
        # periodic scaling pass (the LBS's background loop, §5.2)
        lbs = self.lbs
        env = self.env
        horizon = self.spec.duration + self.exp.drain
        env.every(lbs.cfg.decision_interval / 5.0,
                  lambda: lbs.check_scaling(env.now()),
                  until=horizon)
        scaler = self._autoscaler
        if scaler is not None:
            # the LBS replica controller's observation/decision loop
            env.every(scaler.cfg.interval,
                      lambda: scaler.tick(env.now()), until=horizon)

    def scaling_events(self) -> List[dict]:
        """Typed control-plane scaling decisions this run made — LBS
        replica-pool actions (autoscaler) merged with per-DAG SGS set
        actions (``LoadBalancer.scaling_log``) in time order, as plain
        JSON-ready dicts for ``ExperimentResult.scaling_events``."""
        events = list(getattr(self.lbs, "scaling_log", ()))
        if self._autoscaler is not None:
            events.extend(self._autoscaler.events)
        events.sort(key=lambda e: (e.t, e.component))
        return [e.to_dict() for e in events]

    def attach_metrics(self, metrics: "Metrics") -> bool:
        rec = metrics.completion_recorder()
        for s in self.lbs.sgss.values():
            s.on_complete = rec
        return True

    def collect(self, metrics: "Metrics") -> None:
        for s in self.lbs.sgss.values():
            metrics.add_queuing_samples(s.queuing_delays,
                                        s.queuing_delay_times)

    def counters(self) -> Dict[str, int]:
        sgss = self.lbs.sgss.values()
        return {"cold_starts": sum(s.n_cold_starts for s in sgss),
                "warm_hits": sum(s.n_warm_hits for s in sgss),
                "hedges": sum(s.n_hedges for s in sgss)}


class FlatWorkerStack:
    """Base for centralized/decentralized baselines over one flat worker
    pool.  Subclasses provide ``make_scheduler``; the default ``submit``
    serializes every decision through ONE control-plane clock at
    ``exp.sgs_cost`` per DAG function (§2.4's centralized bottleneck).

    The execution backend's hook is wired onto the scheduler after
    construction (every built-in scheduler exposes ``backend_submit`` /
    ``execute`` attributes), so ``make_scheduler`` keeps its 3-argument
    signature and custom stacks run under any backend for free."""

    lbs: Optional[LoadBalancer] = None

    def build(self, env, exp: "Experiment", spec: "WorkloadSpec",
              backend: ExecutionBackend) -> None:
        self.env = env
        self.exp = exp
        self.spec = spec
        self.scheduler = self.make_scheduler(
            build_flat_workers(exp.cluster), env, exp)
        if backend.submit is not None:
            # asynchronous execution seam (core.backends.SubmitFn)
            self.scheduler.backend_submit = backend.submit
        elif backend.execute is not None:
            # pre-seam custom backends that were built without bind()
            self.scheduler.execute = backend.execute
        drop = getattr(backend, "drop_invocations", None)
        if drop is not None and hasattr(self.scheduler, "backend_drop"):
            # batched data plane: release dead members on worker crash
            self.scheduler.backend_drop = drop
        self._clock = _ServiceClock()
        if type(self).submit is FlatWorkerStack.submit:
            # hot path: same closure-over-locals trick as ArchipelagoStack,
            # with the M/D/1 clock acquire hand-inlined
            clock = self._clock
            call_at = env.call_at
            submit_request = self.scheduler.submit_request
            sgs_cost = exp.sgs_cost

            def submit(req: Request, now: float) -> None:
                t = clock.busy_until
                if now > t:
                    t = now
                clock.busy_until = t = t + sgs_cost * req.dag._n_fns
                call_at(t, submit_request, req)

            self.submit = submit

    def make_scheduler(self, workers: List[Worker], env,
                       exp: "Experiment") -> object:
        raise NotImplementedError

    def submit(self, req: Request, now: float) -> None:
        t_sched = self._clock.acquire(
            now, self.exp.sgs_cost * len(req.dag.functions))
        self.env.call_at(t_sched, self.scheduler.submit_request, req)

    def start_background(self) -> None:
        pass

    def attach_metrics(self, metrics: "Metrics") -> bool:
        # custom make_scheduler results may predate the hook: fall back
        if not hasattr(self.scheduler, "on_complete"):
            return False
        self.scheduler.on_complete = metrics.completion_recorder()
        return True

    def collect(self, metrics: "Metrics") -> None:
        metrics.add_queuing_samples(self.scheduler.queuing_delays,
                                    self.scheduler.queuing_delay_times)

    def counters(self) -> Dict[str, int]:
        return {"cold_starts": self.scheduler.n_cold_starts,
                "warm_hits": self.scheduler.n_warm_hits}


@register_stack("fifo", "baseline")
class CentralizedFIFOStack(FlatWorkerStack):
    """Centralized FIFO + reactive sandboxes + fixed keep-alive (§7.1).

    ``params``: ``keepalive`` (seconds, default 900).
    """

    PARAMS = frozenset({"keepalive"})

    def make_scheduler(self, workers, env, exp):
        return CentralizedFIFO(
            workers, env, keepalive=float(exp.params.get("keepalive", 900.0)))


@register_stack("sparrow")
class SparrowStack(FlatWorkerStack):
    """Sparrow-style power-of-two probing [41] (Fig. 2d).  No control-plane
    clock: probing is parallel, so submission is immediate (as in the
    original ``run_sparrow`` driver).

    ``params``: ``probes`` (default 2).
    """

    PARAMS = frozenset({"probes"})

    def make_scheduler(self, workers, env, exp):
        return SparrowScheduler(workers, env,
                                probes=int(exp.params.get("probes", 2)),
                                seed=exp.seed)

    def build(self, env, exp: "Experiment", spec: "WorkloadSpec",
              backend: ExecutionBackend) -> None:
        super().build(env, exp, spec, backend)
        submit_request = self.scheduler.submit_request
        self.submit = lambda req, now: submit_request(req)

    def submit(self, req: Request, now: float) -> None:
        self.scheduler.submit_request(req)


# ---------------------------------------------------------------------------
# Extensibility proof: a NEW stack added purely via the registry
# ---------------------------------------------------------------------------


class PullScheduler(CentralizedFIFO):
    """Worker-initiated (pull-based) scheduling à la Hiku [Akbari &
    Hauswirth 2025]: instead of the queue head picking a worker, each idle
    worker pulls work it can serve warm.

    The central dispatcher only holds ready invocations; whenever a worker
    has a free core it scans the first ``scan_limit`` queued invocations for
    one it holds a WARM sandbox for (late binding → accidental affinity
    becomes deliberate affinity) and falls back to the queue head.  This
    sidesteps CentralizedFIFO's strict head-of-line blocking while keeping
    its reactive sandbox + keep-alive model.
    """

    def __init__(self, workers: List[Worker], env, keepalive: float = 900.0,
                 scan_limit: int = 16):
        super().__init__(workers, env, keepalive=keepalive)
        self.scan_limit = scan_limit

    def _dispatch(self) -> None:
        now = self.env.now()
        q = self._queue
        progress = True
        while q and progress:
            progress = False
            for w in self.workers:
                if not q:
                    break
                if w.free_cores <= 0:
                    continue
                # the pulling worker prefers queued work it can serve warm
                pick = 0
                sbx: Optional[Sandbox] = None
                for j, inv in enumerate(
                        itertools.islice(q, self.scan_limit)):
                    s = w.warm_available(inv.fn.name, now)
                    if s is not None:
                        pick, sbx = j, s
                        break
                inv = q[pick]
                del q[pick]
                if sbx is None:
                    sbx = w.warm_available(inv.fn.name, now)
                self._start(inv, w, sbx, now)
                progress = True


@register_stack("pull")
class PullStack(FlatWorkerStack):
    """Pull-based worker-initiated scheduler (see ``PullScheduler``).

    ``params``: ``keepalive`` (default 900), ``scan_limit`` (default 16).
    """

    PARAMS = frozenset({"keepalive", "scan_limit"})

    def make_scheduler(self, workers, env, exp):
        return PullScheduler(
            workers, env,
            keepalive=float(exp.params.get("keepalive", 900.0)),
            scan_limit=int(exp.params.get("scan_limit", 16)))
