"""Execution backends: *what actually runs an invocation* behind a stack.

The scheduler stacks (``repro.core.stacks``) decide *where and when* an
invocation runs; an :class:`ExecutionBackend` decides *what executing it
means*.  The split mirrors Dirigent's control-plane / data-plane seam: the
same declarative ``Experiment`` — same stacks, sweeps and BENCH artifacts —
drives a purely modeled simulation, a deterministic scripted stub, or real
jitted JAX calls whose measured wall times feed back into scheduling.

Backends are registered by name exactly like stacks::

    from repro.core.backends import ExecutionBackend, register_backend

    @register_backend("my-backend")
    class MyBackend(ExecutionBackend):
        def build(self, exp, spec):
            self.execute = my_execute_hook      # Invocation -> seconds
            return spec                         # optionally re-specced

Built-ins:

* ``modeled`` (default) — analytic execution: an invocation occupies a core
  for ``fn.exec_time`` seconds.  ``execute`` stays ``None`` so schedulers
  take the exact pre-backend fast path — decision-identical to the
  equivalence goldens by construction.
* ``stub`` — deterministic scripted exec/setup times (CI): the workload's
  ``FunctionSpec``s are rewritten from ``exec_time``/``setup_time`` kwargs
  and the execute hook replays them, exercising the real-execution code path
  without real hardware work.
* ``jax`` — hardware-in-the-loop: calibrates every served model (real XLA
  compile = sandbox setup cost), rewrites the workload with *measured*
  ``FunctionSpec``s, and executes each invocation as a real jitted JAX call
  (``repro.serving.executor.JaxModelExecutor``).  See ``docs/SERVING.md``.
"""
from __future__ import annotations

import dataclasses
from typing import (TYPE_CHECKING, Any, Callable, Dict, List, Mapping,
                    Optional, Type, Union)

from .types import DagSpec, ExecuteFn, FunctionSpec

if TYPE_CHECKING:   # pragma: no cover - typing only, avoids a core->sim cycle
    from ..serving.executor import JaxModelExecutor, ServedModel
    from ..sim.experiment import Experiment
    from ..sim.workload import WorkloadSpec

__all__ = [
    "ExecutionBackend", "ModeledBackend", "StubBackend", "JaxBackend",
    "register_backend", "get_backend", "available_backends",
    "resolve_backend", "respec_dag", "respec_workload",
]


class ExecutionBackend:
    """Base class for execution backends (subclass + ``@register_backend``).

    Lifecycle: ``simulate`` resolves the experiment's backend, calls
    ``build(exp, spec)`` once before the stack is constructed, and hands the
    backend to every stack's ``build`` — stacks thread ``self.execute`` into
    their schedulers uniformly.

    ``execute`` is the data-plane hook (``Invocation -> seconds of
    execution``).  ``None`` means "modeled": schedulers charge
    ``fn.exec_time`` directly with zero per-invocation indirection (the
    simulator hot path, see docs/PERF.md).  ``build`` may also return a
    re-specced workload (measured or scripted ``FunctionSpec``s) — the stack
    and metrics layers only ever see the resolved spec.
    """

    name: str = "base"
    execute: Optional[ExecuteFn] = None

    def build(self, exp: "Experiment", spec: "WorkloadSpec") -> "WorkloadSpec":
        return spec

    def counters(self) -> Dict[str, int]:
        return {}


_BACKENDS: Dict[str, Type[ExecutionBackend]] = {}


def register_backend(name: str, *aliases: str
                     ) -> Callable[[Type[ExecutionBackend]],
                                   Type[ExecutionBackend]]:
    """Class decorator: make a backend constructible by name through
    ``Experiment(backend=name)``.  Raises on duplicate registration."""

    def deco(cls: Type[ExecutionBackend]) -> Type[ExecutionBackend]:
        names = (name, *aliases)
        taken = [n for n in names if n in _BACKENDS]
        if taken:       # validate before inserting: no partial registration
            raise ValueError(f"backend {taken[0]!r} is already registered")
        for n in names:
            _BACKENDS[n] = cls
        cls.name = name
        return cls

    return deco


def get_backend(name: str) -> Type[ExecutionBackend]:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered backends: "
            f"{', '.join(sorted(_BACKENDS))}") from None


def available_backends() -> List[str]:
    return sorted(_BACKENDS)


def resolve_backend(backend: Union[str, ExecutionBackend],
                    kwargs: Optional[Mapping[str, Any]] = None
                    ) -> ExecutionBackend:
    """A name constructs a fresh backend from ``kwargs``; a ready instance
    passes through (reuse one ``JaxBackend`` across sweep cells so models
    calibrate once)."""
    if isinstance(backend, str):
        return get_backend(backend)(**dict(kwargs or {}))
    if kwargs:
        raise ValueError(
            "backend_kwargs only apply when `backend` is a name; "
            "configure the instance directly instead")
    return backend


# ---------------------------------------------------------------------------
# Workload re-speccing (shared by stub/jax: swap FunctionSpecs, keep slack)
# ---------------------------------------------------------------------------


def respec_dag(dag: DagSpec, fn_specs: Mapping[str, FunctionSpec],
               slack: Optional[float] = None) -> DagSpec:
    """Copy of ``dag`` with its ``FunctionSpec``s substituted from
    ``fn_specs`` (missing names keep the modeled spec) and the deadline
    re-derived as new-critical-path + slack (default: the slack the original
    DAG granted).  Identity when nothing changes, so a no-op backend stays
    decision-identical to ``modeled``."""
    fns = tuple(fn_specs.get(f.name, f) for f in dag.functions)
    if fns == dag.functions:
        return dag
    if slack is None:
        slack = dag.slack
    return DagSpec(dag_id=dag.dag_id, functions=fns,
                   edges=dag.edges).with_deadline(slack=slack)


def respec_workload(spec: "WorkloadSpec",
                    fn_specs: Mapping[str, FunctionSpec],
                    slacks: Optional[Mapping[str, float]] = None
                    ) -> "WorkloadSpec":
    """``respec_dag`` over every tenant; extra fields of ``WorkloadSpec``
    subclasses (served models, prewarm plans) carry over unchanged."""
    tenants = [(respec_dag(dag, fn_specs,
                           None if slacks is None
                           else slacks.get(dag.dag_id, dag.slack)), proc)
               for dag, proc in spec.tenants]
    return dataclasses.replace(spec, tenants=tenants)


# ---------------------------------------------------------------------------
# Built-in backends
# ---------------------------------------------------------------------------


@register_backend("modeled")
class ModeledBackend(ExecutionBackend):
    """Analytic execution (the default): an invocation holds a core for
    ``fn.exec_time`` simulated seconds.  ``execute`` is ``None`` so the
    schedulers' modeled fast path runs unchanged — ``backend="modeled"`` is
    byte-identical to the pre-backend simulator (equivalence goldens)."""


@register_backend("stub")
class StubBackend(ExecutionBackend):
    """Deterministic scripted execution for CI and backend-seam tests.

    ``exec_time`` / ``setup_time`` script the respective ``FunctionSpec``
    fields: a scalar applies to every function, a mapping scripts per
    function name, unset keeps the workload's modeled value.  The hook runs
    through the schedulers' *real-execution* code path (the one ``jax``
    takes) while returning exactly the scripted seconds, so runs are
    reproducible without hardware work.
    """

    def __init__(self, exec_time: Union[float, Mapping[str, float], None] = None,
                 setup_time: Union[float, Mapping[str, float], None] = None):
        self.exec_time = exec_time
        self.setup_time = setup_time
        self.n_executions = 0

    @staticmethod
    def _scripted(table: Union[float, Mapping[str, float], None],
                  name: str, default: float) -> float:
        if table is None:
            return default
        if isinstance(table, Mapping):
            return float(table.get(name, default))
        return float(table)

    def build(self, exp: "Experiment", spec: "WorkloadSpec") -> "WorkloadSpec":
        known = {f.name for dag, _ in spec.tenants for f in dag.functions}
        for label, table in (("exec_time", self.exec_time),
                             ("setup_time", self.setup_time)):
            if isinstance(table, Mapping) and set(table) - known:
                raise ValueError(
                    f"stub {label} scripts unknown function(s) "
                    f"{sorted(set(table) - known)}; workload functions: "
                    f"{', '.join(sorted(known))}")
        fn_specs: Dict[str, FunctionSpec] = {}
        for dag, _ in spec.tenants:
            for f in dag.functions:
                fn_specs[f.name] = FunctionSpec(
                    name=f.name,
                    exec_time=self._scripted(self.exec_time, f.name,
                                             f.exec_time),
                    mem_mb=f.mem_mb,
                    setup_time=self._scripted(self.setup_time, f.name,
                                              f.setup_time))

        def execute(inv) -> float:
            # the scripted time was written into the re-specced FunctionSpec,
            # so the hook replays it: scheduling sees the same number the
            # metrics will, exactly like a calibrated real backend
            self.n_executions += 1
            return inv.fn.exec_time

        self.execute = execute
        return respec_workload(spec, fn_specs)

    def counters(self) -> Dict[str, int]:
        return {"n_executions": self.n_executions}


@register_backend("jax")
class JaxBackend(ExecutionBackend):
    """Hardware-in-the-loop: real jitted JAX execution under the schedulers.

    Needs served models: either the workload is a serving workload
    (``repro.serving.engine.serving_workload`` attaches ``spec.served``) or
    ``served={fn_name: ServedModel}`` is passed directly.  ``build``
    calibrates each model (real XLA compile + timed runs — the measured
    sandbox setup/exec costs become the ``FunctionSpec``s, so every
    scheduling decision operates on real numbers) and the execute hook runs
    the actual model per invocation.  Calibration is cached per served-model
    set (keyed on the ``ServedModel`` objects themselves, so sweep cells
    that rebuild the workload from the same apps calibrate once): pass one
    ``JaxBackend`` instance across sweep cells to compile once.
    """

    def __init__(self, served: Optional[Mapping[str, "ServedModel"]] = None,
                 mem_mb: float = 512.0, calib_runs: int = 3):
        self.served = served
        self.mem_mb = mem_mb
        self.calib_runs = calib_runs
        self.executor: Optional["JaxModelExecutor"] = None
        self.fn_specs: Optional[Dict[str, FunctionSpec]] = None
        self._calibrated_key: Optional[tuple] = None

    def build(self, exp: "Experiment", spec: "WorkloadSpec") -> "WorkloadSpec":
        served = self.served if self.served is not None \
            else getattr(spec, "served", None)
        if not served:
            raise ValueError(
                'backend="jax" needs served models: use a serving workload '
                '(repro.serving.engine.serving_workload) or pass '
                'backend_kwargs=dict(served={fn_name: ServedModel})')
        key = tuple(sorted((name, id(m)) for name, m in served.items()))
        if self.executor is None or self._calibrated_key != key:
            from ..serving.executor import JaxModelExecutor  # lazy: needs jax
            self.executor = JaxModelExecutor(dict(served))
            self.fn_specs = self.executor.calibrate(mem_mb=self.mem_mb,
                                                    runs=self.calib_runs)
            self._calibrated_key = key
        self.execute = self.executor.execute
        return respec_workload(spec, self.fn_specs,
                               getattr(spec, "slacks", None))

    def counters(self) -> Dict[str, int]:
        n = self.executor.n_executions if self.executor is not None else 0
        return {"n_executions": n}
