"""Execution backends: *what actually runs an invocation* behind a stack.

The scheduler stacks (``repro.core.stacks``) decide *where and when* an
invocation runs; an :class:`ExecutionBackend` decides *what executing it
means*.  The split mirrors Dirigent's control-plane / data-plane seam: the
same declarative ``Experiment`` — same stacks, sweeps and BENCH artifacts —
drives a purely modeled simulation, a deterministic scripted stub, or real
jitted JAX calls whose measured wall times feed back into scheduling.

Backends are registered by name exactly like stacks::

    from repro.core.backends import ExecutionBackend, register_backend

    @register_backend("my-backend")
    class MyBackend(ExecutionBackend):
        def build(self, exp, spec):
            self.execute = my_execute_hook      # Invocation -> seconds
            return spec                         # optionally re-specced

Built-ins:

* ``modeled`` (default) — analytic execution: an invocation occupies a core
  for ``fn.exec_time`` seconds.  ``execute`` stays ``None`` so schedulers
  take the exact pre-backend fast path — decision-identical to the
  equivalence goldens by construction.
* ``stub`` — deterministic scripted exec/setup times (CI): the workload's
  ``FunctionSpec``s are rewritten from ``exec_time``/``setup_time`` kwargs
  and the execute hook replays them, exercising the real-execution code path
  without real hardware work.
* ``stub-batched`` — the stub seam run through the batching data plane
  (``BatchCoalescer``): deterministic scripted per-batch times, exercising
  window/bucket coalescing and completion ordering without hardware work.
* ``jax`` — hardware-in-the-loop: calibrates every served model (real XLA
  compile = sandbox setup cost), rewrites the workload with *measured*
  ``FunctionSpec``s, and executes each invocation as a real jitted JAX call
  (``repro.serving.executor.JaxModelExecutor``).  See ``docs/SERVING.md``.
* ``jax-batched`` — like ``jax`` but the data plane coalesces concurrently
  in-flight invocations of the same served model into padded batches
  (bucketed by powers of two, per-bucket executables compiled at
  calibration time — ``repro.serving.executor.BatchingJaxExecutor``).
  ``batching="continuous"`` swaps the request-window coalescer for
  step-granular continuous batching (:class:`ContinuousBatcher` over
  ``repro.serving.executor.ContinuousJaxExecutor``): decode-style requests
  join/leave a running batch at token-step boundaries.

The jax backends also take ``kernels={"xla","pallas","pallas_interpret"}``
(see ``repro.kernels.ops``): which implementation serves the model hot
spots.  Both axes are ordinary sweepable ``backend_kwargs`` and are
recorded per result row via :meth:`ExecutionBackend.data_plane`.

The execution contract is *asynchronous*: schedulers dispatch through
``submit(inv, done, delay)`` and the backend completes later by firing
``done(exec_s)`` via ``env.call_after`` (see ``types.SubmitFn``).  Backends
that only define the legacy synchronous ``execute`` hook are adapted
automatically in :meth:`ExecutionBackend.bind`.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import (TYPE_CHECKING, Any, Callable, Dict, List, Mapping,
                    Optional, Tuple, Type, Union)

from .types import (DagSpec, DoneFn, ExecuteFn, FunctionSpec, Invocation,
                    SubmitFn)

if TYPE_CHECKING:   # pragma: no cover - typing only, avoids a core->sim cycle
    from ..serving.executor import JaxModelExecutor, ServedModel
    from ..sim.experiment import Experiment
    from ..sim.workload import WorkloadSpec
    from .sgs import Env

__all__ = [
    "ExecutionBackend", "ModeledBackend", "StubBackend",
    "StubBatchedBackend", "JaxBackend", "BatchedJaxBackend",
    "CompletionQueue", "BatchCoalescer", "ContinuousBatcher",
    "register_backend", "get_backend", "available_backends",
    "resolve_backend", "respec_dag", "respec_workload", "served_model_key",
    "KERNEL_CHOICES", "BATCHING_CHOICES",
]

# kernel-dispatch backends a jax data plane accepts (mirrors
# repro.kernels.ops.KernelType without importing jax at module scope)
KERNEL_CHOICES = ("xla", "pallas", "pallas_interpret")
# batching disciplines of the batched data planes
BATCHING_CHOICES = ("windowed", "continuous")


class ExecutionBackend:
    """Base class for execution backends (subclass + ``@register_backend``).

    Lifecycle: ``simulate`` resolves the experiment's backend, calls
    ``build(exp, spec)`` once before the stack is constructed, then
    ``bind(env)`` with the live event loop, and hands the backend to every
    stack's ``build`` — stacks thread ``self.submit`` into their schedulers
    uniformly.

    ``submit`` is the asynchronous data-plane hook
    (``submit(inv, done, delay)``, see ``types.SubmitFn``): the scheduler
    dispatches and keeps running; the backend fires ``done(exec_s)`` at the
    completion instant via ``env.call_after``.  ``None`` means "modeled":
    schedulers charge ``fn.exec_time`` directly with zero per-invocation
    indirection (the simulator hot path, see docs/PERF.md).

    ``execute`` is the legacy *synchronous* hook (``Invocation -> seconds``).
    Backends that only set it keep working: the default ``bind`` wraps it
    into a ``submit`` that runs the hook at dispatch time, with the
    completion event landing at the exact instant and insertion order the
    pre-seam code produced (an unscripted ``stub`` therefore stays
    decision-identical to ``modeled``).  Batched backends instead deliver
    completions through :class:`CompletionQueue` — deterministic ordering,
    ties broken by ``inv_id``.

    ``build`` may also return a re-specced workload (measured or scripted
    ``FunctionSpec``s) — the stack and metrics layers only ever see the
    resolved spec.
    """

    name: str = "base"
    execute: Optional[ExecuteFn] = None
    submit: Optional[SubmitFn] = None

    def build(self, exp: "Experiment", spec: "WorkloadSpec") -> "WorkloadSpec":
        return spec

    def bind(self, env: "Env") -> None:
        """Attach the live event loop for this run (called once per
        ``simulate``, after ``build`` and before the stack is constructed).

        The default adapts a legacy ``execute`` hook to the asynchronous
        seam.  Backends with a native ``submit`` override this to (re)build
        their per-run state — instances are reusable across sweep cells, so
        anything holding an old env must be reconstructed here.
        """
        self.env = env
        if self.execute is not None:
            execute = self.execute
            call_after = env.call_after

            def submit(inv: Invocation, done: DoneFn, delay: float = 0.0
                       ) -> None:
                # legacy hook: runs synchronously at dispatch time; the
                # completion event lands at exactly the instant, insertion
                # point and order the pre-seam code produced, so an
                # unscripted stub stays decision-identical to modeled
                # (batched backends route completions through a
                # CompletionQueue instead — inv_id-ordered, since batch
                # flush timing has no modeled twin to mirror)
                exec_s = execute(inv)
                call_after(delay + exec_s, done, exec_s)

            self.submit = submit

    def counters(self) -> Dict[str, int]:
        return {}

    def data_plane(self) -> Dict[str, str]:
        """Data-plane identity for result rows: which kernel backend served
        the model hot spots (``kernels``) and which batching discipline the
        submit hook ran (``batching``).  Empty for modeled backends —
        there is no data plane to identify."""
        return {}


_BACKENDS: Dict[str, Type[ExecutionBackend]] = {}


def register_backend(name: str, *aliases: str
                     ) -> Callable[[Type[ExecutionBackend]],
                                   Type[ExecutionBackend]]:
    """Class decorator: make a backend constructible by name through
    ``Experiment(backend=name)``.  Raises on duplicate registration."""

    def deco(cls: Type[ExecutionBackend]) -> Type[ExecutionBackend]:
        names = (name, *aliases)
        taken = [n for n in names if n in _BACKENDS]
        if taken:       # validate before inserting: no partial registration
            raise ValueError(f"backend {taken[0]!r} is already registered")
        for n in names:
            _BACKENDS[n] = cls
        cls.name = name
        return cls

    return deco


def get_backend(name: str) -> Type[ExecutionBackend]:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered backends: "
            f"{', '.join(sorted(_BACKENDS))}") from None


def available_backends() -> List[str]:
    return sorted(_BACKENDS)


def resolve_backend(backend: Union[str, ExecutionBackend],
                    kwargs: Optional[Mapping[str, Any]] = None
                    ) -> ExecutionBackend:
    """A name constructs a fresh backend from ``kwargs``; a ready instance
    passes through (reuse one ``JaxBackend`` across sweep cells so models
    calibrate once)."""
    if isinstance(backend, str):
        return get_backend(backend)(**dict(kwargs or {}))
    if kwargs:
        raise ValueError(
            "backend_kwargs only apply when `backend` is a name; "
            "configure the instance directly instead")
    return backend


# ---------------------------------------------------------------------------
# Asynchronous-seam plumbing: deterministic completions + batch coalescing
# ---------------------------------------------------------------------------


class CompletionQueue:
    """Deterministically ordered completion delivery for a data plane.

    ``schedule(inv, exec_s, done, delay)`` arranges for ``done(exec_s)`` to
    fire at ``env.now() + delay + exec_s``.  Completions due at the same sim
    instant fire in ``inv_id`` order regardless of scheduling order — the
    event heap alone would use insertion order, which for a batched backend
    depends on flush timing.  This is what keeps stub/batched runs exactly
    reproducible.
    """

    def __init__(self, env: "Env"):
        self.env = env
        # (fire_time, inv_id, exec_s, done)
        self._heap: List[Tuple[float, int, float, DoneFn]] = []

    def schedule(self, inv: Invocation, exec_s: float, done: DoneFn,
                 delay: float = 0.0) -> None:
        lag = delay + exec_s
        heapq.heappush(self._heap,
                       (self.env.now() + lag, inv.inv_id, exec_s, done))
        self.env.call_after(lag, self._fire)

    def _fire(self) -> None:
        # one flush event per schedule(); each drains everything due at its
        # fire instant in (time, inv_id) order, so later flushes at the same
        # timestamp find the heap already empty.  Entry times and event times
        # come from the identical float expression (now + lag), so exact
        # comparison is safe — no epsilon that could deliver a completion at
        # an infinitesimally earlier instant.
        now = self.env.now()
        h = self._heap
        while h and h[0][0] <= now:
            _, _, exec_s, done = heapq.heappop(h)
            done(exec_s)


class BatchCoalescer:
    """Per-function time/size-window batching on top of the async seam.

    Invocations submitted for the same function while earlier ones are still
    waiting are coalesced: the first submission opens a ``batch_window``
    (sim seconds); the batch flushes when the window closes or as soon as
    ``max_batch`` invocations have gathered.  ``run_batch(fn_name, invs)``
    executes the whole batch ONCE and returns the shared runtime in seconds
    — every member completes at ``flush_time + runtime`` (the batch moves at
    the speed of the padded executable, not of its slowest member), with
    completions delivered in ``inv_id`` order via :class:`CompletionQueue`.

    A cold invocation (``delay`` = sandbox setup) enrolls only once its
    setup has elapsed, so batches never start before their members could.
    """

    def __init__(self, env: "Env",
                 run_batch: Callable[[str, List[Invocation]], float],
                 batch_window: float = 0.005, max_batch: int = 8):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if batch_window < 0:
            raise ValueError(
                f"batch_window must be >= 0, got {batch_window}")
        self.env = env
        self.run_batch = run_batch
        self.batch_window = batch_window
        self.max_batch = max_batch
        self._cq = CompletionQueue(env)
        self._pending: Dict[str, List[Tuple[Invocation, DoneFn]]] = {}
        # generation per function: a window-flush event is stale if an
        # early (size-triggered) flush already took its batch
        self._gen: Dict[str, int] = {}
        # dead-member tombstones (core.fault worker crash): inv_ids dropped
        # while still in their setup-delay deferral; consumed by _enroll
        self._dropped: set = set()
        # occupancy counters (surfaced through backend.counters())
        self.n_batches = 0
        self.n_batched_invocations = 0
        self.n_batch_slots = 0          # sum of padded bucket sizes
        self.max_occupancy = 0
        self.n_dropped = 0

    def submit(self, inv: Invocation, done: DoneFn, delay: float = 0.0
               ) -> None:
        if delay > 0.0:
            self.env.call_after(delay, self._enroll, inv, done)
        else:
            self._enroll(inv, done)

    def drop(self, inv_ids: List[int]) -> None:
        """Purge dead members (their worker crashed) from the data plane.

        Members still waiting in a window are removed before the flush, so
        the batch that eventually runs contains only live invocations; a
        window whose members ALL died flushes empty and is a no-op.  Members
        whose setup delay has not elapsed are tombstoned and skipped at
        enrollment.  Members already executing in a flushed batch cannot be
        recalled — their completions fire and the scheduler's inflight guard
        discards them (exactly-once accounting lives scheduler-side).
        """
        ids = set(inv_ids)
        if not ids:
            return
        for fn, q in self._pending.items():
            if any(inv.inv_id in ids for inv, _ in q):
                kept = [(inv, d) for inv, d in q if inv.inv_id not in ids]
                self.n_dropped += len(q) - len(kept)
                ids -= {inv.inv_id for inv, _ in q}
                self._pending[fn] = kept
        # not pending: either in setup deferral (tombstone) or already
        # flushed/complete (the stale tombstone is consumed by the inflight
        # guard's silence — it never enrolls again, so it leaks at most one
        # int per crash, bounded by inflight size)
        self._dropped |= ids

    def _enroll(self, inv: Invocation, done: DoneFn) -> None:
        if inv.inv_id in self._dropped:
            self._dropped.discard(inv.inv_id)
            self.n_dropped += 1
            return
        q = self._pending.setdefault(inv.fn.name, [])
        q.append((inv, done))
        if len(q) >= self.max_batch:
            self._flush(inv.fn.name, self._gen.get(inv.fn.name, 0))
        elif len(q) == 1:
            gen = self._gen.get(inv.fn.name, 0)
            if self.batch_window > 0.0:
                self.env.call_after(self.batch_window, self._flush,
                                    inv.fn.name, gen)
            else:
                self._flush(inv.fn.name, gen)

    def _flush(self, fn_name: str, gen: int) -> None:
        if self._gen.get(fn_name, 0) != gen:
            return                      # stale window: batch already ran
        batch = self._pending.get(fn_name)
        if not batch:
            return
        self._gen[fn_name] = gen + 1
        self._pending[fn_name] = []
        invs = [inv for inv, _ in batch]
        runtime = self.run_batch(fn_name, invs)
        k = len(batch)
        self.n_batches += 1
        self.n_batched_invocations += k
        self.n_batch_slots += pow2_bucket(k)
        if k > self.max_occupancy:
            self.max_occupancy = k
        for inv, done in sorted(batch, key=lambda p: p[0].inv_id):
            self._cq.schedule(inv, runtime, done)

    def counters(self) -> Dict[str, int]:
        return {"n_batches": self.n_batches,
                "n_batched_invocations": self.n_batched_invocations,
                "n_batch_slots": self.n_batch_slots,
                "max_batch_occupancy": self.max_occupancy,
                "n_dropped_invocations": self.n_dropped}


class ContinuousBatcher:
    """Step-granular *continuous* batching on top of the async seam.

    Where :class:`BatchCoalescer` gathers whole requests into one padded
    execution (every member runs prefill AND all decode steps together),
    this batcher decomposes a decode-style request into *token steps*:
    in-flight invocations of the same function join and leave a running
    batch at step boundaries.  A new arrival never waits for the current
    generation to finish — it is admitted at the next tick (one batched
    prefill), decodes alongside the residents, and completes as soon as its
    own ``steps_for(fn)`` decode steps have elapsed.  This is the vLLM-style
    iteration-level scheduling discipline, driving the GPU/TPU at decode
    batch occupancy instead of request-window occupancy.

    The data plane supplies three hooks (see
    ``repro.serving.executor.ContinuousJaxExecutor`` for the real twin and
    ``StubBatchedBackend(batching="continuous")`` for the scripted one):

    * ``admit(fn_name, invs, slots) -> seconds`` — batched prefill of the
      joiners into cache slots ``slots``; returns measured wall seconds.
    * ``step(fn_name, slots) -> seconds`` — ONE decode step for every
      active slot; returns measured wall seconds.
    * ``steps_for(fn_name) -> int`` — decode steps a request owes after its
      admitting prefill (the prefill itself yields the first token).

    Determinism: pending joiners are admitted in ``inv_id`` order into the
    lowest free slots; same-instant submissions all join the same first
    tick (the tick is deferred to the end of the current instant); members
    finishing on the same tick complete in ``inv_id`` order via
    :class:`CompletionQueue`.  A cold invocation (``delay`` = sandbox
    setup) enrolls only once its setup has elapsed.
    """

    def __init__(self, env: "Env",
                 admit: Callable[[str, List[Invocation], List[int]], float],
                 step: Callable[[str, List[int]], float],
                 steps_for: Callable[[str], int],
                 max_batch: int = 8,
                 release: Optional[Callable[[str, List[int]], None]] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.env = env
        self.admit = admit
        self.step = step
        self.steps_for = steps_for
        self.max_batch = max_batch
        # optional slot-release hook: called with the cache slots of dropped
        # members so the real executor can scrub its slab (serving.executor
        # ContinuousJaxExecutor.release_slots)
        self.release = release
        self._cq = CompletionQueue(env)
        self._pending: Dict[str, List[Tuple[Invocation, DoneFn]]] = {}
        # slot -> [inv, done, steps_left, join_time]
        self._active: Dict[str, Dict[int, list]] = {}
        self._free: Dict[str, List[int]] = {}       # min-heap of free slots
        self._running: Dict[str, bool] = {}
        # dead-member tombstones (core.fault worker crash): inv_ids dropped
        # while still in their setup-delay deferral; consumed by _enroll
        self._dropped: set = set()
        # occupancy counters (surfaced through backend.counters())
        self.n_prefill_batches = 0
        self.n_joins = 0
        self.n_ticks = 0
        self.n_step_slots = 0           # sum of active sizes over all ticks
        self.max_occupancy = 0
        self.n_dropped = 0

    def submit(self, inv: Invocation, done: DoneFn, delay: float = 0.0
               ) -> None:
        if delay > 0.0:
            self.env.call_after(delay, self._enroll, inv, done)
        else:
            self._enroll(inv, done)

    def drop(self, inv_ids: List[int]) -> None:
        """Purge dead members (their worker crashed) from the data plane.

        Pending joiners are removed before their admitting prefill; active
        residents leave the running batch at the next step boundary — their
        slot is freed immediately (and scrubbed via the ``release`` hook),
        so the tick that follows steps only live members and new joiners are
        admitted into the vacated slots.  Members in their setup deferral
        are tombstoned and skipped at enrollment.  Counters stay coherent:
        a dropped resident was already counted as a join, never as a
        completion, and subsequent ticks no longer count its slot.
        """
        ids = set(inv_ids)
        if not ids:
            return
        for fn, q in self._pending.items():
            if any(inv.inv_id in ids for inv, _ in q):
                kept = [(inv, d) for inv, d in q if inv.inv_id not in ids]
                self.n_dropped += len(q) - len(kept)
                ids -= {inv.inv_id for inv, _ in q}
                self._pending[fn] = kept
        for fn, active in self._active.items():
            hit = sorted(s for s, e in active.items() if e[0].inv_id in ids)
            if not hit:
                continue
            free = self._free[fn]
            for s in hit:
                entry = active.pop(s)
                ids.discard(entry[0].inv_id)
                heapq.heappush(free, s)
            self.n_dropped += len(hit)
            if self.release is not None:
                self.release(fn, hit)
        # remainder: in setup deferral (tombstone; consumed by _enroll) or
        # already completed (stale id, at most one int leaked per crash)
        self._dropped |= ids

    def _enroll(self, inv: Invocation, done: DoneFn) -> None:
        if inv.inv_id in self._dropped:
            self._dropped.discard(inv.inv_id)
            self.n_dropped += 1
            return
        fn = inv.fn.name
        self._pending.setdefault(fn, []).append((inv, done))
        if not self._running.get(fn, False):
            self._running[fn] = True
            # defer the first tick to the end of the current instant so
            # every same-instant submission joins the same prefill batch
            self.env.call_after(0.0, self._tick, fn)

    def _tick(self, fn: str) -> None:
        now = self.env.now()
        pending = self._pending.setdefault(fn, [])
        active = self._active.setdefault(fn, {})
        free = self._free.setdefault(fn, list(range(self.max_batch)))
        dur = 0.0
        if pending and free:
            pending.sort(key=lambda p: p[0].inv_id)
            k = min(len(pending), len(free))
            joiners, self._pending[fn] = pending[:k], pending[k:]
            slots = sorted(heapq.heappop(free) for _ in range(k))
            dur += self.admit(fn, [inv for inv, _ in joiners], slots)
            self.n_prefill_batches += 1
            self.n_joins += k
            steps = self.steps_for(fn)
            for (inv, done), s in zip(joiners, slots):
                active[s] = [inv, done, steps, now]
            if steps <= 0:
                # degenerate prefill-only functions: done at admission,
                # before (and without) any decode step
                self._finish(fn, now, dur)
        if active:
            slots = sorted(active)
            dur += self.step(fn, slots)
            self.n_ticks += 1
            self.n_step_slots += len(slots)
            if len(slots) > self.max_occupancy:
                self.max_occupancy = len(slots)
            for s in slots:
                active[s][2] -= 1
        self._finish(fn, now, dur)
        if self._active[fn] or self._pending.get(fn):
            self.env.call_after(dur, self._tick, fn)
        else:
            self._running[fn] = False

    def _finish(self, fn: str, now: float, dur: float) -> None:
        """Complete every active member that owes no further steps, at
        ``now + dur``; ``exec_s`` reports the member's total residency
        (its own prefill through its last decode step)."""
        active, free = self._active[fn], self._free[fn]
        for s in [s for s, e in active.items() if e[2] <= 0]:
            inv, done, _, join_t = active.pop(s)
            heapq.heappush(free, s)
            total = now + dur - join_t
            self._cq.schedule(inv, total, done, delay=dur - total)

    def counters(self) -> Dict[str, int]:
        return {"n_prefill_batches": self.n_prefill_batches,
                "n_joins": self.n_joins,
                "n_decode_ticks": self.n_ticks,
                "n_step_slots": self.n_step_slots,
                "max_batch_occupancy": self.max_occupancy,
                "n_dropped_invocations": self.n_dropped}


def pow2_bucket(k: int) -> int:
    """Smallest power of two >= k (the padded batch size a batch of ``k``
    executes at)."""
    return 1 << (k - 1).bit_length() if k > 1 else 1


# ---------------------------------------------------------------------------
# Workload re-speccing (shared by stub/jax: swap FunctionSpecs, keep slack)
# ---------------------------------------------------------------------------


def respec_dag(dag: DagSpec, fn_specs: Mapping[str, FunctionSpec],
               slack: Optional[float] = None) -> DagSpec:
    """Copy of ``dag`` with its ``FunctionSpec``s substituted from
    ``fn_specs`` (missing names keep the modeled spec) and the deadline
    re-derived as new-critical-path + slack (default: the slack the original
    DAG granted).  Identity when nothing changes, so a no-op backend stays
    decision-identical to ``modeled``."""
    fns = tuple(fn_specs.get(f.name, f) for f in dag.functions)
    if fns == dag.functions:
        return dag
    if slack is None:
        slack = dag.slack
    return DagSpec(dag_id=dag.dag_id, functions=fns,
                   edges=dag.edges).with_deadline(slack=slack)


def respec_workload(spec: "WorkloadSpec",
                    fn_specs: Mapping[str, FunctionSpec],
                    slacks: Optional[Mapping[str, float]] = None
                    ) -> "WorkloadSpec":
    """``respec_dag`` over every tenant; extra fields of ``WorkloadSpec``
    subclasses (served models, prewarm plans) carry over unchanged."""
    tenants = [(respec_dag(dag, fn_specs,
                           None if slacks is None
                           else slacks.get(dag.dag_id, dag.slack)), proc)
               for dag, proc in spec.tenants]
    return dataclasses.replace(spec, tenants=tenants)


# ---------------------------------------------------------------------------
# Built-in backends
# ---------------------------------------------------------------------------


@register_backend("modeled")
class ModeledBackend(ExecutionBackend):
    """Analytic execution (the default): an invocation holds a core for
    ``fn.exec_time`` simulated seconds.  ``execute`` is ``None`` so the
    schedulers' modeled fast path runs unchanged — ``backend="modeled"`` is
    byte-identical to the pre-backend simulator (equivalence goldens)."""


@register_backend("stub")
class StubBackend(ExecutionBackend):
    """Deterministic scripted execution for CI and backend-seam tests.

    ``exec_time`` / ``setup_time`` script the respective ``FunctionSpec``
    fields: a scalar applies to every function, a mapping scripts per
    function name, unset keeps the workload's modeled value.  The hook runs
    through the schedulers' *real-execution* code path (the one ``jax``
    takes) while returning exactly the scripted seconds, so runs are
    reproducible without hardware work.
    """

    def __init__(self, exec_time: Union[float, Mapping[str, float], None] = None,
                 setup_time: Union[float, Mapping[str, float], None] = None):
        self.exec_time = exec_time
        self.setup_time = setup_time
        self.n_executions = 0

    @staticmethod
    def _scripted(table: Union[float, Mapping[str, float], None],
                  name: str, default: float) -> float:
        if table is None:
            return default
        if isinstance(table, Mapping):
            return float(table.get(name, default))
        return float(table)

    def build(self, exp: "Experiment", spec: "WorkloadSpec") -> "WorkloadSpec":
        known = {f.name for dag, _ in spec.tenants for f in dag.functions}
        for label, table in (("exec_time", self.exec_time),
                             ("setup_time", self.setup_time)):
            if isinstance(table, Mapping) and set(table) - known:
                raise ValueError(
                    f"stub {label} scripts unknown function(s) "
                    f"{sorted(set(table) - known)}; workload functions: "
                    f"{', '.join(sorted(known))}")
        fn_specs: Dict[str, FunctionSpec] = {}
        for dag, _ in spec.tenants:
            for f in dag.functions:
                fn_specs[f.name] = FunctionSpec(
                    name=f.name,
                    exec_time=self._scripted(self.exec_time, f.name,
                                             f.exec_time),
                    mem_mb=f.mem_mb,
                    setup_time=self._scripted(self.setup_time, f.name,
                                              f.setup_time))

        def execute(inv) -> float:
            # the scripted time was written into the re-specced FunctionSpec,
            # so the hook replays it: scheduling sees the same number the
            # metrics will, exactly like a calibrated real backend
            self.n_executions += 1
            return inv.fn.exec_time

        self.execute = execute
        return respec_workload(spec, fn_specs)

    def counters(self) -> Dict[str, int]:
        return {"n_executions": self.n_executions}


@register_backend("stub-batched")
class StubBatchedBackend(StubBackend):
    """Scripted times through the *batching* data plane (CI).

    Same scripting knobs as ``stub`` (``exec_time``/``setup_time``), but the
    submit hook is a native :class:`BatchCoalescer`: concurrently in-flight
    invocations of the same function coalesce into one scripted "batch
    execution" of ``exec_time + batch_cost * (bucket - 1)`` seconds (bucket
    = padded power-of-two size; the default ``batch_cost=0`` models perfect
    batching).  Deterministically exercises window/bucket coalescing, batch
    occupancy counters, and inv_id-ordered completions without hardware.
    """

    def __init__(self,
                 exec_time: Union[float, Mapping[str, float], None] = None,
                 setup_time: Union[float, Mapping[str, float], None] = None,
                 batch_window: float = 0.005, max_batch: int = 8,
                 batch_cost: float = 0.0, batching: str = "windowed",
                 n_steps: int = 4):
        super().__init__(exec_time, setup_time)
        if batching not in BATCHING_CHOICES:
            raise ValueError(f"batching must be one of {BATCHING_CHOICES}, "
                             f"got {batching!r}")
        if n_steps < 0:
            raise ValueError(f"n_steps must be >= 0, got {n_steps}")
        self.batch_window = batch_window
        self.max_batch = max_batch
        self.batch_cost = batch_cost
        self.batching = batching
        self.n_steps = n_steps
        self._coalescer: Optional[BatchCoalescer] = None
        self._batcher: Optional[ContinuousBatcher] = None
        self._fn_exec: Dict[str, float] = {}

    def build(self, exp: "Experiment", spec: "WorkloadSpec") -> "WorkloadSpec":
        spec = super().build(exp, spec)
        # scripted per-function exec times, addressable by name (the
        # continuous hooks receive fn_name, not an Invocation)
        self._fn_exec = {f.name: f.exec_time
                         for dag, _ in spec.tenants for f in dag.functions}
        self.execute = None     # native async submit: skip the legacy adapter
        return spec

    def bind(self, env: "Env") -> None:
        self.env = env
        if self.batching == "continuous":
            # scripted continuous twin: a lone request still costs exactly
            # exec_time (half in the admitting prefill, half spread over
            # n_steps decode ticks), so windowed/continuous stub runs are
            # directly comparable; batch_cost charges padded-slot overhead
            # per tick just like the windowed script does per batch
            def admit(fn_name: str, invs: List[Invocation],
                      slots: List[int]) -> float:
                self.n_executions += 1
                bucket = pow2_bucket(len(slots))
                return (self._fn_exec[fn_name] * 0.5
                        + self.batch_cost * (bucket - 1))

            def step(fn_name: str, slots: List[int]) -> float:
                self.n_executions += 1
                bucket = pow2_bucket(len(slots))
                per_step = self._fn_exec[fn_name] * 0.5 / max(1, self.n_steps)
                return per_step + self.batch_cost * (bucket - 1)

            self._batcher = ContinuousBatcher(env, admit, step,
                                              lambda fn: self.n_steps,
                                              max_batch=self.max_batch)
            self.submit = self._batcher.submit
            self._coalescer = None
            return

        def run_batch(fn_name: str, invs: List[Invocation]) -> float:
            self.n_executions += 1
            bucket = pow2_bucket(len(invs))
            return invs[0].fn.exec_time + self.batch_cost * (bucket - 1)

        self._coalescer = BatchCoalescer(env, run_batch,
                                         batch_window=self.batch_window,
                                         max_batch=self.max_batch)
        self.submit = self._coalescer.submit
        self._batcher = None

    def drop_invocations(self, inv_ids: List[int]) -> None:
        """Dead-member release (core.fault worker crash): purge the crashed
        worker's in-flight members from whichever data plane is bound."""
        if self._batcher is not None:
            self._batcher.drop(inv_ids)
        elif self._coalescer is not None:
            self._coalescer.drop(inv_ids)

    def counters(self) -> Dict[str, int]:
        c = dict(super().counters())
        if self._coalescer is not None:
            c.update(self._coalescer.counters())
        if self._batcher is not None:
            c.update(self._batcher.counters())
        return c

    def data_plane(self) -> Dict[str, str]:
        return {"kernels": "none", "batching": self.batching}


def served_model_key(served: Mapping[str, "ServedModel"]) -> tuple:
    """Content-based calibration-cache key for a served-model set.

    Keys on what determines the compiled executables and their measured
    times (config identity + shapes + batch), NOT on ``id(m)``: object ids
    can be reused after a ``ServedModel`` is garbage-collected, which would
    false-hit the cache and serve stale calibration for a different model.
    """
    return tuple(sorted(
        (name, m.cfg.name, m.cfg.arch_type, m.cfg.n_layers, m.cfg.d_model,
         getattr(m.cfg, "kernels", "xla"), m.prompt_len, m.gen_len, m.batch)
        for name, m in served.items()))


@register_backend("jax")
class JaxBackend(ExecutionBackend):
    """Hardware-in-the-loop: real jitted JAX execution under the schedulers.

    Needs served models: either the workload is a serving workload
    (``repro.serving.engine.serving_workload`` attaches ``spec.served``) or
    ``served={fn_name: ServedModel}`` is passed directly.  ``build``
    calibrates each model (real XLA compile + timed runs — the measured
    sandbox setup/exec costs become the ``FunctionSpec``s, so every
    scheduling decision operates on real numbers) and the execute hook runs
    the actual model per invocation.  Calibration is cached per served-model
    set (keyed on the ``ServedModel`` objects themselves, so sweep cells
    that rebuild the workload from the same apps calibrate once): pass one
    ``JaxBackend`` instance across sweep cells to compile once.
    """

    def __init__(self, served: Optional[Mapping[str, "ServedModel"]] = None,
                 mem_mb: float = 512.0, calib_runs: int = 3,
                 kernels: str = "xla"):
        if kernels not in KERNEL_CHOICES:
            raise ValueError(f"kernels must be one of {KERNEL_CHOICES}, "
                             f"got {kernels!r}")
        self.served = served
        self.mem_mb = mem_mb
        self.calib_runs = calib_runs
        self.kernels = kernels
        self.executor: Optional["JaxModelExecutor"] = None
        self.fn_specs: Optional[Dict[str, FunctionSpec]] = None
        self._calibrated_key: Optional[tuple] = None

    def _resolve_served(self, spec: "WorkloadSpec"
                        ) -> Mapping[str, "ServedModel"]:
        served = self.served if self.served is not None \
            else getattr(spec, "served", None)
        if not served:
            raise ValueError(
                f'backend="{self.name}" needs served models: use a serving '
                'workload (repro.serving.engine.serving_workload) or pass '
                'backend_kwargs=dict(served={fn_name: ServedModel})')
        if any(m.cfg.kernels != self.kernels for m in served.values()):
            # the backend's kernel choice overrides the models': one sweep
            # axis flips every served model between xla and Pallas
            served = {name: dataclasses.replace(
                          m, cfg=m.cfg.with_(kernels=self.kernels))
                      for name, m in served.items()}
        return served

    def _make_executor(self, served: Mapping[str, "ServedModel"]):
        from ..serving.executor import JaxModelExecutor  # lazy: needs jax
        return JaxModelExecutor(dict(served))

    def build(self, exp: "Experiment", spec: "WorkloadSpec") -> "WorkloadSpec":
        served = self._resolve_served(spec)
        key = served_model_key(served)
        if self.executor is None or self._calibrated_key != key:
            self.executor = self._make_executor(served)
            self.fn_specs = self.executor.calibrate(mem_mb=self.mem_mb,
                                                    runs=self.calib_runs)
            self._calibrated_key = key
        # the batching executor has no per-invocation hook; its subclass
        # installs a native async submit in bind() instead
        self.execute = getattr(self.executor, "execute", None)
        return respec_workload(spec, self.fn_specs,
                               getattr(spec, "slacks", None))

    def counters(self) -> Dict[str, int]:
        n = self.executor.n_executions if self.executor is not None else 0
        return {"n_executions": n}

    def data_plane(self) -> Dict[str, str]:
        return {"kernels": self.kernels, "batching": "none"}


@register_backend("jax-batched")
class BatchedJaxBackend(JaxBackend):
    """Hardware-in-the-loop with a *batched* data plane.

    Like ``jax``, but concurrently in-flight invocations of the same
    ``ServedModel`` coalesce (``BatchCoalescer``: ``batch_window`` sim
    seconds / ``max_batch`` size) into ONE padded batched execution —
    bucketed by powers of two, with per-bucket executables compiled at
    calibration time (``BatchingJaxExecutor``), so sweeps pay each compile
    once.  Every member of a batch completes after the batch's measured
    wall time: the hardware amortizes weight reads over the whole batch,
    which is the single biggest real-throughput lever on CPU/TPU serving.

    ``batch_window`` and ``max_batch`` are ordinary sweepable
    ``backend_kwargs``.  Calibration is cached on the content key
    (``served_model_key``); pass one instance across sweep cells to compile
    once.
    """

    def __init__(self, served: Optional[Mapping[str, "ServedModel"]] = None,
                 mem_mb: float = 512.0, calib_runs: int = 3,
                 batch_window: float = 0.005, max_batch: int = 8,
                 batching: str = "windowed", kernels: str = "xla"):
        super().__init__(served, mem_mb=mem_mb, calib_runs=calib_runs,
                         kernels=kernels)
        if batching not in BATCHING_CHOICES:
            raise ValueError(f"batching must be one of {BATCHING_CHOICES}, "
                             f"got {batching!r}")
        self.batch_window = batch_window
        self.max_batch = max_batch
        self.batching = batching
        self._coalescer: Optional[BatchCoalescer] = None
        self._batcher: Optional[ContinuousBatcher] = None

    def _make_executor(self, served: Mapping[str, "ServedModel"]):
        if self.batching == "continuous":
            from ..serving.executor import ContinuousJaxExecutor  # lazy: jax
            return ContinuousJaxExecutor(dict(served),
                                         max_batch=self.max_batch)
        from ..serving.executor import BatchingJaxExecutor  # lazy: needs jax
        return BatchingJaxExecutor(dict(served), max_batch=self.max_batch)

    def bind(self, env: "Env") -> None:
        self.env = env
        if self.batching == "continuous":
            ex = self.executor
            self._batcher = ContinuousBatcher(
                env, ex.admit, ex.step, ex.gen_steps,
                max_batch=self.max_batch,
                release=getattr(ex, "release_slots", None))
            self.submit = self._batcher.submit
            self._coalescer = None
            return
        self._coalescer = BatchCoalescer(env, self.executor.run_batch,
                                         batch_window=self.batch_window,
                                         max_batch=self.max_batch)
        self.submit = self._coalescer.submit
        self._batcher = None

    def drop_invocations(self, inv_ids: List[int]) -> None:
        """Dead-member release (core.fault worker crash): purge the crashed
        worker's in-flight members from whichever data plane is bound.  For
        the continuous plane the freed cache slots are scrubbed in the
        executor's slot slab via ``release_slots``."""
        if self._batcher is not None:
            self._batcher.drop(inv_ids)
        elif self._coalescer is not None:
            self._coalescer.drop(inv_ids)

    def counters(self) -> Dict[str, int]:
        c = dict(super().counters())
        if self._coalescer is not None:
            c.update(self._coalescer.counters())
        if self._batcher is not None:
            c.update(self._batcher.counters())
        return c

    def data_plane(self) -> Dict[str, str]:
        return {"kernels": self.kernels, "batching": self.batching}
