"""Baseline scheduling stacks the paper compares against (§2.4, §7.1).

* ``CentralizedFIFO`` — the paper's main baseline: one global scheduler,
  FIFO request order, *reactive* sandbox allocation, fixed keep-alive
  (15 min) eviction.  Mirrors OpenWhisk-style platforms [3].
* ``SparrowScheduler`` — parallel global scheduling with power-of-two random
  probing [41] (Fig. 2d): per-worker FIFO queues, no sandbox awareness.
"""
from __future__ import annotations

import heapq
import random
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

from .sandbox import Worker
from .sgs import Env, _slowed_done
from .types import (DagSpec, ExecuteFn, Invocation, Request, Sandbox,
                    SandboxState, SubmitFn)


class CentralizedFIFO:
    """One cluster-wide FIFO queue; reactive sandboxes with keep-alive."""

    def __init__(self, workers: List[Worker], env: Env,
                 keepalive: float = 900.0,
                 execute: Optional[ExecuteFn] = None,
                 backend_submit: Optional[SubmitFn] = None):
        self.workers = workers
        self.env = env
        self.keepalive = keepalive
        # async execution seam (core.backends); falls back to the legacy
        # synchronous `execute` hook, then to modeled timing
        self.backend_submit = backend_submit
        self.execute = execute
        self._queue: Deque[Invocation] = deque()
        self._completed_fns: Dict[int, set] = {}
        # fault tolerance (§6.1): in-flight registrations + failed-worker
        # view, same shape as SemiGlobalScheduler (worker_id -> {inv_id ->
        # Invocation}); completions validate against it so a worker crash
        # never fires stale state mutations (core.fault.fail_worker)
        self._inflight: Dict[int, Dict[int, Invocation]] = {}
        self._dead_workers: set = set()
        # gray-failure state (core.fault): per-worker slow-down multipliers
        # + the batching data plane's dead-member release hook
        self._slow: Dict[int, float] = {}
        self.backend_drop: Optional[Callable[[List[int]], None]] = None
        self.n_cold_starts = 0
        self.n_warm_hits = 0
        self.queuing_delays: List[float] = []
        self.queuing_delay_times: List[float] = []   # dispatch timestamps
        self.completed_requests: List[Request] = []
        # flat-metrics completion hook (see SemiGlobalScheduler.on_complete)
        self.on_complete: Optional[Callable[[Request, float], None]] = None

    # -- intake ---------------------------------------------------------------
    def submit_request(self, req: Request) -> None:
        now = self.env.now()
        self._completed_fns[req.req_id] = set()
        for root in req.dag.roots():
            self._queue.append(Invocation(request=req, fn=req.dag.fn(root),
                                          ready_time=now))
        self._dispatch()

    # -- dispatch ---------------------------------------------------------------
    def _dispatch(self) -> None:
        now = self.env.now()
        while self._queue:
            inv = self._queue[0]
            w, sbx = self._choose_worker(inv, now)
            if w is None:
                return          # head-of-line blocking: strict FIFO
            self._queue.popleft()
            self._start(inv, w, sbx, now)

    def _choose_worker(self, inv: Invocation, now: float
                       ) -> Tuple[Optional[Worker], Optional[Sandbox]]:
        cold: Optional[Worker] = None
        for w in self.workers:
            if w.free_cores <= 0:
                continue
            s = w.warm_available(inv.fn.name, now)
            if s is not None:
                return w, s
            if cold is None:
                cold = w
        return cold, None

    def _start(self, inv: Invocation, w: Worker, sbx: Optional[Sandbox],
               now: float) -> None:
        inv.start_time = now
        qd = now - inv.ready_time
        self.queuing_delays.append(qd)
        self.queuing_delay_times.append(now)
        inv.request.total_queuing_delay += qd
        w.busy_cores += 1
        setup = 0.0
        if sbx is None:
            inv.cold_start = True
            inv.request.n_cold_starts += 1
            self.n_cold_starts += 1
            setup = inv.fn.setup_time
            self._make_room(w, inv.fn.mem_mb, now)
            sbx = Sandbox(fn=inv.fn, worker_id=w.worker_id,
                          state=SandboxState.BUSY,
                          ready_at=now + setup, last_used=now)
            w.add_sandbox(sbx)
        else:
            self.n_warm_hits += 1
            sbx.state = SandboxState.BUSY
            sbx.last_used = now
        inflight = self._inflight.get(w.worker_id)
        if inflight is None:
            inflight = self._inflight[w.worker_id] = {}
        inflight[inv.inv_id] = inv
        slow = self._slow
        m = slow.get(w.worker_id) if slow else None
        if self.backend_submit is not None:
            # async seam: dispatch returns immediately; the backend fires
            # the completion callback (possibly after batching)
            def done(exec_s: float, inv=inv, w=w, sbx=sbx) -> None:
                self._complete(inv, w, sbx)
            self.backend_submit(inv, done if m is None
                                else _slowed_done(self.env, done, m), setup)
            return
        exec_s = inv.fn.exec_time if self.execute is None \
            else self.execute(inv)
        if m is not None:
            exec_s *= m
        self.env.call_after(setup + exec_s, self._complete, inv, w, sbx)

    def _make_room(self, w: Worker, mem_mb: float, now: float) -> None:
        """Keep-alive expiry first, then oldest-idle eviction if still full."""
        for s in w.sandboxes:
            if (s.state == SandboxState.WARM
                    and now - s.last_used > self.keepalive):
                w.remove_sandbox(s)
        while w.free_pool_mem < mem_mb:
            idle = [s for s in w.sandboxes if s.state == SandboxState.WARM]
            if not idle:
                return
            w.remove_sandbox(min(idle, key=lambda s: s.last_used))

    def _complete(self, inv: Invocation, w: Worker, sbx: Sandbox) -> None:
        # inflight-generation guard (see SemiGlobalScheduler._complete):
        # drops stale completions from dead workers / retried invocations
        inflight = self._inflight.get(w.worker_id)
        if inflight is None or inflight.pop(inv.inv_id, None) is None:
            return      # fail-stop: execution lost, the retry re-drives it
        now = self.env.now()
        w.busy_cores -= 1
        sbx.state = SandboxState.WARM
        sbx.ready_at = min(sbx.ready_at, now)
        sbx.last_used = now
        req = inv.request
        done = self._completed_fns[req.req_id]
        done.add(inv.fn.name)
        dag = req.dag
        if len(done) == len(dag.functions):
            req.completion_time = now
            rec = self.on_complete
            if rec is not None:
                rec(req, now)
            else:
                self.completed_requests.append(req)
            del self._completed_fns[req.req_id]
        else:
            for child in dag.children(inv.fn.name):
                if all(p in done for p in dag.parents(child)):
                    self._queue.append(Invocation(request=req,
                                                  fn=dag.fn(child),
                                                  ready_time=now))
        self._dispatch()


class SparrowScheduler:
    """Batch-sampling/power-of-two-choices decentralized scheduler [41].

    Each invocation probes ``probes`` random workers and joins the shortest
    per-worker FIFO queue.  Workers run their queues in order; sandbox reuse
    happens only by accident of placement (no sandbox awareness).
    """

    def __init__(self, workers: List[Worker], env: Env, probes: int = 2,
                 seed: int = 0, keepalive: float = 900.0,
                 execute: Optional[ExecuteFn] = None,
                 backend_submit: Optional[SubmitFn] = None):
        self.workers = workers
        self.env = env
        self.probes = probes
        self.keepalive = keepalive
        # async execution seam (core.backends); `execute` is the legacy
        # synchronous hook
        self.backend_submit = backend_submit
        self.execute = execute
        self._rng = random.Random(seed)
        self._wqueues: Dict[int, Deque[Invocation]] = {
            w.worker_id: deque() for w in workers}
        self._completed_fns: Dict[int, set] = {}
        # fault tolerance: see CentralizedFIFO (same registration shape)
        self._inflight: Dict[int, Dict[int, Invocation]] = {}
        self._dead_workers: set = set()
        self._slow: Dict[int, float] = {}
        self.backend_drop: Optional[Callable[[List[int]], None]] = None
        self.n_cold_starts = 0
        self.n_warm_hits = 0
        self.queuing_delays: List[float] = []
        self.queuing_delay_times: List[float] = []   # dispatch timestamps
        self.completed_requests: List[Request] = []
        # flat-metrics completion hook (see SemiGlobalScheduler.on_complete)
        self.on_complete: Optional[Callable[[Request, float], None]] = None

    def submit_request(self, req: Request) -> None:
        now = self.env.now()
        self._completed_fns[req.req_id] = set()
        for root in req.dag.roots():
            self._place(Invocation(request=req, fn=req.dag.fn(root),
                                   ready_time=now))

    def _place(self, inv: Invocation) -> None:
        cands = self._rng.sample(self.workers,
                                 min(self.probes, len(self.workers)))
        w = min(cands, key=lambda w: len(self._wqueues[w.worker_id])
                + w.busy_cores)
        self._wqueues[w.worker_id].append(inv)
        self._drain(w)

    def _drain(self, w: Worker) -> None:
        now = self.env.now()
        q = self._wqueues[w.worker_id]
        while q and w.free_cores > 0:
            inv = q.popleft()
            inv.start_time = now
            qd = now - inv.ready_time
            self.queuing_delays.append(qd)
            self.queuing_delay_times.append(now)
            inv.request.total_queuing_delay += qd
            w.busy_cores += 1
            sbx = w.warm_available(inv.fn.name, now)
            setup = 0.0
            if sbx is None:
                inv.cold_start = True
                inv.request.n_cold_starts += 1
                self.n_cold_starts += 1
                setup = inv.fn.setup_time
                sbx = Sandbox(fn=inv.fn, worker_id=w.worker_id,
                              state=SandboxState.BUSY,
                              ready_at=now + setup, last_used=now)
                w.add_sandbox(sbx)
            else:
                self.n_warm_hits += 1
                sbx.state = SandboxState.BUSY
            inflight = self._inflight.get(w.worker_id)
            if inflight is None:
                inflight = self._inflight[w.worker_id] = {}
            inflight[inv.inv_id] = inv
            slow = self._slow
            m = slow.get(w.worker_id) if slow else None
            if self.backend_submit is not None:
                def done(exec_s: float, inv=inv, w=w, sbx=sbx) -> None:
                    self._complete(inv, w, sbx)
                self.backend_submit(inv, done if m is None
                                    else _slowed_done(self.env, done, m),
                                    setup)
                continue
            exec_s = inv.fn.exec_time if self.execute is None \
                else self.execute(inv)
            if m is not None:
                exec_s *= m
            self.env.call_after(setup + exec_s, self._complete, inv, w, sbx)

    def _complete(self, inv: Invocation, w: Worker, sbx: Sandbox) -> None:
        # inflight-generation guard (see SemiGlobalScheduler._complete)
        inflight = self._inflight.get(w.worker_id)
        if inflight is None or inflight.pop(inv.inv_id, None) is None:
            return      # fail-stop: execution lost, the retry re-drives it
        now = self.env.now()
        w.busy_cores -= 1
        sbx.state = SandboxState.WARM
        sbx.ready_at = min(sbx.ready_at, now)
        sbx.last_used = now
        req = inv.request
        done = self._completed_fns[req.req_id]
        done.add(inv.fn.name)
        dag = req.dag
        if len(done) == len(dag.functions):
            req.completion_time = now
            rec = self.on_complete
            if rec is not None:
                rec(req, now)
            else:
                self.completed_requests.append(req)
            del self._completed_fns[req.req_id]
        else:
            for child in dag.children(inv.fn.name):
                if all(p in done for p in dag.parents(child)):
                    self._place(Invocation(request=req, fn=dag.fn(child),
                                           ready_time=now))
        self._drain(w)
