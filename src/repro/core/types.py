"""Core datatypes shared by the scheduler, load balancer and executors.

Time is measured in float seconds.  All components are *time-agnostic*: they
never read a wall clock; ``now`` is always passed in explicitly so that the
same code runs under the discrete-event simulator (``repro.sim``) and the
real-execution serving engine (``repro.serving``).
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Function / DAG specifications (what the user uploads, §2.1 / §3)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FunctionSpec:
    """A single serverless function: one node of an application DAG."""

    name: str
    exec_time: float            # seconds of pure execution (paper's "execution time")
    mem_mb: float = 128.0       # provisioned memory (T4: 128MB is the common case)
    setup_time: float = 0.250   # sandbox setup overhead (125-400ms modeled, §7.1)

    def __post_init__(self):
        if self.exec_time <= 0:
            raise ValueError(f"exec_time must be positive, got {self.exec_time}")
        if self.mem_mb <= 0:
            raise ValueError(f"mem_mb must be positive, got {self.mem_mb}")


@dataclass(frozen=True)
class DagSpec:
    """An application: a DAG of functions plus a latency deadline.

    ``deadline`` is the user-specified maximum end-to-end execution time for
    one request of this DAG (critical-path exec time + slack), per §3
    "Initial DAG Upload".
    """

    dag_id: str
    functions: Tuple[FunctionSpec, ...]
    # edges are (upstream_name, downstream_name) I/O dependencies
    edges: Tuple[Tuple[str, str], ...] = ()
    deadline: float = 1.0

    def __post_init__(self):
        names = [f.name for f in self.functions]
        if len(set(names)) != len(names):
            raise ValueError("duplicate function names in DAG")
        known = set(names)
        for u, v in self.edges:
            if u not in known or v not in known:
                raise ValueError(f"edge ({u},{v}) references unknown function")
        # Precompute the adjacency/critical-path views once: fn/parents/
        # children/remaining_critical_path sit on the per-invocation hot path
        # (SRSF priority keys, DAG-progress release), and a frozen spec never
        # changes.  ``object.__setattr__`` because the dataclass is frozen.
        fn_map = {f.name: f for f in self.functions}
        parents: Dict[str, List[str]] = {n: [] for n in fn_map}
        children: Dict[str, List[str]] = {n: [] for n in fn_map}
        for u, v in self.edges:
            parents[v].append(u)
            children[u].append(v)
        object.__setattr__(self, "_fn_map", fn_map)
        object.__setattr__(self, "_n_fns", len(self.functions))
        object.__setattr__(self, "_parents", parents)
        object.__setattr__(self, "_children", children)
        object.__setattr__(self, "_roots",
                           [n for n in fn_map if not parents[n]])
        # topological order; raises on cycles
        indeg = {n: len(parents[n]) for n in fn_map}
        frontier = [n for n, d in indeg.items() if d == 0]
        order: List[str] = []
        while frontier:
            n = frontier.pop()
            order.append(n)
            for c in children[n]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    frontier.append(c)
        if len(order) != len(self.functions):
            raise ValueError("DAG contains a cycle")
        object.__setattr__(self, "_topo", order)
        # remaining critical path per node (Kelley [32,33]), leaves-first
        rcp: Dict[str, float] = {}
        for n in reversed(order):
            tail = max((rcp[k] for k in children[n]), default=0.0)
            rcp[n] = fn_map[n].exec_time + tail
        object.__setattr__(self, "_rcp", rcp)
        object.__setattr__(self, "_cp_time",
                           max((rcp[r] for r in self._roots), default=0.0))

    # -- graph helpers (all O(1) dict lookups on the cached views) ----------
    def fn(self, name: str) -> FunctionSpec:
        try:
            return self._fn_map[name]
        except KeyError:
            raise KeyError(name) from None

    def parents(self, name: str) -> List[str]:
        return self._parents[name]

    def children(self, name: str) -> List[str]:
        return self._children[name]

    def roots(self) -> List[str]:
        return self._roots

    def topo_order(self) -> List[str]:
        return list(self._topo)

    def critical_path_time(self) -> float:
        """Critical-path execution time of the whole DAG (Kelley [32,33])."""
        return self._cp_time

    def remaining_critical_path(self, name: str) -> float:
        """Critical-path exec time of the DAG suffix rooted at ``name``
        (inclusive).  Used for remaining-slack computation (§4.2)."""
        return self._rcp[name]

    @property
    def slack(self) -> float:
        """Total slack the user granted on top of the critical path."""
        return self.deadline - self._cp_time

    def with_deadline(self, deadline: Optional[float] = None, *,
                      slack: Optional[float] = None) -> "DagSpec":
        """Copy with a new deadline — absolute (``deadline=``) or derived
        from the cached critical path (``slack=`` sets it to
        ``critical_path_time() + slack``).  This is how calibrated serving
        DAGs get their measured deadlines without hand-rolling a second
        construction pass."""
        if (deadline is None) == (slack is None):
            raise ValueError("pass exactly one of deadline= or slack=")
        if slack is not None:
            deadline = self._cp_time + slack
        return dataclasses.replace(self, deadline=deadline)


# ---------------------------------------------------------------------------
# Requests and function invocations (runtime objects)
# ---------------------------------------------------------------------------

_req_counter = itertools.count()
_inv_counter = itertools.count()


@dataclass(slots=True, eq=False)
class Request:
    """One trigger event for a DAG.  Identity-compared (``eq=False``):
    requests are unique runtime objects, and membership tests sit on the
    completion hot path."""

    dag: DagSpec
    arrival_time: float
    req_id: int = field(default_factory=_req_counter.__next__)
    completion_time: Optional[float] = None
    # bookkeeping
    n_cold_starts: int = 0
    total_queuing_delay: float = 0.0
    sgs_id: Optional[int] = None   # which SGS served it (set by LBS routing)
    # row index in the run's flat metrics columns (``repro.sim.metrics``);
    # -1 outside column-recording runs
    m_idx: int = -1
    # DAG-progress state owned by the serving scheduler (the set of
    # completed function names; a shared sentinel for single-function DAGs;
    # None once the request finished or before it was accepted) — carried on
    # the request so the completion hot path pays an attribute load instead
    # of a per-request dict entry
    fns_done: Optional[object] = None

    @property
    def abs_deadline(self) -> float:
        return self.arrival_time + self.dag.deadline

    @property
    def e2e_latency(self) -> Optional[float]:
        if self.completion_time is None:
            return None
        return self.completion_time - self.arrival_time

    @property
    def deadline_met(self) -> Optional[bool]:
        if self.completion_time is None:
            return None
        return self.completion_time <= self.abs_deadline + 1e-9


@dataclass(slots=True, eq=False)
class Invocation:
    """One function execution belonging to a request (a DAG node instance).
    Identity-compared, like ``Request``."""

    request: Request
    fn: FunctionSpec
    ready_time: float                       # when dependencies were met
    inv_id: int = field(default_factory=_inv_counter.__next__)
    start_time: Optional[float] = None
    cold_start: bool = False

    # -- deadline-aware priority (§4.2) --------------------------------------
    def remaining_critical_path(self) -> float:
        return self.request.dag.remaining_critical_path(self.fn.name)

    def remaining_slack(self, now: float) -> float:
        """Time this invocation can still be queued without pushing the DAG
        past its deadline, assuming the remaining suffix runs back-to-back."""
        return (self.request.abs_deadline - now) - self.remaining_critical_path()

    def priority_key(self) -> Tuple[float, float, int]:
        """Static SRSF key: at any common ``now``, ordering by
        ``abs_deadline - remaining_cp`` is identical to ordering by remaining
        slack; ties broken by least remaining work (paper §4.2), then FIFO."""
        rcp = self.remaining_critical_path()
        return (self.request.abs_deadline - rcp, rcp, self.inv_id)


class SandboxState(enum.Enum):
    ALLOCATING = "allocating"       # being set up (setup_time in flight)
    WARM = "warm"                   # ready for reuse, idle
    BUSY = "busy"                   # currently executing an invocation
    SOFT_EVICTED = "soft_evicted"   # resident but not schedulable (§4.3.3)


_sbx_counter = itertools.count()


class Sandbox:
    """A (possibly idle) execution environment resident on one worker.

    ``state`` is a property: assigning it keeps the owning worker's
    per-``(fn, state)`` indices in sync (see ``sandbox.Worker``), so all
    existing call sites — and tests — can keep mutating ``sbx.state``
    directly while queries stay O(1).
    """

    __slots__ = ("fn", "worker_id", "_state", "ready_at", "last_used",
                 "sbx_id", "_worker")

    def __init__(self, fn: FunctionSpec, worker_id: int, state: SandboxState,
                 ready_at: float = 0.0, last_used: float = 0.0):
        self.fn = fn
        self.worker_id = worker_id
        self._state = state
        self.ready_at = ready_at                # when ALLOCATING finishes
        self.last_used = last_used
        self.sbx_id = next(_sbx_counter)
        self._worker = None                     # set by Worker.add_sandbox

    @property
    def state(self) -> SandboxState:
        return self._state

    @state.setter
    def state(self, new: SandboxState) -> None:
        old = self._state
        if new is old:
            return
        self._state = new
        if self._worker is not None:
            self._worker._reindex(self, old, new)

    def __repr__(self) -> str:
        return (f"Sandbox(fn={self.fn.name!r}, worker_id={self.worker_id}, "
                f"state={self._state}, ready_at={self.ready_at}, "
                f"last_used={self.last_used}, sbx_id={self.sbx_id})")


# Callback the scheduler uses to run a function.  Returns actual runtime (s).
# Simulated executors return fn.exec_time (+ jitter); the real executor runs a
# jitted JAX call and returns measured wall time.
#
# This is the *legacy synchronous* data-plane hook: the scheduler blocks on
# it inside its dispatch path, so a real backend can only run one invocation
# at a time.  New backends implement the asynchronous ``SubmitFn`` seam
# below; ``ExecuteFn`` hooks are adapted automatically
# (``core.backends.ExecutionBackend.bind``).
ExecuteFn = Callable[[Invocation], float]

# Completion callback, provided by the scheduler per dispatched invocation.
# The backend invokes ``done(exec_seconds)`` *at the sim instant the
# invocation finishes* (i.e. via ``env.call_after``, never synchronously from
# inside ``submit``); ``exec_seconds`` is the execution time that was charged
# (measured wall seconds for real backends).
DoneFn = Callable[[float], None]

# Asynchronous execution seam: ``submit(inv, done, delay)`` hands an
# invocation to the data plane and returns immediately — the scheduler's
# control loop (queue pops, proactive allocation, scaling ticks) keeps
# running while the backend executes, possibly coalescing concurrently
# in-flight invocations into batches.  ``delay`` is scheduler-side time that
# must elapse before execution can begin (cold-start sandbox setup): the
# backend fires ``done(exec_s)`` at ``now + delay + exec_s``.
SubmitFn = Callable[[Invocation, DoneFn, float], None]
