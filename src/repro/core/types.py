"""Core datatypes shared by the scheduler, load balancer and executors.

Time is measured in float seconds.  All components are *time-agnostic*: they
never read a wall clock; ``now`` is always passed in explicitly so that the
same code runs under the discrete-event simulator (``repro.sim``) and the
real-execution serving engine (``repro.serving``).
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Function / DAG specifications (what the user uploads, §2.1 / §3)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FunctionSpec:
    """A single serverless function: one node of an application DAG."""

    name: str
    exec_time: float            # seconds of pure execution (paper's "execution time")
    mem_mb: float = 128.0       # provisioned memory (T4: 128MB is the common case)
    setup_time: float = 0.250   # sandbox setup overhead (125-400ms modeled, §7.1)

    def __post_init__(self):
        if self.exec_time <= 0:
            raise ValueError(f"exec_time must be positive, got {self.exec_time}")
        if self.mem_mb <= 0:
            raise ValueError(f"mem_mb must be positive, got {self.mem_mb}")


@dataclass(frozen=True)
class DagSpec:
    """An application: a DAG of functions plus a latency deadline.

    ``deadline`` is the user-specified maximum end-to-end execution time for
    one request of this DAG (critical-path exec time + slack), per §3
    "Initial DAG Upload".
    """

    dag_id: str
    functions: Tuple[FunctionSpec, ...]
    # edges are (upstream_name, downstream_name) I/O dependencies
    edges: Tuple[Tuple[str, str], ...] = ()
    deadline: float = 1.0

    def __post_init__(self):
        names = [f.name for f in self.functions]
        if len(set(names)) != len(names):
            raise ValueError("duplicate function names in DAG")
        known = set(names)
        for u, v in self.edges:
            if u not in known or v not in known:
                raise ValueError(f"edge ({u},{v}) references unknown function")
        # reject cycles eagerly: topo_order raises on cycles
        self.topo_order()

    # -- graph helpers ------------------------------------------------------
    def fn(self, name: str) -> FunctionSpec:
        for f in self.functions:
            if f.name == name:
                return f
        raise KeyError(name)

    def parents(self, name: str) -> List[str]:
        return [u for (u, v) in self.edges if v == name]

    def children(self, name: str) -> List[str]:
        return [v for (u, v) in self.edges if u == name]

    def roots(self) -> List[str]:
        has_parent = {v for (_, v) in self.edges}
        return [f.name for f in self.functions if f.name not in has_parent]

    def topo_order(self) -> List[str]:
        indeg = {f.name: 0 for f in self.functions}
        for _, v in self.edges:
            indeg[v] += 1
        frontier = [n for n, d in indeg.items() if d == 0]
        order: List[str] = []
        while frontier:
            n = frontier.pop()
            order.append(n)
            for c in self.children(n):
                indeg[c] -= 1
                if indeg[c] == 0:
                    frontier.append(c)
        if len(order) != len(self.functions):
            raise ValueError("DAG contains a cycle")
        return order

    def critical_path_time(self) -> float:
        """Critical-path execution time of the whole DAG (Kelley [32,33])."""
        return max(self.remaining_critical_path(r) for r in self.roots())

    def remaining_critical_path(self, name: str) -> float:
        """Critical-path exec time of the DAG suffix rooted at ``name``
        (inclusive).  Used for remaining-slack computation (§4.2)."""
        memo: Dict[str, float] = {}

        def rec(n: str) -> float:
            if n in memo:
                return memo[n]
            kids = self.children(n)
            tail = max((rec(k) for k in kids), default=0.0)
            memo[n] = self.fn(n).exec_time + tail
            return memo[n]

        return rec(name)

    @property
    def slack(self) -> float:
        """Total slack the user granted on top of the critical path."""
        return self.deadline - self.critical_path_time()


# ---------------------------------------------------------------------------
# Requests and function invocations (runtime objects)
# ---------------------------------------------------------------------------

_req_counter = itertools.count()
_inv_counter = itertools.count()


@dataclass
class Request:
    """One trigger event for a DAG."""

    dag: DagSpec
    arrival_time: float
    req_id: int = field(default_factory=lambda: next(_req_counter))
    completion_time: Optional[float] = None
    # bookkeeping
    n_cold_starts: int = 0
    total_queuing_delay: float = 0.0
    sgs_id: Optional[int] = None   # which SGS served it (set by LBS routing)

    @property
    def abs_deadline(self) -> float:
        return self.arrival_time + self.dag.deadline

    @property
    def e2e_latency(self) -> Optional[float]:
        if self.completion_time is None:
            return None
        return self.completion_time - self.arrival_time

    @property
    def deadline_met(self) -> Optional[bool]:
        if self.completion_time is None:
            return None
        return self.completion_time <= self.abs_deadline + 1e-9


@dataclass
class Invocation:
    """One function execution belonging to a request (a DAG node instance)."""

    request: Request
    fn: FunctionSpec
    ready_time: float                       # when dependencies were met
    inv_id: int = field(default_factory=lambda: next(_inv_counter))
    start_time: Optional[float] = None
    cold_start: bool = False

    # -- deadline-aware priority (§4.2) --------------------------------------
    def remaining_critical_path(self) -> float:
        return self.request.dag.remaining_critical_path(self.fn.name)

    def remaining_slack(self, now: float) -> float:
        """Time this invocation can still be queued without pushing the DAG
        past its deadline, assuming the remaining suffix runs back-to-back."""
        return (self.request.abs_deadline - now) - self.remaining_critical_path()

    def priority_key(self) -> Tuple[float, float, int]:
        """Static SRSF key: at any common ``now``, ordering by
        ``abs_deadline - remaining_cp`` is identical to ordering by remaining
        slack; ties broken by least remaining work (paper §4.2), then FIFO."""
        rcp = self.remaining_critical_path()
        return (self.request.abs_deadline - rcp, rcp, self.inv_id)


class SandboxState(enum.Enum):
    ALLOCATING = "allocating"       # being set up (setup_time in flight)
    WARM = "warm"                   # ready for reuse, idle
    BUSY = "busy"                   # currently executing an invocation
    SOFT_EVICTED = "soft_evicted"   # resident but not schedulable (§4.3.3)


_sbx_counter = itertools.count()


@dataclass
class Sandbox:
    fn: FunctionSpec
    worker_id: int
    state: SandboxState
    ready_at: float = 0.0           # when ALLOCATING finishes
    last_used: float = 0.0
    sbx_id: int = field(default_factory=lambda: next(_sbx_counter))


# Callback the scheduler uses to run a function.  Returns actual runtime (s).
# Simulated executors return fn.exec_time (+ jitter); the real executor runs a
# jitted JAX call and returns measured wall time.
ExecuteFn = Callable[[Invocation], float]
