"""Workers and proactive sandbox management (§4.3.2, §4.3.3, Pseudocode 1).

A worker owns a fixed number of execution slots ("cores" in the paper; HBM
instance slots for the TPU adaptation) and a *proactive memory pool* — the
admin-configured amount of memory usable for proactively allocated sandboxes.
Sandboxes are soft state: they can always be evicted without correctness
impact.

Hot-path data structures
------------------------
The paper's own argument (§2.4, §7.4) is that per-decision scheduling cost
bounds platform scale, so the simulator's decision loop must not be
asymptotically worse than the system it models.  Every query the scheduler
makes on the hot path is served from incrementally maintained indices:

* ``Worker`` keeps per-``(fn, state)`` buckets (sorted by ``sbx_id``, i.e.
  creation order, matching the legacy list-scan semantics exactly), an
  incremental ``used_pool_mem`` and per-state counts — ``find``, ``count``,
  ``warm_available`` and the memory properties are O(1) in the number of
  resident sandboxes.
* ``SandboxManager`` keeps per-function schedulable totals, per-function
  sets of workers holding idle (WARM/ALLOCATING) and soft-evicted sandboxes,
  and lazy min-heaps over ``(count, worker)`` keys so even/packed placement
  and soft-eviction victim selection are O(log W) amortized per decision
  instead of a full re-sort of the pool per allocated sandbox.

All index maintenance is driven by ``Sandbox.state`` assignment (a property
that notifies the owning worker) plus ``Worker.add_sandbox`` /
``Worker.remove_sandbox``, so scheduler code and tests keep their original
mutation style.  Decision order is bit-identical to the legacy scan code
(certified by ``tests/test_equivalence.py`` against goldens from the
scan-based reference; see ``benchmarks/equivalence_fingerprint.py``).
"""
from __future__ import annotations

import heapq
from bisect import insort
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

from .types import FunctionSpec, Sandbox, SandboxState

_ALLOC = SandboxState.ALLOCATING
_WARM = SandboxState.WARM
_BUSY = SandboxState.BUSY
_SOFT = SandboxState.SOFT_EVICTED


def _sbx_sort_key(s: "Sandbox") -> int:
    return s.sbx_id


class _FnBucket:
    """Per-(worker, function) sandbox lists by state, each sorted by sbx_id
    (creation order).  Plain attributes instead of an enum-keyed dict: state
    bucketing is the single hottest lookup in the simulator and enum hashing
    dominates it.  BUSY sandboxes are only ever *counted* on hot paths (the
    scheduler never picks one), so they are tracked as a bare counter and
    transitions in/out of BUSY skip all list maintenance."""

    __slots__ = ("alloc", "warm", "soft", "busy_n", "alloc_flag",
                 "evict_pushed")

    def __init__(self):
        self.alloc: List[Sandbox] = []
        self.warm: List[Sandbox] = []
        self.soft: List[Sandbox] = []
        self.busy_n = 0
        # manager-index bookkeeping (see _FnIndex): whether this bucket is
        # counted in the per-function "has ALLOCATING sandboxes" total, and
        # the schedulable count of the live eviction-heap entry (-1: none) —
        # dedupes the one-entry-per-completion heap churn
        self.alloc_flag = False
        self.evict_pushed = -1

    def list_for(self, state: SandboxState) -> Optional[List[Sandbox]]:
        """The sorted list for a state; None for BUSY (counter-only)."""
        if state is _WARM:
            return self.warm
        if state is _ALLOC:
            return self.alloc
        if state is _SOFT:
            return self.soft
        return None


class Worker:
    """One machine of an SGS's worker pool, with O(1) sandbox queries."""

    __slots__ = ("worker_id", "cores", "pool_mem_mb", "busy_cores",
                 "_sandboxes", "_buckets", "_used_pool_mem", "_n_busy",
                 "owner", "pool_index")

    def __init__(self, worker_id: int, cores: int = 4,
                 pool_mem_mb: float = 4096.0, busy_cores: int = 0):
        self.worker_id = worker_id
        self.cores = cores
        self.pool_mem_mb = pool_mem_mb          # proactive pool capacity
        self.busy_cores = busy_cores
        # sbx_id -> Sandbox; insertion order == sbx_id order (creation order)
        self._sandboxes: Dict[int, Sandbox] = {}
        # fn name -> per-state sandbox lists
        self._buckets: Dict[str, _FnBucket] = {}
        self._used_pool_mem = 0.0
        self._n_busy = 0                        # BUSY *sandboxes* (not cores)
        self.owner = None                       # set by SandboxManager
        self.pool_index = worker_id             # position in the owner's pool

    def __repr__(self) -> str:
        return (f"Worker(worker_id={self.worker_id}, cores={self.cores}, "
                f"pool_mem_mb={self.pool_mem_mb}, "
                f"busy_cores={self.busy_cores}, "
                f"n_sandboxes={len(self._sandboxes)})")

    # -- membership -----------------------------------------------------------
    @property
    def sandboxes(self) -> List[Sandbox]:
        """Resident sandboxes in creation order (a fresh list; mutate the
        worker via ``add_sandbox``/``remove_sandbox``, never this list)."""
        return list(self._sandboxes.values())

    def add_sandbox(self, sbx: Sandbox) -> None:
        self._sandboxes[sbx.sbx_id] = sbx
        sbx._worker = self
        name = sbx.fn.name
        state = sbx.state
        bucket = self._buckets.get(name)
        if bucket is None:
            bucket = self._buckets[name] = _FnBucket()
        if state is _BUSY:
            bucket.busy_n += 1
            self._n_busy += 1
        else:
            # a brand-new sandbox always has the largest sbx_id: append keeps
            # the bucket sorted
            bucket.list_for(state).append(sbx)
        self._used_pool_mem += sbx.fn.mem_mb
        if self.owner is not None:
            self.owner._note(self, name, 0 if state is _SOFT else 1, False,
                             state is not _BUSY, state is _SOFT)

    def remove_sandbox(self, sbx: Sandbox) -> None:
        del self._sandboxes[sbx.sbx_id]
        name = sbx.fn.name
        state = sbx.state
        bucket = self._buckets[name]
        if state is _BUSY:
            bucket.busy_n -= 1
            self._n_busy -= 1
        else:
            bucket.list_for(state).remove(sbx)
        self._used_pool_mem -= sbx.fn.mem_mb
        sbx._worker = None
        if self.owner is not None:
            self.owner._note(self, name, 0 if state is _SOFT else -1, False,
                             state is not _BUSY, state is _SOFT)

    def _reindex(self, sbx: Sandbox, old: SandboxState,
                 new: SandboxState) -> None:
        """Called by the ``Sandbox.state`` setter: move between buckets."""
        bucket = self._buckets[sbx.fn.name]
        lst = bucket.list_for(old)
        if lst is None:
            bucket.busy_n -= 1
            self._n_busy -= 1
        else:
            lst.remove(sbx)
        lst = bucket.list_for(new)
        if lst is None:
            bucket.busy_n += 1
            self._n_busy += 1
        else:
            insort(lst, sbx, key=_sbx_sort_key)
        if self.owner is not None:
            delta = ((0 if new is _SOFT else 1)
                     - (0 if old is _SOFT else 1))
            soft_touched = old is _SOFT or new is _SOFT
            self.owner._note(
                self, sbx.fn.name, delta, old is _BUSY,
                old is _BUSY or new is _BUSY or soft_touched, soft_touched)

    # -- memory ---------------------------------------------------------------
    @property
    def used_pool_mem(self) -> float:
        return self._used_pool_mem

    @property
    def free_pool_mem(self) -> float:
        return self.pool_mem_mb - self._used_pool_mem

    def shed_to_capacity(self) -> int:
        """Evict resident non-BUSY sandboxes (creation order — oldest
        first) until used pool memory fits ``pool_mem_mb`` again.  The
        eviction path for a ``memory_pressure`` gray failure after the
        fault handler shrinks ``pool_mem_mb``: BUSY sandboxes are never
        touched, so a worker can stay over budget until executions finish.
        Returns the number of evicted sandboxes."""
        n = 0
        if self._used_pool_mem <= self.pool_mem_mb:
            return n
        for s in self.sandboxes:        # fresh list: safe to remove during
            if self._used_pool_mem <= self.pool_mem_mb:
                break
            if s.state is _BUSY:
                continue
            self.remove_sandbox(s)
            n += 1
        return n

    @property
    def free_cores(self) -> int:
        return self.cores - self.busy_cores

    # -- sandbox queries ------------------------------------------------------
    def bucket_len(self, fn_name: str, state: SandboxState) -> int:
        b = self._buckets.get(fn_name)
        if b is None:
            return 0
        lst = b.list_for(state)
        return b.busy_n if lst is None else len(lst)

    def count(self, fn_name: str, *states: SandboxState) -> int:
        states = states or tuple(SandboxState)
        return sum(self.bucket_len(fn_name, st) for st in states)

    def schedulable_count(self, fn_name: str) -> int:
        """Sandboxes counted for placement decisions: everything except
        soft-evicted (those are invisible to the scheduler, §4.3.3)."""
        b = self._buckets.get(fn_name)
        if b is None:
            return 0
        return len(b.alloc) + len(b.warm) + b.busy_n

    def idle_count(self, fn_name: str) -> int:
        """WARM + ALLOCATING (schedulable and not executing)."""
        b = self._buckets.get(fn_name)
        if b is None:
            return 0
        return len(b.alloc) + len(b.warm)

    def has_non_busy_sandbox(self) -> bool:
        return len(self._sandboxes) > self._n_busy

    def find(self, fn_name: str, state: SandboxState) -> Optional[Sandbox]:
        """Earliest-created resident sandbox of ``fn_name`` in ``state``.
        (BUSY sandboxes are tracked as a counter; finding one falls back to
        the ordered residency map — a cold path the scheduler never takes.)"""
        b = self._buckets.get(fn_name)
        if b is None:
            return None
        lst = b.list_for(state)
        if lst is None:
            for s in self._sandboxes.values():
                if s.fn.name == fn_name and s.state is _BUSY:
                    return s
            return None
        return lst[0] if lst else None

    def has_ready_soft(self, fn_name: str, now: float) -> bool:
        b = self._buckets.get(fn_name)
        if b is None:
            return False
        for s in b.soft:
            if s.ready_at <= now:
                return True
        return False

    def warm_available(self, fn_name: str, now: float) -> Optional[Sandbox]:
        """A sandbox ready for immediate reuse: the earliest-created WARM or
        ALLOCATING sandbox whose setup has finished.  An ALLOCATING sandbox
        transitions to WARM lazily here (legacy scan semantics: only the
        returned sandbox is promoted)."""
        b = self._buckets.get(fn_name)
        if b is None:
            return None
        cutoff = now + 1e-12
        best: Optional[Sandbox] = None
        for s in b.alloc:
            if s.ready_at <= cutoff:
                best = s
                break
        for s in b.warm:
            if s.ready_at <= cutoff:
                if best is None or s.sbx_id < best.sbx_id:
                    best = s
                break
        if best is not None and best.state is _ALLOC:
            best.state = _WARM
        return best


AllocHook = Callable[[Sandbox, Worker], None]


def _pool_key(w: Worker) -> int:
    return w.pool_index


_EMPTY: List[Worker] = []


class _FnIndex:
    """Per-function manager-level indices: schedulable total, worker sets by
    residency kind, and the lazy placement/eviction/warm-candidate heaps."""

    __slots__ = ("total", "idle", "soft", "place_heap", "evict_heap",
                 "idle_sorted", "warm_heap", "n_alloc")

    def __init__(self):
        self.total = 0                      # schedulable sandboxes, all workers
        self.idle: Set[Worker] = set()      # workers with WARM/ALLOCATING
        self.soft: Set[Worker] = set()      # workers with SOFT_EVICTED
        self.place_heap: List[Tuple[int, int]] = []
        self.evict_heap: List[Tuple[int, int]] = []
        # ``idle`` in pool order as (pool_index, worker, bucket) triples,
        # maintained incrementally on membership change (insort/remove,
        # small lists) — the dispatcher walks this on every decision, and
        # both the re-sort per walk and the per-probe bucket lookup it
        # replaces dominated the hot path
        self.idle_sorted: List[Tuple[int, "Worker", _FnBucket]] = []
        # lazy max-heap of (-warm_count, pool_index, worker, bucket): the
        # dispatcher's most-warm-copies pick in O(log W) amortized instead
        # of a full walk.  Only consulted when ``n_alloc`` is 0 — with an
        # ALLOCATING sandbox anywhere, the walk's lazy ALLOC->WARM
        # promotions are observable side effects and the legacy full probe
        # order must run.  Entries are pushed on every warm-count change
        # and validated (count + ownership) at pop.
        self.warm_heap: List[Tuple[int, int, "Worker", _FnBucket]] = []
        self.n_alloc = 0        # workers with a non-empty ALLOCATING bucket


@dataclass
class SandboxManager:
    """Implements Pseudocode 1: even placement, soft eviction, fair hard
    eviction — over one SGS's worker pool.

    ``set_demand`` *reconciles* the actual schedulable allocation against the
    estimator's target each tick (rather than diffing successive estimates):
    this self-heals after hard evictions and reactive cold-start allocations
    change the real count behind the estimator's back.

    Placement and soft-eviction consult lazily invalidated heaps of
    ``(schedulable_count, worker_id)`` keys: every count change pushes a
    fresh entry; stale entries are discarded at pop.  The pop order equals
    the legacy per-sandbox full re-sort of the pool, at O(log W) amortized.
    """

    workers: List[Worker]
    # "even" spreads each function's sandboxes across workers (§4.3.2);
    # "packed" fills one worker before the next (the Fig. 9 ablation).
    placement: str = "even"
    # "fair" = workload-aware victim choice (§4.3.3); "lru" = plain LRU
    # (the §7.3.1 eviction ablation).
    eviction: str = "fair"
    # called when a brand-new sandbox begins allocation (lets the executor
    # model / perform the actual setup work in the background)
    on_allocate: Optional[AllocHook] = None
    # demand targets last pushed by the SGS: fn name -> sandbox count
    demand_map: Dict[str, int] = field(default_factory=dict)
    fn_specs: Dict[str, FunctionSpec] = field(default_factory=dict)
    # counters
    n_hard_evictions: int = 0
    n_soft_evictions: int = 0
    n_allocations: int = 0
    n_revivals: int = 0

    # -- indices (all incremental; see class docstring) ----------------------
    _by_id: Dict[int, Worker] = field(
        default_factory=dict, init=False, repr=False)
    _fns: Dict[str, "_FnIndex"] = field(
        default_factory=dict, init=False, repr=False)

    def __post_init__(self) -> None:
        for i, w in enumerate(self.workers):
            w.owner = self
            w.pool_index = i
            self._by_id[w.worker_id] = w
        # lazy-heap growth bound, computed once (decision-neutral: it only
        # gates when compaction rebuilds a heap; worker removal leaves it
        # conservatively large)
        self.heap_cap = 64 + 8 * len(self.workers)

    # ---------------------------------------------------------- heap keying
    def _place_key(self, count: int, wid: int) -> Tuple[int, int]:
        # even: min count first; packed: max count first — ties by worker_id,
        # exactly the legacy ``sorted`` keys
        return (-count, wid) if self.placement == "packed" else (count, wid)

    def _evict_key(self, count: int, wid: int) -> Tuple[int, int]:
        # mirror image of placement (Pseudocode 1 lines 11-15)
        return (count, wid) if self.placement == "packed" else (-count, wid)

    def _ensure_fn(self, fn_name: str) -> "_FnIndex":
        fi = self._fns.get(fn_name)
        if fi is not None:
            return fi
        fi = _FnIndex()
        for w in self.workers:
            c = w.schedulable_count(fn_name)
            fi.total += c
            fi.place_heap.append(self._place_key(c, w.worker_id))
            fi.evict_heap.append(self._evict_key(c, w.worker_id))
            b = w._buckets.get(fn_name)
            if b is not None:
                b.evict_pushed = c
                b.alloc_flag = bool(b.alloc)
                if b.alloc_flag:
                    fi.n_alloc += 1
                if b.warm:
                    fi.warm_heap.append((-len(b.warm), w.pool_index, w, b))
            if w.idle_count(fn_name):
                fi.idle.add(w)
            if w.bucket_len(fn_name, _SOFT):
                fi.soft.add(w)
        fi.idle_sorted = sorted(
            (w.pool_index, w, w._buckets[fn_name]) for w in fi.idle)
        heapq.heapify(fi.place_heap)
        heapq.heapify(fi.evict_heap)
        heapq.heapify(fi.warm_heap)
        self._fns[fn_name] = fi
        return fi

    def _note(self, w: Worker, fn_name: str, sched_delta: int,
              gained_idle: bool = False, touched_idle: bool = True,
              touched_soft: bool = True) -> None:
        """Worker-event hook: a sandbox of ``fn_name`` on ``w`` was added,
        removed, or changed state.  Keeps totals, sets and heaps in sync.
        ``touched_idle``/``touched_soft`` let callers skip set maintenance
        for transitions that provably cannot change membership."""
        fi = self._fns.get(fn_name)
        if fi is None:
            # first event for this function: build everything from current
            # state (which already includes this event)
            self._ensure_fn(fn_name)
            return
        fi.total += sched_delta
        b = w._buckets[fn_name]         # exists: this event touched it
        if touched_idle:
            if b.alloc or b.warm:
                if w not in fi.idle:
                    fi.idle.add(w)
                    insort(fi.idle_sorted, (w.pool_index, w, b))
            elif w in fi.idle:
                fi.idle.remove(w)
                fi.idle_sorted.remove((w.pool_index, w, b))
        if touched_soft:
            if b.soft:
                fi.soft.add(w)
            else:
                fi.soft.discard(w)
        has_alloc = bool(b.alloc)
        if has_alloc != b.alloc_flag:
            b.alloc_flag = has_alloc
            fi.n_alloc += 1 if has_alloc else -1
        cap = self.heap_cap
        if b.warm:
            # keep a current-count warm-candidate entry live (lazy heap)
            heap = fi.warm_heap
            heapq.heappush(heap, (-len(b.warm), w.pool_index, w, b))
            if len(heap) > cap:
                self._compact_warm(fn_name, fi)
        if sched_delta or gained_idle:
            c = len(b.alloc) + len(b.warm) + b.busy_n
            wid = w.worker_id
            if sched_delta:
                # placement validity depends only on the count, so the place
                # heap needs no entry for pure BUSY->WARM candidacy changes
                heap = fi.place_heap
                heapq.heappush(heap, self._place_key(c, wid))
                if len(heap) > cap:     # bound lazy-entry growth
                    self._compact(fn_name, heap, self._place_key)
            if b.evict_pushed != c:     # dedupe: a live entry already covers c
                b.evict_pushed = c
                heap = fi.evict_heap
                heapq.heappush(heap, self._evict_key(c, wid))
                if len(heap) > cap:
                    self._compact(fn_name, heap, self._evict_key)

    def _compact(self, fn_name: str, heap: List[Tuple[int, int]],
                 keyer: Callable[[int, int], Tuple[int, int]]) -> None:
        """Rebuild a lazy heap from current counts (drops stale entries)."""
        heap[:] = [keyer(w.schedulable_count(fn_name), w.worker_id)
                   for w in self.workers]
        heapq.heapify(heap)

    def _compact_warm(self, fn_name: str, fi: "_FnIndex") -> None:
        """Rebuild the warm-candidate heap from current warm counts."""
        entries = []
        for w in self.workers:
            b = w._buckets.get(fn_name)
            if b is not None and b.warm:
                entries.append((-len(b.warm), w.pool_index, w, b))
        fi.warm_heap[:] = entries
        heapq.heapify(fi.warm_heap)

    # ------------------------------------------------- fused hot transitions
    def mark_busy(self, w: Worker, sbx: Sandbox) -> None:
        """WARM -> BUSY (warm dispatch hit), fused: equivalent to
        ``sbx.state = BUSY`` but with the generic reindex/note cascade
        hand-inlined — this transition changes no schedulable count, so no
        place/evict entries are needed; the warm-candidate heap gets the
        worker's refreshed warm count (if any warm copies remain)."""
        name = sbx.fn.name
        b = w._buckets[name]
        warm = b.warm
        warm.remove(sbx)
        b.busy_n += 1
        w._n_busy += 1
        sbx._state = _BUSY
        fi = self._fns[name]
        if warm:
            heap = fi.warm_heap
            heapq.heappush(heap, (-len(warm), w.pool_index, w, b))
            if len(heap) > 64 + 8 * len(self.workers):
                self._compact_warm(name, fi)
        elif not b.alloc:
            if w in fi.idle:
                fi.idle.remove(w)
                fi.idle_sorted.remove((w.pool_index, w, b))

    def mark_warm(self, w: Worker, sbx: Sandbox) -> None:
        """BUSY -> WARM (completion), fused mirror of ``mark_busy``; pushes
        the refreshed warm-candidate entry and — only when no live entry
        already covers the (unchanged) schedulable count — the one
        eviction-heap entry the worker gains candidacy with."""
        name = sbx.fn.name
        b = w._buckets[name]
        insort(b.warm, sbx, key=_sbx_sort_key)
        b.busy_n -= 1
        w._n_busy -= 1
        sbx._state = _WARM
        fi = self._fns[name]
        cap = self.heap_cap
        if w not in fi.idle:
            fi.idle.add(w)
            insort(fi.idle_sorted, (w.pool_index, w, b))
        heap = fi.warm_heap
        heapq.heappush(heap, (-len(b.warm), w.pool_index, w, b))
        if len(heap) > cap:
            self._compact_warm(name, fi)
        c = len(b.alloc) + len(b.warm) + b.busy_n
        if b.evict_pushed != c:
            b.evict_pushed = c
            heap = fi.evict_heap
            heapq.heappush(heap, self._evict_key(c, w.worker_id))
            if len(heap) > cap:
                self._compact(name, heap, self._evict_key)

    # -------------------------------------------------------- SGS-side views
    def idle_workers(self, fn_name: str) -> List[Worker]:
        """Workers holding a WARM/ALLOCATING sandbox of ``fn_name``, in pool
        order (the dispatcher's warm-candidate index), maintained
        incrementally on membership change."""
        fi = self._fns.get(fn_name)
        if fi is None:
            return _EMPTY
        return [e[1] for e in fi.idle_sorted]

    def has_soft_workers(self, fn_name: str) -> bool:
        fi = self._fns.get(fn_name)
        return fi is not None and bool(fi.soft)

    def remove_worker(self, w: Worker) -> None:
        """Fail-stop removal (§6.1): drop the worker and its sandboxes from
        every index."""
        if w.worker_id not in self._by_id:
            return
        del self._by_id[w.worker_id]
        if w in self.workers:
            self.workers.remove(w)
        for fn_name, b in w._buckets.items():
            fi = self._fns.get(fn_name)
            if fi is None:
                continue
            fi.total -= w.schedulable_count(fn_name)
            if w in fi.idle:
                fi.idle.remove(w)
                fi.idle_sorted.remove((w.pool_index, w, b))
            fi.soft.discard(w)
            if b.alloc_flag:
                b.alloc_flag = False
                fi.n_alloc -= 1
            # purge the failed worker's warm-candidate entries outright so
            # the dispatcher's fast path never has to consider ownership
            if b.warm:
                self._compact_warm(fn_name, fi)
        w.owner = None

    # ------------------------------------------------------------------ API
    def set_demand(self, fn: FunctionSpec, new_demand: int, now: float) -> None:
        """SANDBOXMANAGEMENT(D): allocate when demand rises above the actual
        allocation, soft-evict when it falls below (Pseudocode 1, lines 2-17)."""
        self.fn_specs[fn.name] = fn
        self.demand_map[fn.name] = new_demand
        actual = self.total_sandboxes(fn.name)
        if new_demand > actual:
            self.allocate_sandboxes(fn, new_demand - actual, now)
        elif new_demand < actual:
            self.soft_evict_sandboxes(fn, actual - new_demand)

    # ------------------------------------------------------- even placement
    def allocate_sandboxes(self, fn: FunctionSpec, n: int, now: float) -> None:
        """ALLOCATESANDBOXES (lines 19-38): for each needed sandbox, pick the
        worker with the minimum count of this function's sandboxes (even) or
        the maximum (packed ablation); prefer reviving a soft-evicted sandbox
        there (free), else allocate from the pool, hard-evicting *surplus*
        sandboxes if the pool is saturated."""
        heap = self._ensure_fn(fn.name).place_heap
        packed = self.placement == "packed"
        for _ in range(n):
            placed = False
            stash: List[Tuple[int, int]] = []
            while heap:
                entry = heapq.heappop(heap)
                cnt, wid = entry
                if packed:
                    cnt = -cnt
                w = self._by_id.get(wid)
                if w is None or w.schedulable_count(fn.name) != cnt:
                    continue            # dead worker or stale count
                revived = w.find(fn.name, _SOFT)
                if revived is not None:
                    # Preferentially unmark a soft-evicted sandbox: free.
                    revived.state = (_WARM if revived.ready_at <= now
                                     else _ALLOC)
                    self.n_revivals += 1
                    placed = True
                elif (w.free_pool_mem >= fn.mem_mb
                      or self._hard_evict(w, fn)):
                    sbx = Sandbox(fn=fn, worker_id=w.worker_id,
                                  state=_ALLOC,
                                  ready_at=now + fn.setup_time, last_used=now)
                    w.add_sandbox(sbx)
                    self.n_allocations += 1
                    if self.on_allocate is not None:
                        self.on_allocate(sbx, w)
                    placed = True
                else:
                    stash.append(entry)  # this worker cannot host; try next
                    continue
                break
            for entry in stash:
                heapq.heappush(heap, entry)
            if not placed:
                return              # pool saturated with protected sandboxes

    # ----------------------------------------------------------- soft evict
    def soft_evict_sandboxes(self, fn: FunctionSpec, n: int) -> None:
        """Lines 11-15: mirror-image of placement — repeatedly pick the worker
        holding the *max* sandboxes of this function and soft-evict one there,
        keeping the residue balanced for statistical multiplexing.  (In the
        packed ablation the mirror image is the *min* non-empty worker, so
        packing is preserved.)  Victim selection is O(log W) amortized via the
        eviction heap + the per-worker state buckets."""
        fname = fn.name
        heap = self._ensure_fn(fname).evict_heap
        packed = self.placement == "packed"
        for _ in range(n):
            victim: Optional[_FnBucket] = None
            while heap:
                cnt, wid = heapq.heappop(heap)
                if not packed:
                    cnt = -cnt
                w = self._by_id.get(wid)
                if w is None:
                    continue            # dead worker
                b = w._buckets.get(fname)
                if b is None:
                    continue
                if b.evict_pushed == cnt:
                    b.evict_pushed = -1  # the tracked live entry is consumed
                if (len(b.alloc) + len(b.warm) + b.busy_n != cnt
                        or not (b.alloc or b.warm)):
                    continue            # stale count or no evictable sandbox
                victim = b
                break
            if victim is None:
                return
            # earliest-created WARM, else earliest-created ALLOCATING (the
            # bucket lists are sbx_id-sorted, so this is Worker.find)
            sbx = victim.warm[0] if victim.warm else victim.alloc[0]
            sbx.state = _SOFT           # hooks push refreshed heap entries
            self.n_soft_evictions += 1

    # ------------------------------------------------------ reactive allocation
    def reactive_allocate(self, w: Worker, fn: FunctionSpec,
                          now: float) -> Optional[Sandbox]:
        """Cold-start allocation on the dispatch critical path: make room via
        hard eviction if the pool is full.  Returns ``None`` when the worker
        cannot host the sandbox without harming a protected function — the
        caller must fall back (another worker / requeue), never overcommit
        the worker's proactive memory pool."""
        if w.free_pool_mem < fn.mem_mb and not self._hard_evict(w, fn):
            return None
        sbx = Sandbox(fn=fn, worker_id=w.worker_id,
                      state=_BUSY,
                      ready_at=now + fn.setup_time, last_used=now)
        w.add_sandbox(sbx)
        return sbx

    # ----------------------------------------------------------- hard evict
    def _hard_evict(self, w: Worker, incoming: FunctionSpec) -> bool:
        """HARDEVICT (lines 39-46): evict until ``incoming`` fits.

        Victim choice is workload-aware ("fair", §4.3.3): soft-evicted
        sandboxes go first; among live ones, only functions at-or-above their
        estimated demand are eligible (protects functions whose allocation is
        far below their estimate), preferring the one closest to its estimate.
        Never evicts BUSY sandboxes.  Returns False if ``incoming`` cannot fit
        without harming a protected function.
        """
        while w.free_pool_mem < incoming.mem_mb:
            cands = [s for s in w._sandboxes.values()
                     if s.state is not _BUSY and s.fn.name != incoming.name]
            if not cands:
                return False
            if self.eviction == "lru":
                victim = min(cands, key=lambda s: s.last_used)
            else:
                soft = [s for s in cands if s.state is _SOFT]
                if soft:
                    victim = min(soft, key=self._fairness_key)
                else:
                    surplus = [s for s in cands
                               if self._surplus(s.fn.name) >= 0]
                    if not surplus:
                        return False   # all under-provisioned: back off
                    victim = min(surplus, key=self._fairness_key)
            w.remove_sandbox(victim)
            self.n_hard_evictions += 1
        return True

    def _surplus(self, fn_name: str) -> int:
        return self.total_sandboxes(fn_name) - self.demand_map.get(fn_name, 0)

    def _fairness_key(self, s: Sandbox) -> float:
        """abs(total allocation - estimated demand) for the sandbox's
        function; smaller = closer to its estimate = preferred victim."""
        return abs(self._surplus(s.fn.name))

    # -------------------------------------------------------------- queries
    def total_sandboxes(self, fn_name: str) -> int:
        fi = self._fns.get(fn_name)
        if fi is None:
            # function never indexed: count once and start tracking
            fi = self._ensure_fn(fn_name)
        return fi.total

    def counts_per_worker(self, fn_name: str) -> List[int]:
        return [w.schedulable_count(fn_name) for w in self.workers]
