"""Workers and proactive sandbox management (§4.3.2, §4.3.3, Pseudocode 1).

A worker owns a fixed number of execution slots ("cores" in the paper; HBM
instance slots for the TPU adaptation) and a *proactive memory pool* — the
admin-configured amount of memory usable for proactively allocated sandboxes.
Sandboxes are soft state: they can always be evicted without correctness
impact.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from .types import FunctionSpec, Sandbox, SandboxState


@dataclass
class Worker:
    worker_id: int
    cores: int = 4
    pool_mem_mb: float = 4096.0     # proactive memory pool capacity
    busy_cores: int = 0
    sandboxes: List[Sandbox] = field(default_factory=list)

    # -- memory ---------------------------------------------------------------
    @property
    def used_pool_mem(self) -> float:
        return sum(s.fn.mem_mb for s in self.sandboxes)

    @property
    def free_pool_mem(self) -> float:
        return self.pool_mem_mb - self.used_pool_mem

    @property
    def free_cores(self) -> int:
        return self.cores - self.busy_cores

    # -- sandbox queries ------------------------------------------------------
    def count(self, fn_name: str, *states: SandboxState) -> int:
        states = states or tuple(SandboxState)
        return sum(1 for s in self.sandboxes
                   if s.fn.name == fn_name and s.state in states)

    def schedulable_count(self, fn_name: str) -> int:
        """Sandboxes counted for placement decisions: everything except
        soft-evicted (those are invisible to the scheduler, §4.3.3)."""
        return self.count(fn_name, SandboxState.ALLOCATING,
                          SandboxState.WARM, SandboxState.BUSY)

    def find(self, fn_name: str, state: SandboxState) -> Optional[Sandbox]:
        for s in self.sandboxes:
            if s.fn.name == fn_name and s.state == state:
                return s
        return None

    def warm_available(self, fn_name: str, now: float) -> Optional[Sandbox]:
        """A sandbox ready for immediate reuse.  ALLOCATING sandboxes whose
        setup has finished transition to WARM lazily here."""
        for s in self.sandboxes:
            if s.fn.name != fn_name:
                continue
            if s.state == SandboxState.ALLOCATING and s.ready_at <= now + 1e-12:
                s.state = SandboxState.WARM
            if s.state == SandboxState.WARM and s.ready_at <= now + 1e-12:
                return s
        return None


AllocHook = Callable[[Sandbox, Worker], None]


@dataclass
class SandboxManager:
    """Implements Pseudocode 1: even placement, soft eviction, fair hard
    eviction — over one SGS's worker pool.

    ``set_demand`` *reconciles* the actual schedulable allocation against the
    estimator's target each tick (rather than diffing successive estimates):
    this self-heals after hard evictions and reactive cold-start allocations
    change the real count behind the estimator's back.
    """

    workers: List[Worker]
    # "even" spreads each function's sandboxes across workers (§4.3.2);
    # "packed" fills one worker before the next (the Fig. 9 ablation).
    placement: str = "even"
    # "fair" = workload-aware victim choice (§4.3.3); "lru" = plain LRU
    # (the §7.3.1 eviction ablation).
    eviction: str = "fair"
    # called when a brand-new sandbox begins allocation (lets the executor
    # model / perform the actual setup work in the background)
    on_allocate: Optional[AllocHook] = None
    # demand targets last pushed by the SGS: fn name -> sandbox count
    demand_map: Dict[str, int] = field(default_factory=dict)
    fn_specs: Dict[str, FunctionSpec] = field(default_factory=dict)
    # counters
    n_hard_evictions: int = 0
    n_soft_evictions: int = 0
    n_allocations: int = 0
    n_revivals: int = 0

    # ------------------------------------------------------------------ API
    def set_demand(self, fn: FunctionSpec, new_demand: int, now: float) -> None:
        """SANDBOXMANAGEMENT(D): allocate when demand rises above the actual
        allocation, soft-evict when it falls below (Pseudocode 1, lines 2-17)."""
        self.fn_specs[fn.name] = fn
        self.demand_map[fn.name] = new_demand
        actual = self.total_sandboxes(fn.name)
        if new_demand > actual:
            self.allocate_sandboxes(fn, new_demand - actual, now)
        elif new_demand < actual:
            self.soft_evict_sandboxes(fn, actual - new_demand)

    # ------------------------------------------------------- even placement
    def allocate_sandboxes(self, fn: FunctionSpec, n: int, now: float) -> None:
        """ALLOCATESANDBOXES (lines 19-38): for each needed sandbox, pick the
        worker with the minimum count of this function's sandboxes (even) or
        the maximum (packed ablation); prefer reviving a soft-evicted sandbox
        there (free), else allocate from the pool, hard-evicting *surplus*
        sandboxes if the pool is saturated."""
        for _ in range(n):
            placed = False
            for w in self._placement_order(fn.name):
                revived = w.find(fn.name, SandboxState.SOFT_EVICTED)
                if revived is not None:
                    # Preferentially unmark a soft-evicted sandbox: free.
                    revived.state = (SandboxState.WARM
                                     if revived.ready_at <= now
                                     else SandboxState.ALLOCATING)
                    self.n_revivals += 1
                    placed = True
                    break
                if w.free_pool_mem < fn.mem_mb and not self._hard_evict(w, fn):
                    continue        # this worker cannot host one; try next
                sbx = Sandbox(fn=fn, worker_id=w.worker_id,
                              state=SandboxState.ALLOCATING,
                              ready_at=now + fn.setup_time, last_used=now)
                w.sandboxes.append(sbx)
                self.n_allocations += 1
                if self.on_allocate is not None:
                    self.on_allocate(sbx, w)
                placed = True
                break
            if not placed:
                return              # pool saturated with protected sandboxes

    def _placement_order(self, fn_name: str) -> List[Worker]:
        if self.placement == "packed":
            return sorted(self.workers,
                          key=lambda w: (-w.schedulable_count(fn_name),
                                         w.worker_id))
        return sorted(self.workers,
                      key=lambda w: (w.schedulable_count(fn_name),
                                     w.worker_id))

    # ----------------------------------------------------------- soft evict
    def soft_evict_sandboxes(self, fn: FunctionSpec, n: int) -> None:
        """Lines 11-15: mirror-image of placement — repeatedly pick the worker
        holding the *max* sandboxes of this function and soft-evict one there,
        keeping the residue balanced for statistical multiplexing.  (In the
        packed ablation the mirror image is the *min* non-empty worker, so
        packing is preserved.)"""
        for _ in range(n):
            cands = [w for w in self.workers
                     if w.find(fn.name, SandboxState.WARM) is not None
                     or w.find(fn.name, SandboxState.ALLOCATING) is not None]
            if not cands:
                return
            if self.placement == "packed":
                w = min(cands, key=lambda w: (w.schedulable_count(fn.name),
                                              w.worker_id))
            else:
                w = max(cands, key=lambda w: (w.schedulable_count(fn.name),
                                              -w.worker_id))
            sbx = (w.find(fn.name, SandboxState.WARM)
                   or w.find(fn.name, SandboxState.ALLOCATING))
            sbx.state = SandboxState.SOFT_EVICTED
            self.n_soft_evictions += 1

    # ----------------------------------------------------------- hard evict
    def _hard_evict(self, w: Worker, incoming: FunctionSpec) -> bool:
        """HARDEVICT (lines 39-46): evict until ``incoming`` fits.

        Victim choice is workload-aware ("fair", §4.3.3): soft-evicted
        sandboxes go first; among live ones, only functions at-or-above their
        estimated demand are eligible (protects functions whose allocation is
        far below their estimate), preferring the one closest to its estimate.
        Never evicts BUSY sandboxes.  Returns False if ``incoming`` cannot fit
        without harming a protected function.
        """
        while w.free_pool_mem < incoming.mem_mb:
            cands = [s for s in w.sandboxes
                     if s.state in (SandboxState.SOFT_EVICTED,
                                    SandboxState.WARM,
                                    SandboxState.ALLOCATING)
                     and s.fn.name != incoming.name]
            if not cands:
                return False
            if self.eviction == "lru":
                victim = min(cands, key=lambda s: s.last_used)
            else:
                soft = [s for s in cands
                        if s.state == SandboxState.SOFT_EVICTED]
                if soft:
                    victim = min(soft, key=self._fairness_key)
                else:
                    surplus = [s for s in cands
                               if self._surplus(s.fn.name) >= 0]
                    if not surplus:
                        return False   # all under-provisioned: back off
                    victim = min(surplus, key=self._fairness_key)
            w.sandboxes.remove(victim)
            self.n_hard_evictions += 1
        return True

    def _surplus(self, fn_name: str) -> int:
        alloc = self.total_sandboxes(fn_name)
        return alloc - self.demand_map.get(fn_name, 0)

    def _fairness_key(self, s: Sandbox) -> float:
        """abs(total allocation - estimated demand) for the sandbox's
        function; smaller = closer to its estimate = preferred victim."""
        return abs(self._surplus(s.fn.name))

    # -------------------------------------------------------------- queries
    def total_sandboxes(self, fn_name: str) -> int:
        return sum(w.schedulable_count(fn_name) for w in self.workers)

    def counts_per_worker(self, fn_name: str) -> List[int]:
        return [w.schedulable_count(fn_name) for w in self.workers]
