"""Semi-global scheduler (§4.1, §4.2): deadline-aware SRSF over a worker pool.

The SGS owns a partition of the cluster (its *worker pool*), a priority queue
of ready function invocations, an estimator module, and a sandbox manager
(Fig. 4a).  It is event-driven and time-agnostic: an ``Env`` provides ``now()``
and deferred callbacks, so the same class runs under simulated and real time.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from bisect import insort as _insort
from heapq import heappop as _heappop, heappush as _heappush
from typing import Callable, Dict, List, Optional, Protocol, Set, Tuple

from .estimator import DemandEstimator, RateEstimator
from .sandbox import SandboxManager, Worker, _sbx_sort_key
from .types import (DagSpec, ExecuteFn, FunctionSpec, Invocation, Request,
                    Sandbox, SandboxState, SubmitFn)


class Env(Protocol):
    """Minimal clock + timer interface implemented by repro.sim and
    repro.serving.  Extra ``*args`` are passed to ``fn`` at fire time, which
    lets hot paths avoid allocating a closure per deferred call."""

    def now(self) -> float: ...
    def call_after(self, delay: float, fn: Callable[..., None],
                   *args) -> None: ...


@dataclass
class SGSConfig:
    estimation_interval: float = 0.100   # estimator tick (§4.3.1)
    sla: float = 0.99
    ewma_alpha: float = 0.3
    qdelay_window: int = 20              # samples before a scaling decision
    proactive: bool = True               # proactive sandbox allocation on/off
    ramp_window: float = 2.0             # demand floor duration after an
                                         # LBS-triggered preallocation, so the
                                         # local estimator (which has seen no
                                         # arrivals yet) cannot immediately
                                         # soft-evict the warm-up pool

    even_placement: bool = True          # False -> packed placement (Fig. 9)
    fair_eviction: bool = True           # False -> LRU hard eviction (§7.3.1)
    # Beyond-paper enhancement (default on): reactive allocation at dispatch
    # revives a resident soft-evicted sandbox on the chosen worker at zero
    # cost (Pseudocode 1's preferential reuse applied to the reactive path).
    # Off reproduces the paper's behavior where only the background allocator
    # revives (used for the paper-faithful Fig. 9 ablation).
    revive_on_dispatch: bool = True


# report sent (piggybacked on responses, §5.2.1) to the LBS:
#   (dag_id, sgs_id, queuing_delay_sample, proactive_sandbox_count)
ReportFn = Callable[[str, int, float, int], None]

# shared sentinel marking a single-function request's DAG progress ("done on
# first completion") — avoids a set allocation per request for the dominant
# C1/C2 classes.  Immutable, so sharing is safe.
_SINGLE_FN: frozenset = frozenset()

_BUSY_ST = SandboxState.BUSY
_WARM_ST = SandboxState.WARM


def _slowed_done(env: Env, done: Callable[[float], None],
                 factor: float) -> Callable[[float], None]:
    """Degraded-worker wrapper for the async backend seam: the data plane
    computes the batch's completion instant normally, then the slow
    worker's copy surfaces ``(factor - 1) × exec_s`` later — the batch
    itself (and every healthy member) is unaffected."""
    def slowed(exec_s: float) -> None:
        extra = (factor - 1.0) * exec_s
        if extra > 0.0:
            env.call_after(extra, done, exec_s)
        else:
            done(exec_s)
    return slowed


class SemiGlobalScheduler:
    def __init__(self, sgs_id: int, workers: List[Worker], env: Env,
                 config: Optional[SGSConfig] = None,
                 execute: Optional[ExecuteFn] = None,
                 report: Optional[ReportFn] = None,
                 backend_submit: Optional[SubmitFn] = None):
        self.sgs_id = sgs_id
        self.workers = workers
        self.env = env
        self.cfg = config or SGSConfig()
        # asynchronous execution seam (core.backends): dispatch hands the
        # invocation to the data plane and returns; the backend fires the
        # completion callback later, so the control plane (queue pops,
        # proactive allocation, scaling ticks) never blocks on execution
        self.backend_submit = backend_submit
        self.execute = execute      # legacy synchronous hook (blocks dispatch
                                    # for the execution call); None = modeled
                                    # timing (fn.exec_time)
        self.report = report                # piggyback channel to the LBS

        self.estimator = DemandEstimator(sla=self.cfg.sla,
                                         interval=self.cfg.estimation_interval,
                                         alpha=self.cfg.ewma_alpha)
        self.sandboxes = SandboxManager(
            workers=workers,
            placement="even" if self.cfg.even_placement else "packed",
            eviction="fair" if self.cfg.fair_eviction else "lru")

        # SRSF priority queue of ready invocations (static key, §4.2),
        # flattened to (deadline-rcp, rcp, inv_id, inv) 4-tuples: identical
        # ordering to the old ((k0, k1, id), inv) nesting (inv_id uniquifies
        # before the Invocation could ever be compared) without a nested
        # tuple allocation per push
        self._queue: List[Tuple[float, float, int, Invocation]] = []
        self._dags: Dict[str, DagSpec] = {}       # DAGs this SGS serves
        # fn name -> (floor demand, expiry) set by LBS preallocation
        self._demand_floor: Dict[str, Tuple[int, float]] = {}
        self._ticking = False
        # fault tolerance (§6.1): in-flight tracking + failed-worker view
        # (worker_id -> {inv_id -> Invocation}, insertion-ordered like the
        # old per-worker list but with O(1) completion removal)
        self._inflight: Dict[int, Dict[int, Invocation]] = {}
        self._dead_workers: Set[int] = set()
        # SGS fail-stop (§6.1, core.fault.fail_sgs): when this instance is
        # killed and replaced, deferred callbacks already bound to it
        # (submit_request from routed-but-unfired arrivals, _complete from
        # executions still running on surviving workers) forward to the
        # replacement instead of mutating dead state
        self._successor: Optional["SemiGlobalScheduler"] = None
        # incremental pool-wide free-core count: _dispatch's work-conserving
        # loop gate is O(1) instead of an O(W) any() per queue pop
        self._free_cores = sum(w.cores - w.busy_cores for w in workers)
        # per-dag cached [_FnIndex, ...] for the piggyback sandbox count
        self._dag_fis: Dict[str, List[object]] = {}
        # gray-failure state (core.fault): per-worker execution-time
        # multipliers (slow_worker), the batching data plane's dead-member
        # release hook, and the hedged-retry config (threaded from validated
        # Experiment.params by ArchipelagoStack.build).  All default off —
        # the zero-fault hot path only pays an ``if {}:`` / ``is None`` test.
        self._slow: Dict[int, float] = {}
        self.backend_drop: Optional[Callable[[List[int]], None]] = None
        self._hedge_timeout: Optional[float] = None
        self._hedge_jitter: float = 0.0
        self._hedge_rng = None
        self.n_hedges = 0

        # metrics
        self.n_cold_starts = 0
        self.n_warm_hits = 0
        self.queuing_delays: List[float] = []
        self.queuing_delay_times: List[float] = []   # dispatch timestamps
        self.completed_requests: List[Request] = []
        # flat-metrics completion hook (``Metrics.record_completion``): when
        # set, completed requests are folded into the run's column buffers
        # and released instead of accumulating on ``completed_requests``
        self.on_complete: Optional[Callable[[Request, float], None]] = None

    # ---------------------------------------------------------------- intake
    def submit_request(self, req: Request) -> None:
        """Entry point from the LBS. Enqueues the DAG's root invocations."""
        succ = self._successor
        if succ is not None:        # failed over: the replacement serves it
            succ.submit_request(req)
            return
        now = self.env.now()
        req.sgs_id = self.sgs_id
        dag = req.dag
        self._dags[dag.dag_id] = dag
        # DAG progress rides on the request (attribute load on the
        # completion path instead of a per-request dict entry); single-
        # function DAGs (the common classes) need no progress set — the
        # shared immutable sentinel marks "completes on first invocation"
        req.fns_done = set() if dag._n_fns > 1 else _SINGLE_FN
        # arrival statistics feed the estimator for every constituent
        # function (DemandEstimator.record_arrival hand-inlined: this loop
        # runs once per invocation)
        est_ = self.estimator
        rates = est_._rates
        for f in dag.functions:
            est = rates.get(f.name)
            if est is None:
                est = rates[f.name] = RateEstimator(est_.interval,
                                                    est_.alpha)
            if now - est._window_start >= est.interval:
                est._roll(now)
            est._count += 1
        if not self._ticking:
            self._ensure_ticking()
        queue = self._queue
        roots = dag._roots
        if not queue and len(roots) == 1 and self._free_cores > 0:
            # bypass the heap: the queue is empty and this request's single
            # root would be popped right back by _dispatch — start it
            # directly (identical decision); a failed start queues the
            # invocation exactly like a skipped pop would
            root = roots[0]
            inv = Invocation(request=req, fn=dag._fn_map[root],
                             ready_time=now)
            worker, sbx = self._choose_worker(inv, now)
            if worker is not None and self._start(inv, worker, sbx, now):
                return
            rcp = dag._rcp[root]
            _heappush(queue,
                      (req.arrival_time + dag.deadline - rcp, rcp,
                       inv.inv_id, inv))
            return
        abs_deadline = req.arrival_time + dag.deadline
        rcp_map = dag._rcp
        fn_map = dag._fn_map
        for root in roots:
            inv = Invocation(request=req, fn=fn_map[root], ready_time=now)
            rcp = rcp_map[root]
            _heappush(queue, (abs_deadline - rcp, rcp, inv.inv_id, inv))
        if self._free_cores > 0:    # inlined _dispatch entry gate
            self._dispatch()

    def preallocate(self, dag: DagSpec, n_per_fn: int) -> None:
        """LBS-triggered warm-up during gradual scale-out (§5.2.3)."""
        now = self.env.now()
        self._dags[dag.dag_id] = dag
        self._ensure_ticking()
        for f in dag.functions:
            self._demand_floor[f.name] = (n_per_fn, now + self.cfg.ramp_window)
            cur = self.sandboxes.demand_map.get(f.name, 0)
            if n_per_fn > cur:
                self.sandboxes.set_demand(f, n_per_fn, now)

    # --------------------------------------------------------------- dispatch
    def _dispatch(self) -> None:
        """Work-conserving SRSF dispatch: repeatedly pick the queued
        invocation with the least remaining slack whose resource requirements
        can currently be met, and run it (§4.2)."""
        queue = self._queue
        if not queue or self._free_cores <= 0:
            return
        now = self.env.now()
        pop = heapq.heappop
        choose = self._choose_worker
        start = self._start
        skipped: Optional[List[Tuple[float, float, int, Invocation]]] = None
        while queue and self._free_cores > 0:
            item = pop(queue)
            worker, sbx = choose(item[3], now)
            if worker is None or not start(item[3], worker, sbx, now):
                if skipped is None:
                    skipped = [item]
                else:
                    skipped.append(item)
        if skipped:
            push = heapq.heappush
            for item in skipped:
                push(queue, item)

    def _choose_worker(self, inv: Invocation, now: float
                       ) -> Tuple[Optional[Worker], Optional[Sandbox]]:
        """Prefer a free-core worker holding a WARM sandbox for this function
        (the whole point of even placement); otherwise any free-core worker
        that can fit a reactive sandbox.

        Phase 1 consults the manager's per-function index of workers holding
        idle (WARM/ALLOCATING) sandboxes instead of scanning the whole pool;
        within it, ``warm_available`` performs the same lazy ALLOCATING->WARM
        promotion on every probed candidate as the legacy full scan, and ties
        on warm-copy count break toward the earliest worker in pool order —
        decision order is identical to the legacy code.  Phase 2 (no warm
        candidate anywhere, so phase 1 had no side effects) resolves the
        soft-revival / reactive-cold fallbacks with O(1) per-worker checks.
        """
        fn_name = inv.fn.name
        # deliberate private-index access throughout: this is the hottest
        # loop in the simulator and an accessor call per probe is measurable
        mgr = self.sandboxes
        fi = mgr._fns.get(fn_name)
        if fi is not None:
            if fi.n_alloc == 0:
                # Fast path: no ALLOCATING sandbox of this function anywhere
                # in the pool, so the legacy walk has no lazy-promotion side
                # effects and its answer reduces to "most warm copies,
                # earliest pool position, with a free core" — served from
                # the lazy warm-candidate max-heap in O(log W) amortized.
                # Entries are validated against the live warm count (and
                # worker ownership) at pop; valid entries whose worker has
                # no free core right now are re-pushed after the search.
                heap = fi.warm_heap
                stash = None
                pick_w: Optional[Worker] = None
                pick_s: Optional[Sandbox] = None
                while heap:
                    e = heap[0]
                    w = e[2]
                    warm = e[3].warm
                    if len(warm) != -e[0]:
                        _heappop(heap)              # stale count
                        continue
                    if w.busy_cores >= w.cores:
                        _heappop(heap)              # valid but ineligible
                        if stash is None:
                            stash = [e]
                        else:
                            stash.append(e)
                        continue
                    pick_w = w
                    pick_s = warm[0]
                    break
                if stash is not None:
                    for e in stash:
                        _heappush(heap, e)
                if pick_w is not None:
                    return pick_w, pick_s
            else:
                warm_best: Optional[Worker] = None
                warm_best_count = -1
                warm_sbx: Optional[Sandbox] = None
                for _, w, b in fi.idle_sorted:
                    if w.busy_cores >= w.cores:
                        continue
                    if b.alloc:
                        # lazy ALLOCATING->WARM promotion can fire: full
                        # legacy probe
                        s = w.warm_available(fn_name, now)
                        if s is None:
                            continue
                    else:
                        # no ALLOCATING sandbox -> no promotion possible,
                        # and a WARM sandbox is always past its ready_at
                        # (time is monotone), so the probe reduces to the
                        # bucket head
                        warm = b.warm
                        if not warm:
                            continue
                        s = warm[0]
                    # among warm candidates prefer the one with most warm
                    # copies
                    c = len(b.warm)
                    if c > warm_best_count:
                        warm_best, warm_best_count, warm_sbx = w, c, s
                if warm_best is not None:
                    return warm_best, warm_sbx
        revive = (self.cfg.revive_on_dispatch
                  and fi is not None and bool(fi.soft))
        mem_mb = inv.fn.mem_mb
        cold_best: Optional[Worker] = None
        for w in self.workers:
            if w.busy_cores >= w.cores:
                continue
            if revive and w.has_ready_soft(fn_name, now):
                return w, None      # _start revives it instantly
            if cold_best is None and (w.pool_mem_mb - w._used_pool_mem
                                      >= mem_mb
                                      or len(w._sandboxes) > w._n_busy):
                if not revive:
                    return w, None  # nothing revivable anywhere: first fit
                cold_best = w
        return cold_best, None

    def _start(self, inv: Invocation, w: Worker, sbx: Optional[Sandbox],
               now: float) -> bool:
        """Run ``inv`` on ``w`` (or, on a cold start the chosen worker cannot
        host, fall back to another free-core worker).  Returns False when no
        worker can host a reactive sandbox — the caller requeues the
        invocation instead of overcommitting a proactive memory pool.  On
        failure no scheduling bookkeeping is touched, but attempted hard
        evictions may already have removed unprotected sandboxes on probed
        workers (HARDEVICT evicts one victim at a time and only then
        discovers the remainder is protected — same partial-progress
        semantics as the paper's Pseudocode 1 / the legacy scan code)."""
        setup = 0.0
        if sbx is None:
            # reactive allocation: per Pseudocode 1, preferentially revive a
            # resident soft-evicted sandbox — unmarking incurs no overhead
            revived = (w.find(inv.fn.name, SandboxState.SOFT_EVICTED)
                       if self.cfg.revive_on_dispatch else None)
            if revived is not None and revived.ready_at <= now + 1e-12:
                self.sandboxes.n_revivals += 1
                self.n_warm_hits += 1
                sbx = revived
            else:
                # true cold start: set up a new sandbox on the critical path
                setup = inv.fn.setup_time
                sbx = self.sandboxes.reactive_allocate(w, inv.fn, now)
                if sbx is None:
                    # the chosen worker can't host without harming a
                    # protected function: fall back to any other free-core
                    # worker that can, else requeue — never overcommit, but
                    # never starve while the pool has capacity either
                    mem_mb = inv.fn.mem_mb
                    for cand in self.workers:
                        if cand is w or cand.free_cores <= 0:
                            continue
                        if (cand.free_pool_mem >= mem_mb
                                or cand.has_non_busy_sandbox()):
                            sbx = self.sandboxes.reactive_allocate(
                                cand, inv.fn, now)
                            if sbx is not None:
                                w = cand
                                break
                    if sbx is None:
                        return False    # nowhere to host: requeue
                inv.cold_start = True
                inv.request.n_cold_starts += 1
                self.n_cold_starts += 1
            sbx.state = SandboxState.BUSY
        else:
            self.n_warm_hits += 1
            # warm hit: fused WARM->BUSY transition (the dominant case).
            # Hand-inlined SandboxManager.mark_busy — that method is the
            # reference implementation; any change there must land here too
            # (tests/test_equivalence.py pins the shared behavior).
            mgr = self.sandboxes
            name = sbx.fn.name
            b = w._buckets[name]
            warm = b.warm
            warm.remove(sbx)
            b.busy_n += 1
            w._n_busy += 1
            sbx._state = _BUSY_ST
            fi = mgr._fns[name]
            if warm:
                heap = fi.warm_heap
                _heappush(heap, (-len(warm), w.pool_index, w, b))
                if len(heap) > mgr.heap_cap:
                    mgr._compact_warm(name, fi)
            elif not b.alloc:
                if w in fi.idle:
                    fi.idle.remove(w)
                    fi.idle_sorted.remove((w.pool_index, w, b))
        sbx.last_used = now
        inv.start_time = now
        qdelay = now - inv.ready_time
        self.queuing_delays.append(qdelay)
        self.queuing_delay_times.append(now)
        req = inv.request
        req.total_queuing_delay += qdelay
        w.busy_cores += 1
        self._free_cores -= 1

        # piggyback queuing delay + per-DAG sandbox count to the LBS (§5.2.1)
        if self.report is not None:
            dag_id = req.dag.dag_id
            self.report(dag_id, self.sgs_id, qdelay,
                        self.proactive_sandbox_count(dag_id))

        inflight = self._inflight.get(w.worker_id)
        if inflight is None:
            inflight = self._inflight[w.worker_id] = {}
        inflight[inv.inv_id] = inv
        slow = self._slow
        m = slow.get(w.worker_id) if slow else None
        if self.backend_submit is not None:
            # asynchronous seam: hand the invocation to the data plane and
            # keep scheduling — the backend (possibly batching it with other
            # in-flight invocations) fires `done` at the completion instant
            done = self._make_done(inv, w, sbx)
            if m is not None:
                done = _slowed_done(self.env, done, m)
            self.backend_submit(inv, done, setup)
        elif self.execute is not None:
            # legacy synchronous hook: runs the execution call inside the
            # dispatch path and blocks on it (kept for direct constructions)
            exec_s = self.execute(inv)
            if m is not None:
                exec_s *= m
            self.env.call_after(setup + exec_s, self._complete, inv, w, sbx)
        else:
            exec_s = inv.fn.exec_time
            if m is not None:
                exec_s *= m
            self.env.call_after(setup + exec_s,
                                self._complete, inv, w, sbx)
        ht = self._hedge_timeout
        if ht is not None:
            # per-invocation dispatch timeout: the hedge deadline scales
            # with the invocation's expected execution time (a straggler is
            # "ht× slower than expected"), plus seeded jitter so a stalled
            # batch doesn't hedge in lockstep
            t = ht * inv.fn.exec_time
            rng = self._hedge_rng
            if rng is not None and self._hedge_jitter > 0.0:
                t *= 1.0 + self._hedge_jitter * rng.random()
            self.env.call_after(setup + t, self._hedge_check, w.worker_id,
                                inv.inv_id, inv)
        return True

    def _make_done(self, inv: Invocation, w: Worker, sbx: Sandbox
                   ) -> Callable[[float], None]:
        """Completion callback for the async seam: fired by the backend at
        the invocation's completion instant with its actual runtime."""
        def done(exec_s: float) -> None:
            self._complete(inv, w, sbx)
        return done

    def _hedge_check(self, worker_id: int, inv_id: int,
                     inv: Invocation) -> None:
        """Straggler mitigation: if the dispatched copy has not completed
        by its hedge deadline, enqueue a speculative duplicate (a fresh
        ``Invocation``, so it dispatches like any retry — possibly onto a
        healthy worker).  Whichever copy completes first wins; the loser's
        completion is dropped by the inflight-generation guard and the
        ``fns_done`` duplicate guard in ``_complete``, so a request is
        never double-counted.  A duplicate that still straggles re-hedges
        after its own timeout."""
        succ = self._successor
        if succ is not None:        # failed over: the replacement judges it
            succ._hedge_check(worker_id, inv_id, inv)
            return
        inflight = self._inflight.get(worker_id)
        if inflight is None or inv_id not in inflight:
            return          # completed in time (or the worker died and the
                            # crash path already queued a retry)
        req = inv.request
        done = req.fns_done
        if done is None:
            return          # request finished through another invocation
        if done is not _SINGLE_FN and inv.fn.name in done:
            return          # an earlier hedge already won this function
        self.n_hedges += 1
        retry = Invocation(request=req, fn=inv.fn,
                           ready_time=self.env.now())
        k0, k1, k2 = retry.priority_key()
        _heappush(self._queue, (k0, k1, k2, retry))
        if self._free_cores > 0:
            self._dispatch()

    def _complete(self, inv: Invocation, w: Worker, sbx: Sandbox) -> None:
        succ = self._successor
        if succ is not None:        # failed over: completions continue there
            succ._complete(inv, w, sbx)
            return
        now = self.env.now()
        # Inflight-generation guard: a completion is only valid if *this*
        # invocation is still registered in flight on *this* worker.  Drops
        # stale ``done()`` callbacks from the async backend seam for (a)
        # workers that died after submission (fail_worker popped the whole
        # per-worker dict) and (b) invocations that were re-enqueued as
        # retries (the retry is a fresh Invocation with its own inv_id, so a
        # late original can never double-complete it).  On the healthy path
        # every completion pops its own registration — decision-identical.
        inflight = self._inflight.get(w.worker_id)
        if inflight is None or inflight.pop(inv.inv_id, None) is None:
            return      # fail-stop: this execution was lost and retried
        w.busy_cores -= 1
        self._free_cores += 1
        # fused BUSY->WARM transition (every completion takes it).
        # Hand-inlined SandboxManager.mark_warm — that method is the
        # reference implementation; any change there must land here too
        # (tests/test_equivalence.py pins the shared behavior).
        mgr = self.sandboxes
        name = inv.fn.name
        b = w._buckets[name]
        _insort(b.warm, sbx, key=_sbx_sort_key)
        b.busy_n -= 1
        w._n_busy -= 1
        sbx._state = _WARM_ST
        fi = mgr._fns[name]
        cap = mgr.heap_cap
        if w not in fi.idle:
            fi.idle.add(w)
            _insort(fi.idle_sorted, (w.pool_index, w, b))
        heap = fi.warm_heap
        _heappush(heap, (-len(b.warm), w.pool_index, w, b))
        if len(heap) > cap:
            mgr._compact_warm(name, fi)
        c = len(b.alloc) + len(b.warm) + b.busy_n
        if b.evict_pushed != c:
            b.evict_pushed = c
            heap = fi.evict_heap
            _heappush(heap, mgr._evict_key(c, w.worker_id))
            if len(heap) > cap:
                mgr._compact(name, heap, mgr._evict_key)
        if sbx.ready_at > now:
            sbx.ready_at = now
        sbx.last_used = now
        req = inv.request
        done = req.fns_done
        if done is None:        # request finished elsewhere (defensive)
            if self._queue and self._free_cores > 0:
                self._dispatch()
            return
        dag = req.dag
        if done is _SINGLE_FN:
            finished = True
        else:
            if inv.fn.name in done:
                # hedged duplicate of an already-counted completion: the
                # winner made the DAG progress and released the children —
                # this copy only returns its core/sandbox (done above)
                if self._queue and self._free_cores > 0:
                    self._dispatch()
                return
            done.add(inv.fn.name)
            finished = len(done) == dag._n_fns
        if finished:
            req.completion_time = now
            req.fns_done = None
            rec = self.on_complete
            if rec is not None:
                rec(req, now)
            else:
                self.completed_requests.append(req)
        else:
            # DAG awareness: release children whose parents all completed
            abs_deadline = req.arrival_time + dag.deadline
            for child in dag._children[inv.fn.name]:
                if all(p in done for p in dag._parents[child]):
                    cinv = Invocation(request=req, fn=dag._fn_map[child],
                                      ready_time=now)
                    rcp = dag._rcp[child]
                    _heappush(self._queue,
                              (abs_deadline - rcp, rcp, cinv.inv_id,
                               cinv))
        if self._queue and self._free_cores > 0:    # inlined dispatch gate
            self._dispatch()

    # ----------------------------------------------------------- estimation
    def _ensure_ticking(self) -> None:
        if self._ticking or not self.cfg.proactive:
            return
        self._ticking = True
        self.env.call_after(self.cfg.estimation_interval, self._tick)

    def _tick(self) -> None:
        """Estimator tick: refresh per-function demand and drive the sandbox
        manager (allocate / soft-evict) — runs off the critical path."""
        now = self.env.now()
        for dag in self._dags.values():
            for f in dag.functions:
                d = self.estimator.demand(f.name, f.exec_time, now)
                floor = self._demand_floor.get(f.name)
                if floor is not None:
                    if now < floor[1]:
                        d = max(d, floor[0])
                    else:
                        del self._demand_floor[f.name]
                self.sandboxes.set_demand(f, d, now)
        self.env.call_after(self.cfg.estimation_interval, self._tick)

    # -------------------------------------------------------------- queries
    def queue_length(self) -> int:
        return len(self._queue)

    def proactive_sandbox_count(self, dag_id: str) -> int:
        # per-dispatch piggyback path: read the per-function schedulable
        # totals straight off the manager indices (= total_sandboxes, O(1)).
        # The _FnIndex objects are stable once created, so the per-dag list
        # is resolved once and reused.
        fis = self._dag_fis.get(dag_id)
        if fis is None:
            dag = self._dags.get(dag_id)
            if dag is None:
                return 0
            mgr = self.sandboxes
            fis = self._dag_fis[dag_id] = [
                mgr._fns.get(f.name) or mgr._ensure_fn(f.name)
                for f in dag.functions]
        if len(fis) == 1:       # single-function DAGs: the dominant case
            return fis[0].total
        total = 0
        for fi in fis:
            total += fi.total
        return total
