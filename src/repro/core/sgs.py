"""Semi-global scheduler (§4.1, §4.2): deadline-aware SRSF over a worker pool.

The SGS owns a partition of the cluster (its *worker pool*), a priority queue
of ready function invocations, an estimator module, and a sandbox manager
(Fig. 4a).  It is event-driven and time-agnostic: an ``Env`` provides ``now()``
and deferred callbacks, so the same class runs under simulated and real time.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Protocol, Set, Tuple

from .estimator import DemandEstimator
from .sandbox import SandboxManager, Worker
from .types import (DagSpec, ExecuteFn, FunctionSpec, Invocation, Request,
                    Sandbox, SandboxState)


class Env(Protocol):
    """Minimal clock + timer interface implemented by repro.sim and
    repro.serving."""

    def now(self) -> float: ...
    def call_after(self, delay: float, fn: Callable[[], None]) -> None: ...


@dataclass
class SGSConfig:
    estimation_interval: float = 0.100   # estimator tick (§4.3.1)
    sla: float = 0.99
    ewma_alpha: float = 0.3
    qdelay_window: int = 20              # samples before a scaling decision
    proactive: bool = True               # proactive sandbox allocation on/off
    ramp_window: float = 2.0             # demand floor duration after an
                                         # LBS-triggered preallocation, so the
                                         # local estimator (which has seen no
                                         # arrivals yet) cannot immediately
                                         # soft-evict the warm-up pool

    even_placement: bool = True          # False -> packed placement (Fig. 9)
    fair_eviction: bool = True           # False -> LRU hard eviction (§7.3.1)
    # Beyond-paper enhancement (default on): reactive allocation at dispatch
    # revives a resident soft-evicted sandbox on the chosen worker at zero
    # cost (Pseudocode 1's preferential reuse applied to the reactive path).
    # Off reproduces the paper's behavior where only the background allocator
    # revives (used for the paper-faithful Fig. 9 ablation).
    revive_on_dispatch: bool = True


# report sent (piggybacked on responses, §5.2.1) to the LBS:
#   (dag_id, sgs_id, queuing_delay_sample, proactive_sandbox_count)
ReportFn = Callable[[str, int, float, int], None]


class SemiGlobalScheduler:
    def __init__(self, sgs_id: int, workers: List[Worker], env: Env,
                 config: Optional[SGSConfig] = None,
                 execute: Optional[ExecuteFn] = None,
                 report: Optional[ReportFn] = None):
        self.sgs_id = sgs_id
        self.workers = workers
        self.env = env
        self.cfg = config or SGSConfig()
        self.execute = execute              # real-execution hook (serving/)
        self.report = report                # piggyback channel to the LBS

        self.estimator = DemandEstimator(sla=self.cfg.sla,
                                         interval=self.cfg.estimation_interval,
                                         alpha=self.cfg.ewma_alpha)
        self.sandboxes = SandboxManager(
            workers=workers,
            placement="even" if self.cfg.even_placement else "packed",
            eviction="fair" if self.cfg.fair_eviction else "lru")

        # SRSF priority queue of ready invocations (static key, §4.2)
        self._queue: List[Tuple[Tuple[float, float, int], Invocation]] = []
        # DAG progress: req_id -> set of completed function names
        self._completed_fns: Dict[int, Set[str]] = {}
        self._dags: Dict[str, DagSpec] = {}       # DAGs this SGS serves
        # fn name -> (floor demand, expiry) set by LBS preallocation
        self._demand_floor: Dict[str, Tuple[int, float]] = {}
        self._ticking = False
        # fault tolerance (§6.1): in-flight tracking + failed-worker view
        self._inflight: Dict[int, List[Invocation]] = {}
        self._dead_workers: Set[int] = set()

        # metrics
        self.n_cold_starts = 0
        self.n_warm_hits = 0
        self.queuing_delays: List[float] = []
        self.completed_requests: List[Request] = []

    # ---------------------------------------------------------------- intake
    def submit_request(self, req: Request) -> None:
        """Entry point from the LBS. Enqueues the DAG's root invocations."""
        now = self.env.now()
        req.sgs_id = self.sgs_id
        dag = req.dag
        self._dags[dag.dag_id] = dag
        self._completed_fns[req.req_id] = set()
        # arrival statistics feed the estimator for every constituent function
        for f in dag.functions:
            self.estimator.record_arrival(f.name, now)
        self._ensure_ticking()
        for root in dag.roots():
            inv = Invocation(request=req, fn=dag.fn(root), ready_time=now)
            heapq.heappush(self._queue, (inv.priority_key(), inv))
        self._dispatch()

    def preallocate(self, dag: DagSpec, n_per_fn: int) -> None:
        """LBS-triggered warm-up during gradual scale-out (§5.2.3)."""
        now = self.env.now()
        self._dags[dag.dag_id] = dag
        self._ensure_ticking()
        for f in dag.functions:
            self._demand_floor[f.name] = (n_per_fn, now + self.cfg.ramp_window)
            cur = self.sandboxes.demand_map.get(f.name, 0)
            if n_per_fn > cur:
                self.sandboxes.set_demand(f, n_per_fn, now)

    # --------------------------------------------------------------- dispatch
    def _dispatch(self) -> None:
        """Work-conserving SRSF dispatch: repeatedly pick the queued
        invocation with the least remaining slack whose resource requirements
        can currently be met, and run it (§4.2)."""
        now = self.env.now()
        skipped: List[Tuple[Tuple[float, float, int], Invocation]] = []
        while self._queue and any(w.free_cores > 0 for w in self.workers):
            key, inv = heapq.heappop(self._queue)
            worker, sbx = self._choose_worker(inv, now)
            if worker is None:
                skipped.append((key, inv))
                continue
            self._start(inv, worker, sbx, now)
        for item in skipped:
            heapq.heappush(self._queue, item)

    def _choose_worker(self, inv: Invocation, now: float
                       ) -> Tuple[Optional[Worker], Optional[Sandbox]]:
        """Prefer a free-core worker holding a WARM sandbox for this function
        (the whole point of even placement); otherwise any free-core worker
        that can fit a reactive sandbox."""
        warm_best: Optional[Worker] = None
        soft_best: Optional[Worker] = None
        cold_best: Optional[Worker] = None
        for w in self.workers:
            if w.free_cores <= 0:
                continue
            if w.warm_available(inv.fn.name, now) is not None:
                # among warm candidates prefer the one with most warm copies
                if (warm_best is None or
                        w.count(inv.fn.name, SandboxState.WARM)
                        > warm_best.count(inv.fn.name, SandboxState.WARM)):
                    warm_best = w
            elif self.cfg.revive_on_dispatch and soft_best is None and any(
                    s.fn.name == inv.fn.name
                    and s.state == SandboxState.SOFT_EVICTED
                    and s.ready_at <= now for s in w.sandboxes):
                # resident soft-evicted sandbox: revivable at zero cost
                soft_best = w
            elif cold_best is None and (
                    w.free_pool_mem >= inv.fn.mem_mb
                    or any(s.state != SandboxState.BUSY for s in w.sandboxes)):
                cold_best = w
        if warm_best is not None:
            return warm_best, warm_best.warm_available(inv.fn.name, now)
        if soft_best is not None:
            return soft_best, None      # _start revives it instantly
        if cold_best is not None:
            return cold_best, None
        return None, None

    def _start(self, inv: Invocation, w: Worker, sbx: Optional[Sandbox],
               now: float) -> None:
        inv.start_time = now
        qdelay = now - inv.ready_time
        self.queuing_delays.append(qdelay)
        inv.request.total_queuing_delay += qdelay
        w.busy_cores += 1
        setup = 0.0
        if sbx is None:
            # reactive allocation: per Pseudocode 1, preferentially revive a
            # resident soft-evicted sandbox — unmarking incurs no overhead
            revived = (w.find(inv.fn.name, SandboxState.SOFT_EVICTED)
                       if self.cfg.revive_on_dispatch else None)
            if revived is not None and revived.ready_at <= now + 1e-12:
                self.sandboxes.n_revivals += 1
                self.n_warm_hits += 1
                sbx = revived
                sbx.state = SandboxState.BUSY
                sbx.last_used = now
            else:
                # true cold start: set up a new sandbox on the critical path
                inv.cold_start = True
                inv.request.n_cold_starts += 1
                self.n_cold_starts += 1
                setup = inv.fn.setup_time
                if w.free_pool_mem < inv.fn.mem_mb:
                    self.sandboxes._hard_evict(w, inv.fn)
                sbx = Sandbox(fn=inv.fn, worker_id=w.worker_id,
                              state=SandboxState.BUSY,
                              ready_at=now + setup, last_used=now)
                w.sandboxes.append(sbx)
        else:
            self.n_warm_hits += 1
            sbx.state = SandboxState.BUSY
            sbx.last_used = now

        # piggyback queuing delay + per-DAG sandbox count to the LBS (§5.2.1)
        if self.report is not None:
            self.report(inv.request.dag.dag_id, self.sgs_id, qdelay,
                        self.proactive_sandbox_count(inv.request.dag.dag_id))

        self._inflight.setdefault(w.worker_id, []).append(inv)
        if self.execute is not None:
            # real execution: measured wall time (serving engine)
            runtime = setup + self.execute(inv)
            self.env.call_after(runtime, lambda: self._complete(inv, w, sbx))
        else:
            self.env.call_after(setup + inv.fn.exec_time,
                                lambda: self._complete(inv, w, sbx))

    def _complete(self, inv: Invocation, w: Worker, sbx: Sandbox) -> None:
        now = self.env.now()
        if w.worker_id in self._dead_workers:
            return      # fail-stop: this execution was lost and retried
        inflight = self._inflight.get(w.worker_id)
        if inflight is not None and inv in inflight:
            inflight.remove(inv)
        w.busy_cores -= 1
        sbx.state = SandboxState.WARM
        sbx.ready_at = min(sbx.ready_at, now)
        sbx.last_used = now
        req = inv.request
        done = self._completed_fns.get(req.req_id)
        if done is None:        # request finished elsewhere (defensive)
            self._dispatch()
            return
        done.add(inv.fn.name)
        dag = req.dag
        if len(done) == len(dag.functions):
            req.completion_time = now
            self.completed_requests.append(req)
            del self._completed_fns[req.req_id]
        else:
            # DAG awareness: release children whose parents all completed
            for child in dag.children(inv.fn.name):
                if all(p in done for p in dag.parents(child)):
                    cinv = Invocation(request=req, fn=dag.fn(child),
                                      ready_time=now)
                    heapq.heappush(self._queue, (cinv.priority_key(), cinv))
        self._dispatch()

    # ----------------------------------------------------------- estimation
    def _ensure_ticking(self) -> None:
        if self._ticking or not self.cfg.proactive:
            return
        self._ticking = True
        self.env.call_after(self.cfg.estimation_interval, self._tick)

    def _tick(self) -> None:
        """Estimator tick: refresh per-function demand and drive the sandbox
        manager (allocate / soft-evict) — runs off the critical path."""
        now = self.env.now()
        for dag in self._dags.values():
            for f in dag.functions:
                d = self.estimator.demand(f.name, f.exec_time, now)
                floor = self._demand_floor.get(f.name)
                if floor is not None:
                    if now < floor[1]:
                        d = max(d, floor[0])
                    else:
                        del self._demand_floor[f.name]
                self.sandboxes.set_demand(f, d, now)
        self.env.call_after(self.cfg.estimation_interval, self._tick)

    # -------------------------------------------------------------- queries
    def queue_length(self) -> int:
        return len(self._queue)

    def proactive_sandbox_count(self, dag_id: str) -> int:
        dag = self._dags.get(dag_id)
        if dag is None:
            return 0
        return sum(self.sandboxes.total_sandboxes(f.name)
                   for f in dag.functions)
