"""Archipelago core: the paper's contribution as a composable library.

Public API:
    FunctionSpec, DagSpec, Request        -- workload model
    SemiGlobalScheduler, SGSConfig        -- deadline-aware SRSF scheduler
    LoadBalancer, LBSConfig               -- sandbox-aware routing + scaling
    DemandEstimator, poisson_ppf          -- proactive demand estimation
    SandboxManager, Worker                -- even placement, soft/hard evict
    CentralizedFIFO, SparrowScheduler     -- paper baselines
    build_cluster, ClusterConfig          -- one-call stack construction
    register_stack, get_stack, Stack      -- pluggable scheduler-stack
                                             registry (docs/API.md)
    register_backend, ExecutionBackend    -- pluggable execution backends:
                                             modeled / stub / jax
                                             (docs/SERVING.md)
    FaultPlan, register_fault, fail_sgs   -- declarative chaos injection +
                                             §6.1 failover (docs/FAULTS.md)
    AutoscaleConfig, LBSReplicaAutoscaler -- elastic LBS replica pool
                                             (docs/SCENARIOS.md)
"""
from .types import (DagSpec, FunctionSpec, Invocation, Request, Sandbox,
                    SandboxState)
from .estimator import DemandEstimator, RateEstimator, poisson_ppf
from .sandbox import SandboxManager, Worker
from .sgs import Env, SGSConfig, SemiGlobalScheduler
from .lbs import ConsistentHashRing, LBSConfig, LoadBalancer
from .baselines import CentralizedFIFO, SparrowScheduler
from .cluster import ClusterConfig, build_cluster, build_flat_workers
from .backends import (BatchCoalescer, BatchedJaxBackend, CompletionQueue,
                       ContinuousBatcher, ExecutionBackend, JaxBackend,
                       ModeledBackend, StubBackend, StubBatchedBackend,
                       available_backends, get_backend, register_backend)
from .stacks import (Stack, available_stacks, get_stack, register_stack)
from .autoscale import (AutoscaleConfig, LBSReplicaAutoscaler, ScalingEvent,
                        scaling_summary)
from .fault import (FaultContext, FaultEvent, FaultInjector, FaultPlan,
                    StateStore, available_faults, checkpoint_lbs,
                    checkpoint_sgs, control_plane_delay, fail_sgs,
                    fail_worker, get_fault, mass_eviction, recovery_summary,
                    register_fault, restore_lbs, restore_sgs, sgs_failstop,
                    time_to_recovery, worker_crash)

__all__ = [
    "DagSpec", "FunctionSpec", "Invocation", "Request", "Sandbox",
    "SandboxState", "DemandEstimator", "RateEstimator", "poisson_ppf",
    "SandboxManager", "Worker", "Env", "SGSConfig", "SemiGlobalScheduler",
    "ConsistentHashRing", "LBSConfig", "LoadBalancer", "CentralizedFIFO",
    "SparrowScheduler", "ClusterConfig", "build_cluster", "build_flat_workers",
    "Stack", "available_stacks", "get_stack", "register_stack",
    "ExecutionBackend", "ModeledBackend", "StubBackend", "StubBatchedBackend",
    "JaxBackend", "BatchedJaxBackend", "BatchCoalescer", "CompletionQueue",
    "ContinuousBatcher",
    "available_backends", "get_backend", "register_backend",
    "StateStore", "checkpoint_lbs", "checkpoint_sgs", "fail_worker",
    "restore_lbs", "restore_sgs", "fail_sgs",
    "FaultPlan", "FaultEvent", "FaultInjector", "FaultContext",
    "worker_crash", "sgs_failstop", "mass_eviction", "control_plane_delay",
    "register_fault", "get_fault", "available_faults",
    "time_to_recovery", "recovery_summary",
    "AutoscaleConfig", "ScalingEvent", "LBSReplicaAutoscaler",
    "scaling_summary",
]
