"""Cluster partitioning (§4.1): carve a machine set into (SGS, worker pool)
pairs.  "A simple approach we espouse is to organize each rack as a worker
pool with one of the machines running the SGS."
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from .lbs import LBSConfig, LoadBalancer
from .sandbox import Worker
from .sgs import Env, SGSConfig, SemiGlobalScheduler
from .types import ExecuteFn, SubmitFn


@dataclass
class ClusterConfig:
    n_sgs: int = 8                 # paper testbed: 8 SGSs x 8 workers (§7.1)
    workers_per_sgs: int = 8
    cores_per_worker: int = 20
    # paper machines have 256GB (§7.1); a quarter reserved as proactive pool
    pool_mem_mb: float = 65536.0
    # Placement topology: one rack per SGS pool (§4.1), racks grouped into
    # availability zones.  Worker ids are globally consistent
    # (``wid = sid * workers_per_sgs + j``), so rack/AZ membership is pure
    # arithmetic on the id — the same topology holds for the flat baseline
    # pools, which share the id scheme.
    racks_per_az: int = 4

    @property
    def n_workers(self) -> int:
        return self.n_sgs * self.workers_per_sgs

    @property
    def n_racks(self) -> int:
        return self.n_sgs

    @property
    def n_azs(self) -> int:
        per = max(1, self.racks_per_az)
        return (self.n_sgs + per - 1) // per

    def rack_of(self, worker_id: int) -> int:
        """Rack (== SGS pool id) that hosts ``worker_id``."""
        return worker_id // self.workers_per_sgs

    def az_of(self, worker_id: int) -> int:
        """Availability zone that hosts ``worker_id``."""
        return self.rack_of(worker_id) // max(1, self.racks_per_az)

    def rack_workers(self, rack: int) -> range:
        """Worker ids placed in ``rack``."""
        return range(rack * self.workers_per_sgs,
                     (rack + 1) * self.workers_per_sgs)

    def az_racks(self, az: int) -> range:
        """Rack ids grouped into availability zone ``az``."""
        per = max(1, self.racks_per_az)
        return range(az * per, min((az + 1) * per, self.n_sgs))


def build_sgs_pool(env: Env, cc: ClusterConfig,
                   sgs_cfg: Optional[SGSConfig],
                   sgs_ids: List[int],
                   execute: Optional[ExecuteFn] = None,
                   backend_submit: Optional[SubmitFn] = None
                   ) -> List[SemiGlobalScheduler]:
    """Construct the SGSs named by ``sgs_ids`` (a subset of
    ``range(cc.n_sgs)``), each over its rack-sized worker pool.  Worker ids
    are globally consistent — SGS ``sid`` always owns workers
    ``[sid * workers_per_sgs, (sid+1) * workers_per_sgs)`` — so a sharded
    run (``repro.sim.shard``) building disjoint subsets in separate
    processes assigns exactly the ids a full ``build_cluster`` would."""
    sgss: List[SemiGlobalScheduler] = []
    for sid in sgs_ids:
        wid = sid * cc.workers_per_sgs
        pool = [Worker(worker_id=wid + j, cores=cc.cores_per_worker,
                       pool_mem_mb=cc.pool_mem_mb)
                for j in range(cc.workers_per_sgs)]
        sgss.append(SemiGlobalScheduler(sgs_id=sid, workers=pool, env=env,
                                        config=sgs_cfg, execute=execute,
                                        backend_submit=backend_submit))
    return sgss


def build_cluster(env: Env, cluster: Optional[ClusterConfig] = None,
                  sgs_cfg: Optional[SGSConfig] = None,
                  lbs_cfg: Optional[LBSConfig] = None,
                  execute: Optional[ExecuteFn] = None,
                  backend_submit: Optional[SubmitFn] = None) -> LoadBalancer:
    """Construct the full Archipelago stack: workers -> SGSs -> LBS.

    ``backend_submit`` is the execution backend's asynchronous data-plane
    hook (``core.backends``), threaded uniformly into every SGS;
    ``execute`` is the legacy synchronous hook.  Both ``None`` keeps the
    modeled fast path (invocations charge ``fn.exec_time``)."""
    cc = cluster or ClusterConfig()
    sgss = build_sgs_pool(env, cc, sgs_cfg, list(range(cc.n_sgs)),
                          execute=execute, backend_submit=backend_submit)
    return LoadBalancer(sgss, config=lbs_cfg)


def build_flat_workers(cluster: Optional[ClusterConfig] = None) -> List[Worker]:
    """All workers in one flat pool (for the centralized baselines)."""
    cc = cluster or ClusterConfig()
    n = cc.n_sgs * cc.workers_per_sgs
    return [Worker(worker_id=i, cores=cc.cores_per_worker,
                   pool_mem_mb=cc.pool_mem_mb) for i in range(n)]
