"""Load balancing service (§5): sandbox-aware routing + per-DAG SGS scaling.

Responsibilities (§5.1): spread load across SGSs, and route requests to
maximize the number that land on a proactively allocated sandbox.  Scaling
follows Pseudocode 2: the universal indicator is per-DAG queuing delay
piggybacked on responses; the metric is the sandbox-count-weighted mean
queuing delay normalized by the DAG's slack.
"""
from __future__ import annotations

import bisect
import hashlib
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .autoscale import ScalingEvent
from .sgs import SemiGlobalScheduler
from .types import DagSpec, Request


def _hash(key: str) -> int:
    return int.from_bytes(hashlib.md5(key.encode()).digest()[:8], "big")


class ConsistentHashRing:
    """Classic Karger ring [31] with virtual nodes.

    Membership is dynamic: :meth:`add_node`/:meth:`remove_node` re-shard
    incrementally, so when the SGS set changes (control-plane elasticity,
    failover replacement pools) only the key range owned by the affected
    node's vnodes moves — roughly ``1/n`` of lookups for one of ``n``
    nodes — and every other key keeps its owner."""

    def __init__(self, ids: List[int], vnodes: int = 50):
        if not ids:
            # lookup() would otherwise die later with a bare
            # ZeroDivisionError from `% len(self._points)`
            raise ValueError(
                "ConsistentHashRing needs at least one SGS id")
        self._vnodes = vnodes
        self._points: List[int] = []
        self._owner: Dict[int, int] = {}
        for sid in ids:
            for v in range(vnodes):
                h = _hash(f"sgs-{sid}-vn{v}")
                self._points.append(h)
                self._owner[h] = sid
        self._points.sort()
        self._ids = sorted(set(ids))

    def ids(self) -> List[int]:
        return list(self._ids)

    def lookup(self, key: str) -> int:
        h = _hash(key)
        i = bisect.bisect_right(self._points, h) % len(self._points)
        return self._owner[self._points[i]]

    def successors(self, key: str) -> List[int]:
        """All SGS ids in ring order starting at the key's owner — the scale
        out order ("the next one in the ring", §5.2.2)."""
        first = self.lookup(key)
        ids = self._ids
        start = ids.index(first)
        return [ids[(start + k) % len(ids)] for k in range(len(ids))]

    # ------------------------------------------------------------ re-sharding
    def add_node(self, sid: int) -> None:
        """Insert one SGS id's vnodes (no-op if already present): only keys
        that hash between a new vnode and its predecessor move to ``sid``."""
        if sid in self._ids:
            return
        for v in range(self._vnodes):
            h = _hash(f"sgs-{sid}-vn{v}")
            if h in self._owner:        # 64-bit collision: keep the incumbent
                continue
            bisect.insort(self._points, h)
            self._owner[h] = sid
        bisect.insort(self._ids, sid)

    def remove_node(self, sid: int) -> None:
        """Drop one SGS id's vnodes: its key range redistributes to the ring
        successors; all other keys keep their owner.  Removing the last id
        raises (an empty ring cannot route)."""
        if sid not in self._ids:
            raise ValueError(f"unknown SGS id {sid}")
        if len(self._ids) == 1:
            raise ValueError(
                "ConsistentHashRing needs at least one SGS id")
        owner = self._owner
        self._points = [p for p in self._points if owner[p] != sid]
        for h in [h for h, o in owner.items() if o == sid]:
            del owner[h]
        self._ids.remove(sid)


@dataclass
class LBSConfig:
    scale_out_threshold: float = 0.3    # SOT (§7.5 knee)
    scale_in_threshold: float = 0.05    # well below SOT to avoid oscillation
    qdelay_window: int = 10             # samples per active SGS per decision
    decision_interval: float = 0.25     # fallback cadence for low-RPS DAGs
    scale_in_patience: int = 3          # consecutive below-SIT decisions
    discount_factor: float = 0.25       # removed-list ticket scaling (§5.2.3)
    ewma_alpha: float = 0.3
    gradual: bool = True                # False -> instant scale-out ablation
    sandbox_aware: bool = False         # handled via lottery tickets
    seed: int = 0
    # churn damping for the per-DAG SGS set (defaults are decision-neutral:
    # 0.0 / None reproduce the historical behavior exactly)
    scale_out_cooldown: float = 0.0     # min seconds between per-DAG adds
    max_sgs_per_dag: Optional[int] = None   # hard cap on a DAG's active set


@dataclass
class _DagState:
    dag: DagSpec
    active: List[int] = field(default_factory=list)     # in scale-out order
    removed: List[int] = field(default_factory=list)
    # piggybacked state per SGS
    qdelay_ewma: Dict[int, float] = field(default_factory=dict)
    qdelay_samples: Dict[int, int] = field(default_factory=dict)
    sandbox_count: Dict[int, int] = field(default_factory=dict)
    # unfolded piggyback reports [(sgs_id, qdelay, sandbox_count), ...]:
    # ``report`` is on the per-dispatch hot path, so samples are buffered
    # and folded into the EWMA/window dicts lazily at every read point
    # (_fold) — the fold preserves per-SGS sample order, so every value
    # ever *read* is bit-identical to eager per-sample updates
    pending: List[tuple] = field(default_factory=list)
    # max(dag.slack, 1e-6), computed once (the lottery divides by it on
    # every multi-SGS draw)
    slack_floor: float = 1.0
    last_decision: float = 0.0
    last_scale_out: float = -1e18       # for LBSConfig.scale_out_cooldown
    below_sit_streak: int = 0
    n_scale_outs: int = 0
    n_scale_ins: int = 0

    def __post_init__(self):
        self.slack_floor = max(self.dag.slack, 1e-6)


class LoadBalancer:
    def __init__(self, sgss: List[SemiGlobalScheduler],
                 config: Optional[LBSConfig] = None):
        self.cfg = config or LBSConfig()
        self._alpha = self.cfg.ewma_alpha
        self.sgss: Dict[int, SemiGlobalScheduler] = {s.sgs_id: s for s in sgss}
        self.ring = ConsistentHashRing(list(self.sgss))
        self._dag_state: Dict[str, _DagState] = {}
        self._rng = random.Random(self.cfg.seed)
        # wire the piggyback channel
        for s in sgss:
            s.report = self.report
        # history for benchmarks: (time, dag_id, n_active)
        self.scale_events: List[tuple] = []
        # typed mirror of the same decisions (core.autoscale.ScalingEvent):
        # merged with the LBS replica autoscaler's events into
        # ExperimentResult.scaling_events
        self.scaling_log: List[ScalingEvent] = []

    # ----------------------------------------------------------------- route
    def select(self, req: Request, now: float) -> SemiGlobalScheduler:
        """Routing decision only (lets callers model control-plane latency
        between the decision and the submission)."""
        st = self._dag_state.get(req.dag.dag_id)   # inlined _state fast path
        if st is None:
            st = self._state(req.dag, now)
        return self.sgss[self._lottery(st)]

    def route(self, req: Request, now: float) -> SemiGlobalScheduler:
        sgs = self.select(req, now)
        sgs.submit_request(req)
        return sgs

    def _state(self, dag: DagSpec, now: float) -> _DagState:
        st = self._dag_state.get(dag.dag_id)
        if st is None:
            # Initial SGS selection by consistent hashing (§5.2.2)
            first = self.ring.lookup(dag.dag_id)
            st = _DagState(dag=dag, active=[first], last_decision=now)
            st.sandbox_count[first] = 1
            self._dag_state[dag.dag_id] = st
        return st

    def _lottery(self, st: _DagState) -> int:
        """Lottery scheduling (§5.2.3): tickets proportional to each SGS's
        proactive sandbox count for this DAG; removed-list SGSs keep
        discounted tickets so scale-in drains gradually.

        Hotspot damping (§5.1 responsibility (1)): tickets are divided by
        (1 + qdelay/slack) using the piggybacked per-SGS queuing delay.
        Without this, sandbox-proportional routing is a positive feedback
        loop — a hot SGS receives more requests, estimates more demand,
        allocates more sandboxes, and earns even more tickets while its
        queue grows.
        """
        active = st.active
        if not self.cfg.gradual:
            # instant-scaling ablation: plain round-robin over active SGSs
            return active[self._rng.randrange(len(active))]
        if len(active) == 1 and not st.removed:
            # single-SGS fast path (the common case): the draw is a foregone
            # conclusion, but still consume one uniform so the RNG stream —
            # and therefore every later multi-SGS lottery — is unchanged
            self._rng.random()
            return active[0]
        if st.pending:
            self._fold(st)      # multi-SGS draw reads EWMAs/counts
        # damping divisor: 1 + qdelay/slack (hotspot damping, see docstring);
        # hand-inlined — this runs once per routed request under scale-out.
        # Stored sandbox counts are already clamped >= 1 (``_fold``,
        # ``_state``, ``_scale_out``), so the historical
        # ``max(1.0, float(count))`` reduces to a default of 1.
        slack = st.slack_floor
        ewma_get = st.qdelay_ewma.get
        count_get = st.sandbox_count.get
        tickets: List[Tuple[int, float]] = []
        append = tickets.append
        total = 0.0
        for sid in active:
            t = count_get(sid, 1) / (1.0 + ewma_get(sid, 0.0) / slack)
            append((sid, t))
            total += t
        if st.removed:
            discount = self.cfg.discount_factor
            for sid in st.removed:
                t = (discount * count_get(sid, 1)
                     / (1.0 + ewma_get(sid, 0.0) / slack))
                append((sid, t))
                total += t
        pick = self._rng.random() * total
        acc = 0.0
        for sid, t in tickets:
            acc += t
            if pick <= acc:
                return sid
        return tickets[-1][0]

    # ------------------------------------------------------------- piggyback
    def report(self, dag_id: str, sgs_id: int, qdelay: float,
               sandbox_count: int) -> None:
        st = self._dag_state.get(dag_id)
        if st is None:
            return
        st.pending.append((sgs_id, qdelay, sandbox_count))

    def _fold(self, st: _DagState) -> None:
        """Apply buffered piggyback reports in arrival order (see
        ``_DagState.pending``).  Called before any read of the EWMA/window/
        count dicts; produces exactly the values eager per-report updates
        would have."""
        pending = st.pending
        if not pending:
            return
        a = self._alpha
        ewma = st.qdelay_ewma
        samples = st.qdelay_samples
        counts = st.sandbox_count
        for sgs_id, qdelay, sandbox_count in pending:
            prev = ewma.get(sgs_id)
            ewma[sgs_id] = qdelay if prev is None \
                else a * qdelay + (1 - a) * prev
            samples[sgs_id] = samples.get(sgs_id, 0) + 1
            counts[sgs_id] = sandbox_count if sandbox_count > 1 else 1
        pending.clear()

    # --------------------------------------------------------------- scaling
    def scaling_metric(self, st: _DagState) -> float:
        """Pseudocode 2, lines 3-6: sandbox-count weighted queuing delay,
        normalized by the DAG's available slack (deadline-awareness)."""
        num = 0.0
        den = 0.0
        for sid in st.active:
            n = st.sandbox_count.get(sid, 1)
            qd = st.qdelay_ewma.get(sid, 0.0)
            num += n * qd
            den += n
        if den == 0:
            return 0.0
        weighted = num / den
        slack = max(st.dag.slack, 1e-6)
        return weighted / slack

    def check_scaling(self, now: float) -> None:
        """Periodic scaling pass over every DAG (engine calls this each
        decision interval; decisions also gate on filled windows, §5.2.2)."""
        for st in self._dag_state.values():
            if st.pending:
                self._fold(st)
            window_full = all(
                st.qdelay_samples.get(sid, 0) >= self.cfg.qdelay_window
                for sid in st.active)
            timed_out = now - st.last_decision >= self.cfg.decision_interval
            if not (window_full or (timed_out and any(st.qdelay_samples.values()))):
                continue
            metric = self.scaling_metric(st)
            if metric > self.cfg.scale_out_threshold:
                st.below_sit_streak = 0
                if (self.cfg.scale_out_cooldown > 0.0
                        and now - st.last_scale_out
                        < self.cfg.scale_out_cooldown):
                    continue    # cooling down: keep observing
                if not self._scale_out(st, now):
                    continue    # already at max SGSs: keep observing
                st.last_scale_out = now
                action = "scale_out"
            elif metric < self.cfg.scale_in_threshold and len(st.active) > 1:
                # oscillation damping: require several consecutive quiet
                # decisions before dissociating an SGS (§5.2.2 "well below")
                st.below_sit_streak += 1
                if st.below_sit_streak < self.cfg.scale_in_patience:
                    st.last_decision = now
                    continue
                st.below_sit_streak = 0
                self._scale_in(st, now)
                action = "scale_in"
            else:
                st.below_sit_streak = 0
                continue
            # reinitialize windows (and the EWMAs themselves) so the next
            # decision observes only post-decision data (§5.2.2)
            st.qdelay_samples = {sid: 0 for sid in st.active}
            st.qdelay_ewma = {}
            st.last_decision = now
            n_active = len(st.active)
            self.scale_events.append((now, st.dag.dag_id, n_active))
            delta = 1 if action == "scale_out" else -1
            self.scaling_log.append(ScalingEvent(
                t=round(now, 6), component="sgs", action=action,
                n_before=n_active - delta, n_after=n_active,
                metric=round(metric, 6), detail={"dag_id": st.dag.dag_id}))

    def _scale_out(self, st: _DagState, now: float) -> bool:
        cap = self.cfg.max_sgs_per_dag
        if cap is not None and len(st.active) >= cap:
            return False
        for sid in self.ring.successors(st.dag.dag_id):
            if sid not in st.active:
                if sid in st.removed:
                    st.removed.remove(sid)
                st.active.append(sid)
                st.n_scale_outs += 1
                # gradual ramp-up: the new SGS pre-allocates the mean sandbox
                # count across active SGSs (including itself), and starts with
                # 1 lottery ticket (§5.2.3)
                if self.cfg.gradual:
                    counts = [st.sandbox_count.get(s, 0) for s in st.active]
                    avg = max(1, int(round(sum(counts) / len(st.active))))
                    per_fn = max(1, avg // max(1, len(st.dag.functions)))
                    self.sgss[sid].preallocate(st.dag, per_fn)
                st.sandbox_count[sid] = 1
                return True
        return False

    def _scale_in(self, st: _DagState, now: float) -> None:
        # remove the SGS that was added last (§5.2.2)
        sid = st.active.pop()
        st.removed.append(sid)
        st.n_scale_ins += 1

    # ----------------------------------------------------- SGS-set elasticity
    def add_sgs(self, sgs: SemiGlobalScheduler) -> None:
        """Join a new SGS into the live control plane: wire its piggyback
        channel and re-shard the consistent-hash ring incrementally (only
        the new node's key range moves, so existing per-DAG active sets are
        untouched — new DAGs and future scale-outs see the larger set)."""
        if sgs.sgs_id in self.sgss:
            raise ValueError(f"SGS id {sgs.sgs_id} already present")
        self.sgss[sgs.sgs_id] = sgs
        sgs.report = self.report
        self.ring.add_node(sgs.sgs_id)

    def remove_sgs(self, sgs_id: int) -> None:
        """Retire one SGS from the control plane: drop its ring vnodes (its
        key range redistributes minimally) and scrub it from every DAG's
        active/removed sets and piggyback state.  A DAG whose entire active
        set was the retiree is re-homed through the post-removal ring, like
        a fresh DAG.  Removing the last SGS raises."""
        if sgs_id not in self.sgss:
            raise ValueError(f"unknown SGS id {sgs_id}")
        if len(self.sgss) == 1:
            raise ValueError("cannot remove the last SGS")
        self.ring.remove_node(sgs_id)
        del self.sgss[sgs_id]
        for dag_id, st in self._dag_state.items():
            if st.pending:
                st.pending = [p for p in st.pending if p[0] != sgs_id]
            if sgs_id in st.removed:
                st.removed.remove(sgs_id)
            if sgs_id in st.active:
                st.active.remove(sgs_id)
                if not st.active:
                    home = self.ring.lookup(dag_id)
                    st.active.append(home)
                    st.sandbox_count.setdefault(home, 1)
            st.qdelay_ewma.pop(sgs_id, None)
            st.qdelay_samples.pop(sgs_id, None)
            st.sandbox_count.pop(sgs_id, None)

    # -------------------------------------------------------------- failover
    def replace_sgs(self, new_sgs: SemiGlobalScheduler) -> None:
        """SGS failover rewiring (§6.1, ``core.fault.fail_sgs``): swap the
        live instance behind an existing ``sgs_id``.  The consistent-hash
        ring and the per-DAG active/removed lists key on the id, so routing
        re-routes to the replacement with no ring churn — the paper's
        "a replacement instance restores from the store and continues".
        Per-SGS queuing-delay state from the dead instance is dropped (its
        queue died with it); sandbox counts are kept — they describe the
        surviving worker pool, not the dead scheduler process."""
        sid = new_sgs.sgs_id
        self.sgss[sid] = new_sgs
        new_sgs.report = self.report
        for st in self._dag_state.values():
            if st.pending:
                st.pending = [p for p in st.pending if p[0] != sid]
            st.qdelay_ewma.pop(sid, None)
            st.qdelay_samples.pop(sid, None)

    # --------------------------------------------------------------- queries
    def n_active(self, dag_id: str) -> int:
        st = self._dag_state.get(dag_id)
        return len(st.active) if st else 0
