"""Fault tolerance (§6.1) and declarative chaos injection.

Fail-stop model with an immediate failure detector:

* **Worker failures** — the owning scheduler updates its cluster view (the
  worker leaves the pool, its sandboxes are gone); invocations that were
  executing there are re-enqueued (retry).  Recovery pressure is handled by
  the existing machinery: lost capacity raises queuing delay, the LBS
  observes it and scales the affected DAGs out; even placement means
  surviving workers still hold proactive sandboxes.
* **SGS / LB failures** — all state an SGS or the LB needs to resume
  (estimator state, sandbox demand targets, per-DAG SGS mappings) is kept
  in a reliable external ``StateStore``; a replacement instance restores
  from it and continues (``fail_sgs``).

Chaos injection is declarative: a :class:`FaultPlan` is a tuple of typed,
seeded :class:`FaultEvent`\\ s carried on ``Experiment.faults`` as a
sweepable axis.  ``simulate`` compiles the plan through a
:class:`FaultInjector` into plain ``env.call_at`` events — a run without a
plan never touches any of this (pay-for-what-you-use; the zero-fault
equivalence goldens stay decision-identical).  New fault shapes register
with :func:`register_fault`, mirroring the stack/backend registries
(docs/FAULTS.md).
"""
from __future__ import annotations

import copy
import heapq
import random
from dataclasses import dataclass
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple)

from .lbs import LoadBalancer
from .sgs import SemiGlobalScheduler
from .types import Invocation, SandboxState


class StateStore:
    """The paper's 'reliable external store' (a KV store; in the prototype a
    goroutine-served map, here an in-process dict with deep-copy semantics
    so restored state is decoupled from the writer's objects)."""

    def __init__(self):
        self._data: Dict[str, Any] = {}
        self.n_writes = 0

    def put(self, key: str, value: Any) -> None:
        self._data[key] = copy.deepcopy(value)
        self.n_writes += 1

    def get(self, key: str, default: Any = None) -> Any:
        v = self._data.get(key, default)
        return copy.deepcopy(v)


# ---------------------------------------------------------------------------
# SGS state checkpoint / restore
# ---------------------------------------------------------------------------


def checkpoint_sgs(sgs: SemiGlobalScheduler, store: StateStore) -> None:
    """Persist the soft state a replacement SGS needs (§6.1): demand targets
    + estimator rates.  (Queued invocations are re-submitted by the LBS on
    failover in a real deployment; sandboxes are soft state by design.)"""
    store.put(f"sgs/{sgs.sgs_id}/demand", dict(sgs.sandboxes.demand_map))
    store.put(f"sgs/{sgs.sgs_id}/fn_specs", dict(sgs.sandboxes.fn_specs))
    store.put(f"sgs/{sgs.sgs_id}/dags", dict(sgs._dags))


def restore_sgs(sgs: SemiGlobalScheduler, store: StateStore,
                now: float) -> None:
    """Bring a fresh SGS instance up from the store: re-learn served DAGs
    and proactively re-allocate to the recorded demand."""
    sgs._dags.update(store.get(f"sgs/{sgs.sgs_id}/dags", {}))
    sgs.sandboxes.fn_specs.update(store.get(f"sgs/{sgs.sgs_id}/fn_specs", {}))
    demand = store.get(f"sgs/{sgs.sgs_id}/demand", {})
    for fn_name, d in demand.items():
        spec = sgs.sandboxes.fn_specs.get(fn_name)
        if spec is not None and d > 0:
            # hold the restored demand as a floor for ramp_window (same
            # mechanism as LBS preallocation): the fresh estimator has seen
            # no arrivals yet, so without the floor the next estimation tick
            # would soft-evict the pool the checkpoint just rebuilt
            sgs._demand_floor[fn_name] = (d, now + sgs.cfg.ramp_window)
            sgs.sandboxes.set_demand(spec, d, now)
    sgs._ensure_ticking()


def checkpoint_lbs(lbs: LoadBalancer, store: StateStore) -> None:
    """Persist per-DAG SGS mappings (active/removed lists)."""
    for st in lbs._dag_state.values():
        lbs._fold(st)       # reading sandbox_count: apply buffered reports
    mapping = {dag_id: {"active": list(st.active),
                        "removed": list(st.removed),
                        "sandbox_count": dict(st.sandbox_count)}
               for dag_id, st in lbs._dag_state.items()}
    store.put("lbs/mapping", mapping)


def restore_lbs(lbs: LoadBalancer, store: StateStore, now: float) -> None:
    mapping = store.get("lbs/mapping", {})
    for dag_id, m in mapping.items():
        st = lbs._dag_state.get(dag_id)
        if st is None:
            continue    # DAG spec re-registers on its next request
        st.active = [s for s in m["active"] if s in lbs.sgss]
        st.removed = [s for s in m["removed"] if s in lbs.sgss]
        st.sandbox_count.update(m["sandbox_count"])


# ---------------------------------------------------------------------------
# Worker failure injection
# ---------------------------------------------------------------------------


def fail_worker(scheduler: Any, worker_id: int) -> int:
    """Fail-stop one worker: remove it from the scheduler's cluster view,
    drop its sandboxes, and re-enqueue invocations that were running on it.
    Works for SGS instances and the flat baselines (CentralizedFIFO /
    Sparrow / pull), which share the ``_inflight``/``_dead_workers``
    registration shape.  Returns the number of re-enqueued invocations."""
    if isinstance(scheduler, SemiGlobalScheduler):
        return _fail_worker_sgs(scheduler, worker_id)
    return _fail_worker_flat(scheduler, worker_id)


def _fail_worker_sgs(sgs: SemiGlobalScheduler, worker_id: int) -> int:
    w = next((w for w in sgs.workers if w.worker_id == worker_id), None)
    if w is None:
        return 0
    # keep the SGS's free-core accounting consistent before the view changes
    sgs._free_cores -= max(0, w.free_cores)
    if sgs.workers is not sgs.sandboxes.workers:
        sgs.workers.remove(w)
    # removes from the manager's pool view and every per-function index
    sgs.sandboxes.remove_worker(w)
    # retry in-flight invocations: the completion callbacks for this worker
    # become no-ops (the inflight registration is gone) and the request is
    # re-driven from the queue
    now = sgs.env.now()
    n_retry = 0
    for inv in list(sgs._inflight.get(worker_id, {}).values()):
        retry = Invocation(request=inv.request, fn=inv.fn, ready_time=now)
        k0, k1, k2 = retry.priority_key()
        heapq.heappush(sgs._queue, (k0, k1, k2, retry))
        n_retry += 1
    sgs._dead_workers.add(worker_id)
    sgs._inflight.pop(worker_id, None)
    sgs._dispatch()
    return n_retry


def _fail_worker_flat(sched: Any, worker_id: int) -> int:
    """Fail-stop for the flat baselines.  Sparrow additionally loses the
    dead worker's local queue; those invocations are re-placed too."""
    w = next((w for w in sched.workers if w.worker_id == worker_id), None)
    if w is None:
        return 0
    sched.workers.remove(w)
    sched._dead_workers.add(worker_id)
    now = sched.env.now()
    retries: List[Invocation] = []
    for inv in list(sched._inflight.pop(worker_id, {}).values()):
        retries.append(Invocation(request=inv.request, fn=inv.fn,
                                  ready_time=now))
    wq = getattr(sched, "_wqueues", None)
    if wq is not None:                  # Sparrow: drain the lost local queue
        for inv in wq.pop(worker_id, ()):
            retries.append(Invocation(request=inv.request, fn=inv.fn,
                                      ready_time=now))
    place = getattr(sched, "_place", None)
    if place is not None:
        for retry in retries:
            place(retry)
    else:                               # FIFO-shaped: back of the queue
        sched._queue.extend(retries)
        sched._dispatch()
    return len(retries)


# ---------------------------------------------------------------------------
# SGS fail-stop + StateStore-backed failover
# ---------------------------------------------------------------------------


def fail_sgs(lbs: LoadBalancer, sgs_id: int, store: StateStore, env: Any,
             ) -> Tuple[Optional[SemiGlobalScheduler], int]:
    """Fail-stop one SGS and bring up a replacement restored from the
    reliable store (§6.1): "a replacement instance restores from it and
    continues".

    Only the scheduler *process* dies — the worker pool (a rack) survives:
    warm sandboxes stay resident and executions already running there keep
    running (their completions forward to the replacement through the
    victim's ``_successor`` pointer).  What dies with the process is the
    SRSF queue — re-enqueued into the replacement as retries, modeling the
    LBS re-submitting un-acked work — and the demand estimator, rebuilt
    from the checkpointed targets and held as a floor for ``ramp_window``.
    Returns ``(replacement, n_retry)``; ``(None, 0)`` if the id is unknown
    or already failed over."""
    victim = lbs.sgss.get(sgs_id)
    if victim is None or victim._successor is not None:
        return None, 0
    now = env.now()
    replacement = SemiGlobalScheduler(
        sgs_id, victim.workers, env, config=victim.cfg,
        execute=victim.execute, backend_submit=victim.backend_submit)
    # The replacement adopts a pool that is already warm: eagerly rebuild
    # the per-function indices so the fused hot-path transitions (which
    # assume the index exists) are safe for sandboxes created pre-failure.
    mgr = replacement.sandboxes
    for w in victim.workers:
        for fn_name in w._buckets:
            mgr._ensure_fn(fn_name)
    # Executions on surviving workers keep running: adopt the in-flight
    # registrations (by reference — the victim's bound callbacks forward
    # here via _successor and pop from this same dict).
    replacement._inflight = victim._inflight
    replacement._dead_workers = victim._dead_workers
    # Metric streams continue across the failover (same id, same pool).
    replacement.queuing_delays = victim.queuing_delays
    replacement.queuing_delay_times = victim.queuing_delay_times
    replacement.completed_requests = victim.completed_requests
    replacement.n_cold_starts = victim.n_cold_starts
    replacement.n_warm_hits = victim.n_warm_hits
    replacement.on_complete = victim.on_complete
    # Soft state from the store: served DAGs, fn specs, demand targets.
    restore_sgs(replacement, store, now)
    # The dead scheduler's queue: re-submitted by the LBS on failover.
    n_retry = 0
    for _, _, _, inv in victim._queue:
        retry = Invocation(request=inv.request, fn=inv.fn, ready_time=now)
        k0, k1, k2 = retry.priority_key()
        heapq.heappush(replacement._queue, (k0, k1, k2, retry))
        n_retry += 1
    victim._queue = []
    victim._successor = replacement
    lbs.replace_sgs(replacement)
    replacement._dispatch()
    return replacement, n_retry


# ---------------------------------------------------------------------------
# Declarative fault plans
# ---------------------------------------------------------------------------


def _freeze_kwargs(kw: Mapping[str, Any]) -> Tuple[Tuple[str, Any], ...]:
    return tuple(sorted(kw.items()))


@dataclass(frozen=True)
class FaultEvent:
    """One declarative fault: a registered kind plus its schedule.

    Schedule is either ``at`` (fire once at that simulated time) or
    ``rate`` (a seeded Poisson process of occurrences per second over
    ``[start, end)``; ``end=None`` means the run horizon).  ``kwargs`` are
    the handler's arguments, stored as a sorted tuple of pairs so events
    hash, pickle (``run_sweep`` workers) and compare cleanly."""
    kind: str
    at: Optional[float] = None
    rate: Optional[float] = None
    start: float = 0.0
    end: Optional[float] = None
    kwargs: Tuple[Tuple[str, Any], ...] = ()

    def arg_dict(self) -> Dict[str, Any]:
        return dict(self.kwargs)

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "at": self.at, "rate": self.rate,
                "start": self.start, "end": self.end,
                "kwargs": dict(self.kwargs)}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "FaultEvent":
        return cls(kind=d["kind"], at=d.get("at"), rate=d.get("rate"),
                   start=d.get("start", 0.0), end=d.get("end"),
                   kwargs=_freeze_kwargs(d.get("kwargs", {})))


@dataclass(frozen=True)
class FaultPlan:
    """A seeded, declarative chaos schedule — the sweepable ``faults=``
    axis on ``Experiment``.  Frozen (hashable, picklable) so plans can sit
    in sweep axes and ship to ``run_sweep`` worker processes."""
    events: Tuple[FaultEvent, ...] = ()
    seed: int = 0
    name: str = ""
    # §6.1 periodic StateStore checkpoint cadence, used when the plan
    # contains sgs_failstop events: a fail-stop victim cannot checkpoint at
    # the failure instant, so the replacement restores state up to this
    # many seconds stale.
    checkpoint_interval: float = 0.25

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))

    def label(self) -> str:
        if self.name:
            return self.name
        if not self.events:
            return "none"
        return "+".join(ev.kind for ev in self.events)

    def to_dict(self) -> Dict[str, Any]:
        return {"events": [ev.to_dict() for ev in self.events],
                "seed": self.seed, "name": self.name,
                "checkpoint_interval": self.checkpoint_interval}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "FaultPlan":
        return cls(events=tuple(FaultEvent.from_dict(e)
                                for e in d.get("events", [])),
                   seed=d.get("seed", 0), name=d.get("name", ""),
                   checkpoint_interval=d.get("checkpoint_interval", 0.25))


# -- event constructors ------------------------------------------------------


def worker_crash(k: int = 1, at: Optional[float] = None,
                 rate: Optional[float] = None, start: float = 0.0,
                 end: Optional[float] = None, sgs: Optional[int] = None,
                 spare: int = 1) -> FaultEvent:
    """Fail-stop ``k`` workers per occurrence, uniformly over pools that
    would keep at least ``spare`` workers.  Exactly one of ``at``
    (one-shot) / ``rate`` (Poisson occurrences per second) is required;
    ``sgs`` narrows the blast radius to one scheduler's pool."""
    if (at is None) == (rate is None):
        raise ValueError("worker_crash needs exactly one of at= / rate=")
    return FaultEvent("worker_crash", at=at, rate=rate, start=start, end=end,
                      kwargs=_freeze_kwargs(
                          {"k": k, "sgs": sgs, "spare": spare}))


def sgs_failstop(at: float, sgs: Optional[int] = None) -> FaultEvent:
    """Kill one SGS at ``at``; a replacement restores from the StateStore
    and the LBS re-routes (no-op on stacks without an SGS tier).  ``sgs``
    None picks a victim with the plan's seeded RNG."""
    return FaultEvent("sgs_failstop", at=at,
                      kwargs=_freeze_kwargs({"sgs": sgs}))


def mass_eviction(at: float, frac: float = 1.0,
                  sgs: Optional[int] = None) -> FaultEvent:
    """Cold-boot storm: evict a fraction of all idle sandboxes at ``at``.
    Demand targets survive, so proactive allocation immediately rebuilds
    the pool — a setup-work avalanche (Dirigent's lifecycle-churn regime)."""
    return FaultEvent("mass_eviction", at=at,
                      kwargs=_freeze_kwargs({"frac": frac, "sgs": sgs}))


def control_plane_delay(at: Optional[float] = None,
                        rate: Optional[float] = None, stall: float = 0.05,
                        target: str = "both", start: float = 0.0,
                        end: Optional[float] = None) -> FaultEvent:
    """Control-plane latency spike: LBS/SGS decision servers stall for
    ``stall`` seconds (GC pause, leader re-election).  ``target`` is
    ``"lbs"``, ``"sgs"`` or ``"both"``."""
    if (at is None) == (rate is None):
        raise ValueError(
            "control_plane_delay needs exactly one of at= / rate=")
    return FaultEvent("control_plane_delay", at=at, rate=rate, start=start,
                      end=end,
                      kwargs=_freeze_kwargs(
                          {"stall": stall, "target": target}))


# -- fault registry (mirrors stacks/backends) --------------------------------

FaultHandler = Callable[..., None]      # handler(ctx, **kwargs)

_FAULTS: Dict[str, FaultHandler] = {}


def register_fault(name: str) -> Callable[[FaultHandler], FaultHandler]:
    """Decorator registering a fault handler under ``name``.  Handlers take
    a :class:`FaultContext` plus the event's kwargs; new fault shapes are
    one decorated function (docs/FAULTS.md)."""
    def deco(fn: FaultHandler) -> FaultHandler:
        if name in _FAULTS:
            raise ValueError(f"fault {name!r} is already registered")
        _FAULTS[name] = fn
        return fn
    return deco


def get_fault(name: str) -> FaultHandler:
    try:
        return _FAULTS[name]
    except KeyError:
        raise ValueError(
            f"unknown fault {name!r}; registered faults: "
            f"{', '.join(sorted(_FAULTS))}") from None


def available_faults() -> List[str]:
    return sorted(_FAULTS)


# -- injection ---------------------------------------------------------------


@dataclass
class FaultContext:
    """What a fault handler gets to work with at fire time."""
    env: Any
    stack: Any
    rng: random.Random
    injector: "FaultInjector"

    def schedulers(self, sgs: Optional[int] = None) -> List[Any]:
        """Live scheduler instances: the SGS tier (optionally one id) for
        archipelago-shaped stacks, else the single flat scheduler."""
        lbs = getattr(self.stack, "lbs", None)
        if lbs is not None:
            if sgs is not None:
                s = lbs.sgss.get(sgs)
                return [s] if s is not None else []
            return [lbs.sgss[sid] for sid in sorted(lbs.sgss)]
        sched = getattr(self.stack, "scheduler", None)
        return [sched] if sched is not None else []

    def record(self, kind: str, **info: Any) -> None:
        self.injector.record(kind, self.env.now(), **info)


class FaultInjector:
    """Compiles a :class:`FaultPlan` into plain event-loop callbacks.

    ``simulate`` constructs one when ``Experiment.faults`` is set and calls
    :meth:`install` after the stack is built — occurrence times are
    expanded (seeded, deterministic) and scheduled with ``env.call_at``; if
    the plan kills SGSs, a periodic §6.1 checkpoint hook persists the
    doomed instances' soft state to the injector's StateStore so failover
    has something to restore from."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.rng = random.Random(plan.seed)
        self.store = StateStore()
        self.fault_events: List[Dict[str, Any]] = []
        self.n_retries = 0

    def record(self, kind: str, t: float, **info: Any) -> None:
        self.fault_events.append(
            {"kind": kind, "t": round(float(t), 6), **info})

    def occurrences(self, ev: FaultEvent, horizon: float) -> List[float]:
        """Fire times for one event: ``at`` verbatim, ``rate`` as a seeded
        Poisson process over [start, min(end, horizon))."""
        if ev.at is not None:
            return [float(ev.at)]
        if not ev.rate or ev.rate <= 0.0:
            return []
        end = horizon if ev.end is None else min(ev.end, horizon)
        out: List[float] = []
        t = ev.start
        while True:
            t += self.rng.expovariate(ev.rate)
            if t >= end:
                return out
            out.append(t)

    def install(self, env: Any, stack: Any, horizon: float) -> None:
        ctx = FaultContext(env=env, stack=stack, rng=self.rng, injector=self)
        lbs = getattr(stack, "lbs", None)
        doomed: set = set()
        for ev in self.plan.events:
            handler = get_fault(ev.kind)       # fail fast on unknown kinds
            kwargs = ev.arg_dict()
            for t in self.occurrences(ev, horizon):
                kw = dict(kwargs)
                if ev.kind == "sgs_failstop" and lbs is not None and lbs.sgss:
                    if kw.get("sgs") is None:  # seeded victim choice, fixed
                        ids = sorted(lbs.sgss)  # at install so checkpoints
                        kw["sgs"] = ids[self.rng.randrange(len(ids))]  # cover it
                    doomed.add(kw["sgs"])
                env.call_at(t, self._fire, ctx, handler, kw)
        if doomed and lbs is not None:
            # Periodic checkpoints, scoped to the instances this plan will
            # kill: checkpointing all 80 xl-tier SGSs every 250 ms would
            # deep-copy DAG specs the run never restores.
            interval = max(1e-3, self.plan.checkpoint_interval)
            self._checkpoint(lbs, doomed)               # t=0 baseline
            env.every(interval, lambda: self._checkpoint(lbs, doomed),
                      until=horizon)

    @staticmethod
    def _fire(ctx: "FaultContext", handler: FaultHandler,
              kw: Dict[str, Any]) -> None:
        handler(ctx, **kw)

    def _checkpoint(self, lbs: LoadBalancer, doomed: set) -> None:
        for sid in sorted(doomed):
            s = lbs.sgss.get(sid)
            if s is not None and s._successor is None:
                checkpoint_sgs(s, self.store)
        checkpoint_lbs(lbs, self.store)


# -- built-in handlers -------------------------------------------------------


@register_fault("worker_crash")
def _worker_crash(ctx: FaultContext, k: int = 1, sgs: Optional[int] = None,
                  spare: int = 1, **_: Any) -> None:
    scheds = ctx.schedulers(sgs)
    killed: List[int] = []
    n_retry = 0
    keep = max(1, spare)        # never take a pool to zero workers
    for _i in range(int(k)):
        eligible = [(s, w) for s in scheds if len(s.workers) > keep
                    for w in s.workers]
        if not eligible:
            break
        s, w = eligible[ctx.rng.randrange(len(eligible))]
        n_retry += fail_worker(s, w.worker_id)
        killed.append(w.worker_id)
    ctx.injector.n_retries += n_retry
    ctx.record("worker_crash", killed=killed, n_retry=n_retry)


@register_fault("sgs_failstop")
def _sgs_failstop(ctx: FaultContext, sgs: Optional[int] = None,
                  **_: Any) -> None:
    lbs = getattr(ctx.stack, "lbs", None)
    if lbs is None or sgs is None or sgs not in lbs.sgss:
        ctx.record("sgs_failstop", sgs=sgs, skipped=True)
        return
    replacement, n_retry = fail_sgs(lbs, sgs, ctx.injector.store, ctx.env)
    ctx.injector.n_retries += n_retry
    ctx.record("sgs_failstop", sgs=sgs, n_retry=n_retry,
               restored=replacement is not None)


@register_fault("mass_eviction")
def _mass_eviction(ctx: FaultContext, frac: float = 1.0,
                   sgs: Optional[int] = None, **_: Any) -> None:
    n_evicted = 0
    for sched in ctx.schedulers(sgs):
        for w in sched.workers:
            for s in w.sandboxes:       # fresh list: safe to remove during
                if s.state is SandboxState.BUSY:
                    continue            # executing: kill the worker instead
                if frac >= 1.0 or ctx.rng.random() < frac:
                    w.remove_sandbox(s)
                    n_evicted += 1
    ctx.record("mass_eviction", frac=frac, n_evicted=n_evicted)


@register_fault("control_plane_delay")
def _control_plane_delay(ctx: FaultContext, stall: float = 0.05,
                         target: str = "both", **_: Any) -> None:
    # Modeled by advancing the M/D/1 decision-service clocks' busy_until:
    # decisions arriving behind the spike queue exactly as they would
    # behind a blocked single-threaded decision loop.  Data plane untouched.
    now = ctx.env.now()
    stack = ctx.stack
    n_clocks = 0
    clocks: List[Any] = []
    if target in ("lbs", "both"):
        clocks.extend(getattr(stack, "_lb_clocks", ()) or ())
    if target in ("sgs", "both"):
        sgs_clocks = getattr(stack, "_sgs_clocks", None)
        if sgs_clocks:
            clocks.extend(sgs_clocks.values())
        c = getattr(stack, "_clock", None)     # flat stacks: one clock
        if c is not None:
            clocks.append(c)
    for c in clocks:
        c.busy_until = max(c.busy_until, now) + stall
        n_clocks += 1
    ctx.record("control_plane_delay", stall=stall, target=target,
               n_clocks=n_clocks)


# ---------------------------------------------------------------------------
# Recovery metrics
# ---------------------------------------------------------------------------


def time_to_recovery(metrics: Any, t_fault: float, horizon: float,
                     window: float = 0.5, tolerance: float = 0.05,
                     baseline_windows: int = 4) -> Optional[Dict[str, Any]]:
    """Windowed time-to-deadline-recovery after a fault at ``t_fault``.

    baseline = deadline-met over the ``baseline_windows * window`` seconds
    before the fault; recovery = end of the first post-fault window whose
    deadline-met is back within ``tolerance`` of baseline.  Windows use the
    zero-copy ``Metrics.window`` views.  Returns ``{"baseline_met",
    "dip_met", "recovery_s"}`` (``recovery_s`` None if the run ends
    unrecovered; ``dip_met`` is the worst post-fault window) or None when
    there is no pre-fault signal to compare against."""
    t0 = max(0.0, t_fault - baseline_windows * window)
    base = metrics.window(t0, t_fault).deadline_met_frac()
    if base != base:        # NaN: nothing completed pre-fault
        return None
    target = base - tolerance
    dip: Optional[float] = None
    recovery_s: Optional[float] = None
    t = t_fault
    while t < horizon:
        m = metrics.window(t, min(t + window, horizon)).deadline_met_frac()
        if m == m:          # skip empty windows
            dip = m if dip is None else min(dip, m)
            if m >= target:
                recovery_s = (t + window) - t_fault
                break
        t += window
    out = {"baseline_met": round(base, 6),
           "recovery_s": None if recovery_s is None else round(recovery_s, 6)}
    out["dip_met"] = None if dip is None else round(dip, 6)
    return out


def recovery_summary(metrics: Any, injector: FaultInjector, horizon: float,
                     window: float = 0.5,
                     tolerance: float = 0.05) -> Dict[str, Any]:
    """Per-fired-fault recovery report for ``ExperimentResult.recovery``."""
    events: List[Dict[str, Any]] = []
    for rec in injector.fault_events:
        t = rec.get("t")
        if t is None:
            continue
        entry: Dict[str, Any] = {"kind": rec["kind"], "t": t}
        r = time_to_recovery(metrics, t, horizon, window, tolerance)
        if r is not None:
            entry.update(r)
        events.append(entry)
    return {"window_s": window, "tolerance": tolerance, "events": events}
