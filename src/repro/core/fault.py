"""Fault tolerance (§6.1).

Fail-stop model with an immediate failure detector:

* **Worker failures** — the owning SGS updates its cluster view (the worker
  leaves the pool, its sandboxes are gone); invocations that were executing
  there are re-enqueued (retry).  Recovery pressure is handled by the
  existing machinery: lost capacity raises queuing delay, the LBS observes
  it and scales the affected DAGs out; even placement means surviving
  workers still hold proactive sandboxes.
* **SGS / LB failures** — all state an SGS or the LB needs to resume
  (estimator state, sandbox demand targets, per-DAG SGS mappings) is kept
  in a reliable external ``StateStore``; a replacement instance restores
  from it and continues.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from .lbs import LoadBalancer
from .sgs import SemiGlobalScheduler
from .types import Invocation, SandboxState


class StateStore:
    """The paper's 'reliable external store' (a KV store; in the prototype a
    goroutine-served map, here an in-process dict with deep-copy semantics
    so restored state is decoupled from the writer's objects)."""

    def __init__(self):
        self._data: Dict[str, Any] = {}
        self.n_writes = 0

    def put(self, key: str, value: Any) -> None:
        self._data[key] = copy.deepcopy(value)
        self.n_writes += 1

    def get(self, key: str, default: Any = None) -> Any:
        v = self._data.get(key, default)
        return copy.deepcopy(v)


# ---------------------------------------------------------------------------
# SGS state checkpoint / restore
# ---------------------------------------------------------------------------


def checkpoint_sgs(sgs: SemiGlobalScheduler, store: StateStore) -> None:
    """Persist the soft state a replacement SGS needs (§6.1): demand targets
    + estimator rates.  (Queued invocations are re-submitted by the LBS on
    failover in a real deployment; sandboxes are soft state by design.)"""
    store.put(f"sgs/{sgs.sgs_id}/demand", dict(sgs.sandboxes.demand_map))
    store.put(f"sgs/{sgs.sgs_id}/fn_specs", dict(sgs.sandboxes.fn_specs))
    store.put(f"sgs/{sgs.sgs_id}/dags", dict(sgs._dags))


def restore_sgs(sgs: SemiGlobalScheduler, store: StateStore,
                now: float) -> None:
    """Bring a fresh SGS instance up from the store: re-learn served DAGs
    and proactively re-allocate to the recorded demand."""
    sgs._dags.update(store.get(f"sgs/{sgs.sgs_id}/dags", {}))
    sgs.sandboxes.fn_specs.update(store.get(f"sgs/{sgs.sgs_id}/fn_specs", {}))
    demand = store.get(f"sgs/{sgs.sgs_id}/demand", {})
    for fn_name, d in demand.items():
        spec = sgs.sandboxes.fn_specs.get(fn_name)
        if spec is not None and d > 0:
            sgs.sandboxes.set_demand(spec, d, now)
    sgs._ensure_ticking()


def checkpoint_lbs(lbs: LoadBalancer, store: StateStore) -> None:
    """Persist per-DAG SGS mappings (active/removed lists)."""
    for st in lbs._dag_state.values():
        lbs._fold(st)       # reading sandbox_count: apply buffered reports
    mapping = {dag_id: {"active": list(st.active),
                        "removed": list(st.removed),
                        "sandbox_count": dict(st.sandbox_count)}
               for dag_id, st in lbs._dag_state.items()}
    store.put("lbs/mapping", mapping)


def restore_lbs(lbs: LoadBalancer, store: StateStore, now: float) -> None:
    mapping = store.get("lbs/mapping", {})
    for dag_id, m in mapping.items():
        st = lbs._dag_state.get(dag_id)
        if st is None:
            continue    # DAG spec re-registers on its next request
        st.active = [s for s in m["active"] if s in lbs.sgss]
        st.removed = [s for s in m["removed"] if s in lbs.sgss]
        st.sandbox_count.update(m["sandbox_count"])


# ---------------------------------------------------------------------------
# Worker failure injection
# ---------------------------------------------------------------------------


def fail_worker(sgs: SemiGlobalScheduler, worker_id: int) -> int:
    """Fail-stop one worker: remove it from the SGS's cluster view, drop its
    sandboxes, and re-enqueue invocations that were running on it.  Returns
    the number of re-enqueued invocations."""
    import heapq

    w = next((w for w in sgs.workers if w.worker_id == worker_id), None)
    if w is None:
        return 0
    # keep the SGS's free-core accounting consistent before the view changes
    sgs._free_cores -= max(0, w.free_cores)
    if sgs.workers is not sgs.sandboxes.workers:
        sgs.workers.remove(w)
    # removes from the manager's pool view and every per-function index
    sgs.sandboxes.remove_worker(w)
    # retry in-flight invocations: the completion callbacks for this worker
    # become no-ops because the request is re-driven from the queue
    now = sgs.env.now()
    n_retry = 0
    for inv in list(sgs._inflight.get(worker_id, {}).values()):
        retry = Invocation(request=inv.request, fn=inv.fn, ready_time=now)
        k0, k1, k2 = retry.priority_key()
        heapq.heappush(sgs._queue, (k0, k1, k2, retry))
        n_retry += 1
    sgs._dead_workers.add(worker_id)
    sgs._inflight.pop(worker_id, None)
    sgs._dispatch()
    return n_retry
