"""Fault tolerance (§6.1) and declarative chaos injection.

Fail-stop model with an immediate failure detector:

* **Worker failures** — the owning scheduler updates its cluster view (the
  worker leaves the pool, its sandboxes are gone); invocations that were
  executing there are re-enqueued (retry).  Recovery pressure is handled by
  the existing machinery: lost capacity raises queuing delay, the LBS
  observes it and scales the affected DAGs out; even placement means
  surviving workers still hold proactive sandboxes.
* **SGS / LB failures** — all state an SGS or the LB needs to resume
  (estimator state, sandbox demand targets, per-DAG SGS mappings) is kept
  in a reliable external ``StateStore``; a replacement instance restores
  from it and continues (``fail_sgs``).

Chaos injection is declarative: a :class:`FaultPlan` is a tuple of typed,
seeded :class:`FaultEvent`\\ s carried on ``Experiment.faults`` as a
sweepable axis.  ``simulate`` compiles the plan through a
:class:`FaultInjector` into plain ``env.call_at`` events — a run without a
plan never touches any of this (pay-for-what-you-use; the zero-fault
equivalence goldens stay decision-identical).  New fault shapes register
with :func:`register_fault`, mirroring the stack/backend registries
(docs/FAULTS.md).
"""
from __future__ import annotations

import copy
import heapq
import random
from dataclasses import dataclass
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple)

from .lbs import LoadBalancer
from .sgs import SemiGlobalScheduler
from .types import Invocation, SandboxState


class StateStore:
    """The paper's 'reliable external store' (a KV store; in the prototype a
    goroutine-served map, here an in-process dict with deep-copy semantics
    so restored state is decoupled from the writer's objects)."""

    def __init__(self):
        self._data: Dict[str, Any] = {}
        self.n_writes = 0

    def put(self, key: str, value: Any) -> None:
        self._data[key] = copy.deepcopy(value)
        self.n_writes += 1

    def get(self, key: str, default: Any = None) -> Any:
        v = self._data.get(key, default)
        return copy.deepcopy(v)


# ---------------------------------------------------------------------------
# SGS state checkpoint / restore
# ---------------------------------------------------------------------------


def checkpoint_sgs(sgs: SemiGlobalScheduler, store: StateStore) -> None:
    """Persist the soft state a replacement SGS needs (§6.1): demand targets
    + estimator rates.  (Queued invocations are re-submitted by the LBS on
    failover in a real deployment; sandboxes are soft state by design.)"""
    store.put(f"sgs/{sgs.sgs_id}/demand", dict(sgs.sandboxes.demand_map))
    store.put(f"sgs/{sgs.sgs_id}/fn_specs", dict(sgs.sandboxes.fn_specs))
    store.put(f"sgs/{sgs.sgs_id}/dags", dict(sgs._dags))


def restore_sgs(sgs: SemiGlobalScheduler, store: StateStore,
                now: float) -> None:
    """Bring a fresh SGS instance up from the store: re-learn served DAGs
    and proactively re-allocate to the recorded demand."""
    sgs._dags.update(store.get(f"sgs/{sgs.sgs_id}/dags", {}))
    sgs.sandboxes.fn_specs.update(store.get(f"sgs/{sgs.sgs_id}/fn_specs", {}))
    demand = store.get(f"sgs/{sgs.sgs_id}/demand", {})
    for fn_name, d in demand.items():
        spec = sgs.sandboxes.fn_specs.get(fn_name)
        if spec is not None and d > 0:
            # hold the restored demand as a floor for ramp_window (same
            # mechanism as LBS preallocation): the fresh estimator has seen
            # no arrivals yet, so without the floor the next estimation tick
            # would soft-evict the pool the checkpoint just rebuilt
            sgs._demand_floor[fn_name] = (d, now + sgs.cfg.ramp_window)
            sgs.sandboxes.set_demand(spec, d, now)
    sgs._ensure_ticking()


def checkpoint_lbs(lbs: LoadBalancer, store: StateStore) -> None:
    """Persist per-DAG SGS mappings (active/removed lists)."""
    for st in lbs._dag_state.values():
        lbs._fold(st)       # reading sandbox_count: apply buffered reports
    mapping = {dag_id: {"active": list(st.active),
                        "removed": list(st.removed),
                        "sandbox_count": dict(st.sandbox_count)}
               for dag_id, st in lbs._dag_state.items()}
    store.put("lbs/mapping", mapping)


def restore_lbs(lbs: LoadBalancer, store: StateStore, now: float) -> None:
    mapping = store.get("lbs/mapping", {})
    for dag_id, m in mapping.items():
        st = lbs._dag_state.get(dag_id)
        if st is None:
            continue    # DAG spec re-registers on its next request
        st.active = [s for s in m["active"] if s in lbs.sgss]
        st.removed = [s for s in m["removed"] if s in lbs.sgss]
        st.sandbox_count.update(m["sandbox_count"])


# ---------------------------------------------------------------------------
# Worker failure injection
# ---------------------------------------------------------------------------


def fail_worker(scheduler: Any, worker_id: int) -> int:
    """Fail-stop one worker: remove it from the scheduler's cluster view,
    drop its sandboxes, and re-enqueue invocations that were running on it.
    Works for SGS instances and the flat baselines (CentralizedFIFO /
    Sparrow / pull), which share the ``_inflight``/``_dead_workers``
    registration shape.  Returns the number of re-enqueued invocations."""
    if isinstance(scheduler, SemiGlobalScheduler):
        return _fail_worker_sgs(scheduler, worker_id)
    return _fail_worker_flat(scheduler, worker_id)


def _fail_worker_sgs(sgs: SemiGlobalScheduler, worker_id: int) -> int:
    w = next((w for w in sgs.workers if w.worker_id == worker_id), None)
    if w is None:
        return 0
    # keep the SGS's free-core accounting consistent before the view changes
    sgs._free_cores -= max(0, w.free_cores)
    if sgs.workers is not sgs.sandboxes.workers:
        sgs.workers.remove(w)
    # removes from the manager's pool view and every per-function index
    sgs.sandboxes.remove_worker(w)
    # retry in-flight invocations: the completion callbacks for this worker
    # become no-ops (the inflight registration is gone) and the request is
    # re-driven from the queue
    now = sgs.env.now()
    n_retry = 0
    dropped: List[int] = []
    for inv in list(sgs._inflight.get(worker_id, {}).values()):
        dropped.append(inv.inv_id)
        retry = Invocation(request=inv.request, fn=inv.fn, ready_time=now)
        k0, k1, k2 = retry.priority_key()
        heapq.heappush(sgs._queue, (k0, k1, k2, retry))
        n_retry += 1
    sgs._dead_workers.add(worker_id)
    sgs._inflight.pop(worker_id, None)
    sgs._slow.pop(worker_id, None)
    # a batching data plane may still hold the dead members in a pending
    # window or an active decode slot: release them before the retries land
    drop = getattr(sgs, "backend_drop", None)
    if drop is not None and dropped:
        drop(dropped)
    sgs._dispatch()
    return n_retry


def _fail_worker_flat(sched: Any, worker_id: int) -> int:
    """Fail-stop for the flat baselines.  Sparrow additionally loses the
    dead worker's local queue; those invocations are re-placed too."""
    w = next((w for w in sched.workers if w.worker_id == worker_id), None)
    if w is None:
        return 0
    sched.workers.remove(w)
    sched._dead_workers.add(worker_id)
    now = sched.env.now()
    retries: List[Invocation] = []
    dropped: List[int] = []
    for inv in list(sched._inflight.pop(worker_id, {}).values()):
        dropped.append(inv.inv_id)
        retries.append(Invocation(request=inv.request, fn=inv.fn,
                                  ready_time=now))
    wq = getattr(sched, "_wqueues", None)
    if wq is not None:                  # Sparrow: drain the lost local queue
        for inv in wq.pop(worker_id, ()):
            retries.append(Invocation(request=inv.request, fn=inv.fn,
                                      ready_time=now))
    slow = getattr(sched, "_slow", None)
    if slow is not None:
        slow.pop(worker_id, None)
    drop = getattr(sched, "backend_drop", None)
    if drop is not None and dropped:
        drop(dropped)
    place = getattr(sched, "_place", None)
    if place is not None:
        for retry in retries:
            place(retry)
    else:                               # FIFO-shaped: back of the queue
        sched._queue.extend(retries)
        sched._dispatch()
    return len(retries)


# ---------------------------------------------------------------------------
# SGS fail-stop + StateStore-backed failover
# ---------------------------------------------------------------------------


def fail_sgs(lbs: LoadBalancer, sgs_id: int, store: StateStore, env: Any,
             ) -> Tuple[Optional[SemiGlobalScheduler], int]:
    """Fail-stop one SGS and bring up a replacement restored from the
    reliable store (§6.1): "a replacement instance restores from it and
    continues".

    Only the scheduler *process* dies — the worker pool (a rack) survives:
    warm sandboxes stay resident and executions already running there keep
    running (their completions forward to the replacement through the
    victim's ``_successor`` pointer).  What dies with the process is the
    SRSF queue — re-enqueued into the replacement as retries, modeling the
    LBS re-submitting un-acked work — and the demand estimator, rebuilt
    from the checkpointed targets and held as a floor for ``ramp_window``.
    Returns ``(replacement, n_retry)``; ``(None, 0)`` if the id is unknown
    or already failed over."""
    victim = lbs.sgss.get(sgs_id)
    if victim is None or victim._successor is not None:
        return None, 0
    now = env.now()
    replacement = SemiGlobalScheduler(
        sgs_id, victim.workers, env, config=victim.cfg,
        execute=victim.execute, backend_submit=victim.backend_submit)
    # The replacement adopts a pool that is already warm: eagerly rebuild
    # the per-function indices so the fused hot-path transitions (which
    # assume the index exists) are safe for sandboxes created pre-failure.
    mgr = replacement.sandboxes
    for w in victim.workers:
        for fn_name in w._buckets:
            mgr._ensure_fn(fn_name)
    # Executions on surviving workers keep running: adopt the in-flight
    # registrations (by reference — the victim's bound callbacks forward
    # here via _successor and pop from this same dict).
    replacement._inflight = victim._inflight
    replacement._dead_workers = victim._dead_workers
    # Degraded-mode state rides the pool, not the scheduler process: slow
    # workers stay slow across failover, the data-plane drop hook and the
    # hedging config carry over (shared rng: the hedge stream continues).
    replacement._slow = victim._slow
    replacement.backend_drop = victim.backend_drop
    replacement._hedge_timeout = victim._hedge_timeout
    replacement._hedge_jitter = victim._hedge_jitter
    replacement._hedge_rng = victim._hedge_rng
    replacement.n_hedges = victim.n_hedges
    # Metric streams continue across the failover (same id, same pool).
    replacement.queuing_delays = victim.queuing_delays
    replacement.queuing_delay_times = victim.queuing_delay_times
    replacement.completed_requests = victim.completed_requests
    replacement.n_cold_starts = victim.n_cold_starts
    replacement.n_warm_hits = victim.n_warm_hits
    replacement.on_complete = victim.on_complete
    # Soft state from the store: served DAGs, fn specs, demand targets.
    restore_sgs(replacement, store, now)
    # The dead scheduler's queue: re-submitted by the LBS on failover.
    n_retry = 0
    for _, _, _, inv in victim._queue:
        retry = Invocation(request=inv.request, fn=inv.fn, ready_time=now)
        k0, k1, k2 = retry.priority_key()
        heapq.heappush(replacement._queue, (k0, k1, k2, retry))
        n_retry += 1
    victim._queue = []
    victim._successor = replacement
    lbs.replace_sgs(replacement)
    replacement._dispatch()
    return replacement, n_retry


def evacuate_sgs(lbs: LoadBalancer, sgs_id: int) -> int:
    """Re-home a worker-less SGS's load onto a surviving peer.

    A rack-power / AZ-outage event can take an SGS's *entire* pool down; a
    scheduler with zero workers would hold its queue (and everything the
    LBS keeps routing to it) forever.  Model the LBS health-check re-route
    with the same mechanism §6.1 failover uses: move the queued
    invocations to the survivor and leave a ``_successor`` pointer so
    in-flight submissions and completions forward there.  The survivor is
    the peer with the most free cores (ties: lowest id) — deterministic,
    so seeded plans replay exactly.  Returns the number of re-homed
    queued invocations; no-op unless the pool is actually empty."""
    victim = lbs.sgss.get(sgs_id)
    if victim is None or victim._successor is not None or victim.workers:
        return 0
    survivors = [s for sid, s in sorted(lbs.sgss.items())
                 if sid != sgs_id and s.workers and s._successor is None]
    if not survivors:
        return 0
    succ = max(survivors, key=lambda s: s._free_cores)
    n_moved = 0
    for item in victim._queue:
        heapq.heappush(succ._queue, item)
        n_moved += 1
    victim._queue = []
    victim._successor = succ
    succ._dispatch()
    return n_moved


# ---------------------------------------------------------------------------
# Declarative fault plans
# ---------------------------------------------------------------------------


def _freeze_kwargs(kw: Mapping[str, Any]) -> Tuple[Tuple[str, Any], ...]:
    return tuple(sorted(kw.items()))


@dataclass(frozen=True)
class FaultEvent:
    """One declarative fault: a registered kind plus its schedule.

    Schedule is either ``at`` (fire once at that simulated time) or
    ``rate`` (a seeded Poisson process of occurrences per second over
    ``[start, end)``; ``end=None`` means the run horizon).  ``kwargs`` are
    the handler's arguments, stored as a sorted tuple of pairs so events
    hash, pickle (``run_sweep`` workers) and compare cleanly."""
    kind: str
    at: Optional[float] = None
    rate: Optional[float] = None
    start: float = 0.0
    end: Optional[float] = None
    kwargs: Tuple[Tuple[str, Any], ...] = ()

    def arg_dict(self) -> Dict[str, Any]:
        return dict(self.kwargs)

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "at": self.at, "rate": self.rate,
                "start": self.start, "end": self.end,
                "kwargs": dict(self.kwargs)}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "FaultEvent":
        return cls(kind=d["kind"], at=d.get("at"), rate=d.get("rate"),
                   start=d.get("start", 0.0), end=d.get("end"),
                   kwargs=_freeze_kwargs(d.get("kwargs", {})))


@dataclass(frozen=True)
class FaultPlan:
    """A seeded, declarative chaos schedule — the sweepable ``faults=``
    axis on ``Experiment``.  Frozen (hashable, picklable) so plans can sit
    in sweep axes and ship to ``run_sweep`` worker processes."""
    events: Tuple[FaultEvent, ...] = ()
    seed: int = 0
    name: str = ""
    # §6.1 periodic StateStore checkpoint cadence, used when the plan
    # contains sgs_failstop events: a fail-stop victim cannot checkpoint at
    # the failure instant, so the replacement restores state up to this
    # many seconds stale.
    checkpoint_interval: float = 0.25

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))

    def label(self) -> str:
        if self.name:
            return self.name
        if not self.events:
            return "none"
        return "+".join(ev.kind for ev in self.events)

    def to_dict(self) -> Dict[str, Any]:
        return {"events": [ev.to_dict() for ev in self.events],
                "seed": self.seed, "name": self.name,
                "checkpoint_interval": self.checkpoint_interval}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "FaultPlan":
        return cls(events=tuple(FaultEvent.from_dict(e)
                                for e in d.get("events", [])),
                   seed=d.get("seed", 0), name=d.get("name", ""),
                   checkpoint_interval=d.get("checkpoint_interval", 0.25))


# -- event constructors ------------------------------------------------------


def worker_crash(k: int = 1, at: Optional[float] = None,
                 rate: Optional[float] = None, start: float = 0.0,
                 end: Optional[float] = None, sgs: Optional[int] = None,
                 spare: int = 1) -> FaultEvent:
    """Fail-stop ``k`` workers per occurrence, uniformly over pools that
    would keep at least ``spare`` workers.  Exactly one of ``at``
    (one-shot) / ``rate`` (Poisson occurrences per second) is required;
    ``sgs`` narrows the blast radius to one scheduler's pool."""
    if (at is None) == (rate is None):
        raise ValueError("worker_crash needs exactly one of at= / rate=")
    return FaultEvent("worker_crash", at=at, rate=rate, start=start, end=end,
                      kwargs=_freeze_kwargs(
                          {"k": k, "sgs": sgs, "spare": spare}))


def sgs_failstop(at: float, sgs: Optional[int] = None) -> FaultEvent:
    """Kill one SGS at ``at``; a replacement restores from the StateStore
    and the LBS re-routes (no-op on stacks without an SGS tier).  ``sgs``
    None picks a victim with the plan's seeded RNG."""
    return FaultEvent("sgs_failstop", at=at,
                      kwargs=_freeze_kwargs({"sgs": sgs}))


def mass_eviction(at: float, frac: float = 1.0,
                  sgs: Optional[int] = None) -> FaultEvent:
    """Cold-boot storm: evict a fraction of all idle sandboxes at ``at``.
    Demand targets survive, so proactive allocation immediately rebuilds
    the pool — a setup-work avalanche (Dirigent's lifecycle-churn regime)."""
    return FaultEvent("mass_eviction", at=at,
                      kwargs=_freeze_kwargs({"frac": frac, "sgs": sgs}))


def control_plane_delay(at: Optional[float] = None,
                        rate: Optional[float] = None, stall: float = 0.05,
                        target: str = "both", start: float = 0.0,
                        end: Optional[float] = None) -> FaultEvent:
    """Control-plane latency spike: LBS/SGS decision servers stall for
    ``stall`` seconds (GC pause, leader re-election).  ``target`` is
    ``"lbs"``, ``"sgs"`` or ``"both"``."""
    if (at is None) == (rate is None):
        raise ValueError(
            "control_plane_delay needs exactly one of at= / rate=")
    return FaultEvent("control_plane_delay", at=at, rate=rate, start=start,
                      end=end,
                      kwargs=_freeze_kwargs(
                          {"stall": stall, "target": target}))


# -- correlated / gray-failure event constructors ----------------------------


def rack_power(at: float, rack: Optional[int] = None,
               spare_racks: int = 1) -> FaultEvent:
    """Power loss for one rack (== one SGS worker pool, §4.1): every
    worker in it fail-stops at once.  ``rack=None`` picks a live rack with
    the plan's seeded RNG; at least ``spare_racks`` other live racks are
    always kept.  On archipelago the orphaned SGS is evacuated onto a
    surviving peer (:func:`evacuate_sgs`)."""
    return FaultEvent("rack_power", at=at,
                      kwargs=_freeze_kwargs(
                          {"rack": rack, "spare_racks": spare_racks}))


def az_outage(at: float, az: Optional[int] = None,
              spare_azs: int = 1) -> FaultEvent:
    """Availability-zone outage: every rack in the zone loses power
    simultaneously (``ClusterConfig.racks_per_az`` racks per AZ).
    ``az=None`` picks a live zone with the plan's seeded RNG; at least
    ``spare_azs`` other live zones always survive."""
    return FaultEvent("az_outage", at=at,
                      kwargs=_freeze_kwargs(
                          {"az": az, "spare_azs": spare_azs}))


def cascading_crash(at: Optional[float] = None,
                    rate: Optional[float] = None, p: float = 0.5,
                    k0: int = 1, max_kills: Optional[int] = None,
                    start: float = 0.0, end: Optional[float] = None,
                    sgs: Optional[int] = None,
                    spare: int = 1) -> FaultEvent:
    """Correlated cascade: ``k0`` seed crashes, each of which propagates
    another crash with probability ``p`` (a seeded branching process — the
    retry/overload storm one failure puts on its neighbours).  ``p`` is
    part of the frozen event, so identical plans replay identical
    cascades.  Bounded by ``max_kills`` and the ``spare``-per-pool floor."""
    if (at is None) == (rate is None):
        raise ValueError("cascading_crash needs exactly one of at= / rate=")
    if not 0.0 <= float(p) <= 1.0:
        raise ValueError(f"cascading_crash propagation p={p} must be in "
                         f"[0, 1]")
    return FaultEvent("cascading_crash", at=at, rate=rate, start=start,
                      end=end,
                      kwargs=_freeze_kwargs(
                          {"p": p, "k0": k0, "max_kills": max_kills,
                           "sgs": sgs, "spare": spare}))


def slow_worker(k: int = 1, factor: float = 4.0, at: Optional[float] = None,
                rate: Optional[float] = None, start: float = 0.0,
                end: Optional[float] = None,
                duration: Optional[float] = None,
                sgs: Optional[int] = None) -> FaultEvent:
    """Gray failure: ``k`` seeded workers keep accepting work but execute
    it ``factor``× slower (thermal throttling, a noisy neighbour, a dying
    disk).  Nothing is killed and no detector fires — mitigation is the
    hedged-retry layer, not failover.  ``duration=None`` degrades for the
    rest of the run."""
    if (at is None) == (rate is None):
        raise ValueError("slow_worker needs exactly one of at= / rate=")
    if float(factor) <= 0.0:
        raise ValueError(f"slow_worker factor={factor} must be > 0")
    return FaultEvent("slow_worker", at=at, rate=rate, start=start, end=end,
                      kwargs=_freeze_kwargs(
                          {"k": k, "factor": factor, "duration": duration,
                           "sgs": sgs}))


def flaky_network(at: Optional[float] = None, rate: Optional[float] = None,
                  jitter: float = 0.02, target: str = "both",
                  start: float = 0.0, end: Optional[float] = None
                  ) -> FaultEvent:
    """Gray failure: seeded jitter on the LBS↔SGS control-plane service
    clocks — each occurrence stalls every targeted decision server for an
    independent uniform draw in ``[0, jitter)`` seconds (packet loss /
    retransmit storms, not a clean partition).  Pair with ``rate=`` for a
    sustained flaky link."""
    if (at is None) == (rate is None):
        raise ValueError("flaky_network needs exactly one of at= / rate=")
    if float(jitter) <= 0.0:
        raise ValueError(f"flaky_network jitter={jitter} must be > 0")
    return FaultEvent("flaky_network", at=at, rate=rate, start=start,
                      end=end,
                      kwargs=_freeze_kwargs(
                          {"jitter": jitter, "target": target}))


def memory_pressure(at: float, frac: float = 0.5, duration: float = 1.0,
                    sgs: Optional[int] = None) -> FaultEvent:
    """Gray failure: the proactive pool temporarily loses ``frac`` of its
    memory on every targeted worker (co-located batch job, page-cache
    bloat).  Resident sandboxes over the shrunk budget are evicted
    (oldest-first, never BUSY) — a real eviction storm, since demand
    targets survive and proactive allocation immediately rebuilds the
    pool.  Capacity restores after ``duration`` seconds."""
    if not 0.0 < float(frac) <= 1.0:
        raise ValueError(f"memory_pressure frac={frac} must be in (0, 1]")
    if float(duration) <= 0.0:
        raise ValueError(f"memory_pressure duration={duration} must be > 0")
    return FaultEvent("memory_pressure", at=at,
                      kwargs=_freeze_kwargs(
                          {"frac": frac, "duration": duration, "sgs": sgs}))


# -- fault registry (mirrors stacks/backends) --------------------------------

FaultHandler = Callable[..., None]      # handler(ctx, **kwargs)

_FAULTS: Dict[str, FaultHandler] = {}


def register_fault(name: str) -> Callable[[FaultHandler], FaultHandler]:
    """Decorator registering a fault handler under ``name``.  Handlers take
    a :class:`FaultContext` plus the event's kwargs; new fault shapes are
    one decorated function (docs/FAULTS.md)."""
    def deco(fn: FaultHandler) -> FaultHandler:
        if name in _FAULTS:
            raise ValueError(f"fault {name!r} is already registered")
        _FAULTS[name] = fn
        return fn
    return deco


def get_fault(name: str) -> FaultHandler:
    try:
        return _FAULTS[name]
    except KeyError:
        raise ValueError(
            f"unknown fault {name!r}; registered faults: "
            f"{', '.join(sorted(_FAULTS))}") from None


def available_faults() -> List[str]:
    return sorted(_FAULTS)


# -- injection ---------------------------------------------------------------


@dataclass
class FaultContext:
    """What a fault handler gets to work with at fire time."""
    env: Any
    stack: Any
    rng: random.Random
    injector: "FaultInjector"

    def schedulers(self, sgs: Optional[int] = None) -> List[Any]:
        """Live scheduler instances: the SGS tier (optionally one id) for
        archipelago-shaped stacks, else the single flat scheduler."""
        lbs = getattr(self.stack, "lbs", None)
        if lbs is not None:
            if sgs is not None:
                s = lbs.sgss.get(sgs)
                return [s] if s is not None else []
            return [lbs.sgss[sid] for sid in sorted(lbs.sgss)]
        sched = getattr(self.stack, "scheduler", None)
        return [sched] if sched is not None else []

    def record(self, kind: str, **info: Any) -> None:
        self.injector.record(kind, self.env.now(), **info)


class FaultInjector:
    """Compiles a :class:`FaultPlan` into plain event-loop callbacks.

    ``simulate`` constructs one when ``Experiment.faults`` is set and calls
    :meth:`install` after the stack is built — occurrence times are
    expanded (seeded, deterministic) and scheduled with ``env.call_at``; if
    the plan kills SGSs, a periodic §6.1 checkpoint hook persists the
    doomed instances' soft state to the injector's StateStore so failover
    has something to restore from."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.rng = random.Random(plan.seed)
        self.store = StateStore()
        self.fault_events: List[Dict[str, Any]] = []
        self.n_retries = 0

    def record(self, kind: str, t: float, **info: Any) -> None:
        self.fault_events.append(
            {"kind": kind, "t": round(float(t), 6), **info})

    def occurrences(self, ev: FaultEvent, horizon: float) -> List[float]:
        """Fire times for one event: ``at`` verbatim, ``rate`` as a seeded
        Poisson process over [start, min(end, horizon))."""
        if ev.at is not None:
            return [float(ev.at)]
        if not ev.rate or ev.rate <= 0.0:
            return []
        end = horizon if ev.end is None else min(ev.end, horizon)
        out: List[float] = []
        t = ev.start
        while True:
            t += self.rng.expovariate(ev.rate)
            if t >= end:
                return out
            out.append(t)

    def install(self, env: Any, stack: Any, horizon: float) -> None:
        ctx = FaultContext(env=env, stack=stack, rng=self.rng, injector=self)
        lbs = getattr(stack, "lbs", None)
        doomed: set = set()
        for ev in self.plan.events:
            handler = get_fault(ev.kind)       # fail fast on unknown kinds
            kwargs = ev.arg_dict()
            for t in self.occurrences(ev, horizon):
                kw = dict(kwargs)
                if ev.kind == "sgs_failstop" and lbs is not None and lbs.sgss:
                    if kw.get("sgs") is None:  # seeded victim choice, fixed
                        ids = sorted(lbs.sgss)  # at install so checkpoints
                        kw["sgs"] = ids[self.rng.randrange(len(ids))]  # cover it
                    doomed.add(kw["sgs"])
                env.call_at(t, self._fire, ctx, handler, kw)
        if doomed and lbs is not None:
            # Periodic checkpoints, scoped to the instances this plan will
            # kill: checkpointing all 80 xl-tier SGSs every 250 ms would
            # deep-copy DAG specs the run never restores.
            interval = max(1e-3, self.plan.checkpoint_interval)
            self._checkpoint(lbs, doomed)               # t=0 baseline
            env.every(interval, lambda: self._checkpoint(lbs, doomed),
                      until=horizon)

    @staticmethod
    def _fire(ctx: "FaultContext", handler: FaultHandler,
              kw: Dict[str, Any]) -> None:
        handler(ctx, **kw)

    def _checkpoint(self, lbs: LoadBalancer, doomed: set) -> None:
        for sid in sorted(doomed):
            s = lbs.sgss.get(sid)
            if s is not None and s._successor is None:
                checkpoint_sgs(s, self.store)
        checkpoint_lbs(lbs, self.store)


# -- built-in handlers -------------------------------------------------------


@register_fault("worker_crash")
def _worker_crash(ctx: FaultContext, k: int = 1, sgs: Optional[int] = None,
                  spare: int = 1, **_: Any) -> None:
    scheds = ctx.schedulers(sgs)
    killed: List[int] = []
    n_retry = 0
    keep = max(1, spare)        # never take a pool to zero workers
    for _i in range(int(k)):
        eligible = [(s, w) for s in scheds if len(s.workers) > keep
                    for w in s.workers]
        if not eligible:
            break
        s, w = eligible[ctx.rng.randrange(len(eligible))]
        n_retry += fail_worker(s, w.worker_id)
        killed.append(w.worker_id)
    ctx.injector.n_retries += n_retry
    ctx.record("worker_crash", killed=killed, n_retry=n_retry)


@register_fault("sgs_failstop")
def _sgs_failstop(ctx: FaultContext, sgs: Optional[int] = None,
                  **_: Any) -> None:
    lbs = getattr(ctx.stack, "lbs", None)
    if lbs is None or sgs is None or sgs not in lbs.sgss:
        ctx.record("sgs_failstop", sgs=sgs, skipped=True)
        return
    replacement, n_retry = fail_sgs(lbs, sgs, ctx.injector.store, ctx.env)
    ctx.injector.n_retries += n_retry
    ctx.record("sgs_failstop", sgs=sgs, n_retry=n_retry,
               restored=replacement is not None)


@register_fault("mass_eviction")
def _mass_eviction(ctx: FaultContext, frac: float = 1.0,
                   sgs: Optional[int] = None, **_: Any) -> None:
    n_evicted = 0
    for sched in ctx.schedulers(sgs):
        for w in sched.workers:
            for s in w.sandboxes:       # fresh list: safe to remove during
                if s.state is SandboxState.BUSY:
                    continue            # executing: kill the worker instead
                if frac >= 1.0 or ctx.rng.random() < frac:
                    w.remove_sandbox(s)
                    n_evicted += 1
    ctx.record("mass_eviction", frac=frac, n_evicted=n_evicted)


def _collect_clocks(stack: Any, target: str) -> List[Any]:
    """The M/D/1 decision-service clocks a control-plane fault targets:
    LBS replica clocks and/or the per-SGS (or flat single) clocks."""
    clocks: List[Any] = []
    if target in ("lbs", "both"):
        clocks.extend(getattr(stack, "_lb_clocks", ()) or ())
    if target in ("sgs", "both"):
        sgs_clocks = getattr(stack, "_sgs_clocks", None)
        if sgs_clocks:
            clocks.extend(sgs_clocks.values())
        c = getattr(stack, "_clock", None)     # flat stacks: one clock
        if c is not None:
            clocks.append(c)
    return clocks


@register_fault("control_plane_delay")
def _control_plane_delay(ctx: FaultContext, stall: float = 0.05,
                         target: str = "both", **_: Any) -> None:
    # Modeled by advancing the M/D/1 decision-service clocks' busy_until:
    # decisions arriving behind the spike queue exactly as they would
    # behind a blocked single-threaded decision loop.  Data plane untouched.
    now = ctx.env.now()
    n_clocks = 0
    for c in _collect_clocks(ctx.stack, target):
        c.busy_until = max(c.busy_until, now) + stall
        n_clocks += 1
    ctx.record("control_plane_delay", stall=stall, target=target,
               n_clocks=n_clocks)


# -- correlated fault handlers (worker → rack → AZ topology) -----------------


def _topology(ctx: FaultContext) -> Any:
    """The cluster's placement topology.  Rack/AZ membership is arithmetic
    on globally consistent worker ids, so one ``ClusterConfig`` describes
    archipelago pools and the flat baseline pools alike."""
    from .cluster import ClusterConfig
    exp = getattr(ctx.stack, "exp", None)
    cc = getattr(exp, "cluster", None) if exp is not None else None
    return cc if cc is not None else ClusterConfig()


def _live_racks(scheds: List[Any], cc: Any) -> Dict[int, List[Tuple[Any, int]]]:
    """rack id → [(owning scheduler, worker_id)] over the live cluster."""
    live: Dict[int, List[Tuple[Any, int]]] = {}
    for s in scheds:
        for w in s.workers:
            live.setdefault(cc.rack_of(w.worker_id), []).append(
                (s, w.worker_id))
    return live


def _kill_rack(ctx: FaultContext, rack: int,
               members: List[Tuple[Any, int]]) -> int:
    """Fail-stop every worker in ``rack``; on archipelago the rack IS an
    SGS pool, so the orphaned scheduler is evacuated onto a survivor."""
    n_retry = 0
    for s, wid in sorted(members, key=lambda m: m[1]):
        n_retry += fail_worker(s, wid)
    lbs = getattr(ctx.stack, "lbs", None)
    if lbs is not None:
        n_retry += evacuate_sgs(lbs, rack)
    return n_retry


@register_fault("rack_power")
def _rack_power(ctx: FaultContext, rack: Optional[int] = None,
                spare_racks: int = 1, **_: Any) -> None:
    cc = _topology(ctx)
    live = _live_racks(ctx.schedulers(), cc)
    keep = max(0, int(spare_racks))
    if len(live) <= keep or (rack is not None and rack not in live):
        ctx.record("rack_power", rack=rack, skipped=True)
        return
    if rack is None:
        racks = sorted(live)
        rack = racks[ctx.rng.randrange(len(racks))]
    n_killed = len(live[rack])
    n_retry = _kill_rack(ctx, rack, live[rack])
    ctx.injector.n_retries += n_retry
    ctx.record("rack_power", rack=rack, n_killed=n_killed, n_retry=n_retry)


@register_fault("az_outage")
def _az_outage(ctx: FaultContext, az: Optional[int] = None,
               spare_azs: int = 1, **_: Any) -> None:
    cc = _topology(ctx)
    live = _live_racks(ctx.schedulers(), cc)
    per = max(1, cc.racks_per_az)
    zones: Dict[int, List[int]] = {}
    for r in sorted(live):
        zones.setdefault(r // per, []).append(r)
    keep = max(0, int(spare_azs))
    if len(zones) <= keep or (az is not None and az not in zones):
        ctx.record("az_outage", az=az, skipped=True)
        return
    if az is None:
        ids = sorted(zones)
        az = ids[ctx.rng.randrange(len(ids))]
    racks = zones[az]
    n_killed = sum(len(live[r]) for r in racks)
    n_retry = 0
    for r in racks:
        n_retry += _kill_rack(ctx, r, live[r])
    ctx.injector.n_retries += n_retry
    ctx.record("az_outage", az=az, racks=racks, n_killed=n_killed,
               n_retry=n_retry)


@register_fault("cascading_crash")
def _cascading_crash(ctx: FaultContext, p: float = 0.5, k0: int = 1,
                     max_kills: Optional[int] = None,
                     sgs: Optional[int] = None, spare: int = 1,
                     **_: Any) -> None:
    scheds = ctx.schedulers(sgs)
    keep = max(1, int(spare))   # same floor as worker_crash: pools survive
    limit = (int(max_kills) if max_kills is not None
             else sum(len(s.workers) for s in scheds))
    p = float(p)
    killed: List[int] = []
    n_retry = 0
    pending = int(k0)
    while pending > 0 and len(killed) < limit:
        eligible = [(s, w) for s in scheds if len(s.workers) > keep
                    for w in s.workers]
        if not eligible:
            break
        s, w = eligible[ctx.rng.randrange(len(eligible))]
        n_retry += fail_worker(s, w.worker_id)
        killed.append(w.worker_id)
        pending -= 1
        if ctx.rng.random() < p:    # the failure propagates
            pending += 1
    ctx.injector.n_retries += n_retry
    ctx.record("cascading_crash", p=p, killed=killed, n_retry=n_retry)


# -- degraded-mode (gray failure) handlers -----------------------------------


def _restore_speed(sched: Any, worker_id: int, factor: float) -> None:
    if sched._slow.get(worker_id) == factor:
        del sched._slow[worker_id]


@register_fault("slow_worker")
def _slow_worker(ctx: FaultContext, k: int = 1, factor: float = 4.0,
                 duration: Optional[float] = None,
                 sgs: Optional[int] = None, **_: Any) -> None:
    factor = float(factor)
    slowed: List[int] = []
    eligible = [(s, w.worker_id) for s in ctx.schedulers(sgs)
                if getattr(s, "_slow", None) is not None
                for w in s.workers if w.worker_id not in s._slow]
    for _i in range(int(k)):
        if not eligible:
            break
        s, wid = eligible.pop(ctx.rng.randrange(len(eligible)))
        s._slow[wid] = factor
        slowed.append(wid)
        if duration is not None:
            ctx.env.call_after(float(duration), _restore_speed, s, wid,
                               factor)
    ctx.record("slow_worker", factor=factor, slowed=slowed)


@register_fault("flaky_network")
def _flaky_network(ctx: FaultContext, jitter: float = 0.02,
                   target: str = "both", **_: Any) -> None:
    # Same seam as control_plane_delay, but each clock draws its own
    # seeded stall in [0, jitter) — jitter, not a synchronized pause.
    now = ctx.env.now()
    jitter = float(jitter)
    n_clocks = 0
    total = 0.0
    for c in _collect_clocks(ctx.stack, target):
        stall = ctx.rng.random() * jitter
        c.busy_until = max(c.busy_until, now) + stall
        n_clocks += 1
        total += stall
    ctx.record("flaky_network", jitter=jitter, n_clocks=n_clocks,
               total_stall=round(total, 6))


def _restore_pool_mem(w: Any, cut: float) -> None:
    w.pool_mem_mb += cut


@register_fault("memory_pressure")
def _memory_pressure(ctx: FaultContext, frac: float = 0.5,
                     duration: float = 1.0, sgs: Optional[int] = None,
                     **_: Any) -> None:
    frac = float(frac)
    n_workers = 0
    n_evicted = 0
    for sched in ctx.schedulers(sgs):
        for w in sched.workers:
            cut = w.pool_mem_mb * frac
            if cut <= 0.0:
                continue
            w.pool_mem_mb -= cut
            n_evicted += w.shed_to_capacity()
            n_workers += 1
            ctx.env.call_after(float(duration), _restore_pool_mem, w, cut)
    ctx.record("memory_pressure", frac=frac, duration=duration,
               n_workers=n_workers, n_evicted=n_evicted)


# ---------------------------------------------------------------------------
# Recovery metrics
# ---------------------------------------------------------------------------


def time_to_recovery(metrics: Any, t_fault: float, horizon: float,
                     window: float = 0.5, tolerance: float = 0.05,
                     baseline_windows: int = 4) -> Optional[Dict[str, Any]]:
    """Windowed time-to-deadline-recovery after a fault at ``t_fault``.

    baseline = deadline-met over the ``baseline_windows * window`` seconds
    before the fault; recovery = end of the first post-fault window whose
    deadline-met is back within ``tolerance`` of baseline.  Windows use the
    zero-copy ``Metrics.window`` views.  Returns ``{"baseline_met",
    "dip_met", "recovery_s"}`` (``recovery_s`` None if the run ends
    unrecovered; ``dip_met`` is the worst post-fault window) or None when
    there is no pre-fault signal to compare against."""
    t0 = max(0.0, t_fault - baseline_windows * window)
    base = metrics.window(t0, t_fault).deadline_met_frac()
    if base != base:        # NaN: nothing completed pre-fault
        return None
    target = base - tolerance
    dip: Optional[float] = None
    recovery_s: Optional[float] = None
    t = t_fault
    while t < horizon:
        m = metrics.window(t, min(t + window, horizon)).deadline_met_frac()
        if m == m:          # skip empty windows
            dip = m if dip is None else min(dip, m)
            if m >= target:
                recovery_s = (t + window) - t_fault
                break
        t += window
    out = {"baseline_met": round(base, 6),
           "recovery_s": None if recovery_s is None else round(recovery_s, 6)}
    out["dip_met"] = None if dip is None else round(dip, 6)
    return out


def recovery_summary(metrics: Any, injector: FaultInjector, horizon: float,
                     window: float = 0.5,
                     tolerance: float = 0.05) -> Dict[str, Any]:
    """Per-fired-fault recovery report for ``ExperimentResult.recovery``."""
    events: List[Dict[str, Any]] = []
    for rec in injector.fault_events:
        t = rec.get("t")
        if t is None:
            continue
        entry: Dict[str, Any] = {"kind": rec["kind"], "t": t}
        r = time_to_recovery(metrics, t, horizon, window, tolerance)
        if r is not None:
            entry.update(r)
        events.append(entry)
    # roll-up for the bench scoreboards: worst time-to-recovery across the
    # plan's fired faults, and how many measurable dips never recovered
    recovered = [e["recovery_s"] for e in events
                 if e.get("recovery_s") is not None]
    n_unrecovered = sum(1 for e in events
                        if "recovery_s" in e and e["recovery_s"] is None)
    return {"window_s": window, "tolerance": tolerance,
            "max_recovery_s": max(recovered) if recovered else None,
            "n_unrecovered": n_unrecovered, "events": events}
