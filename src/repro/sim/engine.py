"""Minimal deterministic discrete-event engine implementing ``core.sgs.Env``."""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, List, Tuple


class SimEnv:
    """Heap-based event loop.  Deterministic: ties broken by insertion order."""

    def __init__(self):
        self._now = 0.0
        self._seq = itertools.count()
        self._events: List[Tuple[float, int, Callable[[], None]]] = []
        self.n_events = 0

    # -- core.sgs.Env interface ------------------------------------------------
    def now(self) -> float:
        return self._now

    def call_after(self, delay: float, fn: Callable[..., None],
                   *args) -> None:
        """Defer ``fn(*args)``; passing args directly (rather than closing
        over them) avoids a closure allocation per scheduled event on the
        simulation hot path."""
        self.call_at(self._now + max(0.0, delay), fn, *args)

    def call_at(self, t: float, fn: Callable[..., None], *args) -> None:
        if t < self._now - 1e-12:
            raise ValueError(f"cannot schedule in the past: {t} < {self._now}")
        heapq.heappush(self._events, (t, next(self._seq), fn, args))

    # -- driving -----------------------------------------------------------------
    def run_until(self, t_end: float) -> None:
        events = self._events
        while events and events[0][0] <= t_end:
            t, _, fn, args = heapq.heappop(events)
            self._now = t
            self.n_events += 1
            fn(*args)
        self._now = max(self._now, t_end)

    def run(self) -> None:
        events = self._events
        while events:
            t, _, fn, args = heapq.heappop(events)
            self._now = t
            self.n_events += 1
            fn(*args)

    def every(self, interval: float, fn: Callable[[], None],
              until: float = float("inf")) -> None:
        """Recurring callback helper (estimation ticks, scaling passes)."""

        def tick():
            if self._now > until:
                return
            fn()
            self.call_after(interval, tick)

        self.call_after(interval, tick)
