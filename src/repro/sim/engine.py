"""Minimal deterministic discrete-event engine implementing ``core.sgs.Env``."""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, List, Tuple


class SimEnv:
    """Heap-based event loop.  Deterministic: ties broken by insertion order."""

    def __init__(self):
        self._now = 0.0
        self._seq = itertools.count()
        self._events: List[Tuple[float, int, Callable[[], None]]] = []
        self.n_events = 0

    # -- core.sgs.Env interface ------------------------------------------------
    def now(self) -> float:
        return self._now

    def call_after(self, delay: float, fn: Callable[[], None]) -> None:
        self.call_at(self._now + max(0.0, delay), fn)

    def call_at(self, t: float, fn: Callable[[], None]) -> None:
        if t < self._now - 1e-12:
            raise ValueError(f"cannot schedule in the past: {t} < {self._now}")
        heapq.heappush(self._events, (t, next(self._seq), fn))

    # -- driving -----------------------------------------------------------------
    def run_until(self, t_end: float) -> None:
        while self._events and self._events[0][0] <= t_end:
            t, _, fn = heapq.heappop(self._events)
            self._now = t
            self.n_events += 1
            fn()
        self._now = max(self._now, t_end)

    def run(self) -> None:
        while self._events:
            t, _, fn = heapq.heappop(self._events)
            self._now = t
            self.n_events += 1
            fn()

    def every(self, interval: float, fn: Callable[[], None],
              until: float = float("inf")) -> None:
        """Recurring callback helper (estimation ticks, scaling passes)."""

        def tick():
            if self._now > until:
                return
            fn()
            self.call_after(interval, tick)

        self.call_after(interval, tick)
