"""Minimal deterministic discrete-event engine implementing ``core.sgs.Env``."""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, List, Tuple


class SimEnv:
    """Heap-based event loop.  Deterministic: ties broken by insertion order."""

    def __init__(self):
        self._now = 0.0
        self._seq = itertools.count()
        self._seq_next = self._seq.__next__
        self._events: List[Tuple[float, int, Callable[[], None]]] = []
        self.n_events = 0

    # -- core.sgs.Env interface ------------------------------------------------
    def now(self) -> float:
        return self._now

    def call_after(self, delay: float, fn: Callable[..., None],
                   *args) -> None:
        """Defer ``fn(*args)``; passing args directly (rather than closing
        over them) avoids a closure allocation per scheduled event on the
        simulation hot path.  The push is hand-inlined (this is the single
        most-called scheduling entry point): ``t >= now`` holds by
        construction, so ``call_at``'s past-check is unnecessary."""
        now = self._now
        t = now + delay
        if t < now:                 # negative delay clamps to "immediately"
            t = now
        heapq.heappush(self._events, (t, self._seq_next(), fn, args))

    def call_at(self, t: float, fn: Callable[..., None], *args) -> None:
        if t < self._now - 1e-12:
            raise ValueError(f"cannot schedule in the past: {t} < {self._now}")
        heapq.heappush(self._events, (t, self._seq_next(), fn, args))

    # -- driving -----------------------------------------------------------------
    def run_until(self, t_end: float) -> None:
        events = self._events
        pop = heapq.heappop
        n = 0
        try:
            while events and events[0][0] <= t_end:
                t, _, fn, args = pop(events)
                self._now = t
                n += 1
                fn(*args)
        finally:
            self.n_events += n
        self._now = max(self._now, t_end)

    def run_until_before(self, t_end: float) -> None:
        """Like :meth:`run_until` but with an *exclusive* bound: processes
        every event with ``t < t_end`` (strictly), then advances the clock to
        ``t_end``.  The sharded core (``repro.sim.shard``) uses this for
        lookahead barriers placed exactly on a potential event time — the
        event at ``t_end`` must run in the *next* epoch, after cross-shard
        state for ``t_end`` has been exchanged."""
        events = self._events
        pop = heapq.heappop
        n = 0
        try:
            while events and events[0][0] < t_end:
                t, _, fn, args = pop(events)
                self._now = t
                n += 1
                fn(*args)
        finally:
            self.n_events += n
        self._now = max(self._now, t_end)

    def run(self) -> None:
        events = self._events
        pop = heapq.heappop
        n = 0
        try:
            while events:
                t, _, fn, args = pop(events)
                self._now = t
                n += 1
                fn(*args)
        finally:
            self.n_events += n

    def every(self, interval: float, fn: Callable[[], None],
              until: float = float("inf")) -> None:
        """Recurring callback helper (estimation ticks, scaling passes)."""

        def tick():
            if self._now > until:
                return
            fn()
            self.call_after(interval, tick)

        self.call_after(interval, tick)
