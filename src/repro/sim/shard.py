"""Sharded parallel simulation core: epoch-synchronized SGS islands.

Archipelago's partition structure (§3) is the parallelism: between LBS
routing points the SGSs are independent islands — an SGS only ever reacts
to (a) submissions routed to it by the LBS and (b) its own internal events
(dispatch, completion, estimator ticks).  ``simulate_sharded`` exploits
this as a conservative parallel discrete-event simulation:

* The **coordinator** (parent process) runs the whole control plane — the
  arrival pump, the LBS replica clocks, routing/lottery draws, the
  piggyback-EWMA fold state, per-DAG SGS scaling, and the optional LBS
  replica autoscaler — on a real :class:`~repro.sim.engine.SimEnv` whose
  events are inserted in exactly the sequential order (so ``(t, seq)``
  tie-breaks replicate automatically).
* Each **shard** (child process) owns a disjoint set of SGSs with their
  worker pools and sandbox state, advancing its own event loop.
* They synchronize at **epoch barriers**: the coordinator advances every
  shard to a time bound ``T`` and collects the piggyback reports generated
  up to ``T``; routed submissions and scale-out preallocations accumulated
  since the previous barrier ride on the advance message as compact numpy
  blocks.

Barrier placement is driven by *lookahead*: a submission routed at arrival
time ``t`` cannot reach an SGS before ``t + minlat`` where ``minlat`` is
the minimum control-plane latency (``lb_cost + sgs_cost * min_fns``), so
shards may safely run ahead of the coordinator by up to ``minlat``.
Barriers are forced only where cross-shard state is actually read:

* **Scale ticks** (``LoadBalancer.check_scaling`` every
  ``decision_interval / 5``) read every DAG's folded report window —
  inclusive barrier exactly at the tick time.
* **Multi-SGS routed arrivals** (a DAG whose active set has >1 SGS, or a
  non-empty removed list) read per-SGS EWMAs in the lottery — barrier at
  ``min(next_tick, t + minlat)``; when the bound is ``t + minlat`` it is
  *exclusive* (``SimEnv.run_until_before``) because a submission can land
  at exactly that instant and must execute in the next epoch.

Single-SGS arrivals (the common case — the fast path in
``LoadBalancer._lottery`` consumes one RNG draw and reads no report state)
and LBS autoscaler ticks (which read only coordinator-local clocks) run
ahead of the shard frontier freely.

Determinism is a hard contract: same seed ⇒ an ``ExperimentResult``
byte-identical to the single-process path at ANY shard count and ANY
partition of SGS ids (``tests/test_shards.py`` pins both).  See
docs/PERF.md ("The sharded core") for the epoch protocol and message
formats.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.cluster import ClusterConfig, build_sgs_pool
from ..core.stacks import _ServiceClock, make_archipelago_submit
from ..core.lbs import LoadBalancer
from ..core.types import DagSpec, Request
from .engine import SimEnv

__all__ = ["simulate_sharded", "validate_shardable", "default_partition"]

# response sentinel a shard sends instead of a payload when its loop raised
_ERR = "__shard_error__"


# ---------------------------------------------------------------------------
# Validation / partitioning
# ---------------------------------------------------------------------------


def validate_shardable(exp, hooks: Sequence = (),
                       timed_calls: Sequence = ()) -> None:
    """Reject experiment shapes the sharded core cannot reproduce
    byte-identically.  Raises ``ValueError`` with the reason; callers that
    cannot shard for *environmental* reasons (daemonic pool workers) fall
    back to the sequential path silently instead — that path is identical
    by contract, so only semantic mismatches are errors."""
    n = int(exp.shards)
    if exp.stack != "archipelago":
        raise ValueError(
            f"shards={n} requires stack='archipelago' (the sharded core "
            f"partitions SGS islands); got stack={exp.stack!r}")
    if exp.backend != "modeled":
        raise ValueError(
            f"shards={n} requires the modeled execution backend (shard "
            f"processes own their data plane); got "
            f"backend={exp.backend_name()!r}")
    if exp.faults is not None and exp.faults.events:
        raise ValueError(
            f"shards={n} does not support fault plans yet (fault events "
            f"mutate cross-shard control-plane state mid-epoch)")
    if exp.params.get("hedge_timeout"):
        raise ValueError(
            f"shards={n} does not support hedged retries (shard processes "
            f"build their SGS pools directly, bypassing the stack's hedge "
            f"wiring); drop params['hedge_timeout'] or run sequentially")
    if hooks or timed_calls:
        raise ValueError(
            f"shards={n} does not support simulate(hooks=/timed_calls=) "
            f"(they observe one process's event loop)")
    if exp.workload_method != "numpy":
        raise ValueError(
            f"shards={n} requires workload_method='numpy'")
    cc = exp.cluster or ClusterConfig()
    if n > cc.n_sgs:
        raise ValueError(
            f"shards={n} exceeds the cluster's {cc.n_sgs} SGSs "
            f"(each shard needs at least one island)")
    spec = exp.resolve_workload()
    if getattr(spec, "pre_pump", None) is not None:
        raise ValueError(
            f"shards={n} does not support workloads with a pre_pump hook")


def default_partition(n_sgs: int, shards: int) -> List[List[int]]:
    """Contiguous near-even blocks of SGS ids, one per shard."""
    return [a.tolist() for a in np.array_split(np.arange(n_sgs), shards)]


def _check_partition(partition: Sequence[Sequence[int]], n_sgs: int) -> None:
    flat = [s for part in partition for s in part]
    if sorted(flat) != list(range(n_sgs)):
        raise ValueError(
            f"partition must cover each SGS id 0..{n_sgs - 1} exactly once")
    if any(len(p) == 0 for p in partition):
        raise ValueError("every shard needs at least one SGS id")


# ---------------------------------------------------------------------------
# Coordinator-side SGS stand-in
# ---------------------------------------------------------------------------


class _SGSProxy:
    """What the coordinator's ``LoadBalancer`` sees instead of a live
    ``SemiGlobalScheduler``: the id (routing is by id), the piggyback
    ``report`` attribute the LBS wires in, and ``preallocate`` — which
    records the scale-out warm-up into the owning shard's outbox instead of
    touching sandbox state."""

    __slots__ = ("sgs_id", "report", "_pre_out", "_dag_pos")

    def __init__(self, sgs_id: int, pre_out: List[tuple],
                 dag_pos: Dict[str, int]):
        self.sgs_id = sgs_id
        self._pre_out = pre_out
        self._dag_pos = dag_pos

    def preallocate(self, dag: DagSpec, n_per_fn: int) -> None:
        self._pre_out.append((self.sgs_id, self._dag_pos[dag.dag_id],
                              n_per_fn))

    def submit_request(self, req: Request) -> None:  # pragma: no cover
        raise RuntimeError(
            "submissions to a sharded SGS go through the epoch outbox, "
            "not the proxy")


# ---------------------------------------------------------------------------
# Shard worker (child process)
# ---------------------------------------------------------------------------


def _shard_worker(conn, cc: ClusterConfig, sgs_cfg,
                  tenant_dags: List[DagSpec], sgs_ids: List[int]) -> None:
    """One shard: a private event loop over this partition's SGSs.

    Protocol (coordinator → shard):

    * ``("adv", T, inclusive, subs, pre)`` — apply preallocations ``pre``
      (``(sgs_id, dag_idx, n_per_fn)`` triples, generated at the previous
      tick = this shard's current clock), schedule submission block ``subs``
      (parallel numpy arrays ``(m_idx, sgs_id, t_sched, arrival_t,
      dag_idx)``), then advance to ``T`` (``run_until`` when inclusive,
      ``run_until_before`` otherwise).  Replies with the epoch's piggyback
      report block ``(rt, dag_idx, sgs_id, qdelay, sandbox_count)`` as
      numpy arrays (or ``None``).
    * ``("fin",)`` — reply with the terminal payload (completion columns,
      leftover in-flight rows, per-SGS queuing samples, counters, event
      count) and exit.
    """
    try:
        env = SimEnv()
        sgss = build_sgs_pool(env, cc, sgs_cfg, list(sgs_ids))
        by_id = {s.sgs_id: s for s in sgss}
        dag_pos = {d.dag_id: k for k, d in enumerate(tenant_dags)}

        reports: List[tuple] = []

        def report(dag_id: str, sgs_id: int, qdelay: float,
                   sandbox_count: int,
                   _append=reports.append, _pos=dag_pos) -> None:
            _append((env._now, _pos[dag_id], sgs_id, qdelay, sandbox_count))

        comp: List[tuple] = []
        pend: Dict[int, Request] = {}

        def on_complete(req: Request, now: float,
                        _append=comp.append, _pop=pend.pop) -> None:
            _append((req.m_idx, now, req.n_cold_starts, req.sgs_id,
                     req.total_queuing_delay))
            _pop(req.m_idx, None)

        for s in sgss:
            s.report = report
            s.on_complete = on_complete

        call_at = env.call_at
        while True:
            msg = conn.recv()
            tag = msg[0]
            if tag == "adv":
                _, T, inclusive, subs, pre = msg
                if pre is not None:
                    for sid, didx, n_per in pre:
                        by_id[sid].preallocate(tenant_dags[didx], n_per)
                if subs is not None:
                    mi, si, ts, at, di = subs
                    for m, s, t, a, d in zip(mi.tolist(), si.tolist(),
                                             ts.tolist(), at.tolist(),
                                             di.tolist()):
                        req = Request(dag=tenant_dags[d], arrival_time=a)
                        req.m_idx = m
                        pend[m] = req
                        call_at(t, by_id[s].submit_request, req)
                if inclusive:
                    env.run_until(T)
                else:
                    env.run_until_before(T)
                if reports:
                    rt, rd, rs, rq, rc = zip(*reports)
                    reports.clear()
                    conn.send((np.asarray(rt, dtype=np.float64),
                               np.asarray(rd, dtype=np.int64),
                               np.asarray(rs, dtype=np.int64),
                               np.asarray(rq, dtype=np.float64),
                               np.asarray(rc, dtype=np.int64)))
                else:
                    conn.send(None)
            elif tag == "fin":
                if comp:
                    ci, ct, cold, cs, cq = zip(*comp)
                    comp_block = (np.asarray(ci, dtype=np.int64),
                                  np.asarray(ct, dtype=np.float64),
                                  np.asarray(cold, dtype=np.int64),
                                  np.asarray(cs, dtype=np.int64),
                                  np.asarray(cq, dtype=np.float64))
                else:
                    comp_block = None
                pend_rows = [(i, r.n_cold_starts,
                              -1 if r.sgs_id is None else r.sgs_id,
                              r.total_queuing_delay)
                             for i, r in pend.items()]
                queuing = [(s.sgs_id,
                            np.asarray(s.queuing_delays, dtype=np.float64),
                            np.asarray(s.queuing_delay_times,
                                       dtype=np.float64))
                           for s in sgss]
                conn.send({
                    "comp": comp_block,
                    "pend": pend_rows,
                    "queuing": queuing,
                    "cold_starts": sum(s.n_cold_starts for s in sgss),
                    "warm_hits": sum(s.n_warm_hits for s in sgss),
                    "n_events": env.n_events,
                })
                return
            else:  # pragma: no cover - protocol bug
                raise RuntimeError(f"unknown shard message {tag!r}")
    except BaseException:  # pragma: no cover - surfaced by the coordinator
        import traceback
        try:
            conn.send((_ERR, traceback.format_exc()))
        except Exception:
            pass
        raise


# ---------------------------------------------------------------------------
# Coordinator
# ---------------------------------------------------------------------------


def simulate_sharded(exp, partition: Optional[Sequence[Sequence[int]]] = None):
    """Run ``exp`` through the sharded core, returning an
    ``ExperimentResult`` byte-identical to ``simulate`` on the sequential
    path (modulo ``wall_s``).  ``partition`` overrides the default
    contiguous split with an explicit list of SGS-id groups (any partition
    yields identical results — the determinism property the tests sweep)."""
    # local imports: experiment.py imports this module lazily, and a
    # top-level import back into it would be circular
    from ..core.backends import resolve_backend
    from ..core.stacks import get_stack
    from .experiment import (SimResult, _arrival_columns, _build_result,
                             _validate_params)
    from .metrics import Metrics

    stack_cls = get_stack(exp.stack)
    _validate_params(exp, stack_cls)
    spec = exp.resolve_workload()
    backend = resolve_backend(exp.backend, exp.backend_kwargs)
    spec = backend.build(exp, spec)
    env = SimEnv()
    backend.bind(env)
    cc = exp.cluster or ClusterConfig()
    n_shards = int(exp.shards)
    if partition is None:
        partition = default_partition(cc.n_sgs, n_shards)
    else:
        partition = [list(p) for p in partition]
        _check_partition(partition, cc.n_sgs)
    n_shards = len(partition)
    counters_before = dict(backend.counters())

    t0 = time.perf_counter()
    times, dags, arr_np, idx_np, tenant_dags = _arrival_columns(
        spec, exp.seed, exp.workload_method)
    metrics = Metrics.flat(arr_np, idx_np, tenant_dags)
    n = len(times)
    dag_pos = {d.dag_id: k for k, d in enumerate(tenant_dags)}
    dag_ids = [d.dag_id for d in tenant_dags]
    # conservative lookahead: no routed submission can land earlier than
    # arrival + (one LB decision + the smallest SGS decision)
    minlat = exp.lb_cost + exp.sgs_cost * (
        min(d._n_fns for d in tenant_dags) if tenant_dags else 1)
    if minlat <= 0.0:
        raise ValueError(
            "sharded runs need positive control-plane decision costs "
            "(lb_cost + sgs_cost): the lookahead window is what lets "
            "shards run ahead of the coordinator")

    # --- coordinator control plane (mirrors ArchipelagoStack.build) --------
    owner: Dict[int, int] = {}
    for k, part in enumerate(partition):
        for sid in part:
            owner[sid] = k
    sub_out: List[List[tuple]] = [[] for _ in range(n_shards)]
    pre_out: List[List[tuple]] = [[] for _ in range(n_shards)]
    proxies = [_SGSProxy(sid, pre_out[owner[sid]], dag_pos)
               for sid in range(cc.n_sgs)]
    lb = LoadBalancer(proxies, config=exp.lbs)
    auto = exp.autoscale
    if auto is not None:
        n_lb = int(exp.params.get("n_lbs", auto.min_replicas))
        n_lb = max(1, max(auto.min_replicas, min(n_lb, auto.max_replicas)))
    else:
        n_lb = max(1, int(exp.params.get("n_lbs", 4)))
    lb_clocks = [_ServiceClock() for _ in range(n_lb)]
    sgs_clocks = {sid: _ServiceClock() for sid in lb.sgss}
    scaler = None
    if auto is not None:
        from ..core.autoscale import LBSReplicaAutoscaler
        scaler = LBSReplicaAutoscaler(lb_clocks, exp.lb_cost, auto,
                                      make_clock=_ServiceClock)

    idx_l = idx_np.tolist()

    def deliver(t_sched: float, sgs_id: int, req: Request,
                _out=sub_out, _owner=owner) -> None:
        _out[_owner[sgs_id]].append((req.m_idx, sgs_id, t_sched))

    submit = make_archipelago_submit(lb_clocks, sgs_clocks, lb.select,
                                     env.call_at, exp.lb_cost, exp.sgs_cost,
                                     scaler=scaler, deliver=deliver)

    # --- parent event chains, inserted in the sequential order -------------
    # (pump first, then the scale-tick chain, then the autoscaler chain —
    # matching _run_experiment + ArchipelagoStack.start_background, so
    # (t, seq) heap tie-breaks replicate the single-process run)
    horizon = spec.duration + exp.drain

    def pump(i: int) -> None:
        now = times[i]
        req = Request(dag=dags[i], arrival_time=now)
        req.m_idx = i
        submit(req, now)
        i += 1
        if i < n:
            env.call_at(times[i], pump, i)

    pump._shard_kind = 1

    tick_interval = lb.cfg.decision_interval / 5.0
    next_tick = [tick_interval]

    def tick_scale() -> None:
        t = env._now
        next_tick[0] = t + tick_interval
        lb.check_scaling(t)
        env.call_after(tick_interval, tick_scale)

    tick_scale._shard_kind = 2

    if n:
        env.call_at(times[0], pump, 0)
    env.call_after(tick_interval, tick_scale)
    if scaler is not None:
        auto_interval = scaler.cfg.interval

        def tick_auto() -> None:
            scaler.tick(env._now)
            env.call_after(auto_interval, tick_auto)

        env.call_after(auto_interval, tick_auto)

    # --- spawn shards -------------------------------------------------------
    import multiprocessing
    ctx = multiprocessing.get_context("spawn")
    conns = []
    procs = []
    try:
        for part in partition:
            pconn, cconn = ctx.Pipe()
            p = ctx.Process(target=_shard_worker,
                            args=(cconn, cc, exp.sgs, tenant_dags,
                                  list(part)),
                            daemon=True)
            p.start()
            cconn.close()
            conns.append(pconn)
            procs.append(p)

        # --- merged piggyback-report buffer --------------------------------
        r_t: List[float] = []
        r_did: List[int] = []
        r_sid: List[int] = []
        r_qd: List[float] = []
        r_cnt: List[int] = []
        rpos = 0
        barrier_wait = 0.0
        n_epochs = 0

        def _recv(k: int):
            blk = conns[k].recv()
            if isinstance(blk, tuple) and len(blk) == 2 and blk[0] == _ERR:
                raise RuntimeError(f"shard {k} failed:\n{blk[1]}")
            return blk

        def barrier(T: float, inclusive: bool) -> None:
            nonlocal rpos, barrier_wait, n_epochs
            for k in range(n_shards):
                out = sub_out[k]
                if out:
                    mi_l, si_l, ts_l = zip(*out)
                    out.clear()
                    mi = np.asarray(mi_l, dtype=np.int64)
                    subs = (mi, np.asarray(si_l, dtype=np.int64),
                            np.asarray(ts_l, dtype=np.float64),
                            arr_np[mi], idx_np[mi])
                else:
                    subs = None
                # NOTE: proxies hold a reference to pre_out[k]; clear in
                # place (send() pickles synchronously, so clearing after is
                # safe)
                pre = pre_out[k]
                conns[k].send(("adv", T, inclusive, subs,
                               pre if pre else None))
                if pre:
                    del pre[:]
            w0 = time.perf_counter()
            blocks = [_recv(k) for k in range(n_shards)]
            barrier_wait += time.perf_counter() - w0
            n_epochs += 1
            live = [b for b in blocks if b is not None]
            if live:
                if len(live) == 1:
                    bt, bd, bs, bq, bc = live[0]
                else:
                    bt = np.concatenate([b[0] for b in live])
                    bd = np.concatenate([b[1] for b in live])
                    bs = np.concatenate([b[2] for b in live])
                    bq = np.concatenate([b[3] for b in live])
                    bc = np.concatenate([b[4] for b in live])
                # stable time-sort: equal-instant ties keep the fixed shard
                # order, and per-SGS report order (the one the EWMA fold is
                # sensitive to) is preserved because an SGS lives in exactly
                # one shard
                order = np.argsort(bt, kind="stable")
                r_t.extend(bt[order].tolist())
                r_did.extend(bd[order].tolist())
                r_sid.extend(bs[order].tolist())
                r_qd.extend(bq[order].tolist())
                r_cnt.extend(bc[order].tolist())
            if rpos > 65536:    # trim the consumed prefix
                del r_t[:rpos]
                del r_did[:rpos]
                del r_sid[:rpos]
                del r_qd[:rpos]
                del r_cnt[:rpos]
                rpos = 0

        lb_report = lb.report

        def feed(t: float) -> None:
            """Deliver received piggyback reports with timestamp <= t into
            the LBS pending buffers (exactly what the in-process report
            channel would have accumulated by now)."""
            nonlocal rpos
            pos = rpos
            end = len(r_t)
            while pos < end and r_t[pos] <= t:
                lb_report(dag_ids[r_did[pos]], r_sid[pos], r_qd[pos],
                          r_cnt[pos])
                pos += 1
            rpos = pos

        # --- the epoch drive loop ------------------------------------------
        import heapq
        heap = env._events
        heappop = heapq.heappop
        dag_state = lb._dag_state
        S = 0.0             # shard frontier (all shards advanced to S)
        S_excl = False      # True: the frontier barrier was exclusive
        parent_events = 0
        while heap:
            head = heap[0]
            t = head[0]
            if t > horizon:
                break
            if t > S or (t == S and S_excl):
                kind = getattr(head[2], "_shard_kind", 0)
                if kind == 2:
                    # scale tick: needs every report generated up to (and
                    # including) the tick instant
                    barrier(t, True)
                    S, S_excl = t, False
                    continue
                if kind == 1:
                    st = dag_state.get(dags[head[3][0]].dag_id)
                    if st is not None and (len(st.active) > 1 or st.removed):
                        # multi-SGS lottery reads per-SGS EWMAs: stall until
                        # reports through t are in.  The bound is capped at
                        # the next scale tick so tick barriers stay exact.
                        b = t + minlat
                        if next_tick[0] <= b:
                            barrier(next_tick[0], True)
                            S, S_excl = next_tick[0], False
                        else:
                            # a submission can land at exactly t + minlat
                            # (idle clocks): exclusive bound so it executes
                            # next epoch, after delivery
                            barrier(b, False)
                            S, S_excl = b, True
                        continue
            feed(t)
            heappop(heap)
            env._now = t
            parent_events += 1
            head[2](*head[3])
        env._now = max(env._now, horizon)
        # final epoch: drain every shard through the horizon and flush any
        # leftover outbox (submissions scheduled past the horizon simply
        # stay unprocessed, exactly like the sequential heap leftovers)
        barrier(horizon, True)
        for k in range(n_shards):
            conns[k].send(("fin",))
        finals = [_recv(k) for k in range(n_shards)]
        for p in procs:
            p.join()
    finally:
        for c in conns:
            c.close()
        for p in procs:
            if p.is_alive():
                p.terminate()

    # --- merge shard state into the run's metrics --------------------------
    blocks = [f["comp"] for f in finals if f["comp"] is not None]
    if blocks:
        ci = np.concatenate([b[0] for b in blocks])
        ct = np.concatenate([b[1] for b in blocks])
        cold = np.concatenate([b[2] for b in blocks])
        cs = np.concatenate([b[3] for b in blocks])
        cq = np.concatenate([b[4] for b in blocks])
    else:
        ci = np.empty(0, dtype=np.int64)
        ct = np.empty(0, dtype=np.float64)
        cold = np.empty(0, dtype=np.int64)
        cs = np.empty(0, dtype=np.int64)
        cq = np.empty(0, dtype=np.float64)
    pending: Dict[int, Request] = {}
    for f in finals:
        for i, n_cold, sid, qd in f["pend"]:
            r = Request(dag=tenant_dags[idx_l[i]], arrival_time=times[i])
            r.m_idx = i
            r.n_cold_starts = n_cold
            r.sgs_id = None if sid < 0 else sid
            r.total_queuing_delay = qd
            pending[i] = r
    metrics.absorb_sharded(ci, ct, cold, cs, cq, pending)
    # queuing-sample chunks in global ascending SGS id — the order
    # ArchipelagoStack.collect adds them in (dict insertion order)
    chunks = {sid: (d, qt) for f in finals for sid, d, qt in f["queuing"]}
    for sid in sorted(chunks):
        d, qt = chunks[sid]
        metrics.add_queuing_samples(d, qt)

    shard_events = [f["n_events"] for f in finals]
    env.n_events = parent_events + sum(shard_events)
    warm_hits = sum(f["warm_hits"] for f in finals)
    wall = time.perf_counter() - t0

    counters = {k: v - counters_before.get(k, 0)
                for k, v in backend.counters().items()}
    sim = SimResult(metrics=metrics, env=env, lbs=lb, scheduler=None,
                    backend=backend, backend_counters=counters,
                    injector=None)
    # sharded-run telemetry for benchmarks (per-shard event counts, barrier
    # wait): carried on the live sim handle, NOT the result row — rows stay
    # byte-identical to the sequential path
    sim.shard_stats = {
        "shards": n_shards,
        "partition": [list(p) for p in partition],
        "parent_events": parent_events,
        "shard_events": shard_events,
        "n_epochs": n_epochs,
        "barrier_wait_s": round(barrier_wait, 4),
    }
    events = list(getattr(lb, "scaling_log", ()))
    if scaler is not None:
        events.extend(scaler.events)
    events.sort(key=lambda e: (e.t, e.component))
    scaling = [e.to_dict() for e in events]
    return _build_result(exp, spec, sim, warm_hits, wall, scaling)
