"""Discrete-event simulation substrate: reproduces the paper's CloudLab
evaluation (74 machines, Workloads 1 & 2, classes C1-C4) on a laptop."""
from .engine import SimEnv
from .workload import (ArrivalProcess, BurstRate, ConstantRate, DiurnalRate,
                       OnOffRate, PoissonResampled, ScaledRate, Sinusoidal,
                       WindowedRate, WorkloadSpec, make_paper_dag,
                       paper_workload_1, paper_workload_2)
from .metrics import Metrics, summarize
from .traffic import (TrafficSpec, apply_traffic, available_traffic,
                      get_traffic, register_traffic, scenario)
from .experiment import (ClassStats, Experiment, ExperimentResult, SimResult,
                         SweepResult, available_workloads,
                         get_workload_factory, register_workload, run_sweep,
                         simulate)
from .runner import run_archipelago, run_baseline, run_sparrow

__all__ = [
    "SimEnv", "ArrivalProcess", "ConstantRate", "OnOffRate",
    "PoissonResampled", "Sinusoidal", "WorkloadSpec", "make_paper_dag",
    "ScaledRate", "DiurnalRate", "BurstRate", "WindowedRate",
    "paper_workload_1", "paper_workload_2", "Metrics", "summarize",
    "TrafficSpec", "scenario", "apply_traffic",
    "register_traffic", "get_traffic", "available_traffic",
    "ClassStats", "Experiment", "ExperimentResult", "SimResult",
    "SweepResult", "run_sweep", "simulate",
    "register_workload", "get_workload_factory", "available_workloads",
    "run_archipelago", "run_baseline", "run_sparrow",
]
