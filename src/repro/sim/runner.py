"""Legacy end-to-end simulation drivers — thin shims over the experiment API.

``run_archipelago`` / ``run_baseline`` / ``run_sparrow`` predate the
declarative :mod:`repro.sim.experiment` layer.  They are kept so existing
call sites (and the decision-identity goldens in
``tests/test_equivalence.py``) keep working unchanged, but new code should
build an :class:`~repro.sim.experiment.Experiment` and call
:func:`~repro.sim.experiment.simulate` — same pump loop, richer results,
any registered stack (``repro.core.stacks``) instead of these three, and
access to the sharded parallel core (``Experiment.shards``,
:mod:`repro.sim.shard`), which these legacy shims deliberately do not
expose.
"""
from __future__ import annotations

from typing import Optional

from ..core.cluster import ClusterConfig
from ..core.lbs import LBSConfig
from ..core.sgs import SGSConfig
# Re-exported for backward compatibility (these used to live here).
from ..core.stacks import (LB_DECISION_COST, SGS_DECISION_COST,  # noqa: F401
                           _ServiceClock)
from .experiment import (Experiment, SimResult, _arrival_stream,  # noqa: F401
                         _run_experiment, simulate)
from .workload import WorkloadSpec

__all__ = ["SimResult", "run_archipelago", "run_baseline", "run_sparrow",
           "LB_DECISION_COST", "SGS_DECISION_COST"]


def run_archipelago(spec: WorkloadSpec,
                    cluster: Optional[ClusterConfig] = None,
                    sgs_cfg: Optional[SGSConfig] = None,
                    lbs_cfg: Optional[LBSConfig] = None,
                    seed: int = 0,
                    drain: float = 5.0,
                    lb_cost: float = LB_DECISION_COST,
                    sgs_cost: float = SGS_DECISION_COST,
                    n_lbs: int = 4,
                    workload_method: str = "numpy") -> SimResult:
    """Deprecated shim: ``simulate(Experiment(stack="archipelago", ...))``
    minus the result summary (callers here only want the raw SimResult)."""
    _, sim, _, _ = _run_experiment(Experiment(
        stack="archipelago", workload=spec, cluster=cluster, sgs=sgs_cfg,
        lbs=lbs_cfg, params={"n_lbs": n_lbs}, lb_cost=lb_cost,
        sgs_cost=sgs_cost, seed=seed, drain=drain,
        workload_method=workload_method))
    return sim


def run_baseline(spec: WorkloadSpec,
                 cluster: Optional[ClusterConfig] = None,
                 keepalive: float = 900.0,
                 seed: int = 0,
                 drain: float = 5.0,
                 sched_cost: float = SGS_DECISION_COST,
                 workload_method: str = "numpy") -> SimResult:
    """Deprecated shim: ``simulate(Experiment(stack="fifo", ...))``.

    Centralized FIFO + reactive sandboxes + fixed keep-alive (§7.1): the
    single scheduler's per-decision cost is serialized, so at cluster-scale
    RPS it becomes the bottleneck (§2.4), exactly as in the testbed."""
    _, sim, _, _ = _run_experiment(Experiment(
        stack="fifo", workload=spec, cluster=cluster,
        params={"keepalive": keepalive}, sgs_cost=sched_cost, seed=seed,
        drain=drain, workload_method=workload_method))
    return sim


def run_sparrow(spec: WorkloadSpec,
                cluster: Optional[ClusterConfig] = None,
                probes: int = 2,
                seed: int = 0,
                drain: float = 5.0,
                workload_method: str = "numpy") -> SimResult:
    """Deprecated shim: ``simulate(Experiment(stack="sparrow", ...))``."""
    _, sim, _, _ = _run_experiment(Experiment(
        stack="sparrow", workload=spec, cluster=cluster,
        params={"probes": probes}, seed=seed, drain=drain,
        workload_method=workload_method))
    return sim
