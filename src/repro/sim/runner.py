"""End-to-end simulation drivers: Archipelago vs baseline stacks."""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from ..core.baselines import CentralizedFIFO, SparrowScheduler
from ..core.cluster import ClusterConfig, build_cluster, build_flat_workers
from ..core.lbs import LBSConfig, LoadBalancer
from ..core.sgs import SGSConfig
from ..core.types import DagSpec, Request
from .engine import SimEnv
from .metrics import Metrics
from .workload import WorkloadSpec


@dataclass
class SimResult:
    metrics: Metrics
    env: SimEnv
    lbs: Optional[LoadBalancer] = None
    scheduler: object = None


@dataclass(slots=True)
class _ServiceClock:
    """Serializes work through one control-plane component (M/D/1 server).

    The paper's measured per-decision costs (§7.4): LBS routing ~190us,
    SGS scheduling ~241us.  A single centralized scheduler at several
    thousand RPS approaches rho=1 and its queue explodes — exactly the
    §2.4 scalability argument; Archipelago spreads this cost over many
    SGSs.
    """

    busy_until: float = 0.0

    def acquire(self, now: float, service: float) -> float:
        start = self.busy_until
        if now > start:
            start = now
        self.busy_until = start + service
        return self.busy_until


# §7.4 measured control-plane decision costs
LB_DECISION_COST = 190e-6
SGS_DECISION_COST = 241e-6


def _arrival_stream(spec: WorkloadSpec, seed: int, method: str
                    ) -> Tuple[List[float], List[DagSpec]]:
    """Time-sorted arrival times + per-arrival DAGs.

    The vectorized path never materializes per-arrival tuples; numpy floats
    are converted once (``tolist`` round-trips float64 exactly)."""
    if method == "legacy":
        pairs = spec.generate(seed, method="legacy")
        return [t for t, _ in pairs], [d for _, d in pairs]
    if method != "numpy":
        raise ValueError(f"unknown generation method {method!r}")
    ts, idx, tenant_dags = spec.generate_arrays(seed)
    dags = list(map(tenant_dags.__getitem__, idx.tolist()))
    return ts.tolist(), dags


def run_archipelago(spec: WorkloadSpec,
                    cluster: Optional[ClusterConfig] = None,
                    sgs_cfg: Optional[SGSConfig] = None,
                    lbs_cfg: Optional[LBSConfig] = None,
                    seed: int = 0,
                    drain: float = 5.0,
                    lb_cost: float = LB_DECISION_COST,
                    sgs_cost: float = SGS_DECISION_COST,
                    n_lbs: int = 4,
                    workload_method: str = "numpy") -> SimResult:
    env = SimEnv()
    lbs = build_cluster(env, cluster, sgs_cfg, lbs_cfg)
    metrics = Metrics()
    n_lb = max(1, n_lbs)
    lb_clocks = [_ServiceClock() for _ in range(n_lb)]
    sgs_clocks = {sid: _ServiceClock() for sid in lbs.sgss}

    times, dags = _arrival_stream(spec, seed, workload_method)
    n = len(times)
    requests = metrics.requests

    def pump(i: int) -> None:
        # fire arrival i, then lazily schedule arrival i+1: the event heap
        # holds at most one pending arrival instead of the whole trace
        now = env.now()
        dag = dags[i]
        req = Request(dag=dag, arrival_time=now)
        requests.append(req)
        # hop 1: LBS routing decision (LBS is a scalable service: many LBs)
        t_routed = lb_clocks[i % n_lb].acquire(now, lb_cost)
        sgs = lbs.select(req, now)
        # hop 2: SGS scheduling decision, serialized per SGS
        t_sched = sgs_clocks[sgs.sgs_id].acquire(
            t_routed, sgs_cost * len(dag.functions))
        env.call_at(t_sched, sgs.submit_request, req)
        i += 1
        if i < n:
            env.call_at(times[i], pump, i)

    if n:
        env.call_at(times[0], pump, 0)

    # periodic scaling pass (the LBS's background loop, §5.2)
    lcfg = lbs.cfg
    env.every(lcfg.decision_interval / 5.0,
              lambda: lbs.check_scaling(env.now()),
              until=spec.duration + drain)

    env.run_until(spec.duration + drain)
    for s in lbs.sgss.values():
        metrics.queuing_delays.extend(s.queuing_delays)
    return SimResult(metrics=metrics, env=env, lbs=lbs)


def run_baseline(spec: WorkloadSpec,
                 cluster: Optional[ClusterConfig] = None,
                 keepalive: float = 900.0,
                 seed: int = 0,
                 drain: float = 5.0,
                 sched_cost: float = SGS_DECISION_COST,
                 workload_method: str = "numpy") -> SimResult:
    """Centralized FIFO + reactive sandboxes + fixed keep-alive (§7.1).

    The single scheduler's per-decision cost is serialized: at cluster-scale
    RPS it becomes the bottleneck (§2.4), exactly as in the testbed."""
    env = SimEnv()
    workers = build_flat_workers(cluster)
    sched = CentralizedFIFO(workers, env, keepalive=keepalive)
    metrics = Metrics()
    clock = _ServiceClock()
    times, dags = _arrival_stream(spec, seed, workload_method)
    n = len(times)

    def pump(i: int) -> None:
        now = env.now()
        dag = dags[i]
        req = Request(dag=dag, arrival_time=now)
        metrics.requests.append(req)
        t_sched = clock.acquire(now, sched_cost * len(dag.functions))
        env.call_at(t_sched, sched.submit_request, req)
        i += 1
        if i < n:
            env.call_at(times[i], pump, i)

    if n:
        env.call_at(times[0], pump, 0)
    env.run_until(spec.duration + drain)
    metrics.queuing_delays.extend(sched.queuing_delays)
    return SimResult(metrics=metrics, env=env, scheduler=sched)


def run_sparrow(spec: WorkloadSpec,
                cluster: Optional[ClusterConfig] = None,
                probes: int = 2,
                seed: int = 0,
                drain: float = 5.0,
                workload_method: str = "numpy") -> SimResult:
    env = SimEnv()
    workers = build_flat_workers(cluster)
    sched = SparrowScheduler(workers, env, probes=probes, seed=seed)
    metrics = Metrics()
    times, dags = _arrival_stream(spec, seed, workload_method)
    n = len(times)

    def pump(i: int) -> None:
        req = Request(dag=dags[i], arrival_time=env.now())
        metrics.requests.append(req)
        sched.submit_request(req)
        i += 1
        if i < n:
            env.call_at(times[i], pump, i)

    if n:
        env.call_at(times[0], pump, 0)
    env.run_until(spec.duration + drain)
    metrics.queuing_delays.extend(sched.queuing_delays)
    return SimResult(metrics=metrics, env=env, scheduler=sched)
