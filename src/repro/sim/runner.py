"""End-to-end simulation drivers: Archipelago vs baseline stacks."""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from ..core.baselines import CentralizedFIFO, SparrowScheduler
from ..core.cluster import ClusterConfig, build_cluster, build_flat_workers
from ..core.lbs import LBSConfig, LoadBalancer
from ..core.sgs import SGSConfig
from ..core.types import Request
from .engine import SimEnv
from .metrics import Metrics
from .workload import WorkloadSpec


@dataclass
class SimResult:
    metrics: Metrics
    env: SimEnv
    lbs: Optional[LoadBalancer] = None
    scheduler: object = None


@dataclass
class _ServiceClock:
    """Serializes work through one control-plane component (M/D/1 server).

    The paper's measured per-decision costs (§7.4): LBS routing ~190us,
    SGS scheduling ~241us.  A single centralized scheduler at several
    thousand RPS approaches rho=1 and its queue explodes — exactly the
    §2.4 scalability argument; Archipelago spreads this cost over many
    SGSs.
    """

    busy_until: float = 0.0

    def acquire(self, now: float, service: float) -> float:
        start = max(now, self.busy_until)
        self.busy_until = start + service
        return self.busy_until


# §7.4 measured control-plane decision costs
LB_DECISION_COST = 190e-6
SGS_DECISION_COST = 241e-6


def run_archipelago(spec: WorkloadSpec,
                    cluster: Optional[ClusterConfig] = None,
                    sgs_cfg: Optional[SGSConfig] = None,
                    lbs_cfg: Optional[LBSConfig] = None,
                    seed: int = 0,
                    drain: float = 5.0,
                    lb_cost: float = LB_DECISION_COST,
                    sgs_cost: float = SGS_DECISION_COST,
                    n_lbs: int = 4) -> SimResult:
    env = SimEnv()
    lbs = build_cluster(env, cluster, sgs_cfg, lbs_cfg)
    metrics = Metrics()
    lb_clocks = [_ServiceClock() for _ in range(max(1, n_lbs))]
    sgs_clocks = {sid: _ServiceClock() for sid in lbs.sgss}

    arrivals = spec.generate(seed)
    for i, (t, dag) in enumerate(arrivals):
        def fire(t=t, dag=dag, i=i):
            req = Request(dag=dag, arrival_time=env.now())
            metrics.requests.append(req)
            # hop 1: LBS routing decision (LBS is a scalable service: many LBs)
            t_routed = lb_clocks[i % len(lb_clocks)].acquire(env.now(), lb_cost)
            sgs = lbs.select(req, env.now())
            # hop 2: SGS scheduling decision, serialized per SGS
            t_sched = sgs_clocks[sgs.sgs_id].acquire(
                t_routed, sgs_cost * len(dag.functions))
            env.call_at(t_sched, lambda: sgs.submit_request(req))
        env.call_at(t, fire)

    # periodic scaling pass (the LBS's background loop, §5.2)
    lcfg = lbs.cfg
    env.every(lcfg.decision_interval / 5.0,
              lambda: lbs.check_scaling(env.now()),
              until=spec.duration + drain)

    env.run_until(spec.duration + drain)
    for s in lbs.sgss.values():
        metrics.queuing_delays.extend(s.queuing_delays)
    return SimResult(metrics=metrics, env=env, lbs=lbs)


def run_baseline(spec: WorkloadSpec,
                 cluster: Optional[ClusterConfig] = None,
                 keepalive: float = 900.0,
                 seed: int = 0,
                 drain: float = 5.0,
                 sched_cost: float = SGS_DECISION_COST) -> SimResult:
    """Centralized FIFO + reactive sandboxes + fixed keep-alive (§7.1).

    The single scheduler's per-decision cost is serialized: at cluster-scale
    RPS it becomes the bottleneck (§2.4), exactly as in the testbed."""
    env = SimEnv()
    workers = build_flat_workers(cluster)
    sched = CentralizedFIFO(workers, env, keepalive=keepalive)
    metrics = Metrics()
    clock = _ServiceClock()
    for t, dag in spec.generate(seed):
        def fire(t=t, dag=dag):
            req = Request(dag=dag, arrival_time=env.now())
            metrics.requests.append(req)
            t_sched = clock.acquire(env.now(), sched_cost * len(dag.functions))
            env.call_at(t_sched, lambda: sched.submit_request(req))
        env.call_at(t, fire)
    env.run_until(spec.duration + drain)
    metrics.queuing_delays.extend(sched.queuing_delays)
    return SimResult(metrics=metrics, env=env, scheduler=sched)


def run_sparrow(spec: WorkloadSpec,
                cluster: Optional[ClusterConfig] = None,
                probes: int = 2,
                seed: int = 0,
                drain: float = 5.0) -> SimResult:
    env = SimEnv()
    workers = build_flat_workers(cluster)
    sched = SparrowScheduler(workers, env, probes=probes, seed=seed)
    metrics = Metrics()
    for t, dag in spec.generate(seed):
        def fire(t=t, dag=dag):
            req = Request(dag=dag, arrival_time=env.now())
            metrics.requests.append(req)
            sched.submit_request(req)
        env.call_at(t, fire)
    env.run_until(spec.duration + drain)
    metrics.queuing_delays.extend(sched.queuing_delays)
    return SimResult(metrics=metrics, env=env, scheduler=sched)
