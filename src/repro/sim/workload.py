"""Workload generators reproducing §7.1 Table 1.

Four DAG classes:
  C1  single function, short exec, tight deadline        (user-facing)
  C2  single function, short exec, looser deadline       (non-critical UI)
  C3  chained functions, medium exec, relatively strict  (expensive UI)
  C4  branched DAG, high exec, loose deadline            (background batch)

Workload 1: Poisson arrivals whose mean rate is resampled every second.
Workload 2: sinusoidal rate  lam(t) = avg + amp * sin(2*pi*t / period).

Arrival sampling comes in two flavors:

* ``method="numpy"`` (default) — vectorized Lewis-Shedler thinning: sample a
  homogeneous Poisson process at the rate-function's upper bound and accept
  each point with probability rate(t)/max_rate.  Exact for any bounded rate
  function, O(expected arrivals) with numpy-level constants, and
  deterministic per seed across processes and platforms.
* ``method="legacy"`` — the original pure-Python dt=0.01 binning loop, kept
  as the reference implementation (the scheduler-equivalence goldens in
  ``tests/data/golden_equivalence.json`` were captured against it).
"""
from __future__ import annotations

import math
import random
import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.types import DagSpec, FunctionSpec

# ---------------------------------------------------------------------------
# Arrival processes (non-homogeneous Poisson)
# ---------------------------------------------------------------------------


class ArrivalProcess:
    def rate(self, t: float) -> float:
        raise NotImplementedError

    # -- vectorized interface ------------------------------------------------
    def rate_array(self, ts: "np.ndarray") -> "np.ndarray":
        """Vectorized ``rate``; subclasses override with numpy-native
        implementations.  The fallback maps the scalar rate (correct for any
        process, but slow)."""
        return np.fromiter((self.rate(float(t)) for t in ts),
                           dtype=np.float64, count=len(ts))

    def max_rate(self, t_end: float) -> float:
        """An upper bound on ``rate`` over [0, t_end] (thinning envelope)."""
        raise NotImplementedError

    def generate(self, t_end: float, rng: random.Random,
                 dt: float = 0.01) -> List[float]:
        """Legacy generator: per-``dt``-bin Poisson counts spread uniformly
        inside each bin (pure-Python reference implementation)."""
        out: List[float] = []
        t = 0.0
        while t < t_end:
            lam = max(0.0, self.rate(t)) * dt
            n = _poisson_sample(lam, rng)
            for _ in range(n):
                out.append(t + rng.random() * dt)
            t += dt
        out.sort()
        return out

    def generate_np(self, t_end: float,
                    rng: "np.random.Generator") -> "np.ndarray":
        """Vectorized exact NHPP sampling via thinning [Lewis & Shedler '79]:
        N ~ Poisson(lam_max * T) uniform candidate points, each kept with
        probability rate(t) / lam_max."""
        lam_max = float(self.max_rate(t_end))
        if lam_max <= 0.0 or t_end <= 0.0:
            return np.empty(0, dtype=np.float64)
        n = rng.poisson(lam_max * t_end)
        ts = rng.uniform(0.0, t_end, n)
        keep = rng.uniform(0.0, lam_max, n) < np.maximum(
            0.0, self.rate_array(ts))
        out = ts[keep]
        out.sort()
        return out


def _poisson_sample(lam: float, rng: random.Random) -> int:
    if lam <= 0:
        return 0
    if lam > 30:
        # normal approximation for large means
        return max(0, int(rng.gauss(lam, math.sqrt(lam)) + 0.5))
    L = math.exp(-lam)
    k, p = 0, 1.0
    while True:
        p *= rng.random()
        if p <= L:
            return k
        k += 1


@dataclass
class ConstantRate(ArrivalProcess):
    rps: float

    def rate(self, t: float) -> float:
        return self.rps

    def rate_array(self, ts: "np.ndarray") -> "np.ndarray":
        return np.full(len(ts), self.rps)

    def max_rate(self, t_end: float) -> float:
        return self.rps


@dataclass
class Sinusoidal(ArrivalProcess):
    avg: float
    amplitude: float
    period: float
    phase: float = 0.0

    def rate(self, t: float) -> float:
        if not math.isfinite(self.period) or self.period <= 0:
            return self.avg
        return self.avg + self.amplitude * math.sin(
            2 * math.pi * t / self.period + self.phase)

    def rate_array(self, ts: "np.ndarray") -> "np.ndarray":
        if not math.isfinite(self.period) or self.period <= 0:
            return np.full(len(ts), self.avg)
        return self.avg + self.amplitude * np.sin(
            2 * math.pi * ts / self.period + self.phase)

    def max_rate(self, t_end: float) -> float:
        if not math.isfinite(self.period) or self.period <= 0:
            return self.avg
        return self.avg + abs(self.amplitude)


@dataclass
class OnOffRate(ArrivalProcess):
    rps: float
    on_duration: float
    off_duration: float

    def rate(self, t: float) -> float:
        phase = t % (self.on_duration + self.off_duration)
        return self.rps if phase < self.on_duration else 0.0

    def rate_array(self, ts: "np.ndarray") -> "np.ndarray":
        phase = ts % (self.on_duration + self.off_duration)
        return np.where(phase < self.on_duration, self.rps, 0.0)

    def max_rate(self, t_end: float) -> float:
        return self.rps


@dataclass
class PoissonResampled(ArrivalProcess):
    """Workload 1: mean rate resampled every ``resample_every`` seconds."""

    rps_range: Tuple[float, float]
    resample_every: float = 1.0
    seed: int = 0
    _cache: Dict[int, float] = field(default_factory=dict)

    def _rate_for_bin(self, k: int) -> float:
        v = self._cache.get(k)
        if v is None:
            r = random.Random((self.seed << 20) ^ k)
            lo, hi = self.rps_range
            v = self._cache[k] = lo + r.random() * (hi - lo)
        return v

    def rate(self, t: float) -> float:
        return self._rate_for_bin(int(t / self.resample_every))

    def _bin_rates(self, t_end: float) -> "np.ndarray":
        """Per-resample-bin rates covering [0, t_end], indexed by bin number
        directly (evaluating ``rate(k * resample_every)`` instead can land in
        bin k-1 when the bin width is not exactly representable), so both
        samplers see one rate function."""
        n_bins = int(t_end / self.resample_every) + 1
        return np.array([self._rate_for_bin(k) for k in range(n_bins)])

    def rate_array(self, ts: "np.ndarray") -> "np.ndarray":
        if len(ts) == 0:
            return np.empty(0)
        bins = self._bin_rates(float(ts.max()))
        k = (ts / self.resample_every).astype(np.int64)
        return bins[k]

    def max_rate(self, t_end: float) -> float:
        return float(self._bin_rates(t_end).max())


# ---------------------------------------------------------------------------
# Composable rate modulators (traffic scenarios, repro.sim.traffic)
# ---------------------------------------------------------------------------
#
# Each wraps a base ArrivalProcess and reshapes its rate function with a
# deterministic envelope, keeping the full vectorized interface (``rate`` /
# ``rate_array`` / ``max_rate``) so the Lewis-Shedler thinning generator
# stays exact.  All are plain dataclasses: picklable (run_sweep workers) and
# freely nestable (e.g. DiurnalRate over BurstRate over ConstantRate).


@dataclass
class ScaledRate(ArrivalProcess):
    """``factor x`` the base process's instantaneous rate (Zipf-skewed
    multi-tenant mixes reweight tenants with this)."""

    base: ArrivalProcess
    factor: float

    def rate(self, t: float) -> float:
        return self.factor * self.base.rate(t)

    def rate_array(self, ts: "np.ndarray") -> "np.ndarray":
        return self.factor * self.base.rate_array(ts)

    def max_rate(self, t_end: float) -> float:
        return max(0.0, self.factor) * self.base.max_rate(t_end)


@dataclass
class DiurnalRate(ArrivalProcess):
    """Day-cycle envelope: ``base.rate(t) * (1 + depth*sin(2pi t/period +
    phase))``.  ``depth`` in [0, 1) keeps the rate non-negative; the default
    phase starts the run at the trough so one ``period`` spans
    trough → peak → trough (a compressed diurnal day)."""

    base: ArrivalProcess
    period: float
    depth: float = 0.6
    phase: float = -math.pi / 2.0

    def _env(self, t: float) -> float:
        return 1.0 + self.depth * math.sin(
            2.0 * math.pi * t / self.period + self.phase)

    def rate(self, t: float) -> float:
        return self.base.rate(t) * self._env(t)

    def rate_array(self, ts: "np.ndarray") -> "np.ndarray":
        env = 1.0 + self.depth * np.sin(
            2.0 * math.pi * ts / self.period + self.phase)
        return self.base.rate_array(ts) * env

    def max_rate(self, t_end: float) -> float:
        return self.base.max_rate(t_end) * (1.0 + abs(self.depth))


@dataclass
class BurstRate(ArrivalProcess):
    """Flash-crowd envelope: rate is amplified ``amplify``x inside
    ``[at, at + duration)`` with linear ``ramp``-second edges (crowds build
    and disperse; a square wave would be a step discontinuity in the
    thinning envelope)."""

    base: ArrivalProcess
    at: float
    duration: float
    amplify: float = 8.0
    ramp: float = 0.0

    def _env(self, t: float) -> float:
        if t < self.at or t >= self.at + self.duration:
            return 1.0
        m = 1.0
        if self.ramp > 0.0:
            m = min(1.0, (t - self.at) / self.ramp,
                    (self.at + self.duration - t) / self.ramp)
        return 1.0 + (self.amplify - 1.0) * m

    def rate(self, t: float) -> float:
        return self.base.rate(t) * self._env(t)

    def rate_array(self, ts: "np.ndarray") -> "np.ndarray":
        inside = (ts >= self.at) & (ts < self.at + self.duration)
        if self.ramp > 0.0:
            m = np.minimum(1.0, np.minimum(
                (ts - self.at) / self.ramp,
                (self.at + self.duration - ts) / self.ramp))
        else:
            m = 1.0
        env = np.where(inside, 1.0 + (self.amplify - 1.0) * m, 1.0)
        return self.base.rate_array(ts) * env

    def max_rate(self, t_end: float) -> float:
        peak = max(1.0, self.amplify) if t_end > self.at else 1.0
        return self.base.max_rate(t_end) * peak


@dataclass
class WindowedRate(ArrivalProcess):
    """Tenant lifetime window: the base rate inside ``[start, end)``, zero
    outside (tenants arriving and departing mid-run)."""

    base: ArrivalProcess
    start: float = 0.0
    end: Optional[float] = None

    def rate(self, t: float) -> float:
        if t < self.start or (self.end is not None and t >= self.end):
            return 0.0
        return self.base.rate(t)

    def rate_array(self, ts: "np.ndarray") -> "np.ndarray":
        alive = ts >= self.start
        if self.end is not None:
            alive &= ts < self.end
        return np.where(alive, self.base.rate_array(ts), 0.0)

    def max_rate(self, t_end: float) -> float:
        return self.base.max_rate(t_end)


# ---------------------------------------------------------------------------
# Paper DAG classes
# ---------------------------------------------------------------------------


def make_paper_dag(cls: str, dag_id: str, rng: random.Random,
                   setup_range: Tuple[float, float] = (0.125, 0.400),
                   ) -> DagSpec:
    """Sample a DAG from class C1..C4 per Table 1.

    Exec-time/slack ranges (seconds):
      C1: exec [0.050,0.100], slack [0.100,0.150], single fn
      C2: exec [0.100,0.200], slack [0.300,0.500], single fn
      C3: exec [0.250,0.400] total over a 2-chain, slack [0.200,0.300]
      C4: exec [0.300,0.600] per fn over a branched 4-fn DAG,
          slack [0.500,1.000]
    Sandbox setup overheads sampled from [125,400] ms (§7.1).
    """
    u = lambda lo, hi: lo + rng.random() * (hi - lo)
    setup = u(*setup_range)
    if cls == "C1":
        e = u(0.050, 0.100)
        fns = (FunctionSpec(f"{dag_id}/f0", e, mem_mb=128, setup_time=setup),)
        edges: Tuple[Tuple[str, str], ...] = ()
        cp = e
        slack = u(0.100, 0.150)
    elif cls == "C2":
        e = u(0.100, 0.200)
        fns = (FunctionSpec(f"{dag_id}/f0", e, mem_mb=128, setup_time=setup),)
        edges = ()
        cp = e
        slack = u(0.300, 0.500)
    elif cls == "C3":
        total = u(0.250, 0.400)
        e0, e1 = total * 0.5, total * 0.5
        fns = (FunctionSpec(f"{dag_id}/f0", e0, mem_mb=128, setup_time=setup),
               FunctionSpec(f"{dag_id}/f1", e1, mem_mb=128, setup_time=setup))
        edges = ((f"{dag_id}/f0", f"{dag_id}/f1"),)
        cp = total
        slack = u(0.200, 0.300)
    elif cls == "C4":
        total = u(0.300, 0.600)     # Table 1 exec time is per-DAG total
        e = [total / 4.0] * 4
        names = [f"{dag_id}/f{i}" for i in range(4)]
        fns = tuple(FunctionSpec(n, t, mem_mb=256, setup_time=setup)
                    for n, t in zip(names, e))
        # diamond: f0 -> (f1, f2) -> f3
        edges = ((names[0], names[1]), (names[0], names[2]),
                 (names[1], names[3]), (names[2], names[3]))
        cp = e[0] + max(e[1], e[2]) + e[3]
        slack = u(0.500, 1.000)
    else:
        raise ValueError(f"unknown class {cls}")
    return DagSpec(dag_id=dag_id, functions=fns, edges=edges,
                   deadline=cp + slack)


@dataclass
class WorkloadSpec:
    """A set of (DAG, arrival process) tenants plus a duration."""

    tenants: List[Tuple[DagSpec, ArrivalProcess]]
    duration: float

    def _tenant_seed(self, seed: int, i: int) -> int:
        return (seed << 16) ^ (i * 2654435761 & 0xFFFFFFFF)

    def generate_arrays(self, seed: int = 0
                        ) -> Tuple["np.ndarray", "np.ndarray",
                                   List[DagSpec]]:
        """Vectorized arrival generation: returns time-sorted arrival times,
        the per-arrival tenant index, and the tenant DAG list.  The runner
        streams straight off these arrays without materializing per-arrival
        tuples or closures."""
        times: List[np.ndarray] = []
        idxs: List[np.ndarray] = []
        dags: List[DagSpec] = []
        for i, (dag, proc) in enumerate(self.tenants):
            rng = np.random.default_rng(self._tenant_seed(seed, i))
            ts = proc.generate_np(self.duration, rng)
            times.append(ts)
            idxs.append(np.full(len(ts), i, dtype=np.int64))
            dags.append(dag)
        all_t = np.concatenate(times) if times else np.empty(0)
        all_i = np.concatenate(idxs) if idxs else np.empty(0, dtype=np.int64)
        order = np.argsort(all_t, kind="stable")
        return all_t[order], all_i[order], dags

    def generate(self, seed: int = 0,
                 method: str = "numpy") -> List[Tuple[float, DagSpec]]:
        """All (arrival_time, dag) pairs across tenants, time-sorted.

        ``method="numpy"`` (default) uses vectorized thinning;
        ``method="legacy"`` is the original per-dt-bin Python loop (the
        reference for the scheduler-equivalence goldens).
        """
        if method == "numpy":
            ts, idx, dags = self.generate_arrays(seed)
            return [(t, dags[i]) for t, i in zip(ts.tolist(), idx.tolist())]
        if method != "legacy":
            raise ValueError(f"unknown generation method {method!r}")
        out: List[Tuple[float, DagSpec]] = []
        for i, (dag, proc) in enumerate(self.tenants):
            sub = random.Random(self._tenant_seed(seed, i))
            for t in proc.generate(self.duration, sub):
                out.append((t, dag))
        out.sort(key=lambda p: p[0])
        return out

    def offered_core_load(self) -> float:
        """Mean core-seconds demanded per second (for utilization checks)."""
        total = 0.0
        for dag, proc in self.tenants:
            # average rate over the duration (coarse numeric mean)
            n = 200
            mean_rate = sum(max(0.0, proc.rate(self.duration * k / n))
                            for k in range(n)) / n
            work = sum(f.exec_time for f in dag.functions)
            total += mean_rate * work
        return total


# -- the two macro workloads (§7.1), scalable for small machines ------------


def paper_workload_1(duration: float = 30.0, scale: float = 1.0,
                     dags_per_class: int = 2, seed: int = 7) -> WorkloadSpec:
    """Poisson arrivals; mean resampled each second from per-class ranges."""
    rng = random.Random(seed)
    ranges = {"C1": (800, 1200), "C2": (600, 900),
              "C3": (600, 800), "C4": (50, 150)}
    tenants = []
    for cls, (lo, hi) in ranges.items():
        for k in range(dags_per_class):
            dag = make_paper_dag(cls, f"{cls}-{k}", rng)
            # stable per-tenant seed: builtin hash() is salted per process
            # (PYTHONHASHSEED), which silently made every run irreproducible
            proc = PoissonResampled(
                (lo * scale / dags_per_class, hi * scale / dags_per_class),
                seed=seed ^ zlib.crc32(f"{cls}-{k}".encode()) & 0xFFFF)
            tenants.append((dag, proc))
    return WorkloadSpec(tenants, duration)


def paper_workload_2(duration: float = 30.0, scale: float = 1.0,
                     dags_per_class: int = 2, seed: int = 11) -> WorkloadSpec:
    """Sinusoidal arrivals with Table 1 parameters."""
    rng = random.Random(seed)
    params = {  # avg-range, amplitude-range, period-range
        "C1": ((600, 1200), (100, 800), (10, 20)),
        "C2": ((400, 800), (200, 400), (30, 40)),
        "C3": ((500, 1000), (200, 600), (10, 20)),
        "C4": ((200, 200), (0, 0), (math.inf, math.inf)),
    }
    u = lambda lo, hi: lo if lo == hi else lo + rng.random() * (hi - lo)
    tenants = []
    for cls, (avg_r, amp_r, per_r) in params.items():
        for k in range(dags_per_class):
            dag = make_paper_dag(cls, f"{cls}-{k}", rng)
            avg = u(*avg_r) * scale / dags_per_class
            amp = u(*amp_r) * scale / dags_per_class
            per = u(*per_r) if math.isfinite(per_r[0]) else math.inf
            # keep instantaneous rate non-negative; random phase decorrelates
            # tenant peaks (utilization oscillates rather than spiking as one)
            amp = min(amp, avg)
            tenants.append((dag, Sinusoidal(avg, amp, per,
                                            phase=rng.random() * 2 * math.pi)))
    return WorkloadSpec(tenants, duration)
