"""Traffic scenarios: realistic arrival shapes as a sweepable axis.

Dirigent's yardstick (PAPERS.md) is that cluster managers are judged under
churn and bursts, not steady state, and NOAH shows scheduling verdicts flip
under bursty workload-adaptive traffic — so traffic shapes are first-class
here, not hand-edited workload kwargs.  A *scenario* is a registered, seeded
transformation of a :class:`~repro.sim.workload.WorkloadSpec`: it rewrites
tenants' :class:`~repro.sim.workload.ArrivalProcess`\\ es with the composable
rate modulators (``DiurnalRate``/``BurstRate``/``WindowedRate``/
``ScaledRate``) and may add or retire tenants outright.

Scenarios are carried on ``Experiment.traffic`` — a registered name
(``"flash_crowd"``) or a :class:`TrafficSpec` with kwargs — so they sweep
and parallelize like any other field:

    run_sweep(base, {"traffic": ["steady", "diurnal", "flash_crowd"],
                     "stack": ["archipelago", "sparrow"]})

Built-in scenarios (all seeded through ``TrafficSpec.seed``, independent of
``Experiment.seed`` so arrival draws vary per cell while the scenario shape
stays fixed):

* ``steady`` — identity (explicit no-op baseline for matrices).
* ``diurnal`` — a shared day-cycle envelope over every tenant (correlated
  trough → peak → trough across the run).
* ``flash_crowd`` — a seeded fraction of tenants is amplified ``amplify``x
  inside a burst window (the crowd hits specific applications).
* ``tenant_churn`` — a seeded fraction of tenants departs mid-run and fresh
  tenants (new DAG ids, never seen at t=0) arrive mid-run.
* ``zipf_mix`` — per-tenant rates reweighted by a seeded Zipf permutation
  (skewed multi-tenant popularity), mean factor 1 so aggregate load is
  comparable to the unskewed run.

New scenarios register with :func:`register_traffic`, mirroring the
stack/backend/fault registries (docs/SCENARIOS.md)::

    @register_traffic("my_shape")
    def my_shape(spec, rng, **kwargs):    # -> new WorkloadSpec
        ...
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Mapping, Tuple, Union

from .workload import (ArrivalProcess, BurstRate, DiurnalRate, ScaledRate,
                       WindowedRate, WorkloadSpec, make_paper_dag)

__all__ = [
    "TrafficSpec", "register_traffic", "get_traffic", "available_traffic",
    "apply_traffic",
]


def _freeze_kwargs(kw: Mapping[str, Any]) -> Tuple[Tuple[str, Any], ...]:
    return tuple(sorted(kw.items()))


@dataclass(frozen=True)
class TrafficSpec:
    """One declarative traffic scenario: a registered name plus kwargs.

    Frozen with kwargs as a sorted tuple of pairs (the ``FaultEvent``
    convention) so specs hash, pickle (``run_sweep`` workers) and compare
    cleanly.  ``seed`` drives only the scenario's own choices (which tenants
    burst/churn, Zipf rank order) — arrival sampling stays on the
    experiment's seed."""

    name: str
    kwargs: Tuple[Tuple[str, Any], ...] = ()
    seed: int = 0

    def arg_dict(self) -> Dict[str, Any]:
        return dict(self.kwargs)

    def label(self) -> str:
        return self.name

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "kwargs": dict(self.kwargs),
                "seed": self.seed}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "TrafficSpec":
        return cls(name=d["name"], kwargs=_freeze_kwargs(d.get("kwargs", {})),
                   seed=d.get("seed", 0))


def scenario(name: str, seed: int = 0, **kwargs: Any) -> TrafficSpec:
    """Convenience constructor: ``scenario("flash_crowd", amplify=4.0)``."""
    return TrafficSpec(name=name, kwargs=_freeze_kwargs(kwargs), seed=seed)


# -- registry (mirrors stacks/backends/faults) -------------------------------

# builder(spec, rng, **kwargs) -> new WorkloadSpec
TrafficBuilder = Callable[..., WorkloadSpec]

_TRAFFIC: Dict[str, TrafficBuilder] = {}


def register_traffic(name: str, *aliases: str
                     ) -> Callable[[TrafficBuilder], TrafficBuilder]:
    """Decorator: make a scenario constructible by name through
    ``Experiment(traffic=name)``.  Raises on duplicate registration."""

    def deco(fn: TrafficBuilder) -> TrafficBuilder:
        names = (name, *aliases)
        taken = [n for n in names if n in _TRAFFIC]
        if taken:       # validate before inserting: no partial registration
            raise ValueError(
                f"traffic scenario {taken[0]!r} is already registered")
        for n in names:
            _TRAFFIC[n] = fn
        return fn

    return deco


def get_traffic(name: str) -> TrafficBuilder:
    try:
        return _TRAFFIC[name]
    except KeyError:
        raise ValueError(
            f"unknown traffic scenario {name!r}; registered scenarios: "
            f"{', '.join(sorted(_TRAFFIC))}") from None


def available_traffic() -> List[str]:
    return sorted(_TRAFFIC)


def apply_traffic(spec: WorkloadSpec,
                  traffic: Union[str, TrafficSpec]) -> WorkloadSpec:
    """Resolve and apply one scenario to a resolved workload spec.  A bare
    string is shorthand for ``TrafficSpec(name)`` with default kwargs."""
    ts = TrafficSpec(name=traffic) if isinstance(traffic, str) else traffic
    builder = get_traffic(ts.name)
    return builder(spec, random.Random(ts.seed), **ts.arg_dict())


# -- built-in scenarios ------------------------------------------------------


@register_traffic("steady")
def steady(spec: WorkloadSpec, rng: random.Random) -> WorkloadSpec:
    """Identity scenario: the explicit no-op baseline of a scenario matrix
    (``traffic=None`` skips the subsystem entirely and is decision-identical
    to pre-scenario runs; ``"steady"`` routes through it)."""
    return WorkloadSpec(list(spec.tenants), spec.duration)


@register_traffic("diurnal")
def diurnal(spec: WorkloadSpec, rng: random.Random, period: float = 0.0,
            depth: float = 0.6,
            phase: float = -math.pi / 2.0) -> WorkloadSpec:
    """Correlated day-cycle load: every tenant's rate swings together
    between ``(1-depth)x`` and ``(1+depth)x`` — the whole-population
    utilization wave autoscalers are sized against.  ``period`` defaults to
    the run duration (one compressed day per run)."""
    per = period if period > 0.0 else spec.duration
    tenants = [(dag, DiurnalRate(proc, period=per, depth=depth, phase=phase))
               for dag, proc in spec.tenants]
    return WorkloadSpec(tenants, spec.duration)


@register_traffic("flash_crowd")
def flash_crowd(spec: WorkloadSpec, rng: random.Random, at: float = 0.0,
                duration: float = 0.0, amplify: float = 8.0,
                frac: float = 0.25, ramp: float = 0.0) -> WorkloadSpec:
    """A flash crowd hits a seeded ``frac`` of tenants: their rates are
    amplified ``amplify``x inside ``[at, at+duration)`` with
    ``ramp``-second linear edges.  Defaults: the burst is centered at
    mid-run, lasts 10% of the run, and ramps over 20% of its width."""
    t0 = at if at > 0.0 else 0.5 * spec.duration
    dur = duration if duration > 0.0 else 0.1 * spec.duration
    edge = ramp if ramp > 0.0 else 0.2 * dur
    n = len(spec.tenants)
    k = max(1, int(round(frac * n)))
    hot = set(rng.sample(range(n), min(k, n)))
    tenants = [
        (dag, BurstRate(proc, at=t0, duration=dur, amplify=amplify,
                        ramp=edge) if i in hot else proc)
        for i, (dag, proc) in enumerate(spec.tenants)]
    return WorkloadSpec(tenants, spec.duration)


@register_traffic("tenant_churn")
def tenant_churn(spec: WorkloadSpec, rng: random.Random,
                 leave_frac: float = 0.3, join_frac: float = 0.3,
                 window: Tuple[float, float] = (0.2, 0.8)) -> WorkloadSpec:
    """Tenant arrival/departure churn: a seeded ``leave_frac`` of tenants
    departs at seeded times inside ``window`` (fraction of the run), and
    ``join_frac * n`` fresh tenants — *new* DAG ids the control plane has
    never seen, cloned from seeded templates' class and arrival shape —
    join at seeded times.  This is Dirigent's lifecycle-churn regime: the
    consistent-hash ring and per-DAG state meet DAGs mid-run instead of a
    fixed t=0 population."""
    n = len(spec.tenants)
    lo, hi = window
    u = lambda: spec.duration * (lo + rng.random() * (hi - lo))
    n_leave = int(round(leave_frac * n))
    leavers = set(rng.sample(range(n), min(n_leave, n)))
    tenants: List[Tuple[Any, ArrivalProcess]] = [
        (dag, WindowedRate(proc, end=u()) if i in leavers else proc)
        for i, (dag, proc) in enumerate(spec.tenants)]
    n_join = int(round(join_frac * n))
    for j in range(n_join):
        dag_t, proc_t = spec.tenants[rng.randrange(n)]
        cls = dag_t.dag_id.split("-")[0]
        new_dag = make_paper_dag(cls, f"{cls}-join{j}", rng)
        tenants.append((new_dag, WindowedRate(proc_t, start=u())))
    return WorkloadSpec(tenants, spec.duration)


@register_traffic("zipf_mix")
def zipf_mix(spec: WorkloadSpec, rng: random.Random,
             s: float = 1.1) -> WorkloadSpec:
    """Skewed multi-tenant popularity: tenant rates reweighted by a seeded
    Zipf(s) permutation, normalized to mean factor 1 (aggregate offered
    load stays comparable to the unskewed mix — the skew moves load between
    tenants, concentrating per-DAG hotspots)."""
    n = len(spec.tenants)
    if n == 0:
        return WorkloadSpec([], spec.duration)
    ranks = list(range(n))
    rng.shuffle(ranks)
    weights = [(r + 1) ** -s for r in ranks]
    norm = n / sum(weights)
    tenants = [(dag, ScaledRate(proc, factor=w * norm))
               for (dag, proc), w in zip(spec.tenants, weights)]
    return WorkloadSpec(tenants, spec.duration)
