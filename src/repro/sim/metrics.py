"""Evaluation metrics (§7.1): E2E latency, % deadlines met, queuing delay,
cold starts."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.types import Request


def percentile(xs: Sequence[float], p: float) -> float:
    """Nearest-rank percentile; p in [0,100]."""
    if not xs:
        return float("nan")
    return _pct_sorted(sorted(xs), p)


def _pct_sorted(s: Sequence[float], p: float) -> float:
    """Nearest-rank percentile over an already-sorted sequence."""
    if not s:
        return float("nan")
    k = max(0, min(len(s) - 1, int(round(p / 100.0 * (len(s) - 1)))))
    return s[k]


@dataclass
class Metrics:
    requests: List[Request] = field(default_factory=list)
    queuing_delays: List[float] = field(default_factory=list)
    # per-sample dispatch timestamps, parallel to ``queuing_delays`` — lets
    # steady-state views filter delay samples and requests consistently
    queuing_delay_times: List[float] = field(default_factory=list)
    # sorted-latency cache: ``summarize``/``latency_pct`` take several
    # percentiles per report and each used to re-sort the full latency list.
    # Keyed on (n_requests, n_completed): requests are append-only and a
    # completion_time is written exactly once, so any change to the latency
    # set moves one of the two counts.  compare=False keeps dataclass
    # equality on the data fields only.
    _lat_cache: Optional[Tuple[Tuple[int, int], List[float]]] = field(
        default=None, repr=False, compare=False)

    @property
    def completed(self) -> List[Request]:
        return [r for r in self.requests if r.completion_time is not None]

    def sorted_latencies(self) -> List[float]:
        """E2E latencies of completed requests, ascending — one sort per
        (requests, completions) state, cached across percentile calls."""
        done = self.completed
        key = (len(self.requests), len(done))
        if self._lat_cache is None or self._lat_cache[0] != key:
            self._lat_cache = (key, sorted(r.e2e_latency for r in done))
        return self._lat_cache[1]

    def after_warmup(self, warmup: float) -> "Metrics":
        """Steady-state view: only requests arriving after ``warmup`` count
        (excludes the cold-cluster transient, as any fixed-duration testbed
        run longer than the transient effectively does).  Queuing-delay
        samples are filtered by their dispatch timestamp the same way; a
        legacy Metrics built without timestamps keeps all samples."""
        reqs = [r for r in self.requests if r.arrival_time >= warmup]
        if len(self.queuing_delay_times) == len(self.queuing_delays):
            kept = [(t, d) for t, d in zip(self.queuing_delay_times,
                                           self.queuing_delays)
                    if t >= warmup]
            times = [t for t, _ in kept]
            delays = [d for _, d in kept]
        else:           # timestamps unavailable: keep the old behavior
            times = []
            delays = list(self.queuing_delays)
        return Metrics(requests=reqs, queuing_delays=delays,
                       queuing_delay_times=times)

    def latencies(self) -> List[float]:
        return [r.e2e_latency for r in self.completed]

    def latency_pct(self, p: float) -> float:
        return _pct_sorted(self.sorted_latencies(), p)

    def deadline_met_frac(self) -> float:
        done = self.completed
        if not done:
            return float("nan")
        # incomplete requests count as missed (conservative, like the paper's
        # fixed-duration runs)
        met = sum(1 for r in done if r.deadline_met)
        return met / len(self.requests)

    def cold_start_count(self) -> int:
        return sum(r.n_cold_starts for r in self.requests)

    def cold_start_frac(self) -> float:
        """Cold starts per invocation, numerator and denominator both over
        COMPLETED requests (an in-flight request's invocation count is not
        yet knowable, and mixing sets let the fraction exceed 1 under
        load)."""
        done = self.completed
        if not done:
            return float("nan")
        n_cold = sum(r.n_cold_starts for r in done)
        n_inv = sum(len(r.dag.functions) for r in done)
        return n_cold / max(1, n_inv)

    def by_class(self) -> Dict[str, "Metrics"]:
        out: Dict[str, Metrics] = {}
        for r in self.requests:
            cls = r.dag.dag_id.split("-")[0]
            out.setdefault(cls, Metrics()).requests.append(r)
        return out


def summarize(name: str, m: Metrics) -> str:
    lat = m.sorted_latencies()          # one sort feeds all three ranks
    if not lat:
        return f"{name}: no completed requests"
    return (f"{name}: n={len(m.requests)} done={len(lat)} "
            f"p50={_pct_sorted(lat,50)*1e3:.1f}ms "
            f"p99={_pct_sorted(lat,99)*1e3:.1f}ms "
            f"p99.9={_pct_sorted(lat,99.9)*1e3:.1f}ms "
            f"deadlines_met={m.deadline_met_frac()*100:.2f}% "
            f"cold_starts={m.cold_start_count()}")
