"""Evaluation metrics (§7.1): E2E latency, % deadlines met, queuing delay,
cold starts.

Two recording modes share one ``Metrics`` interface:

* **Object mode** (the legacy layout, used by tests and ad-hoc analysis):
  ``Metrics(requests=[...])`` holds live ``Request`` objects and every
  statistic is computed by scanning them.  Constructing a ``Metrics``
  directly — or appending to ``.requests`` — keeps exactly the historical
  semantics, including visibility of post-append mutations.

* **Flat column mode** (what ``simulate`` uses): the arrival columns
  (times + per-arrival tenant index) are attached wholesale from the
  vectorized workload generator *before* the run, and schedulers record
  completions through ``record_completion`` into append-only parallel
  buffers (completion time, cold starts, SGS id, total queuing delay).
  No per-``Request`` object is retained after its completion — at
  million-request scale this is the difference between O(n) Python object
  churn per report and a handful of numpy passes.  ``after_warmup`` is a
  zero-copy view (an index cutoff into the time-sorted arrival column plus
  a timestamp threshold for queuing samples); ``summarize``/``latency_pct``/
  ``deadline_met_frac``/``cold_start_frac``/``by_class`` are vectorized.
  The ``requests`` property stays available as a *compatibility view* that
  materializes equivalent ``Request`` objects on demand (bit-identical
  float fields), so existing figures and tests keep working unchanged.

  Flat-mode views describe the whole attached arrival trace: they are
  meant to be read after the run (that is when ``simulate`` reads them).
  A mid-run hook that must observe partial state should consult the
  scheduler objects (queue lengths, counters) rather than the metrics
  plane — in legacy object mode the request list grows with the pump, in
  flat mode future arrivals already occupy (incomplete) rows.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.types import DagSpec, Request


def percentile(xs: Sequence[float], p: float) -> float:
    """Nearest-rank percentile; p in [0,100]."""
    if len(xs) == 0:
        return float("nan")
    return _pct_sorted(sorted(xs), p)


def _pct_sorted(s: Sequence[float], p: float) -> float:
    """Nearest-rank percentile over an already-sorted sequence."""
    n = len(s)
    if n == 0:
        return float("nan")
    k = max(0, min(n - 1, int(round(p / 100.0 * (n - 1)))))
    return s[k]


def _dag_class(dag_id: str) -> str:
    return dag_id.split("-")[0]


class _FlatColumns:
    """One run's append-only column store (shared by every view of it).

    Arrival-side columns are attached once, in arrival-time order, straight
    from ``WorkloadSpec.generate_arrays`` — the pump never touches them.
    Completion-side records are one appended tuple per completed request
    (cheaper than per-scalar numpy stores on the hot path) and are
    transposed to numpy lazily, cached per completion count.
    ``pending`` maps row index -> live ``Request`` for the (few) requests
    in flight, so views over incomplete requests stay exact.
    """

    __slots__ = ("n", "arrival", "dag_idx", "dags", "dag_deadline",
                 "dag_n_fns", "dag_class_id", "class_names", "pending",
                 "comp", "_fin", "_mat")

    def __init__(self, arrival: np.ndarray, dag_idx: np.ndarray,
                 dags: List[DagSpec]):
        self.n = len(arrival)
        self.arrival = np.ascontiguousarray(arrival, dtype=np.float64)
        self.dag_idx = np.ascontiguousarray(dag_idx, dtype=np.int64)
        self.dags = list(dags)
        self.dag_deadline = np.array([d.deadline for d in self.dags],
                                     dtype=np.float64)
        self.dag_n_fns = np.array([len(d.functions) for d in self.dags],
                                  dtype=np.int64)
        names: List[str] = []
        ids: List[int] = []
        seen: Dict[str, int] = {}
        for d in self.dags:
            cls = _dag_class(d.dag_id)
            cid = seen.setdefault(cls, len(seen))
            if cid == len(names):
                names.append(cls)
            ids.append(cid)
        self.class_names = names
        self.dag_class_id = np.array(ids, dtype=np.int64) \
            if ids else np.empty(0, dtype=np.int64)
        self.pending: Dict[int, Request] = {}
        # (row idx, completion time, cold starts, sgs id, total queuing
        # delay) per completed request, in completion order
        self.comp: List[Tuple[int, float, int, int, float]] = []
        self._fin: Optional[Tuple[int, Tuple[np.ndarray, ...]]] = None
        self._mat: Optional[Tuple[int, List[Request]]] = None

    # -- recording (hot path) ------------------------------------------------
    def record_completion(self, req: Request, now: float) -> None:
        i = req.m_idx
        sid = req.sgs_id
        self.comp.append((i, now, req.n_cold_starts,
                          -1 if sid is None else sid,
                          req.total_queuing_delay))
        self.pending.pop(i, None)

    # -- lazily finalized numpy views ---------------------------------------
    def finalized(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                 np.ndarray, np.ndarray]:
        """(comp_idx, comp_time, comp_cold, comp_sgs, comp_qd) as arrays,
        rebuilt only when more completions were recorded since last use."""
        n_comp = len(self.comp)
        if self._fin is None or self._fin[0] != n_comp:
            if n_comp:
                ci, ct, cc, cs, cq = zip(*self.comp)
            else:
                ci = ct = cc = cs = cq = ()
            self._fin = (n_comp, (
                np.asarray(ci, dtype=np.int64),
                np.asarray(ct, dtype=np.float64),
                np.asarray(cc, dtype=np.int64),
                np.asarray(cs, dtype=np.int64),
                np.asarray(cq, dtype=np.float64)))
        return self._fin[1]

    def materialize(self) -> List[Request]:
        """Compatibility view: equivalent ``Request`` objects in arrival
        order — live objects for in-flight requests, reconstructed ones
        (bit-identical float fields) for completed rows.

        The view covers the whole attached arrival trace: read it after the
        run (or a drain point), not from mid-run hooks — rows whose arrival
        has not fired yet materialize as not-yet-completed requests.  The
        cache key includes the pending count so a post-run view is rebuilt
        whenever arrivals or completions advanced."""
        key = (len(self.comp), len(self.pending))
        if self._mat is not None and self._mat[0] == key:
            return self._mat[1]
        comp_t = np.full(self.n, np.nan)
        comp_cold = np.zeros(self.n, dtype=np.int64)
        comp_sgs = np.full(self.n, -2, dtype=np.int64)
        comp_qd = np.zeros(self.n, dtype=np.float64)
        ci, ct, cc, cs, cq = self.finalized()
        comp_t[ci] = ct
        comp_cold[ci] = cc
        comp_sgs[ci] = cs
        comp_qd[ci] = cq
        arrival = self.arrival.tolist()
        dag_of = self.dag_idx.tolist()
        ct_l = comp_t.tolist()
        cc_l = comp_cold.tolist()
        cs_l = comp_sgs.tolist()
        cq_l = comp_qd.tolist()
        pending = self.pending
        dags = self.dags
        out: List[Request] = []
        for i in range(self.n):
            r = pending.get(i)
            if r is None:
                r = Request(dag=dags[dag_of[i]], arrival_time=arrival[i])
                r.m_idx = i
                t = ct_l[i]
                if t == t:                      # not NaN -> completed
                    r.completion_time = t
                    r.n_cold_starts = cc_l[i]
                    sid = cs_l[i]
                    r.sgs_id = None if sid < 0 else sid
                    r.total_queuing_delay = cq_l[i]
            out.append(r)
        self._mat = (key, out)
        return out


class _CompLen:
    """Stands in for ``_FlatColumns.comp`` after a sharded merge: every
    consumer keys on ``len(comp)`` (cache invalidation) and reads rows only
    through ``finalized()``, so a merged run carries just the count — the
    actual columns are installed directly as the finalized arrays, skipping
    a pointless n-tuple Python list at 10M-request scale."""

    __slots__ = ("n",)

    def __init__(self, n: int):
        self.n = n

    def __len__(self) -> int:
        return self.n

    def append(self, row) -> None:  # pragma: no cover - guards misuse
        raise RuntimeError(
            "cannot record into a sharded-merged Metrics (completions were "
            "absorbed as finalized columns)")


class Metrics:
    """Unified metrics container — see the module docstring for the two
    recording modes.  The constructor signature (``requests``,
    ``queuing_delays``, ``queuing_delay_times``) is the historical object
    mode; ``Metrics.flat(...)`` builds the column-recording mode."""

    __slots__ = ("_requests", "_qd", "_qt", "_lat_cache", "_cols", "_lo",
                 "_hi", "_warm_t", "_qt_hi", "_cls", "_qchunks", "_qcache",
                 "_comp_cache")

    def __init__(self, requests: Optional[List[Request]] = None,
                 queuing_delays: Optional[List[float]] = None,
                 queuing_delay_times: Optional[List[float]] = None):
        self._requests = requests if requests is not None else []
        self._qd = queuing_delays if queuing_delays is not None else []
        self._qt = (queuing_delay_times if queuing_delay_times is not None
                    else [])
        # sorted-latency cache (object mode): keyed on
        # (n_requests, n_completed) — requests are append-only and a
        # completion_time is written exactly once, so any change to the
        # latency set moves one of the two counts.
        self._lat_cache: Optional[Tuple[Tuple[int, int], List[float]]] = None
        self._cols: Optional[_FlatColumns] = None
        self._lo = 0                    # arrival-row cutoff (warmup views)
        self._hi: Optional[int] = None  # arrival-row upper cutoff (windows)
        self._warm_t = 0.0              # queuing-sample timestamp cutoff
        self._qt_hi = float("inf")      # queuing-sample upper timestamp
        self._cls: Optional[int] = None  # class-id restriction (by_class)
        self._qchunks: List[Tuple[Sequence[float], Sequence[float]]] = []
        self._qcache = None             # (n_chunks, delays, times)
        self._comp_cache = None         # (n_comp, completion-window arrays)

    # ------------------------------------------------------------------ flat
    @classmethod
    def flat(cls, arrival: np.ndarray, dag_idx: np.ndarray,
             dags: List[DagSpec]) -> "Metrics":
        """Column-recording mode for one run: arrival columns attached
        wholesale; completions recorded via :meth:`record_completion`."""
        m = cls()
        m._cols = _FlatColumns(arrival, dag_idx, dags)
        return m

    def _view(self, lo: int, warm_t: float, cls_id: Optional[int],
              hi: Optional[int] = None,
              qt_hi: float = float("inf")) -> "Metrics":
        v = Metrics()
        v._cols = self._cols
        v._lo = lo
        v._hi = hi
        v._warm_t = warm_t
        v._qt_hi = qt_hi
        v._cls = cls_id
        v._qchunks = self._qchunks
        return v

    @property
    def is_flat(self) -> bool:
        return self._cols is not None

    def record_completion(self, req: Request, now: float) -> None:
        """Hot-path completion hook (flat mode): fold the request's final
        accounting into the column buffers and release the object."""
        self._cols.record_completion(req, now)

    def completion_recorder(self) -> Callable[[Request, float], None]:
        """The fastest bound completion hook for schedulers to call — the
        column store's own method in flat mode (one call frame fewer than
        going through :meth:`record_completion`)."""
        if self._cols is not None:
            return self._cols.record_completion
        return self.record_completion

    def absorb_sharded(self, comp_idx: np.ndarray, comp_time: np.ndarray,
                       comp_cold: np.ndarray, comp_sgs: np.ndarray,
                       comp_qd: np.ndarray,
                       pending: Dict[int, Request]) -> None:
        """Install a sharded run's merged completion columns (flat mode
        only — ``repro.sim.shard`` coordinator).  The five arrays are the
        exact shape ``_FlatColumns.finalized()`` would build from per-tuple
        recording (row idx, completion time, cold starts, SGS id, total
        queuing delay); order across rows is irrelevant to every statistic
        (percentiles sort, the rest are sums/masks/scatters by row index).
        ``pending`` holds reconstructed stand-ins for requests still in
        flight at the horizon, exactly like the live objects the sequential
        pump would have left behind."""
        c = self._cols
        if c is None:
            raise RuntimeError("absorb_sharded requires flat-column mode")
        n = len(comp_idx)
        c._fin = (n, (comp_idx, comp_time, comp_cold, comp_sgs, comp_qd))
        c.comp = _CompLen(n)
        c.pending = pending

    def add_queuing_samples(self, delays: Sequence[float],
                            times: Sequence[float]) -> None:
        """Fold one scheduler's queuing-delay samples into this run's
        metrics (called by ``Stack.collect``).  Chunks are kept by
        reference and concatenated lazily in flat mode."""
        if self._cols is not None:
            self._qchunks.append((delays, times))
            self._qcache = None
        else:
            self._qd.extend(delays)
            self._qt.extend(times)

    # -- flat internals ------------------------------------------------------
    def _q_arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        """(delays, times) filtered to this view's warmup window."""
        key = len(self._qchunks)
        if self._qcache is None or self._qcache[0] != key:
            if self._qchunks:
                d = np.concatenate([np.asarray(c[0], dtype=np.float64)
                                    for c in self._qchunks])
                t = np.concatenate([np.asarray(c[1], dtype=np.float64)
                                    for c in self._qchunks])
            else:
                d = np.empty(0)
                t = np.empty(0)
            if self._warm_t > 0.0 or self._qt_hi != float("inf"):
                keep = (t >= self._warm_t) & (t < self._qt_hi)
                d = d[keep]
                t = t[keep]
            self._qcache = (key, d, t)
        return self._qcache[1], self._qcache[2]

    def _comp_window(self) -> Tuple[np.ndarray, ...]:
        """Completion columns restricted to this view (warmup cutoff and
        optional class restriction), cached per completion count."""
        c = self._cols
        key = len(c.comp)
        if self._comp_cache is None or self._comp_cache[0] != key:
            ci, ct, cc, cs, cq = c.finalized()
            if self._lo > 0 or self._hi is not None:
                keep = ci >= self._lo
                if self._hi is not None:
                    keep &= ci < self._hi
                ci, ct, cc, cs, cq = (ci[keep], ct[keep], cc[keep],
                                      cs[keep], cq[keep])
            if self._cls is not None:
                keep = c.dag_class_id[c.dag_idx[ci]] == self._cls
                ci, ct, cc, cs, cq = (ci[keep], ct[keep], cc[keep],
                                      cs[keep], cq[keep])
            self._comp_cache = (key, ci, ct, cc, cs, cq)
        return self._comp_cache[1:]

    def _n_rows(self) -> int:
        """Requests in this view's window (flat mode)."""
        c = self._cols
        hi = c.n if self._hi is None else min(self._hi, c.n)
        if self._cls is None:
            return max(0, hi - self._lo)
        if hi <= self._lo:
            return 0
        return int((c.dag_class_id[c.dag_idx[self._lo:hi]]
                    == self._cls).sum())

    def _pending_in_window(self) -> List[Request]:
        c = self._cols
        lo, cid = self._lo, self._cls
        hi = c.n if self._hi is None else self._hi
        out = []
        for i, r in c.pending.items():
            if lo <= i < hi and (cid is None
                                 or c.dag_class_id[c.dag_idx[i]] == cid):
                out.append(r)
        return out

    # ------------------------------------------------------------ properties
    @property
    def requests(self) -> List[Request]:
        """The per-request view.  Object mode: the live backing list
        (mutable, appendable).  Flat mode: a materialized compatibility
        list in arrival order — read-only by construction (appending to it
        does not record)."""
        if self._cols is None:
            return self._requests
        reqs = self._cols.materialize()
        if self._lo > 0 or self._hi is not None:
            reqs = reqs[self._lo:self._hi]
        if self._cls is not None:
            c = self._cols
            cid_of = c.dag_class_id[c.dag_idx[self._lo:self._hi]].tolist()
            reqs = [r for r, k in zip(reqs, cid_of) if k == self._cls]
        return reqs

    @property
    def queuing_delays(self) -> Sequence[float]:
        if self._cols is None:
            return self._qd
        return self._q_arrays()[0]

    @property
    def queuing_delay_times(self) -> Sequence[float]:
        if self._cols is None:
            return self._qt
        return self._q_arrays()[1]

    @property
    def completed(self) -> List[Request]:
        if self._cols is None:
            return [r for r in self._requests
                    if r.completion_time is not None]
        return [r for r in self.requests if r.completion_time is not None]

    @property
    def n_requests(self) -> int:
        """Request count in this view — O(1)-ish in flat mode (no object
        materialization)."""
        if self._cols is None:
            return len(self._requests)
        return self._n_rows()

    @property
    def n_completed(self) -> int:
        """Completed-request count, maintained incrementally in flat mode
        (the historical ``len(m.completed)`` rebuilt a list per access)."""
        if self._cols is None:
            return sum(1 for r in self._requests
                       if r.completion_time is not None)
        return len(self._comp_window()[0])

    # ------------------------------------------------------------- statistics
    def sorted_latencies(self) -> Sequence[float]:
        """E2E latencies of completed requests, ascending — one sort per
        (requests, completions) state, cached across percentile calls."""
        if self._cols is not None:
            ci, ct = self._comp_window()[:2]
            lat = ct - self._cols.arrival[ci]
            lat.sort()
            return lat
        done = self.completed
        key = (len(self._requests), len(done))
        if self._lat_cache is None or self._lat_cache[0] != key:
            self._lat_cache = (key, sorted(r.e2e_latency for r in done))
        return self._lat_cache[1]

    def after_warmup(self, warmup: float) -> "Metrics":
        """Steady-state view: only requests arriving after ``warmup`` count
        (excludes the cold-cluster transient, as any fixed-duration testbed
        run longer than the transient effectively does).  Queuing-delay
        samples are filtered by their dispatch timestamp the same way; a
        legacy Metrics built without timestamps keeps all samples.

        Flat mode returns a zero-copy view (an index cutoff into the
        time-sorted arrival column); object mode copies the filtered lists
        as before."""
        if self._cols is not None:
            lo = int(np.searchsorted(self._cols.arrival, warmup, "left"))
            return self._view(max(self._lo, lo),
                              max(self._warm_t, warmup), self._cls,
                              self._hi, self._qt_hi)
        reqs = [r for r in self._requests if r.arrival_time >= warmup]
        if len(self._qt) == len(self._qd):
            kept = [(t, d) for t, d in zip(self._qt, self._qd)
                    if t >= warmup]
            times = [t for t, _ in kept]
            delays = [d for _, d in kept]
        else:           # timestamps unavailable: keep the old behavior
            times = []
            delays = list(self._qd)
        return Metrics(requests=reqs, queuing_delays=delays,
                       queuing_delay_times=times)

    def window(self, t0: float, t1: float) -> "Metrics":
        """Time-window view over arrivals in ``[t0, t1)`` (recovery metrics:
        deadline-met/latency before vs. after a fault).  Queuing-delay
        samples are filtered by dispatch timestamp the same way.

        Flat mode is a zero-copy view: two ``searchsorted`` cuts into the
        time-sorted arrival column, composed with any prior
        ``after_warmup``/``window`` restriction.  Object mode copies the
        filtered lists (legacy semantics)."""
        if self._cols is not None:
            arr = self._cols.arrival
            lo = int(np.searchsorted(arr, t0, "left"))
            hi = int(np.searchsorted(arr, t1, "left"))
            prev_hi = self._cols.n if self._hi is None else self._hi
            return self._view(max(self._lo, lo),
                              max(self._warm_t, t0), self._cls,
                              min(prev_hi, hi), min(self._qt_hi, t1))
        reqs = [r for r in self._requests if t0 <= r.arrival_time < t1]
        if len(self._qt) == len(self._qd):
            kept = [(t, d) for t, d in zip(self._qt, self._qd)
                    if t0 <= t < t1]
            times = [t for t, _ in kept]
            delays = [d for _, d in kept]
        else:           # timestamps unavailable: keep every sample
            times = []
            delays = list(self._qd)
        return Metrics(requests=reqs, queuing_delays=delays,
                       queuing_delay_times=times)

    def latencies(self) -> Sequence[float]:
        if self._cols is not None:
            ci, ct = self._comp_window()[:2]
            return ct - self._cols.arrival[ci]
        return [r.e2e_latency for r in self.completed]

    def latency_pct(self, p: float) -> float:
        return float(_pct_sorted(self.sorted_latencies(), p))

    def deadline_met_frac(self) -> float:
        if self._cols is not None:
            ci, ct = self._comp_window()[:2]
            if len(ci) == 0:
                return float("nan")
            c = self._cols
            abs_dl = c.arrival[ci] + c.dag_deadline[c.dag_idx[ci]]
            met = int((ct <= abs_dl + 1e-9).sum())
            return met / self._n_rows()
        done = self.completed
        if not done:
            return float("nan")
        # incomplete requests count as missed (conservative, like the paper's
        # fixed-duration runs)
        met = sum(1 for r in done if r.deadline_met)
        return met / len(self._requests)

    def cold_start_count(self) -> int:
        if self._cols is not None:
            cc = self._comp_window()[2]
            pending_cold = sum(r.n_cold_starts
                               for r in self._pending_in_window())
            return int(cc.sum()) + pending_cold
        return sum(r.n_cold_starts for r in self._requests)

    def cold_start_frac(self) -> float:
        """Cold starts per invocation, numerator and denominator both over
        COMPLETED requests (an in-flight request's invocation count is not
        yet knowable, and mixing sets let the fraction exceed 1 under
        load)."""
        if self._cols is not None:
            ci, _, cc = self._comp_window()[:3]
            if len(ci) == 0:
                return float("nan")
            c = self._cols
            n_inv = int(c.dag_n_fns[c.dag_idx[ci]].sum())
            return int(cc.sum()) / max(1, n_inv)
        done = self.completed
        if not done:
            return float("nan")
        n_cold = sum(r.n_cold_starts for r in done)
        n_inv = sum(len(r.dag.functions) for r in done)
        return n_cold / max(1, n_inv)

    def accounting(self) -> Dict[str, int]:
        """Full-run request accounting for the fault-tolerance invariant
        ``completed + lost + pending == arrivals`` (docs/FAULTS.md).

        Always describes the WHOLE attached trace, ignoring any
        ``after_warmup``/``window``/class restriction — loss is a global
        property of a run, not of a view.  ``lost`` counts arrivals that
        neither completed nor remain in flight (a scheduler leak: a fault
        path dropped a request without retrying it); ``duplicate_completions``
        counts completion records beyond the first per request (a
        suppression bug: hedged retries or stale batch completions recorded
        twice).  A fault-tolerant run has both at zero — under any fault
        plan, since every in-flight request is retried and the drain phase
        runs the queues dry.  Object mode cannot distinguish lost from
        in-flight (incomplete requests are simply incomplete objects), so
        it reports them all as ``pending``.
        """
        c = self._cols
        if c is None:
            arrivals = len(self._requests)
            completed = sum(1 for r in self._requests
                            if r.completion_time is not None)
            return {"arrivals": arrivals, "completed": completed,
                    "unique_completed": completed,
                    "pending": arrivals - completed, "lost": 0,
                    "duplicate_completions": 0}
        completed = len(c.comp)
        unique = int(len(np.unique(c.finalized()[0]))) if completed else 0
        pending = len(c.pending)
        return {"arrivals": c.n, "completed": completed,
                "unique_completed": unique, "pending": pending,
                "lost": c.n - unique - pending,
                "duplicate_completions": completed - unique}

    def by_class(self) -> Dict[str, "Metrics"]:
        """Per-DAG-class views (C1..C4 style).  Flat mode: shared-column
        views keyed by class id; object mode: filtered copies, exactly the
        historical behavior (queuing samples are not class-attributed)."""
        if self._cols is not None:
            c = self._cols
            out: Dict[str, Metrics] = {}
            hi = c.n if self._hi is None else min(self._hi, c.n)
            if hi <= self._lo:
                present = []
            else:
                present = np.unique(
                    c.dag_class_id[c.dag_idx[self._lo:hi]]).tolist()
            for cid in present:
                if self._cls is not None and cid != self._cls:
                    continue
                v = self._view(self._lo, self._warm_t, cid,
                               self._hi, self._qt_hi)
                v._qchunks = []     # class views carry no queuing samples
                out[c.class_names[cid]] = v
            return out
        out2: Dict[str, Metrics] = {}
        for r in self._requests:
            cls = _dag_class(r.dag.dag_id)
            out2.setdefault(cls, Metrics())._requests.append(r)
        return out2


def summarize(name: str, m: Metrics) -> str:
    lat = m.sorted_latencies()          # one sort feeds all three ranks
    if len(lat) == 0:
        return f"{name}: no completed requests"
    return (f"{name}: n={m.n_requests} done={len(lat)} "
            f"p50={_pct_sorted(lat,50)*1e3:.1f}ms "
            f"p99={_pct_sorted(lat,99)*1e3:.1f}ms "
            f"p99.9={_pct_sorted(lat,99.9)*1e3:.1f}ms "
            f"deadlines_met={m.deadline_met_frac()*100:.2f}% "
            f"cold_starts={m.cold_start_count()}")
