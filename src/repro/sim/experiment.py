"""Declarative experiment API: one generic pump loop for every stack.

The paper's evaluation (§7) is a matrix of scheduler stacks × workloads ×
cluster shapes.  ``Experiment`` names one cell of that matrix declaratively;
``simulate`` drives any registered stack (``repro.core.stacks``) through a
single arrival-pump loop; ``ExperimentResult`` is the typed, JSON-round-
trippable summary; ``run_sweep`` expands seed/scale/cluster grids with a
stable row schema.

    from repro.sim import Experiment, simulate

    r = simulate(Experiment(stack="archipelago",
                            workload_factory="paper_workload_2",
                            workload_kwargs=dict(duration=10.0, scale=0.1),
                            warmup=3.0))
    print(r.latency_percentiles["p99.9"], r.deadline_met_frac)

The legacy ``run_archipelago``/``run_baseline``/``run_sparrow`` drivers in
``repro.sim.runner`` are thin shims over this loop and remain decision-
identical to their pre-refactor selves (``tests/test_equivalence.py``).

The ``backend`` axis selects *what executes an invocation* (``modeled`` —
the default analytic simulation — ``stub`` scripted times, or ``jax`` real
hardware-in-the-loop execution; ``repro.core.backends``), orthogonal to the
scheduler stack, so real-execution scenarios are ordinary sweep cells.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time

import numpy as np
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple, Union)

from ..core.autoscale import AutoscaleConfig
from ..core.backends import ExecutionBackend, resolve_backend
from ..core.cluster import ClusterConfig
from ..core.fault import FaultInjector, FaultPlan, recovery_summary
from ..core.lbs import LBSConfig, LoadBalancer
from ..core.sgs import SGSConfig
from ..core.stacks import (LB_DECISION_COST, SGS_DECISION_COST, Stack,
                           get_stack)
from ..core.types import DagSpec, Request
from .engine import SimEnv
from .metrics import Metrics, percentile
from .traffic import TrafficSpec, apply_traffic
from .workload import WorkloadSpec, paper_workload_1, paper_workload_2

__all__ = [
    "Experiment", "ExperimentResult", "ClassStats", "SimResult",
    "simulate", "run_sweep", "SweepResult", "WORKLOAD_FACTORIES",
    "register_workload", "get_workload_factory", "available_workloads",
]

# Named workload factories so sweeps can construct per-cell workloads from a
# string + kwargs (a shared WorkloadSpec would pin scale/duration/seed).
# Registered through ``register_workload`` — same shape as ``register_stack``
# and ``register_backend``.
WORKLOAD_FACTORIES: Dict[str, Callable[..., WorkloadSpec]] = {}


def register_workload(name: str, *aliases: str
                      ) -> Callable[[Callable[..., WorkloadSpec]],
                                    Callable[..., WorkloadSpec]]:
    """Decorator: make a workload factory constructible by name through
    ``Experiment(workload_factory=name)``.  Raises on duplicate
    registration."""

    def deco(fn: Callable[..., WorkloadSpec]) -> Callable[..., WorkloadSpec]:
        names = (name, *aliases)
        taken = [n for n in names if n in WORKLOAD_FACTORIES]
        if taken:       # validate before inserting: no partial registration
            raise ValueError(
                f"workload factory {taken[0]!r} is already registered")
        for n in names:
            WORKLOAD_FACTORIES[n] = fn
        return fn

    return deco


def get_workload_factory(name: str) -> Callable[..., WorkloadSpec]:
    import_err: Optional[BaseException] = None
    if name not in WORKLOAD_FACTORIES:
        # serving factories register on import of repro.serving.engine; pull
        # it in lazily so `workload_factory="serving_apps"` works without the
        # caller importing the (jax-dependent) serving package first
        try:
            from ..serving import engine as _serving_engine  # noqa: F401
        except ImportError as e:                        # pragma: no cover
            import_err = e
    try:
        return WORKLOAD_FACTORIES[name]
    except KeyError:
        extra = (f" (importing repro.serving failed: {import_err})"
                 if import_err is not None else "")
        raise ValueError(
            f"unknown workload factory {name!r}; registered factories: "
            f"{', '.join(sorted(WORKLOAD_FACTORIES))}{extra}") from import_err


def available_workloads() -> List[str]:
    return sorted(WORKLOAD_FACTORIES)


register_workload("paper_workload_1")(paper_workload_1)
register_workload("paper_workload_2")(paper_workload_2)


@dataclass
class SimResult:
    """Raw simulation handles (the legacy ``run_*`` return type)."""

    metrics: Metrics
    env: SimEnv
    lbs: Optional[LoadBalancer] = None
    scheduler: object = None
    # the built execution backend (executor handles, counters) — None only
    # for legacy constructions
    backend: Optional[ExecutionBackend] = None
    # this run's data-plane counter deltas (n_executions, batch occupancy,
    # ...): backend.counters() accumulates across sweep cells when one
    # instance is shared, so the per-run view is a before/after difference
    backend_counters: Dict[str, int] = field(default_factory=dict)
    # the FaultInjector when the experiment carried a FaultPlan (fired
    # events, retry counters, the §6.1 StateStore) — None on fault-free runs
    injector: Optional[FaultInjector] = None


@dataclass
class Experiment:
    """One declarative simulation: workload × cluster × stack × knobs.

    Workload is either an explicit ``workload`` spec or a
    ``workload_factory`` (callable or a registered name) applied to
    ``workload_kwargs`` — use the factory form in sweeps so each cell can
    vary scale/duration.  ``backend`` selects the execution backend
    (registered name + ``backend_kwargs``, or a ready
    ``ExecutionBackend`` instance — share one across sweep cells so e.g.
    JAX models calibrate once); the default ``"modeled"`` is the pure
    analytic simulation.  ``params`` holds stack-specific knobs (``n_lbs``,
    ``keepalive``, ``probes``, ``scan_limit``, ...); ``sgs``/``lbs`` carry
    the Archipelago policy configs; ``lb_cost``/``sgs_cost`` are the §7.4
    control-plane decision costs.
    """

    stack: str = "archipelago"
    backend: Union[str, ExecutionBackend] = "modeled"
    backend_kwargs: Dict[str, Any] = field(default_factory=dict)
    workload: Optional[WorkloadSpec] = None
    workload_factory: Union[str, Callable[..., WorkloadSpec], None] = None
    workload_kwargs: Dict[str, Any] = field(default_factory=dict)
    cluster: Optional[ClusterConfig] = None
    sgs: Optional[SGSConfig] = None
    lbs: Optional[LBSConfig] = None
    params: Dict[str, Any] = field(default_factory=dict)
    lb_cost: float = LB_DECISION_COST
    sgs_cost: float = SGS_DECISION_COST
    seed: int = 0
    warmup: float = 0.0            # steady-state window start (metrics only)
    drain: float = 5.0             # extra simulated time after last arrival
    workload_method: str = "numpy"
    # declarative chaos schedule (core.fault, docs/FAULTS.md): compiled into
    # the event loop by ``simulate``; None (the default) adds nothing to the
    # run, so zero-fault experiments stay decision-identical
    faults: Optional[FaultPlan] = None
    # declarative traffic scenario (sim.traffic, docs/SCENARIOS.md): a
    # registered name or TrafficSpec applied to the resolved workload —
    # None (the default) leaves the workload untouched, so scenario-free
    # experiments stay decision-identical
    traffic: Union[str, TrafficSpec, None] = None
    # elastic control plane (core.autoscale, docs/SCENARIOS.md): when set,
    # the archipelago stack's LBS replica pool autoscales from observed
    # decision-clock utilization instead of the static params["n_lbs"]
    autoscale: Optional[AutoscaleConfig] = None
    # sharded parallel core (sim.shard, docs/PERF.md "Sharded core"): N > 1
    # partitions the SGSs into N process-local islands advancing their own
    # event loops, synchronized at LBS epoch boundaries.  None (the default)
    # keeps the single-process path untouched; any shard count is required
    # to produce byte-identical ExperimentResult rows (a hard contract,
    # pinned by tests/test_shards.py).  Sweepable like any top-level field:
    # ``run_sweep(base, {"shards": [None, 2, 4]})``.
    shards: Optional[int] = None
    name: str = ""

    def resolve_workload(self) -> WorkloadSpec:
        spec = self.workload
        if spec is None:
            f = self.workload_factory
            if isinstance(f, str):
                f = get_workload_factory(f)
            if f is None:
                raise ValueError(
                    "Experiment needs either `workload` or "
                    "`workload_factory`")
            spec = f(**self.workload_kwargs)
        if self.traffic is not None:
            spec = apply_traffic(spec, self.traffic)
        return spec

    def backend_name(self) -> str:
        return self.backend if isinstance(self.backend, str) \
            else self.backend.name

    def label(self) -> str:
        if self.name:
            return self.name
        wl = (self.workload_factory
              if isinstance(self.workload_factory, str) else "custom")
        b = self.backend_name()
        tail = "" if b == "modeled" else f"/{b}"
        t = self.traffic
        scen = "" if t is None else \
            f"+{t if isinstance(t, str) else t.label()}"
        return f"{self.stack}/{wl}/seed{self.seed}{tail}{scen}"


# ---------------------------------------------------------------------------
# Typed results
# ---------------------------------------------------------------------------

_PCTS: Tuple[Tuple[str, float], ...] = (
    ("p50", 50.0), ("p90", 90.0), ("p99", 99.0), ("p99.9", 99.9))


def _pct_dict(xs: Sequence[float]) -> Dict[str, Optional[float]]:
    """Nearest-rank percentiles (same rule as ``metrics.percentile``), one
    vectorized sort for all requested ranks — this runs on ~1e6-sample
    arrays per ``simulate`` call (flat metrics hand numpy arrays straight
    through; lists are converted once)."""
    n = len(xs)
    if n == 0:
        return {k: None for k, _ in _PCTS}
    s = np.sort(np.asarray(xs, dtype=np.float64))
    n1 = n - 1
    return {k: float(s[max(0, min(n1, int(round(p / 100.0 * n1))))])
            for k, p in _PCTS}


def _none_if_nan(x: float) -> Optional[float]:
    return None if math.isnan(x) else x


@dataclass
class ClassStats:
    """Per-DAG-class (C1..C4 style) steady-state breakdown."""

    n_requests: int
    n_completed: int
    p50: Optional[float]
    p99: Optional[float]
    deadline_met_frac: Optional[float]
    cold_starts: int

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ClassStats":
        return cls(**d)


@dataclass
class ExperimentResult:
    """Structured summary of one ``simulate`` run.

    All latency/queuing/deadline statistics are computed on the steady-state
    window (arrivals at ``t >= warmup``; queuing-delay samples are timestamp-
    filtered the same way).  ``warm_hits`` is a whole-run scheduler counter.
    ``to_dict``/``from_dict`` round-trip losslessly through JSON (``sim``,
    the raw simulation handle, is deliberately excluded and ``None`` after
    ``from_dict``).
    """

    name: str
    stack: str
    seed: int
    duration: float
    warmup: float
    n_requests_total: int          # whole run, including warmup
    n_requests: int                # steady-state window
    n_completed: int
    latency_percentiles: Dict[str, Optional[float]]
    queuing_percentiles: Dict[str, Optional[float]]
    deadline_met_frac: Optional[float]
    cold_start_count: int
    cold_start_frac: Optional[float]
    warm_hits: int
    per_class: Dict[str, ClassStats]
    n_events: int
    wall_s: float
    backend: str = "modeled"       # execution backend the run used
    # per-run data-plane counters (this cell only, even when a backend
    # instance is shared across sweep cells): n_executions for stub/jax;
    # batched backends add n_batches / n_batched_invocations / n_batch_slots
    # / max_batch_occupancy (see docs/SERVING.md "Batched serving")
    backend_counters: Dict[str, int] = field(default_factory=dict)
    # data-plane identity: {"kernels": xla|pallas|pallas_interpret,
    # "batching": none|windowed|continuous} for jax/stub-batched backends,
    # {} for modeled (see docs/KERNELS.md)
    data_plane: Dict[str, str] = field(default_factory=dict)
    # chaos-run fields (empty/zero on fault-free runs): fired fault events
    # ({"kind", "t", ...} per occurrence), total retried invocations, and
    # the per-fault windowed recovery report ({"window_s", "tolerance",
    # "events": [{"kind", "t", "baseline_met", "dip_met", "recovery_s"}]})
    # — see docs/FAULTS.md "Recovery metrics"
    fault_events: List[Dict[str, Any]] = field(default_factory=list)
    n_retries: int = 0
    recovery: Dict[str, Any] = field(default_factory=dict)
    # whole-run request accounting (Metrics.accounting): {"arrivals",
    # "completed", "unique_completed", "pending", "lost",
    # "duplicate_completions"} — the fault-tolerance invariant is
    # lost == 0 and duplicate_completions == 0 (docs/FAULTS.md)
    accounting: Dict[str, int] = field(default_factory=dict)
    # hedged-retry dispatches the SGSs issued (params["hedge_timeout"],
    # docs/FAULTS.md "Straggler mitigation"); 0 when hedging is off
    n_hedges: int = 0
    # typed control-plane scaling decisions in time order (LBS replica pool
    # + per-DAG SGS set; ``core.autoscale.ScalingEvent.to_dict`` shape:
    # {"t", "component", "action", "n_before", "n_after", "metric",
    # "detail"}) — see docs/SCENARIOS.md "Reading scaling_events"
    scaling_events: List[Dict[str, Any]] = field(default_factory=list)
    sim: Optional[SimResult] = field(default=None, repr=False, compare=False)

    def to_dict(self) -> Dict[str, Any]:
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self) if f.name != "sim"}
        d["latency_percentiles"] = dict(self.latency_percentiles)
        d["queuing_percentiles"] = dict(self.queuing_percentiles)
        d["backend_counters"] = dict(self.backend_counters)
        d["data_plane"] = dict(self.data_plane)
        d["fault_events"] = [dict(e) for e in self.fault_events]
        d["recovery"] = dict(self.recovery)
        d["accounting"] = dict(self.accounting)
        d["scaling_events"] = [dict(e) for e in self.scaling_events]
        d["per_class"] = {k: v.to_dict()
                          for k, v in sorted(self.per_class.items())}
        return d

    def detach_sim(self) -> "ExperimentResult":
        """Drop the live simulation handle (``sim``: metrics columns, event
        loop, scheduler objects).  After detaching, the result is a plain
        record — everything left round-trips losslessly through
        ``to_dict``/``from_dict`` and pickles across process boundaries,
        which is what lets ``run_sweep`` farm cells to worker processes.
        ``run_sweep`` detaches every cell unless ``keep_sim=True``.
        Returns self for chaining."""
        self.sim = None
        return self

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ExperimentResult":
        kw = dict(d)
        kw["per_class"] = {k: ClassStats.from_dict(v)
                           for k, v in d["per_class"].items()}
        return cls(**kw)


def _build_result(exp: Experiment, spec: WorkloadSpec, sim: SimResult,
                  warm_hits: int, wall_s: float,
                  scaling_events: Optional[List[Dict[str, Any]]] = None,
                  n_hedges: int = 0) -> ExperimentResult:
    # one code path for both metrics modes: flat (column) metrics serve
    # ``latencies``/``n_requests``/``by_class`` as vectorized views, the
    # legacy object mode scans its request list exactly as before
    m = sim.metrics.after_warmup(exp.warmup) if exp.warmup > 0 \
        else sim.metrics
    per_class = {}
    for cls_name, cm in m.by_class().items():
        pcts = _pct_dict(cm.latencies())
        per_class[cls_name] = ClassStats(
            n_requests=cm.n_requests,
            n_completed=cm.n_completed,
            p50=pcts["p50"],
            p99=pcts["p99"],
            deadline_met_frac=_none_if_nan(cm.deadline_met_frac()),
            cold_starts=cm.cold_start_count())
    fault_events: List[Dict[str, Any]] = []
    n_retries = 0
    recovery: Dict[str, Any] = {}
    if sim.injector is not None:
        fault_events = list(sim.injector.fault_events)
        n_retries = sim.injector.n_retries
        # recovery windows are absolute-time views over the whole trace
        # (the pre-fault baseline may predate the warmup cutoff)
        recovery = recovery_summary(sim.metrics, sim.injector,
                                    spec.duration + exp.drain)
    return ExperimentResult(
        name=exp.label(),
        stack=exp.stack,
        seed=exp.seed,
        duration=spec.duration,
        warmup=exp.warmup,
        n_requests_total=sim.metrics.n_requests,
        n_requests=m.n_requests,
        n_completed=m.n_completed,
        latency_percentiles=_pct_dict(m.latencies()),
        queuing_percentiles=_pct_dict(m.queuing_delays),
        deadline_met_frac=_none_if_nan(m.deadline_met_frac()),
        cold_start_count=m.cold_start_count(),
        cold_start_frac=_none_if_nan(m.cold_start_frac()),
        warm_hits=warm_hits,
        per_class=per_class,
        n_events=sim.env.n_events,
        wall_s=round(wall_s, 4),
        backend=exp.backend_name(),
        backend_counters=dict(sim.backend_counters),
        data_plane=(dict(sim.backend.data_plane())
                    if sim.backend is not None else {}),
        fault_events=fault_events,
        n_retries=n_retries,
        recovery=recovery,
        accounting=sim.metrics.accounting(),
        n_hedges=n_hedges,
        scaling_events=list(scaling_events or []),
        sim=sim)


# ---------------------------------------------------------------------------
# The one generic arrival-pump loop
# ---------------------------------------------------------------------------


def _arrival_stream(spec: WorkloadSpec, seed: int, method: str
                    ) -> Tuple[List[float], List[DagSpec]]:
    """Time-sorted arrival times + per-arrival DAGs.

    The vectorized path never materializes per-arrival tuples; numpy floats
    are converted once (``tolist`` round-trips float64 exactly)."""
    times, dags, _, _, _ = _arrival_columns(spec, seed, method)
    return times, dags


def _arrival_columns(spec: WorkloadSpec, seed: int, method: str
                     ) -> Tuple[List[float], List[DagSpec], np.ndarray,
                                np.ndarray, List[DagSpec]]:
    """``_arrival_stream`` plus the raw arrival columns the flat metrics
    plane attaches wholesale: (times, per-arrival dags, time array,
    per-arrival tenant-dag index array, tenant dag list)."""
    if method == "legacy":
        pairs = spec.generate(seed, method="legacy")
        times = [t for t, _ in pairs]
        dags = [d for _, d in pairs]
        # rebuild the tenant index from object identity (the legacy
        # generator hands per-arrival DAG objects, one per tenant)
        tenant_dags: List[DagSpec] = []
        by_id: Dict[int, int] = {}
        idx = []
        for d in dags:
            k = by_id.get(id(d))
            if k is None:
                k = by_id[id(d)] = len(tenant_dags)
                tenant_dags.append(d)
            idx.append(k)
        return (times, dags, np.asarray(times, dtype=np.float64),
                np.asarray(idx, dtype=np.int64), tenant_dags)
    if method != "numpy":
        raise ValueError(f"unknown generation method {method!r}")
    ts, idx_arr, tenant_dags = spec.generate_arrays(seed)
    dags = list(map(tenant_dags.__getitem__, idx_arr.tolist()))
    return ts.tolist(), dags, ts, idx_arr, tenant_dags


def _validate_params(exp: Experiment, stack_cls: type) -> None:
    """Reject unknown ``Experiment.params`` keys for stacks that declare a
    ``PARAMS`` frozenset (every built-in does) — a typo like
    ``params={"n_lb": 4}`` silently no-ops otherwise.  Custom stacks
    without the attribute skip validation (back-compat); the error style
    matches the stack/backend registry lookups."""
    allowed = getattr(stack_cls, "PARAMS", None)
    if allowed is None or not exp.params:
        return
    unknown = sorted(k for k in exp.params if k not in allowed)
    if unknown:
        raise ValueError(
            f"unknown param(s) {', '.join(map(repr, unknown))} for stack "
            f"{exp.stack!r}; known params: "
            f"{', '.join(sorted(allowed)) or '(none)'}")


Hook = Callable[[SimEnv, Stack], None]


def simulate(exp: Experiment, *,
             hooks: Sequence[Tuple[float, Hook]] = (),
             timed_calls: Sequence[Tuple[float, Hook]] = ()
             ) -> ExperimentResult:
    """Run one experiment through the generic pump loop.

    ``hooks`` are periodic observers ``(interval, fn(env, stack))``
    (demand sampling, custom telemetry); ``timed_calls`` fire once at the
    given simulated time (fault injection).  Both run inside the event loop
    and may mutate the stack — they exist so benchmarks never have to
    re-plumb the pump by hand.

    ``exp.shards`` > 1 routes the run through the sharded parallel core
    (``repro.sim.shard``): SGS islands advance in separate processes with
    epoch synchronization at LBS decision boundaries, returning a result
    byte-identical to this single-process path.  Inside a daemonic
    ``run_sweep`` pool worker (which cannot spawn children) the request is
    honored by the sequential path instead — identical rows either way.
    """
    if exp.shards is not None and int(exp.shards) > 1:
        import multiprocessing

        from .shard import simulate_sharded, validate_shardable
        validate_shardable(exp, hooks, timed_calls)
        if not multiprocessing.current_process().daemon:
            return simulate_sharded(exp)
    exp_spec, sim, stack, wall = _run_experiment(exp, hooks, timed_calls)
    counters = stack.counters()
    warm_hits = counters.get("warm_hits", 0)
    sev = getattr(stack, "scaling_events", None)
    scaling = sev() if callable(sev) else []
    return _build_result(exp, exp_spec, sim, warm_hits, wall, scaling,
                         n_hedges=counters.get("hedges", 0))


def _run_experiment(exp: Experiment,
                    hooks: Sequence[Tuple[float, Hook]] = (),
                    timed_calls: Sequence[Tuple[float, Hook]] = ()
                    ) -> Tuple[WorkloadSpec, SimResult, Stack, float]:
    """The pump loop without result summarization (the legacy ``run_*``
    shims return the raw ``SimResult`` and skip the summary entirely).

    Order of construction: workload resolves first, then the execution
    backend re-specs it (calibration / scripted times), then ``bind`` hands
    the backend the live event loop (building its asynchronous ``submit``
    seam — legacy ``execute``-only backends are adapted here), then the
    stack builds against the resolved backend.  A spec-provided ``pre_pump``
    hook (serving prewarm — the §3 "initial DAG upload") runs after the
    stack is built but before any arrival fires.
    """
    stack_cls = get_stack(exp.stack)
    _validate_params(exp, stack_cls)
    spec = exp.resolve_workload()
    backend = resolve_backend(exp.backend, exp.backend_kwargs)
    spec = backend.build(exp, spec)
    env = SimEnv()
    backend.bind(env)
    stack: Stack = stack_cls()
    stack.build(env, exp, spec, backend)
    pre_pump = getattr(spec, "pre_pump", None)
    if pre_pump is not None:
        pre_pump(env, stack)
    # snapshot data-plane counters so the reported view is this run's delta
    # (a shared backend instance accumulates across sweep cells)
    counters_before = dict(backend.counters())

    t0 = time.perf_counter()
    times, dags, arr_np, idx_np, tenant_dags = _arrival_columns(
        spec, exp.seed, exp.workload_method)
    # flat metrics plane: arrival columns attach wholesale, schedulers
    # record completions straight into column buffers and release the
    # Request objects.  Stacks that cannot wire the completion hook (custom
    # schedulers predating it) fall back to the legacy per-object list.
    flat = Metrics.flat(arr_np, idx_np, tenant_dags)
    attach = getattr(stack, "attach_metrics", None)
    if attach is not None and attach(flat):
        metrics = flat
        pending = flat._cols.pending
        requests = None
    else:
        metrics = Metrics()
        pending = None
        requests = metrics.requests
    n = len(times)
    submit = stack.submit

    # arrival i fires exactly at times[i] (the event heap is driven by the
    # same float), so the pump reads the clock off the trace instead of
    # calling env.now() per arrival
    if pending is not None:
        def pump(i: int) -> None:
            # fire arrival i, then lazily schedule arrival i+1: the event
            # heap holds one pending arrival instead of the whole trace
            now = times[i]
            req = Request(dag=dags[i], arrival_time=now)
            req.m_idx = i
            pending[i] = req
            submit(req, now)
            i += 1
            if i < n:
                env.call_at(times[i], pump, i)
    else:
        def pump(i: int) -> None:
            now = times[i]
            req = Request(dag=dags[i], arrival_time=now)
            requests.append(req)
            submit(req, now)
            i += 1
            if i < n:
                env.call_at(times[i], pump, i)

    if n:
        env.call_at(times[0], pump, 0)
    stack.start_background()
    horizon = spec.duration + exp.drain
    for interval, fn in hooks:
        env.every(interval, lambda fn=fn: fn(env, stack), until=horizon)
    for t, fn in timed_calls:
        env.call_at(t, fn, env, stack)
    injector: Optional[FaultInjector] = None
    if exp.faults is not None and exp.faults.events:
        injector = FaultInjector(exp.faults)
        injector.install(env, stack, horizon)

    env.run_until(horizon)
    stack.collect(metrics)
    wall = time.perf_counter() - t0

    counters = {k: v - counters_before.get(k, 0)
                for k, v in backend.counters().items()}
    sim = SimResult(metrics=metrics, env=env,
                    lbs=getattr(stack, "lbs", None),
                    scheduler=getattr(stack, "scheduler", None),
                    backend=backend, backend_counters=counters,
                    injector=injector)
    return spec, sim, stack, wall


# ---------------------------------------------------------------------------
# Sweeps
# ---------------------------------------------------------------------------


def _override(exp: Experiment, path: str, value: Any) -> Experiment:
    """Return a copy of ``exp`` with one (possibly dotted) field replaced.

    ``"seed"`` (or ``"backend"``, ``"stack"``, ...) replaces a top-level
    field; ``"cluster.n_sgs"`` / ``"sgs.proactive"`` /
    ``"lbs.scale_out_threshold"`` replace a field of a nested config
    (instantiating the default config when unset); ``"params.probes"`` /
    ``"workload_kwargs.scale"`` / ``"backend_kwargs.exec_time"`` set one
    dict key.
    """
    head, _, rest = path.partition(".")
    if not rest:
        if head not in {f.name for f in dataclasses.fields(exp)}:
            raise ValueError(f"unknown Experiment field {head!r}")
        return dataclasses.replace(exp, **{head: value})
    if head in ("params", "workload_kwargs", "backend_kwargs"):
        d = dict(getattr(exp, head))
        d[rest] = value
        return dataclasses.replace(exp, **{head: d})
    defaults = {"cluster": ClusterConfig, "sgs": SGSConfig, "lbs": LBSConfig,
                "autoscale": AutoscaleConfig}
    if head not in defaults:
        raise ValueError(f"cannot sweep over {path!r}")
    sub = getattr(exp, head) or defaults[head]()
    return dataclasses.replace(
        exp, **{head: dataclasses.replace(sub, **{rest: value})})


@dataclass
class SweepResult:
    """Grid-sweep output with a stable row schema.

    Each row is ``{"cell": {axis: value, ...}, "result": <ExperimentResult
    dict>}``; rows appear in cartesian-product order of ``axes`` (first axis
    slowest).  Every cell is an independent fresh simulation, so rows are
    deterministic per (seed, config) and independent of execution order.
    """

    axes: Dict[str, List[Any]]
    rows: List[Dict[str, Any]]
    # live ExperimentResult objects (with .sim) when run with keep_sim=True
    experiment_results: Optional[List[ExperimentResult]] = None

    def __iter__(self):
        return iter(self.rows)

    def __len__(self) -> int:
        return len(self.rows)

    def to_dict(self) -> Dict[str, Any]:
        # axis/cell values may be rich objects (e.g. FaultPlan): serialize
        # through their own to_dict so sweep JSONs stay self-contained
        def val(v: Any) -> Any:
            to_d = getattr(v, "to_dict", None)
            return to_d() if callable(to_d) else v

        return {"schema": 1,
                "axes": {k: [val(v) for v in vs]
                         for k, vs in self.axes.items()},
                "rows": [{"cell": {k: val(v) for k, v in r["cell"].items()},
                          "result": r["result"]} for r in self.rows]}

    def results(self) -> List[ExperimentResult]:
        if self.experiment_results is not None:
            return list(self.experiment_results)
        return [ExperimentResult.from_dict(r["result"]) for r in self.rows]


def _expand_cells(base: Experiment, axes: Mapping[str, Sequence[Any]]
                  ) -> List[Tuple[Dict[str, Any], Experiment]]:
    """The sweep grid in cartesian-product order (first axis slowest):
    [(cell dict, fully-overridden Experiment), ...]."""
    names = list(axes)
    cells: List[Tuple[Dict[str, Any], Experiment]] = []
    for combo in itertools.product(*(list(axes[k]) for k in names)):
        exp = base
        cell: Dict[str, Any] = {}
        for k, v in zip(names, combo):
            exp = _override(exp, k, v)
            cell[k] = v
        cells.append((cell, exp))
    return cells


def _picklable(v: Any) -> bool:
    import pickle
    try:
        pickle.dumps(v)
    except Exception:
        return False
    return True


def _run_cell(exp: Experiment) -> Dict[str, Any]:
    """Worker-process entry point: one fresh simulation, serialized through
    the lossless ``to_dict`` round-trip (the live ``sim`` handle never
    crosses the process boundary)."""
    return simulate(exp).detach_sim().to_dict()


def run_sweep(base: Experiment, axes: Mapping[str, Sequence[Any]],
              keep_sim: bool = False, workers: int = 1) -> SweepResult:
    """Cartesian sweep over ``axes`` (axis name → values; names follow
    ``_override``'s dotted-path rules) starting from ``base``.

    ``workers=N`` (N > 1) farms the cells to a spawn-context process pool.
    Every cell is an independent fresh simulation with per-cell seeding, so
    rows come back in the same deterministic cartesian order with payloads
    identical to sequential execution (``wall_s``, the one wall-clock
    timing field, is the only value that can differ between runs at all —
    parallel or not).  Parallel execution requires the per-cell
    ``Experiment``s to pickle: use *named* workload factories and *named*
    backends; a base experiment carrying live objects (a shared
    ``ExecutionBackend`` instance, a spec with closure hooks) falls back to
    sequential execution with a warning.  ``keep_sim=True`` retains the
    live per-cell results (including ``.sim``) on
    ``SweepResult.experiment_results`` for bespoke analysis and therefore
    always runs sequentially in-process."""
    cells = _expand_cells(base, axes)
    rows: List[Dict[str, Any]] = []
    objs: List[ExperimentResult] = []
    use_pool = workers > 1 and not keep_sim and len(cells) > 1
    if workers > 1 and keep_sim:
        import warnings
        warnings.warn(
            f"run_sweep(workers={workers}): keep_sim=True retains live "
            f"simulation handles that cannot cross a process boundary; "
            f"falling back to sequential execution",
            RuntimeWarning, stacklevel=2)
    if use_pool:
        import pickle
        try:
            pickle.dumps([exp for _, exp in cells])
        except Exception as e:
            import warnings
            # name the offending field so the fix ("use a *named* workload
            # factory/backend") is obvious from the warning alone
            bad = sorted({f.name for f in dataclasses.fields(base)
                          for _, exp in cells
                          if not _picklable(getattr(exp, f.name))})
            detail = (f"field(s) {', '.join(map(repr, bad))} are not "
                      f"picklable" if bad else "cells are not picklable")
            warnings.warn(
                f"run_sweep(workers={workers}): {detail} ({e!r}); falling "
                f"back to sequential execution",
                RuntimeWarning, stacklevel=2)
            use_pool = False
    if use_pool:
        import multiprocessing
        ctx = multiprocessing.get_context("spawn")
        with ctx.Pool(min(workers, len(cells))) as pool:
            results = pool.map(_run_cell, [exp for _, exp in cells])
        rows = [{"cell": cell, "result": d}
                for (cell, _), d in zip(cells, results)]
    else:
        for cell, exp in cells:
            res = simulate(exp)
            rows.append({"cell": cell, "result": res.to_dict()})
            if keep_sim:
                objs.append(res)
            else:
                # explicit detach: frees the event loop/metrics columns and
                # keeps the appended row the single serializable source
                res.detach_sim()
    return SweepResult(axes={k: list(v) for k, v in axes.items()}, rows=rows,
                       experiment_results=objs if keep_sim else None)
