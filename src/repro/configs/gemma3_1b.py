"""Gemma-3 1B [hf:google/gemma-3-1b-pt] — 5:1 local:global attention,
sliding window 512, kv=1, 262k vocab, 128k context."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", arch_type="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
    d_ff=6912, vocab_size=262144, head_dim=256,
    local_ratio=5, local_window=512, rope_theta=1_000_000.0,
    mlp="swiglu", tie_embeddings=True,
)

REDUCED = CONFIG.with_(
    n_layers=2, d_model=256, n_heads=2, n_kv_heads=1, head_dim=128,
    d_ff=512, vocab_size=2048, local_ratio=1, local_window=64,
)
