"""MiniCPM-2B [arXiv:2404.06395] — dense llama-like, WSD LR schedule."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", arch_type="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab_size=122753, head_dim=64,
    mlp="swiglu", tie_embeddings=True, lr_schedule="wsd",
)

REDUCED = CONFIG.with_(
    n_layers=2, d_model=288, n_heads=4, n_kv_heads=4, head_dim=72,
    d_ff=768, vocab_size=1024,
)
