"""Phi-3-mini 3.8B [arXiv:2404.14219] — dense, RoPE + SwiGLU + GQA(32)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", arch_type="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32064, head_dim=96,
    mlp="swiglu", tie_embeddings=False,
)

REDUCED = CONFIG.with_(
    n_layers=2, d_model=384, n_heads=4, n_kv_heads=4, head_dim=96,
    d_ff=1024, vocab_size=1024,
)
