"""Architecture registry: the 10 assigned architectures (+ reduced variants).

Every entry cites its source in the module docstring of its config file.
"""
from __future__ import annotations

from typing import Dict, Tuple

from ..models.config import ModelConfig
from . import (gemma3_1b, llama4_scout_17b, mamba2_370m, minicpm_2b,
               minitron_8b, mixtral_8x22b, phi3_mini_3p8b, phi3_vision_4p2b,
               whisper_tiny, zamba2_1p2b)

_MODULES = {
    "minicpm-2b": minicpm_2b,
    "whisper-tiny": whisper_tiny,
    "phi3-mini-3.8b": phi3_mini_3p8b,
    "gemma3-1b": gemma3_1b,
    "minitron-8b": minitron_8b,
    "phi-3-vision-4.2b": phi3_vision_4p2b,
    "zamba2-1.2b": zamba2_1p2b,
    "llama4-scout-17b-a16e": llama4_scout_17b,
    "mamba2-370m": mamba2_370m,
    "mixtral-8x22b": mixtral_8x22b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    m = _MODULES[arch]
    return m.REDUCED if reduced else m.CONFIG


def all_configs(reduced: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, reduced) for a in ARCH_IDS}


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper
# ---------------------------------------------------------------------------

INPUT_SHAPES: Dict[str, Tuple[int, int, str]] = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# long_500k needs sub-quadratic attention state: run only where the cache is
# bounded (SWA / SSM / hybrid); skip for pure full-attention archs (DESIGN.md)
LONG_CONTEXT_ARCHS = ("gemma3-1b", "zamba2-1.2b", "mamba2-370m",
                      "mixtral-8x22b")


def shape_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True
