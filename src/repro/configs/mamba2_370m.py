"""Mamba2-370M [arXiv:2405.21060] — attention-free SSD (state-space duality)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", arch_type="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=64,
    tie_embeddings=True,
)

REDUCED = CONFIG.with_(
    n_layers=2, d_model=256, vocab_size=1024, ssm_state=32,
    ssm_head_dim=32, ssm_chunk=16,
)
