"""Phi-3-vision 4.2B [hf:microsoft/Phi-3-vision-128k-instruct] — phi3-mini
backbone + CLIP ViT frontend.  The vision encoder/projector is a STUB:
``input_specs`` supplies 576 precomputed patch embeddings."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", arch_type="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32064, head_dim=96,
    frontend="vision", n_frontend_tokens=576,
    mlp="swiglu", tie_embeddings=False,
)

REDUCED = CONFIG.with_(
    n_layers=2, d_model=384, n_heads=4, n_kv_heads=4, head_dim=96,
    d_ff=1024, vocab_size=1024, n_frontend_tokens=16,
)
