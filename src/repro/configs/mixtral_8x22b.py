"""Mixtral 8x22B [arXiv:2401.04088] — 8 experts top-2 MoE, GQA kv=8,
sliding-window attention (per assignment card)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", arch_type="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=32768, head_dim=128,
    n_experts=8, experts_per_token=2, sliding_window=4096,
    mlp="swiglu", tie_embeddings=False,
)

REDUCED = CONFIG.with_(
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=512, vocab_size=1024, n_experts=4, experts_per_token=2,
    sliding_window=64,
)
