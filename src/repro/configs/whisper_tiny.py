"""Whisper-tiny [arXiv:2212.04356] — enc-dec; conv/mel frontend is a STUB
(``input_specs`` supplies 1500 precomputed frame embeddings)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", arch_type="encdec",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab_size=51865, head_dim=64,
    n_enc_layers=4, n_enc_tokens=1500,
    frontend="audio", n_frontend_tokens=1500,
    mlp="gelu", tie_embeddings=True,
)

REDUCED = CONFIG.with_(
    n_layers=2, n_enc_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
    head_dim=64, d_ff=512, vocab_size=512, n_enc_tokens=64,
    n_frontend_tokens=64,
)
