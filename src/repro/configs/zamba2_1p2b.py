"""Zamba2-1.2B [arXiv:2411.15242] — Mamba2 backbone + one shared attention
block applied every 6 layers (hybrid)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", arch_type="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=64,
    shared_attn_every=6,
    mlp="swiglu", tie_embeddings=True,
)

REDUCED = CONFIG.with_(
    n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
    d_ff=512, vocab_size=1024, ssm_state=16, ssm_head_dim=32,
    shared_attn_every=2, ssm_chunk=16,
)
