"""Minitron-8B [arXiv:2407.14679] — width-pruned Nemotron-4, GQA kv=8."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", arch_type="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=16384, vocab_size=256000, head_dim=128,
    mlp="swiglu", tie_embeddings=False,
)

REDUCED = CONFIG.with_(
    n_layers=2, d_model=512, n_heads=4, n_kv_heads=2, head_dim=128,
    d_ff=1024, vocab_size=2048,
)
