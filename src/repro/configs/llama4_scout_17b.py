"""Llama-4 Scout 17B-active/16E [hf:meta-llama/Llama-4-Scout-17B-16E] —
MoE 16 experts top-1, GQA kv=8, early-fusion multimodal (text path here)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", arch_type="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    n_experts=16, experts_per_token=1,
    mlp="swiglu", tie_embeddings=False,
)

REDUCED = CONFIG.with_(
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=512, vocab_size=1024, n_experts=4, experts_per_token=1,
)
