"""Unified model zoo: dense / MoE / SSM / hybrid / enc-dec / VLM / audio.

Design notes
------------
* Every architecture is a sequence of homogeneous *layer groups*
  (``cfg.groups()``).  Each group lowers to one ``lax.scan`` over stacked
  parameters, so HLO size is O(groups), not O(layers).
* KV caches for sliding-window groups are ring buffers of size
  ``min(window, max_len)`` — this is what makes ``long_500k`` decode feasible
  for SWA architectures.
* ``frontend`` embeddings (VLM patches / audio frames) are *inputs*: the
  modality encoders are stubs per the assignment carve-out.

Entry points:
  init_params(cfg, key)                        -> params
  forward(cfg, params, tokens, frontend=None)  -> (logits, aux)   # teacher forcing
  init_cache(cfg, batch, max_len)              -> cache
  prefill(cfg, params, tokens, cache, frontend=None) -> (logits, cache)
  decode_step(cfg, params, cache, token, t)    -> (logits, cache)
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops as kernel_ops
from .config import LayerGroup, ModelConfig
from .layers import (attention_block, causal_window_mask, gqa_attention,
                     gelu_mlp, mamba2_block, moe_block, rms_norm, swiglu,
                     apply_rope)

Params = Dict[str, Any]
f32 = jnp.float32


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def _norm_init(key, shape, dtype):
    return jnp.zeros(shape, dtype)


def _dense_init(key, shape, dtype, scale=0.02):
    return (jax.random.normal(key, shape, f32) * scale).astype(dtype)


def _attn_layer_shapes(cfg: ModelConfig, g: LayerGroup) -> Dict[str, tuple]:
    d, hd = cfg.d_model, cfg.hd
    hq, hkv, f = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    s: Dict[str, tuple] = {
        "ln1": (d,), "ln2": (d,),
        "wq": (d, hq * hd), "wk": (d, hkv * hd), "wv": (d, hkv * hd),
        "wo": (hq * hd, d),
    }
    if g.cross_attn:
        s.update({"ln_x": (d,), "xwq": (d, hq * hd), "xwk": (d, hkv * hd),
                  "xwv": (d, hkv * hd), "xwo": (hq * hd, d)})
    if g.moe:
        E = cfg.n_experts
        s.update({"router": (d, E), "w_gate": (E, d, f), "w_up": (E, d, f),
                  "w_down": (E, f, d)})
    elif cfg.mlp == "swiglu":
        s.update({"w_gate": (d, f), "w_up": (d, f), "w_down": (f, d)})
    else:
        s.update({"w_up": (d, f), "w_down": (f, d)})
    return s


def _mamba_layer_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H = cfg.n_ssm_heads
    dxbc = di + 2 * N
    return {
        "ln": (d,),
        "in_proj": (d, 2 * di + 2 * N + H),
        "conv_w": (cfg.ssm_conv, dxbc), "conv_b": (dxbc,),
        "dt_bias": (H,), "A_log": (H,), "D": (H,),
        "norm_w": (di,), "out_proj": (di, d),
    }


def _init_layer(key, shapes: Dict[str, tuple], count: int, dtype) -> Params:
    out = {}
    keys = jax.random.split(key, len(shapes))
    for k, (name, shp) in zip(keys, sorted(shapes.items())):
        full = (count,) + shp if count > 1 else shp
        if name.startswith(("ln", "norm")):
            out[name] = jnp.zeros(full, dtype)
        elif name == "A_log":
            base = jnp.log(jnp.linspace(1.0, 16.0, shp[-1], dtype=f32))
            out[name] = jnp.broadcast_to(base, full).astype(f32)
        elif name in ("dt_bias", "conv_b", "D"):
            out[name] = jnp.zeros(full, f32) if name != "D" \
                else jnp.ones(full, f32)
        else:
            fan_in = shp[-2] if len(shp) >= 2 else shp[-1]
            out[name] = _dense_init(k, full, dtype,
                                    scale=1.0 / math.sqrt(fan_in))
    return out


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dtype = cfg.pdtype()
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": _dense_init(keys[0], (cfg.vocab_padded, cfg.d_model), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense_init(
            keys[1], (cfg.d_model, cfg.vocab_padded), dtype)

    groups = cfg.groups()
    gkeys = jax.random.split(keys[2], len(groups))
    glist: List[Params] = []
    shared_done = False
    for gk, g in zip(gkeys, groups):
        if g.kind == "shared_attn":
            if not shared_done:
                shapes = _attn_layer_shapes(
                    cfg, LayerGroup("attn", 1, moe=False))
                params["shared_attn"] = _init_layer(gk, shapes, 1, dtype)
                shared_done = True
            glist.append({})        # placeholder; uses params["shared_attn"]
        elif g.kind == "mamba":
            glist.append(_init_layer(gk, _mamba_layer_shapes(cfg),
                                     g.count, dtype))
        else:
            glist.append(_init_layer(gk, _attn_layer_shapes(cfg, g),
                                     g.count, dtype))
    params["groups"] = glist

    if cfg.n_enc_layers:
        enc_shapes = _attn_layer_shapes(
            cfg, LayerGroup("attn", cfg.n_enc_layers))
        params["encoder"] = _init_layer(keys[3], enc_shapes,
                                        cfg.n_enc_layers, dtype)
        params["enc_norm"] = jnp.zeros((cfg.d_model,), dtype)
    return params


# ---------------------------------------------------------------------------
# Layer-group execution (shared by forward / prefill / decode)
# ---------------------------------------------------------------------------


def _ffn(cfg: ModelConfig, g: LayerGroup, p: Params,
         x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if g.moe:
        return moe_block(x, p, n_experts=cfg.n_experts,
                         k=cfg.experts_per_token,
                         capacity_factor=cfg.capacity_factor, mlp=cfg.mlp)
    if cfg.mlp == "swiglu":
        return swiglu(x, p["w_gate"], p["w_up"], p["w_down"]), jnp.zeros((), f32)
    return gelu_mlp(x, p["w_up"], p["w_down"]), jnp.zeros((), f32)


def _attn_group_fwd(cfg: ModelConfig, g: LayerGroup, gp: Params,
                    x: jnp.ndarray, positions: jnp.ndarray,
                    mask: Optional[jnp.ndarray],
                    enc_out: Optional[jnp.ndarray],
                    collect_kv: bool):
    """Run a stacked attention group via scan.  Returns (x, aux, kv)."""

    def body(carry, lp):
        h, aux = carry
        a, k, v = attention_block(
            rms_norm(h, lp["ln1"], cfg.norm_eps), lp,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, hd=cfg.hd,
            positions=positions, mask=mask, rope_theta=cfg.rope_theta,
            kernel=cfg.kernels, causal=True, window=g.window)
        h = h + a
        if g.cross_attn:
            xa, _, _ = attention_block(
                rms_norm(h, lp["ln_x"], cfg.norm_eps),
                {"wq": lp["xwq"], "wk": lp["xwk"], "wv": lp["xwv"],
                 "wo": lp["xwo"]},
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, hd=cfg.hd,
                positions=positions, mask=None, rope_theta=cfg.rope_theta,
                kv_override=_enc_kv(cfg, lp, enc_out),
                kernel=cfg.kernels, causal=False, window=0)
            h = h + xa
        f, a_loss = _ffn(cfg, g, lp, rms_norm(h, lp["ln2"], cfg.norm_eps))
        h = h + f
        out = (k, v) if collect_kv else None
        return (h, aux + a_loss), out

    if cfg.remat:
        body = jax.checkpoint(body)     # layer-boundary remat (training mem)
    if g.count == 1 and not _is_stacked(gp):
        (x, aux), kv = body((x, jnp.zeros((), f32)), gp)
        kv = jax.tree.map(lambda t: t[None], kv) if kv is not None else None
        return x, aux, kv
    (x, aux), kv = jax.lax.scan(body, (x, jnp.zeros((), f32)), gp,
                                unroll=cfg.scan_unroll)
    return x, aux, kv


def _is_stacked(gp: Params) -> bool:
    ln = gp.get("ln1", gp.get("ln"))
    return ln is not None and ln.ndim > 1


def _enc_kv(cfg: ModelConfig, lp: Params, enc_out: jnp.ndarray):
    B, Se, _ = enc_out.shape
    k = (enc_out @ lp["xwk"]).reshape(B, Se, cfg.n_kv_heads, cfg.hd)
    v = (enc_out @ lp["xwv"]).reshape(B, Se, cfg.n_kv_heads, cfg.hd)
    return k, v


def _mamba_group_fwd(cfg: ModelConfig, gp: Params, x: jnp.ndarray,
                     cache: Optional[Dict], collect_state: bool):
    def body(carry, inp):
        h = carry
        if cache is not None:
            lp, lc = inp
        else:
            lp, lc = inp, None
        y, new_c = mamba2_block(
            rms_norm(h, lp["ln"], cfg.norm_eps), lp,
            n_heads=cfg.n_ssm_heads, head_dim=cfg.ssm_head_dim,
            d_state=cfg.ssm_state, d_conv=cfg.ssm_conv, chunk=cfg.ssm_chunk,
            cache=lc, kernel=cfg.kernels)
        return h + y, (new_c if (collect_state or cache is not None) else None)

    if cfg.remat:
        body = jax.checkpoint(body)
    if not _is_stacked(gp):
        lc0 = jax.tree.map(lambda a: a[0], cache) if cache is not None \
            else None
        x, nc0 = body(x, (gp, lc0) if cache is not None else gp)
        if nc0 is not None:
            nc0 = jax.tree.map(lambda a: a[None], nc0)
        return x, nc0
    xs = (gp, cache) if cache is not None else gp
    x, new_cache = jax.lax.scan(body, x, xs, unroll=cfg.scan_unroll)
    return x, new_cache


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def _embed(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
           frontend: Optional[jnp.ndarray]) -> jnp.ndarray:
    h = params["embed"][tokens].astype(cfg.dtype())
    h = h * math.sqrt(cfg.d_model)
    if frontend is not None and cfg.frontend and cfg.arch_type != "encdec":
        h = jnp.concatenate([frontend.astype(cfg.dtype()), h], axis=1)
    return h


def _unembed(cfg: ModelConfig, params: Params, h: jnp.ndarray) -> jnp.ndarray:
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return h @ params["embed"].T.astype(h.dtype)
    return h @ params["lm_head"].astype(h.dtype)


def _encode(cfg: ModelConfig, params: Params,
            frontend: jnp.ndarray) -> jnp.ndarray:
    """Whisper-style bidirectional encoder over (stub) frame embeddings."""
    h = frontend.astype(cfg.dtype())
    pos = jnp.arange(h.shape[1])[None, :]

    def body(carry, lp):
        hh = carry
        a, _, _ = attention_block(
            rms_norm(hh, lp["ln1"], cfg.norm_eps), lp,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, hd=cfg.hd,
            positions=pos, mask=None, rope_theta=cfg.rope_theta,
            kernel=cfg.kernels, causal=False, window=0)
        hh = hh + a
        f, _ = _ffn(cfg, LayerGroup("attn", 1), lp,
                    rms_norm(hh, lp["ln2"], cfg.norm_eps))
        return hh + f, None

    h, _ = jax.lax.scan(body, h, params["encoder"], unroll=cfg.scan_unroll)
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# forward (teacher-forcing; training and smoke tests)
# ---------------------------------------------------------------------------


def forward(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
            frontend: Optional[jnp.ndarray] = None,
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: (B, S_text); frontend: (B, F, d) when cfg.frontend is set.
    Returns (logits (B, S_total, V), aux_loss scalar)."""
    enc_out = None
    if cfg.arch_type == "encdec":
        assert frontend is not None, "encoder-decoder needs frontend frames"
        enc_out = _encode(cfg, params, frontend)
        frontend = None
    h = _embed(cfg, params, tokens, frontend)
    B, S, _ = h.shape
    positions = jnp.arange(S)[None, :]
    aux = jnp.zeros((), f32)
    shared_idx = 0
    for g, gp in zip(cfg.groups(), params["groups"]):
        if g.kind == "mamba":
            h, _ = _mamba_group_fwd(cfg, gp, h, None, collect_state=False)
        else:
            lp = params["shared_attn"] if g.kind == "shared_attn" else gp
            mask = causal_window_mask(positions[0], positions[0],
                                      g.window)[None, None, None]
            h, a, _ = _attn_group_fwd(cfg, g, lp, h, positions, mask,
                                      enc_out, collect_kv=False)
            aux = aux + a
            if g.kind == "shared_attn":
                shared_idx += 1
    return _unembed(cfg, params, h), aux


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------


def cache_spec(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Shapes only (jax.eval_shape-compatible via init_cache)."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def _attn_cache_len(g: LayerGroup, max_len: int) -> int:
    return min(g.window, max_len) if g.window > 0 else max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: Optional[int] = None) -> Dict[str, Any]:
    dt = cfg.dtype()
    groups = cfg.groups()
    entries: List[Dict[str, jnp.ndarray]] = []
    for g in groups:
        if g.kind == "mamba":
            entries.append({
                "conv": jnp.zeros((g.count, batch, cfg.ssm_conv - 1,
                                   cfg.d_inner + 2 * cfg.ssm_state), dt),
                "state": jnp.zeros((g.count, batch, cfg.n_ssm_heads,
                                    cfg.ssm_head_dim, cfg.ssm_state), f32),
            })
        else:
            W = _attn_cache_len(g, max_len)
            e = {"k": jnp.zeros((g.count, batch, W, cfg.n_kv_heads, cfg.hd), dt),
                 "v": jnp.zeros((g.count, batch, W, cfg.n_kv_heads, cfg.hd), dt)}
            if g.cross_attn:
                L = enc_len or cfg.n_enc_tokens
                e["xk"] = jnp.zeros((g.count, batch, L, cfg.n_kv_heads,
                                     cfg.hd), dt)
                e["xv"] = jnp.zeros((g.count, batch, L, cfg.n_kv_heads,
                                     cfg.hd), dt)
            entries.append(e)
    return {"layers": entries}


# ---------------------------------------------------------------------------
# prefill: run the prompt, fill caches, return last-position logits
# ---------------------------------------------------------------------------


def prefill(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
            cache: Dict[str, Any],
            frontend: Optional[jnp.ndarray] = None,
            ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    enc_out = None
    if cfg.arch_type == "encdec":
        enc_out = _encode(cfg, params, frontend)
        frontend = None
    h = _embed(cfg, params, tokens, frontend)
    B, S, _ = h.shape
    positions = jnp.arange(S)[None, :]
    new_layers = []
    for g, gp, ce in zip(cfg.groups(), params["groups"], cache["layers"]):
        if g.kind == "mamba":
            zero = {"conv": jnp.zeros_like(ce["conv"][0]),
                    "state": jnp.zeros_like(ce["state"][0])}
            stacked_zero = jax.tree.map(
                lambda t: jnp.zeros_like(t), ce)
            h, nc = _mamba_group_fwd(cfg, gp, h, stacked_zero,
                                     collect_state=True)
            new_layers.append(nc)
        else:
            lp = params["shared_attn"] if g.kind == "shared_attn" else gp
            mask = causal_window_mask(positions[0], positions[0],
                                      g.window)[None, None, None]
            h, _, kv = _attn_group_fwd(cfg, g, lp, h, positions, mask,
                                       enc_out, collect_kv=True)
            k, v = kv
            W = ce["k"].shape[2]
            e = {"k": _ring_fill(ce["k"], k, S, W),
                 "v": _ring_fill(ce["v"], v, S, W)}
            if g.cross_attn:
                def xkv(lp_layer):
                    return _enc_kv(cfg, lp_layer, enc_out)
                if _is_stacked(gp):
                    xk, xv = jax.vmap(
                        lambda l: _enc_kv(cfg, l, enc_out))(gp)
                else:
                    xk1, xv1 = _enc_kv(cfg, lp, enc_out)
                    xk, xv = xk1[None], xv1[None]
                e["xk"], e["xv"] = xk.astype(ce["xk"].dtype), \
                    xv.astype(ce["xv"].dtype)
            new_layers.append(e)
    logits = _unembed(cfg, params, h[:, -1:, :])
    return logits, {"layers": new_layers}


def _ring_fill(dst: jnp.ndarray, kv: jnp.ndarray, S: int, W: int
               ) -> jnp.ndarray:
    """Write prefill K/V (L,B,S,Hkv,hd) into a ring cache of width W."""
    if S >= W:
        tail = kv[:, :, S - W:, :, :]
        slots = (jnp.arange(S - W, S) % W)
        return dst.at[:, :, slots].set(tail.astype(dst.dtype))
    return dst.at[:, :, :S].set(kv.astype(dst.dtype))


# ---------------------------------------------------------------------------
# decode_step: one token, cache of max_len (THE `serve_step` the dry-run lowers)
# ---------------------------------------------------------------------------


def decode_step(cfg: ModelConfig, params: Params, cache: Dict[str, Any],
                token: jnp.ndarray, t: jnp.ndarray,
                ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """token: (B,1) int32; t: scalar int32 absolute position of this token.
    Returns (logits (B,1,V), updated cache)."""
    positions = jnp.full((1, 1), t, jnp.int32)
    return _decode_impl(cfg, params, cache, token, positions, t)


def decode_step_ragged(cfg: ModelConfig, params: Params,
                       cache: Dict[str, Any], token: jnp.ndarray,
                       t: jnp.ndarray,
                       ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """token: (B,1) int32; t: (B,) int32 — PER-ROW absolute positions.

    The continuous-batching decode step: every batch row advances its own
    sequence (per-row RoPE angle, per-row cache slot, per-row attention
    mask / ``valid_len``), so in-flight requests at different depths share
    one fused device step.  With a uniform ``t`` this computes exactly
    :func:`decode_step`."""
    positions = t[:, None].astype(jnp.int32)         # (B,1)
    return _decode_impl(cfg, params, cache, token, positions, t)


def _decode_impl(cfg: ModelConfig, params: Params, cache: Dict[str, Any],
                 token: jnp.ndarray, positions: jnp.ndarray, t: jnp.ndarray,
                 ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    h = params["embed"][token].astype(cfg.dtype()) * math.sqrt(cfg.d_model)
    new_layers = []
    for g, gp, ce in zip(cfg.groups(), params["groups"], cache["layers"]):
        if g.kind == "mamba":
            h, nc = _mamba_group_fwd(cfg, gp, h, ce, collect_state=False)
            new_layers.append(nc)
        else:
            lp = params["shared_attn"] if g.kind == "shared_attn" else gp
            h, nc = _attn_group_decode(cfg, g, lp, ce, h, positions, t)
            new_layers.append(nc)
    logits = _unembed(cfg, params, h)
    return logits, {"layers": new_layers}


def _attn_group_decode(cfg: ModelConfig, g: LayerGroup, gp: Params,
                       ce: Dict[str, jnp.ndarray], x: jnp.ndarray,
                       positions: jnp.ndarray, t: jnp.ndarray):
    """One-token attention-group step.  ``t`` is a scalar (uniform batch,
    the classic ``decode_step``) or (B,) (ragged continuous-batching rows);
    ``positions`` is the matching (1,1) / (B,1) RoPE position array."""
    W = ce["k"].shape[2]
    ragged = jnp.ndim(t) == 1
    slot = jnp.mod(t, W)                    # () or (B,)
    slots = jnp.arange(W)
    tb = t[:, None] if ragged else t        # (B,1) or scalar
    if g.window > 0:
        # absolute position stored in slot s: t - ((t - s) mod W)
        k_pos = tb - jnp.mod(tb - slots, W)
    else:
        k_pos = slots if not ragged else \
            jnp.broadcast_to(slots, (t.shape[0], W))
    valid = (k_pos >= 0) & (k_pos <= tb)    # (W,) or (B,W)
    mask = valid[None, None, None, None, :] if not ragged \
        else valid[:, None, None, None, :]  # (1,1,1,1,W) / (B,1,1,1,W)
    # full-attention caches (W == max_len) hold slots [0, t] as a prefix, so
    # decode routes to the flash-decoding kernel with valid_len = t+1;
    # sliding-window rings are not a prefix layout and stay on the masked
    # jnp reference (see docs/KERNELS.md)
    use_dec_kernel = cfg.kernels != "xla" and g.window <= 0

    def body(carry, inp):
        h = carry
        lp = gp if not _is_stacked(gp) else None
        if lp is None:
            lp, lc = inp
        else:
            lc = inp
        hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
        B = h.shape[0]
        q = (hn @ lp["wq"]).reshape(B, 1, cfg.n_heads, cfg.hd)
        k1 = (hn @ lp["wk"]).reshape(B, 1, cfg.n_kv_heads, cfg.hd)
        v1 = (hn @ lp["wv"]).reshape(B, 1, cfg.n_kv_heads, cfg.hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k1 = apply_rope(k1, positions, cfg.rope_theta)
        if ragged:
            rows = jnp.arange(B)
            nk = lc["k"].at[rows, slot].set(k1[:, 0].astype(lc["k"].dtype))
            nv = lc["v"].at[rows, slot].set(v1[:, 0].astype(lc["v"].dtype))
        else:
            nk = jax.lax.dynamic_update_slice_in_dim(
                lc["k"], k1.astype(lc["k"].dtype), slot, axis=1)
            nv = jax.lax.dynamic_update_slice_in_dim(
                lc["v"], v1.astype(lc["v"].dtype), slot, axis=1)
        if use_dec_kernel:
            vlen = jnp.broadcast_to(t + 1, (B,)).astype(jnp.int32)
            a1 = kernel_ops.decode_attention(q[:, 0], nk, nv, vlen,
                                             backend=cfg.kernels)
            a = a1[:, None]
        else:
            a = gqa_attention(q, nk, nv, mask)
        h = h + a.reshape(B, 1, cfg.n_heads * cfg.hd) @ lp["wo"]
        if g.cross_attn:
            hx = rms_norm(h, lp["ln_x"], cfg.norm_eps)
            qx = (hx @ lp["xwq"]).reshape(B, 1, cfg.n_heads, cfg.hd)
            if cfg.kernels != "xla":
                ax = kernel_ops.attention(qx, lc["xk"], lc["xv"],
                                          causal=False, window=0,
                                          backend=cfg.kernels)
            else:
                ax = gqa_attention(qx, lc["xk"], lc["xv"], None)
            h = h + ax.reshape(B, 1, cfg.n_heads * cfg.hd) @ lp["xwo"]
        f, _ = _ffn(cfg, g, lp, rms_norm(h, lp["ln2"], cfg.norm_eps))
        h = h + f
        nc = dict(lc)
        nc["k"], nc["v"] = nk, nv
        return h, nc

    if not _is_stacked(gp):
        lc0 = jax.tree.map(lambda a: a[0], ce)
        x, nc0 = body(x, lc0)
        return x, jax.tree.map(lambda a: a[None], nc0)
    x, nc = jax.lax.scan(body, x, (gp, ce), unroll=cfg.scan_unroll)
    return x, nc


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def lm_loss(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
            frontend: Optional[jnp.ndarray] = None,
            aux_weight: float = 0.01) -> jnp.ndarray:
    """Next-token cross-entropy (+ MoE load-balance aux).

    Sharding-aware formulation: with vocab-sharded logits,
    ``take_along_axis`` would force GSPMD to all-gather the full (B,S,V)
    logit tensor.  Writing the picked-logit term as a one-hot contraction
    keeps the vocab axis local (partial dot + psum of a (B,S) scalar field)
    — identical math, ~V/shards less collective traffic (EXPERIMENTS.md
    §Perf, bonus iteration)."""
    logits, aux = forward(cfg, params, tokens, frontend)
    # predictions for text positions only (frontend tokens are prompts)
    n_text = tokens.shape[1]
    logits = logits[:, -n_text:, :].astype(f32)
    pred = logits[:, :-1]                        # (B, S-1, V)
    tgt = tokens[:, 1:]                          # (B, S-1)
    lse = jax.nn.logsumexp(pred, axis=-1)        # (B, S-1)
    onehot = jax.nn.one_hot(tgt, pred.shape[-1], dtype=f32)
    picked = jnp.einsum("bsv,bsv->bs", pred, onehot)
    nll = lse - picked
    return nll.mean() + aux_weight * aux
