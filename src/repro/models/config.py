"""Model configuration: one dataclass drives every architecture family.

A model is a stack of *layer groups*.  Each group is homogeneous (same kind,
same shapes) so it lowers to one ``lax.scan`` over stacked parameters — this
keeps HLO size and compile time independent of depth, which matters when
compiling 56-layer models for 512-device meshes on a CPU host.

Heterogeneous patterns (gemma3's 5 local : 1 global, zamba2's shared
attention every-k) are expressed as several groups.  Group order is the
execution order; for interleaved patterns we execute group-by-group, which
permutes layers relative to the original checkpoints.  FLOPs / memory /
collectives — everything the dry-run and roofline measure — are invariant
under this permutation (see DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class LayerGroup:
    """A run of identical layers executed as one scan."""

    kind: str                   # "attn" | "mamba" | "shared_attn_marker"
    count: int
    window: int = 0             # 0 = full causal attention; >0 = sliding window
    cross_attn: bool = False    # decoder layers attending to encoder output
    moe: bool = False           # FFN is a mixture of experts


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str              # dense|moe|ssm|hybrid|encdec|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # -- attention ---------------------------------------------------------
    rope_theta: float = 10_000.0
    sliding_window: int = 0     # uniform SWA width (mixtral-style); 0 = full
    local_window: int = 0       # local:global pattern (gemma3-style)
    local_ratio: int = 0        # local layers per global layer (5 for gemma3)

    # -- MoE -----------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25

    # -- SSM (mamba2 / zamba2) ----------------------------------------------
    ssm_state: int = 0
    ssm_heads: int = 0          # 0 -> d_model // ssm_head_dim
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2

    # -- hybrid (zamba2): one shared attention block every k mamba layers ----
    shared_attn_every: int = 0

    # -- encoder-decoder (whisper) -------------------------------------------
    n_enc_layers: int = 0
    n_enc_tokens: int = 0       # encoder sequence length (1500 audio frames)

    # -- modality frontend stubs (vlm / audio): see DESIGN.md carve-out ------
    frontend: str = ""          # "" | "vision" | "audio"
    n_frontend_tokens: int = 0  # patch/frame embeddings prepended to the seq

    # -- kernel dispatch -----------------------------------------------------
    # which implementation services the hot spots (attention, decode
    # attention over KV caches, the SSD scan): "xla" = pure-jnp reference
    # (default; byte-compatible with the pre-dispatch model), "pallas" =
    # compiled Pallas TPU kernels, "pallas_interpret" = Pallas in interpret
    # mode (CPU validation).  See repro.kernels.ops.KERNEL_TABLE and
    # docs/KERNELS.md.
    kernels: str = "xla"

    # -- misc ------------------------------------------------------------------
    mlp: str = "swiglu"         # "swiglu" | "gelu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # training schedule tag (minicpm's WSD); consumed by repro.train
    lr_schedule: str = "cosine"

    # roofline probes: explicit layer-group override (see launch/roofline.py)
    override_groups: Optional[Tuple[LayerGroup, ...]] = None
    # roofline probes: fully unroll scans so cost_analysis sees straight-line
    # HLO (XLA counts while bodies ONCE regardless of trip count)
    scan_unroll: bool = False
    # activation rematerialization at layer boundaries (training memory)
    remat: bool = True

    # ------------------------------------------------------------------ derived
    @property
    def vocab_padded(self) -> int:
        """Embedding/unembedding allocation size: vocab rounded up to a
        multiple of 256 so the vocab axis shards evenly on any production
        mesh (logit columns beyond vocab_size are never valid targets)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_ssm_heads(self) -> int:
        if self.ssm_heads:
            return self.ssm_heads
        return (self.d_model * self.ssm_expand) // self.ssm_head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def dtype(self) -> jnp.dtype:
        return jnp.dtype(self.compute_dtype)

    def pdtype(self) -> jnp.dtype:
        return jnp.dtype(self.param_dtype)

    # -- layer-group derivation ------------------------------------------------
    def groups(self) -> Tuple[LayerGroup, ...]:
        """Decoder layer groups in execution order."""
        if self.override_groups is not None:
            return self.override_groups
        moe = self.n_experts > 0
        if self.arch_type == "ssm":
            return (LayerGroup("mamba", self.n_layers),)
        if self.arch_type == "hybrid":
            # zamba2: mamba backbone, shared attention block every k layers
            k = self.shared_attn_every or 6
            gs = []
            remaining = self.n_layers
            while remaining > 0:
                c = min(k, remaining)
                gs.append(LayerGroup("mamba", c))
                remaining -= c
                if remaining >= 0 and c == k:
                    gs.append(LayerGroup("shared_attn", 1))
            return tuple(gs)
        if self.local_ratio > 0:
            # gemma3: r local layers per global layer (grouped, see module doc)
            n_global = max(1, self.n_layers // (self.local_ratio + 1))
            n_local = self.n_layers - n_global
            return (LayerGroup("attn", n_local, window=self.local_window,
                               moe=moe),
                    LayerGroup("attn", n_global, moe=moe))
        w = self.sliding_window
        cross = self.arch_type == "encdec"
        return (LayerGroup("attn", self.n_layers, window=w, moe=moe,
                           cross_attn=cross),)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    def pad_heads(self, multiple: int = 16) -> "ModelConfig":
        """Round head counts up to a multiple so they shard evenly on the
        ``model`` mesh axis (beyond-paper perf variant).

        Padding is *exact*: padded heads have zero wk/wv/wo weights, so their
        keys/values/outputs are identically zero and contribute nothing —
        semantics are preserved while the KV cache becomes head-shardable
        (avoiding GSPMD's head-dim sharding + RoPE-split full
        rematerialization).  Costs (multiple/heads)x extra attention FLOPs.
        """
        if not self.n_heads:
            return self
        up = lambda x: -(-x // multiple) * multiple
        return self.with_(n_heads=up(self.n_heads),
                          n_kv_heads=up(self.n_kv_heads))

    # -- sizes ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        d, v = self.d_model, self.vocab_size
        total = v * d                      # embedding
        if not self.tie_embeddings:
            total += v * d
        for g in self.groups():
            for _ in range(g.count):
                if g.kind in ("attn", "shared_attn"):
                    qkv = d * (self.n_heads * self.hd) \
                        + 2 * d * (self.n_kv_heads * self.hd) \
                        + (self.n_heads * self.hd) * d
                    total += qkv
                    if g.cross_attn:
                        total += qkv
                    ff_in = 2 * d * self.d_ff if self.mlp == "swiglu" \
                        else d * self.d_ff
                    ff = ff_in + self.d_ff * d
                    if g.moe:
                        total += self.n_experts * ff + d * self.n_experts
                    else:
                        total += ff
                    total += 2 * d        # norms
                elif g.kind == "mamba":
                    di, ns, nh = self.d_inner, self.ssm_state, self.n_ssm_heads
                    total += d * (2 * di + 2 * ns + nh)   # in_proj
                    total += self.ssm_conv * (di + 2 * ns)  # conv
                    total += di * d                      # out_proj
                    total += 3 * nh                      # A, dt_bias, D
                    total += d                           # norm
        # encoder stack
        if self.n_enc_layers:
            qkv = 4 * d * (self.n_heads * self.hd)
            ff = 2 * d * self.d_ff
            total += self.n_enc_layers * (qkv + ff + 2 * d)
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts), for MODEL_FLOPS."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        ff_in = 2 * d * self.d_ff if self.mlp == "swiglu" else d * self.d_ff
        ff = ff_in + self.d_ff * d
        dead_experts = self.n_experts - self.experts_per_token
        n_moe_layers = sum(g.count for g in self.groups() if g.moe)
        return self.param_count() - n_moe_layers * dead_experts * ff
