"""Model zoo: config-driven unified architectures in pure JAX."""
from .config import LayerGroup, ModelConfig
from .transformer import (decode_step, decode_step_ragged, forward,
                          init_cache, init_params, lm_loss, prefill)

__all__ = ["LayerGroup", "ModelConfig", "decode_step", "decode_step_ragged",
           "forward", "init_cache", "init_params", "lm_loss", "prefill"]
