"""Sharding rules: parameter / cache / input PartitionSpecs for any mesh.

Scheme (DESIGN.md §4):
  * weights — tensor-parallel over ``model`` (heads / FFN hidden / experts /
    vocab); replicated over ``data`` (and ``pod``).
  * activations & caches — batch over ``data`` (x ``pod``); for batch-1
    long-context decode the KV cache is sharded over ``data`` on the
    *sequence* axis instead (context-parallel decode).
  * MoE experts — expert-parallel over ``model`` when the expert count
    divides the axis; otherwise tensor-parallel within each expert.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .config import ModelConfig


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axes(mesh: Mesh):
    """The tensor-parallel logical axis: plain ``model``, or the 2D
    ``(expert, tp)`` split used by the expert-parallel perf variant."""
    if "model" in mesh.axis_names:
        return "model"
    return ("expert", "tp")


def model_axis_size(mesh: Mesh) -> int:
    if "model" in mesh.axis_names:
        return mesh.shape["model"]
    return mesh.shape["expert"] * mesh.shape["tp"]


def _last(ndim: int, axis: str) -> P:
    return P(*([None] * (ndim - 1)), axis)


def _second_last(ndim: int, axis: str) -> P:
    return P(*([None] * (ndim - 2)), axis, None)


FSDP_THRESHOLD_BYTES = 8 << 30      # add data-axis weight sharding above this


def needs_fsdp(cfg: ModelConfig, mesh: Mesh) -> bool:
    """True when model-axis tensor parallelism alone cannot fit the weights
    in a v5e's 16GB HBM (e.g. mixtral-8x22b, llama4-scout)."""
    bytes_per_dev = cfg.param_count() * 2 / model_axis_size(mesh)
    return bytes_per_dev > FSDP_THRESHOLD_BYTES


def param_pspecs(cfg: ModelConfig, params: Any, mesh: Mesh,
                 fsdp: Optional[bool] = None) -> Any:
    """PartitionSpec tree mirroring ``params`` (name-based rules).

    With ``fsdp`` the d_model dimension of large matrices is additionally
    sharded over ``data`` (2D weight sharding); GSPMD then all-gathers
    weights per layer — the standard recipe for models whose weights exceed
    HBM under pure tensor parallelism."""
    msize = model_axis_size(mesh)
    max_ = model_axes(mesh)
    if fsdp is None:
        fsdp = needs_fsdp(cfg, mesh)
    dshard = "data" if fsdp else None
    if max_ == "model":
        expert_parallel = cfg.n_experts > 0 and cfg.n_experts % msize == 0
        e_ax, t_ax = "model", None
    else:
        # 2D split: experts over `expert`, within-expert tensor over `tp`
        expert_parallel = (cfg.n_experts > 0
                           and cfg.n_experts % mesh.shape["expert"] == 0)
        e_ax, t_ax = "expert", "tp"

    def rule(path, leaf) -> P:
        name = None
        for p in reversed(path):
            k = getattr(p, "key", None)
            if isinstance(k, str):
                name = k
                break
        nd = leaf.ndim
        if name in ("embed",):
            return P(max_, dshard)
        if name == "lm_head":
            return P(dshard, max_)
        if name in ("final_norm", "enc_norm") or name.startswith("ln") \
                or name in ("norm_w", "conv_b", "dt_bias", "A_log", "D",
                            "conv_w", "router"):
            return P()
        if name in ("wq", "wk", "wv", "xwq", "xwk", "xwv", "in_proj"):
            # (..., d, h): d over data (fsdp), h over model
            return P(*([None] * (nd - 2)), dshard, max_)
        if name in ("wo", "xwo", "out_proj"):
            # (..., h, d): h over model, d over data (fsdp)
            return P(*([None] * (nd - 2)), max_, dshard)
        if name in ("w_gate", "w_up"):
            if nd == 4:     # stacked MoE (L, E, d, f)
                return (P(None, e_ax, dshard, t_ax) if expert_parallel
                        else P(None, None, dshard, max_))
            return P(*([None] * (nd - 2)), dshard, max_)
        if name == "w_down":
            if nd == 4:     # (L, E, f, d)
                return (P(None, e_ax, t_ax, dshard) if expert_parallel
                        else P(None, None, max_, dshard))
            return P(*([None] * (nd - 2)), max_, dshard)
        return P()

    return jax.tree_util.tree_map_with_path(rule, params)


def cache_pspecs(cfg: ModelConfig, cache: Any, mesh: Mesh,
                 batch: int) -> Any:
    """Cache specs.  batch >= data-axis size -> shard batch; batch smaller
    (long-context) -> shard the KV sequence axis over ``data``."""
    dp = data_axes(mesh)
    max_ = model_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    batch_sharded = batch % dp_size == 0 and batch >= dp_size
    msize = model_axis_size(mesh)
    kv_axis_ok = cfg.n_kv_heads and cfg.n_kv_heads % msize == 0
    hd_ok = cfg.hd % msize == 0 if cfg.n_heads else False
    ssm_heads_ok = cfg.n_ssm_heads % msize == 0 if cfg.ssm_state else False

    def rule(path, leaf) -> P:
        name = None
        for p in reversed(path):
            k = getattr(p, "key", None)
            if isinstance(k, str):
                name = k
                break
        if name in ("k", "v", "xk", "xv"):
            # (L, B, W, Hkv, hd)
            b = dp if batch_sharded else None
            w = None if batch_sharded else "data"
            if kv_axis_ok:
                return P(None, b, w, max_, None)
            if hd_ok:
                return P(None, b, w, None, max_)
            return P(None, b, w, None, None)
        if name == "conv":      # (L, B, dc-1, dxbc)
            b = dp if batch_sharded else None
            return P(None, b, None, max_)
        if name == "state":     # (L, B, H, P, N)
            b = dp if batch_sharded else None
            h = max_ if ssm_heads_ok else None
            return P(None, b, h, None, None)
        return P()

    return jax.tree_util.tree_map_with_path(rule, cache)


def input_pspecs(cfg: ModelConfig, mesh: Mesh, batch: int
                 ) -> Dict[str, P]:
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    b = dp if (batch % dp_size == 0 and batch >= dp_size) else None
    return {
        "tokens": P(b, None),
        "frontend": P(b, None, None),
        "token": P(b, None),
    }


def named(mesh: Mesh, tree: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))
