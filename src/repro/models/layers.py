"""Neural building blocks, pure-functional over parameter dicts.

Everything here is plain jnp (the XLA path).  The Pallas kernels in
``repro.kernels`` implement the same contracts for the hot spots and are
selected via ``repro.kernels.ops`` by the model when enabled.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops as kernel_ops

Params = Dict[str, jnp.ndarray]
f32 = jnp.float32


# ---------------------------------------------------------------------------
# Norms / activations / embeddings
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(f32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(f32))).astype(dt)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    g = jax.nn.silu(x @ w_gate)
    return (g * (x @ w_up)) @ w_down


def gelu_mlp(x: jnp.ndarray, w_up: jnp.ndarray, w_down: jnp.ndarray,
             b_up: Optional[jnp.ndarray] = None,
             b_down: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    h = x @ w_up
    if b_up is not None:
        h = h + b_up
    h = jax.nn.gelu(h)
    y = h @ w_down
    if b_down is not None:
        y = y + b_down
    return y


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=f32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(f32) * freqs      # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(f32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, causal, sliding-window, cross)
# ---------------------------------------------------------------------------


def gqa_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  mask: Optional[jnp.ndarray]) -> jnp.ndarray:
    """q: (B,Sq,Hq,hd)  k,v: (B,Sk,Hkv,hd)  mask: broadcastable to
    (B,Hkv,G,Sq,Sk) or (B,1,1,Sq,Sk).  Returns (B,Sq,Hq,hd)."""
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(f32),
                        k.astype(f32)) / math.sqrt(hd)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(f32))
    return out.reshape(B, Sq, Hq, hd).astype(q.dtype)


def causal_window_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                       window: int) -> jnp.ndarray:
    """(Sq,Sk) boolean mask: causal, optionally sliding-window limited.
    ``window`` <= 0 means unlimited lookback."""
    d = q_pos[:, None] - k_pos[None, :]
    m = d >= 0
    if window > 0:
        m &= d < window
    return m


def attention_block(x: jnp.ndarray, p: Params, *, n_heads: int,
                    n_kv_heads: int, hd: int, positions: jnp.ndarray,
                    mask: Optional[jnp.ndarray], rope_theta: float,
                    kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                    kernel: str = "xla", causal: bool = True, window: int = 0,
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Self- (or cross-) attention sublayer body (no residual / norm).

    Returns (out, k, v) so callers can stash K/V into a cache.
    ``kv_override`` supplies externally computed K/V (cross-attention or a
    decode-time cache).

    ``kernel`` selects the attention implementation (``repro.kernels.ops``):
    the default ``"xla"`` applies the caller-built dense ``mask`` via the
    jnp reference; any Pallas backend instead takes the *structural*
    ``causal``/``window`` description (the flash kernel builds its masks
    per tile — callers pass ``mask=None``)."""
    B, S, d = x.shape
    q = (x @ p["wq"]).reshape(B, S, n_heads, hd)
    if kv_override is None:
        k = (x @ p["wk"]).reshape(B, S, n_kv_heads, hd)
        v = (x @ p["wv"]).reshape(B, S, n_kv_heads, hd)
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    else:
        k, v = kv_override
        q = apply_rope(q, positions, rope_theta)
    if kernel != "xla":
        out = kernel_ops.attention(q, k, v, causal=causal, window=window,
                                   backend=kernel)
    else:
        out = gqa_attention(q, k, v, mask)
    out = out.reshape(B, S, n_heads * hd) @ p["wo"]
    return out, k, v


# ---------------------------------------------------------------------------
# Mixture of Experts (sort-based dispatch with capacity, Switch-style drops)
# ---------------------------------------------------------------------------


def moe_block(x: jnp.ndarray, p: Params, *, n_experts: int, k: int,
              capacity_factor: float, mlp: str = "swiglu",
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,d) -> (y, aux_loss).

    Sort-based dispatch: tokens are routed to their top-k experts, sorted by
    expert id, and scattered into a dense (E, C, d) buffer (tokens beyond an
    expert's capacity are dropped).  Expert FFNs run as batched einsums over
    the leading expert axis — the axis sharded for expert parallelism."""
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    logits = (xt @ p["router"]).astype(f32)                    # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)                      # (T,k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    E = n_experts
    C = max(1, int(math.ceil(k * T / E * capacity_factor)))
    flat_e = eidx.reshape(-1)                                  # (T*k,)
    sort_idx = jnp.argsort(flat_e)                             # stable
    sorted_e = flat_e[sort_idx]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))      # (E,)
    pos_in_e = jnp.arange(T * k) - seg_start[sorted_e]
    keep = pos_in_e < C
    dest = jnp.where(keep, sorted_e * C + pos_in_e, E * C)     # drop bucket
    token_idx = sort_idx // k

    # Gather-based dispatch: scatter only 4-byte indices (slot -> source
    # token), then move the d-wide rows with a single gather.  A direct
    # row scatter-into-zeros would write the (E*C, d) buffer twice (zero
    # init + scatter) and read it once more; this formulation halves the
    # dispatch HBM traffic (see EXPERIMENTS.md §Perf pair A).
    slot_src = jnp.full((E * C + 1,), T, jnp.int32).at[dest].set(
        token_idx.astype(jnp.int32))
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), x.dtype)], axis=0)
    h = xt_pad[slot_src[:E * C]].reshape(E, C, d)
    if mlp == "swiglu":
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, p["w_gate"]))
        u = jnp.einsum("ecd,edf->ecf", h, p["w_up"])
        out_e = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"])
    else:
        hmid = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", h, p["w_up"]))
        out_e = jnp.einsum("ecf,efd->ecd", hmid, p["w_down"])

    out_flat = jnp.concatenate(
        [out_e.reshape(E * C, d), jnp.zeros((1, d), x.dtype)], axis=0)
    # combine: compose the two permutations (sorted->slot, unsort) into ONE
    # row gather instead of two chained d-wide gathers
    inv = jnp.argsort(sort_idx)
    out_tk = out_flat[dest[inv]].reshape(T, k, d)
    y = (out_tk * gates.astype(x.dtype)[..., None]).sum(axis=1)

    # Switch-style load-balance auxiliary loss
    me = probs.mean(axis=0)                                    # (E,)
    one_hot_top1 = jax.nn.one_hot(eidx[:, 0], E, dtype=f32)
    ce = one_hot_top1.mean(axis=0)
    aux = E * jnp.sum(me * ce)
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Mamba2 (SSD — state-space duality, chunked scan)  [arXiv:2405.21060]
# ---------------------------------------------------------------------------


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{j < t <= i} x[..., t].
    Produces the log-decay matrix L = exp(segsum(dA)) lower-triangular."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xh: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                init_state: Optional[jnp.ndarray] = None,
                unroll: bool = False,
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mamba2 SSD core over a full sequence (training / prefill).

    xh: (B,S,H,P)  dt: (B,S,H)  A: (H,) negative  Bm,Cm: (B,S,N)
    Returns (y: (B,S,H,P), final_state: (B,H,P,N)).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    S0 = S
    pad = (-S) % chunk
    if pad:
        # dt=0 on padded steps => decay exp(0)=1 and zero input contribution,
        # so padding never perturbs the state.
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // chunk
    x_c = xh.reshape(Bsz, nc, chunk, H, P)
    dt_c = dt.reshape(Bsz, nc, chunk, H)
    B_c = Bm.reshape(Bsz, nc, chunk, N)
    C_c = Cm.reshape(Bsz, nc, chunk, N)

    dA = dt_c * A[None, None, None, :]                       # (B,nc,Q,H) <= 0
    dA_hbt = jnp.moveaxis(dA, -1, 2)                         # (B,nc,H,Q)
    L = jnp.exp(_segsum(dA_hbt.astype(f32)))                 # (B,nc,H,Q,Q)

    xdt = x_c * dt_c[..., None]                              # input scaled by dt
    # intra-chunk (the "attention-like" quadratic term)
    scores = jnp.einsum("bcqn,bckn->bcqk", C_c.astype(f32), B_c.astype(f32))
    y_diag = jnp.einsum("bchqk,bcqk,bckhp->bcqhp", L, scores,
                        xdt.astype(f32))

    # per-chunk summary state:  sum_k exp(dA_total - cum dA_k) * B_k x_k
    dA_cum = jnp.cumsum(dA_hbt, axis=-1)                     # (B,nc,H,Q)
    decay_out = jnp.exp((dA_cum[..., -1:] - dA_cum).astype(f32))  # (B,nc,H,Q)
    states = jnp.einsum("bchq,bcqn,bcqhp->bchpn", decay_out,
                        B_c.astype(f32), xdt.astype(f32))    # (B,nc,H,P,N)

    # inter-chunk recurrence (sequential over nc)
    chunk_decay = jnp.exp(dA_cum[..., -1].astype(f32))       # (B,nc,H)
    s0 = (jnp.zeros((Bsz, H, P, N), f32) if init_state is None
          else init_state.astype(f32))

    def step(carry, inp):
        dec, st = inp            # (B,H), (B,H,P,N)
        new = carry * dec[..., None, None] + st
        return new, carry        # emit state *entering* the chunk

    decs = jnp.moveaxis(chunk_decay, 1, 0)                   # (nc,B,H)
    sts = jnp.moveaxis(states, 1, 0)                         # (nc,B,H,P,N)
    final_state, prev_states = jax.lax.scan(step, s0, (decs, sts),
                                             unroll=unroll)
    prev_states = jnp.moveaxis(prev_states, 0, 1)            # (B,nc,H,P,N)

    # inter-chunk contribution:  C_q * exp(cum dA_q) * state_in
    decay_in = jnp.exp(dA_cum.astype(f32))                   # (B,nc,H,Q)
    y_off = jnp.einsum("bcqn,bchq,bchpn->bcqhp", C_c.astype(f32),
                       decay_in, prev_states)

    y = (y_diag + y_off).reshape(Bsz, S, H, P)[:, :S0].astype(xh.dtype)
    return y, final_state


def ssd_decode_step(state: jnp.ndarray, x: jnp.ndarray, dt: jnp.ndarray,
                    A: jnp.ndarray, Bm: jnp.ndarray, Cm: jnp.ndarray,
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token recurrent update.
    state: (B,H,P,N)  x: (B,H,P)  dt: (B,H)  Bm,Cm: (B,N)."""
    dA = jnp.exp((dt * A[None, :]).astype(f32))              # (B,H)
    dBx = jnp.einsum("bn,bhp,bh->bhpn", Bm.astype(f32), x.astype(f32),
                     dt.astype(f32))
    new_state = state * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm.astype(f32))
    return y.astype(x.dtype), new_state


def mamba2_block(x: jnp.ndarray, p: Params, *, n_heads: int, head_dim: int,
                 d_state: int, d_conv: int, chunk: int,
                 cache: Optional[Dict] = None, unroll: bool = False,
                 kernel: str = "xla",
                 ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Full Mamba2 mixer (in_proj -> conv -> SSD -> gated norm -> out_proj).

    x: (B,S,d).  With ``cache`` (dict with 'conv' (B,d_conv-1,d_xBC) and
    'state' (B,H,P,N)), runs in stateful decode mode (S may be 1).
    ``kernel`` routes the chunked SSD scan through ``repro.kernels.ops``
    (the S=1 recurrent step is jnp on every backend — see KERNEL_TABLE).
    """
    B, S, d = x.shape
    H, P, N = n_heads, head_dim, d_state
    di = H * P
    zxbcdt = x @ p["in_proj"]                                # (B,S,2di+2N+H... )
    z, xBC, dt = jnp.split(zxbcdt, [di, di + di + 2 * N], axis=-1)
    # causal depthwise conv over the sequence
    if cache is not None:
        conv_in = jnp.concatenate([cache["conv"], xBC], axis=1)
        new_conv = conv_in[:, -(d_conv - 1):, :]
    else:
        conv_in = jnp.pad(xBC, ((0, 0), (d_conv - 1, 0), (0, 0)))
        new_conv = conv_in[:, -(d_conv - 1):, :]
    wconv = p["conv_w"]                                      # (d_conv, di+2N)
    xBC = sum(conv_in[:, i:i + S, :] * wconv[i][None, None, :]
              for i in range(d_conv)) + p["conv_b"][None, None, :]
    xBC = jax.nn.silu(xBC).astype(x.dtype)
    xs, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
    xh = xs.reshape(B, S, H, P)
    dt = jax.nn.softplus(dt.astype(f32) + p["dt_bias"].astype(f32))
    A = -jnp.exp(p["A_log"].astype(f32))                     # (H,)

    if cache is not None and S == 1:
        y1, new_state = kernel_ops.ssd_step(cache["state"], xh[:, 0],
                                            dt[:, 0], A, Bm[:, 0], Cm[:, 0],
                                            backend=kernel)
        y = y1[:, None]
    else:
        init = cache["state"] if cache is not None else None
        if kernel != "xla":
            y, new_state = kernel_ops.ssd(xh, dt.astype(xh.dtype), A, Bm, Cm,
                                          chunk=chunk, init_state=init,
                                          backend=kernel)
        else:
            y, new_state = ssd_chunked(xh, dt.astype(xh.dtype), A, Bm, Cm,
                                       chunk, init_state=init, unroll=unroll)
    y = y + xh * p["D"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(B, S, di)
    # gated RMSNorm (mamba2 style): norm(y * silu(z))
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"])
    out = y @ p["out_proj"]
    new_cache = None
    if cache is not None or True:
        new_cache = {"conv": new_conv, "state": new_state}
    return out, new_cache
