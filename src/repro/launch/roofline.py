import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape), single-pod mesh:

    compute term    = HLO_FLOPs / peak_FLOP/s          (per device)
    memory term     = HLO_bytes / HBM_bw               (per device)
    collective term = collective_wire_bytes / ICI_bw   (per device)

Methodology — the while-loop problem.  XLA's ``cost_analysis`` counts a
while body ONCE regardless of trip count, and scan-over-layers puts every
layer inside a while loop.  We therefore compile *probe* configurations with
one layer per group and two layers per distinct group type — with scans
fully unrolled so the HLO is straight-line — and compose:

    total(metric) = probe_base + sum_T  delta_T * (layers_T - groups_T)

where delta_T is the exact per-layer cost of group type T (difference of two
straight-line compiles).  Collective bytes are read from the probes' HLO by
summing operand/result sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, converted to wire bytes with ring-algorithm
factors.  Memory analysis comes from the FULL compile (the real artifact).
"""
import argparse
import json
import re
from typing import Any, Dict, List, Optional, Tuple

from ..configs import ARCH_IDS, INPUT_SHAPES, get_config, shape_applicable
from ..models.config import LayerGroup, ModelConfig
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16, "s4": 1, "u4": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """bytes of an HLO type string like 'bf16[8,128,2304]{2,1,0}' or a
    tuple '(f32[4], bf16[8,16])'."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo: str, default_group: int) -> Dict[str, float]:
    """Sum *wire* bytes per collective kind from (straight-line) HLO text.

    Ring-algorithm factors per participating device:
      all-gather      (g-1)/g * result
      reduce-scatter  (g-1)/g * operand
      all-reduce      2 (g-1)/g * operand
      all-to-all      (g-1)/g * operand
      collective-permute   1  * operand
    """
    # symbol table: instruction name -> result bytes
    sizes: Dict[str, int] = {}
    for m in re.finditer(
            r"%?([\w.\-]+) = (\([^=]*?\)|\S+?\[[^\]]*\]\S*)\s", hlo):
        sizes[m.group(1)] = _shape_bytes(m.group(2))

    out = {k: 0.0 for k in _COLLECTIVES}
    pat = re.compile(
        r"%?([\w.\-]+) = (\([^=]*?\)|\S+?\[[^\]]*\]\S*)\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)(?:-start)?\(([^)]*)\)(.*)")
    for line in hlo.splitlines():
        line = line.strip()
        m = pat.match(line)
        if not m:
            continue
        name, rtype, kind, operands, rest = m.groups()
        if ".clone" in name and False:
            continue
        result_b = _shape_bytes(rtype)
        operand_b = sum(sizes.get(o.strip().lstrip("%"), 0)
                        for o in operands.split(",") if o.strip())
        # group size from replica_groups
        g = default_group
        gm = re.search(r"replica_groups=\{\{([\d,]+)\}", rest)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
            if gm:
                g = int(gm.group(2))
        g = max(g, 1)
        ring = (g - 1) / g
        if kind == "all-gather":
            out[kind] += ring * result_b
        elif kind == "all-reduce":
            out[kind] += 2 * ring * operand_b
        elif kind == "reduce-scatter":
            out[kind] += ring * operand_b
        elif kind == "all-to-all":
            out[kind] += ring * operand_b
        else:   # collective-permute
            out[kind] += operand_b
    return out


# ---------------------------------------------------------------------------
# probe configurations
# ---------------------------------------------------------------------------


def _type_key(g: LayerGroup) -> Tuple:
    return (g.kind, g.window, g.moe, g.cross_attn)


def _probe_cfg(cfg: ModelConfig, counts: List[int]) -> ModelConfig:
    groups = cfg.groups()
    new = tuple(LayerGroup(g.kind, c, window=g.window,
                           cross_attn=g.cross_attn, moe=g.moe)
                for g, c in zip(groups, counts) if c > 0)
    return cfg.with_(override_groups=new, scan_unroll=True)


def _measure(arch: str, shape: str, mesh, cfg: ModelConfig,
             fsdp: Optional[bool] = None) -> Dict[str, Any]:
    from .dryrun import lower_one
    compiled, info = lower_one(arch, shape, mesh, cfg=cfg, fsdp=fsdp)
    hlo = compiled.as_text()
    n_while = hlo.count(" while(")
    coll = parse_collective_bytes(hlo, default_group=mesh.devices.size)
    return {"flops": info["flops"], "bytes": info["bytes_accessed"],
            "coll": coll, "n_while": n_while}


def _compose(base: Dict, deltas: List[Tuple[Dict, int]]) -> Dict[str, float]:
    """total = base + sum(delta * extra_layers)."""
    tot = {"flops": base["flops"], "bytes": base["bytes"],
           "coll_bytes": sum(base["coll"].values())}
    coll_by_kind = dict(base["coll"])
    for d, extra in deltas:
        tot["flops"] += d["flops"] * extra
        tot["bytes"] += d["bytes"] * extra
        for k, v in d["coll"].items():
            coll_by_kind[k] = coll_by_kind.get(k, 0.0) + v * extra
    tot["coll_bytes"] = sum(coll_by_kind.values())
    tot["coll_by_kind"] = coll_by_kind
    return tot


def model_flops(cfg: ModelConfig, shape: str) -> float:
    """Analytic MODEL_FLOPS (global): 6*N*D train, 2*N*D inference, with
    N = active params (MoE: routed experts only).  Attention's quadratic
    term is excluded by convention — the useful-compute yardstick."""
    seq, batch, kind = INPUT_SHAPES[shape]
    n = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n * batch * seq
    if kind == "prefill":
        return 2.0 * n * batch * seq
    return 2.0 * n * batch          # decode: one token per sequence


def analyze(arch: str, shape: str, use_cache: bool = True) -> Dict[str, Any]:
    res_dir = os.path.join(RESULTS_DIR, "roofline")
    os.makedirs(res_dir, exist_ok=True)
    out_path = os.path.join(res_dir, f"{arch}__{shape}.json")
    if use_cache and os.path.exists(out_path):
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=False)
    # FSDP decision must come from the FULL model's size, not the probes'
    from ..models.sharding import needs_fsdp
    fsdp = needs_fsdp(cfg, mesh)
    groups = cfg.groups()

    # distinct group types and their multiplicities
    types: Dict[Tuple, List[int]] = {}
    for i, g in enumerate(groups):
        types.setdefault(_type_key(g), []).append(i)

    base_counts = [1] * len(groups)
    base = _measure(arch, shape, mesh, _probe_cfg(cfg, base_counts),
                    fsdp=fsdp)

    deltas = []
    for key, idxs in types.items():
        full_layers = sum(groups[i].count for i in idxs)
        extra = full_layers - len(idxs)
        if extra == 0:
            continue
        counts = list(base_counts)
        for i in idxs:
            counts[i] = 2
        probe = _measure(arch, shape, mesh, _probe_cfg(cfg, counts),
                         fsdp=fsdp)
        delta = {"flops": (probe["flops"] - base["flops"]) / len(idxs),
                 "bytes": (probe["bytes"] - base["bytes"]) / len(idxs),
                 "coll": {k: (probe["coll"][k] - base["coll"][k]) / len(idxs)
                          for k in probe["coll"]}}
        deltas.append((delta, extra))

    tot = _compose(base, deltas)
    n_dev = mesh.devices.size

    compute_s = tot["flops"] / PEAK_FLOPS_BF16
    memory_s = tot["bytes"] / HBM_BW
    coll_s = tot["coll_bytes"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    bottleneck = max(terms, key=terms.get)

    mf_global = model_flops(cfg, shape)
    mf_dev = mf_global / n_dev
    result = {
        "arch": arch, "shape": shape, "mesh": "16x16", "n_devices": n_dev,
        "hlo_flops_dev": tot["flops"], "hlo_bytes_dev": tot["bytes"],
        "coll_bytes_dev": tot["coll_bytes"],
        "coll_by_kind": tot["coll_by_kind"],
        **terms,
        "bottleneck": bottleneck.replace("_s", ""),
        "model_flops_global": mf_global,
        "model_flops_dev": mf_dev,
        "useful_ratio": (mf_dev / tot["flops"]) if tot["flops"] else 0.0,
        "probe_while_loops": base["n_while"],
        "fsdp": bool(fsdp),
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    rows = []
    for arch in archs:
        for shape in shapes:
            if not shape_applicable(arch, shape):
                continue
            r = analyze(arch, shape, use_cache=not args.force)
            rows.append(r)
            print(f"{arch:24s} {shape:12s} C={r['compute_s']*1e3:9.3f}ms "
                  f"M={r['memory_s']*1e3:9.3f}ms "
                  f"X={r['collective_s']*1e3:9.3f}ms "
                  f"dom={r['bottleneck']:10s} "
                  f"useful={r['useful_ratio']*100:5.1f}%")
    with open(os.path.join(RESULTS_DIR, "roofline", "table.json"), "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
