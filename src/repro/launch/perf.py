import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Performance hillclimbing (§Perf): hypothesis -> change -> measure ->
validate, on the three chosen (arch x shape) pairs.

Pairs (from the baseline roofline table):
  A. mixtral-8x22b  x train_4k    -- worst roofline fraction (useful 5.7%,
                                     collective 636 s vs compute 86 s)
  B. minicpm-2b     x decode_32k  -- most collective-bound serving shape
                                     (X/C = 5500x; GSPMD full-remat of the
                                     hd-sharded KV cache at the RoPE split)
  C. llama4-scout   x decode_32k  -- most representative of the paper's
                                     technique (MoE decode = the serving
                                     workload Archipelago schedules)

Each variant is measured with the same probe-compose methodology as
repro.launch.roofline; results land in results/perf/<pair>__<variant>.json.
"""
import argparse
import json
from typing import Any, Dict, Optional

import jax

from ..configs import get_config
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from .roofline import (_compose, _measure, _probe_cfg, _type_key,
                       model_flops)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "perf")


def make_expert_mesh():
    """256 chips as (data=16, expert=8, tp=2): experts whole on chips."""
    return jax.make_mesh((16, 8, 2), ("data", "expert", "tp"))


def measure_variant(arch: str, shape: str, *, mesh=None,
                    cfg_transform=None, fsdp: Optional[bool] = None
                    ) -> Dict[str, float]:
    cfg = get_config(arch)
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    mesh = mesh or make_production_mesh(multi_pod=False)
    if fsdp is None:
        # decided by the FULL model's size, not the probes'
        from ..models.sharding import needs_fsdp
        fsdp = needs_fsdp(cfg, mesh)
    groups = cfg.groups()
    types: Dict[Any, list] = {}
    for i, g in enumerate(groups):
        types.setdefault(_type_key(g), []).append(i)
    base_counts = [1] * len(groups)
    base = _measure(arch, shape, mesh, _probe_cfg(cfg, base_counts),
                    fsdp=fsdp)
    deltas = []
    for key, idxs in types.items():
        full_layers = sum(groups[i].count for i in idxs)
        extra = full_layers - len(idxs)
        if extra == 0:
            continue
        counts = list(base_counts)
        for i in idxs:
            counts[i] = 2
        probe = _measure(arch, shape, mesh, _probe_cfg(cfg, counts),
                         fsdp=fsdp)
        deltas.append((
            {"flops": (probe["flops"] - base["flops"]) / len(idxs),
             "bytes": (probe["bytes"] - base["bytes"]) / len(idxs),
             "coll": {k: (probe["coll"][k] - base["coll"][k]) / len(idxs)
                      for k in probe["coll"]}}, extra))
    tot = _compose(base, deltas)
    out = {
        "compute_s": tot["flops"] / PEAK_FLOPS_BF16,
        "memory_s": tot["bytes"] / HBM_BW,
        "collective_s": tot["coll_bytes"] / ICI_BW,
        "hlo_flops_dev": tot["flops"],
        "hlo_bytes_dev": tot["bytes"],
        "coll_bytes_dev": tot["coll_bytes"],
        "coll_by_kind": tot["coll_by_kind"],
    }
    out["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: out[k])
    mf = model_flops(cfg, shape) / mesh.devices.size
    out["useful_ratio"] = mf / max(tot["flops"], 1.0)
    return out


VARIANTS = {
    # -- pair A: mixtral train --------------------------------------------------
    ("mixtral-8x22b", "train_4k"): {
        "baseline": {},
        # H-A1: experts live whole on chips (expert axis 8 x tp 2); MoE
        # traffic becomes all-to-all over 8 instead of full-f tensor shards
        "expert_mesh": {"mesh": "expert"},
        # H-A2: capacity factor 1.25 -> 1.0 shrinks every dispatch/expert
        # buffer by 20% (slight routing-drop quality trade, documented)
        "expert_mesh_cap1": {
            "mesh": "expert",
            "cfg_transform": lambda c: c.with_(capacity_factor=1.0)},
    },
    # -- pair B: minicpm decode -------------------------------------------------
    ("minicpm-2b", "decode_32k"): {
        "baseline": {},
        # H-B1: pad 36 heads -> 48 so the cache shards by kv head; removes
        # the RoPE-split full-remat at +33% attention FLOPs
        "pad_heads": {"cfg_transform": lambda c: c.pad_heads(16)},
    },
    # -- pair C: llama4 decode --------------------------------------------------
    ("llama4-scout-17b-a16e", "decode_32k"): {
        "baseline": {},
        # H-C1: decode is weight-stationary; FSDP all-gathers every weight
        # every token.  Model-axis-only sharding (13.6GB/dev) drops that.
        "no_fsdp": {"fsdp": False},
        # H-C2: pad kv heads 8 -> 16 so the cache shards by head
        "pad_heads": {"cfg_transform": lambda c: c.pad_heads(16)},
        # H-C3: both
        "pad_heads_no_fsdp": {"cfg_transform": lambda c: c.pad_heads(16),
                              "fsdp": False},
    },
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", default=None,
                    help="arch:shape filter, e.g. minicpm-2b:decode_32k")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(RESULTS_DIR, exist_ok=True)
    for (arch, shape), variants in VARIANTS.items():
        if args.pair and args.pair != f"{arch}:{shape}":
            continue
        for vname, opts in variants.items():
            tag = f"{arch}__{shape}__{vname}"
            path = os.path.join(RESULTS_DIR, tag + ".json")
            if os.path.exists(path) and not args.force:
                with open(path) as f:
                    r = json.load(f)
            else:
                mesh = make_expert_mesh() if opts.get("mesh") == "expert" \
                    else None
                r = measure_variant(arch, shape, mesh=mesh,
                                    cfg_transform=opts.get("cfg_transform"),
                                    fsdp=opts.get("fsdp"))
                with open(path, "w") as f:
                    json.dump(r, f, indent=1)
            print(f"{tag:60s} C={r['compute_s']*1e3:10.3f}ms "
                  f"M={r['memory_s']*1e3:10.3f}ms "
                  f"X={r['collective_s']*1e3:10.3f}ms dom={r['bottleneck']}")


if __name__ == "__main__":
    main()
