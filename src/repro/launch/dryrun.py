import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the device
# count at first initialization, and the dry-run needs 512 placeholder host
# devices to build the production meshes.  (Smoke tests / benches import via
# other entry points and see 1 device.)

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination against the production meshes, record memory / cost /
collective statistics for the roofline analysis.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch minicpm-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi
Results land in results/dryrun/<arch>__<shape>__<mesh>.json (+ .hlo.txt).
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, INPUT_SHAPES, get_config, shape_applicable
from ..models import decode_step, init_cache, init_params, prefill
from ..models.config import ModelConfig
from ..models.sharding import (cache_pspecs, input_pspecs, needs_fsdp,
                               param_pspecs)
from ..train.optim import adamw_init
from ..train.steps import make_train_step
from .mesh import make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input: weak-type-correct,
    shardable, no device allocation."""
    seq, batch, kind = INPUT_SHAPES[shape_name]
    out: Dict[str, Any] = {"kind": kind, "batch": batch, "seq": seq}
    if kind == "train":
        n_text = seq - (cfg.n_frontend_tokens if cfg.frontend
                        and cfg.arch_type != "encdec" else 0)
        out["tokens"] = sds((batch, n_text), jnp.int32)
        if cfg.frontend:
            out["frontend"] = sds(
                (batch, cfg.n_frontend_tokens, cfg.d_model), cfg.dtype())
    elif kind == "prefill":
        n_text = seq - (cfg.n_frontend_tokens if cfg.frontend
                        and cfg.arch_type != "encdec" else 0)
        out["tokens"] = sds((batch, n_text), jnp.int32)
        if cfg.frontend:
            out["frontend"] = sds(
                (batch, cfg.n_frontend_tokens, cfg.d_model), cfg.dtype())
        out["cache"] = jax.eval_shape(
            lambda: init_cache(cfg, batch, seq))
    else:   # decode: ONE new token against a cache of `seq`
        out["token"] = sds((batch, 1), jnp.int32)
        out["t"] = sds((), jnp.int32)
        out["cache"] = jax.eval_shape(
            lambda: init_cache(cfg, batch, seq))
    return out


def _abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def lower_one(arch: str, shape_name: str, mesh, *, fsdp: Optional[bool] = None,
              cfg: Optional[ModelConfig] = None
              ) -> Tuple[Any, Dict[str, Any]]:
    """Lower + compile one combination.  Returns (compiled, info).
    ``cfg`` overrides the registry config (roofline probes)."""
    if cfg is None:
        cfg = get_config(arch)
    specs = input_specs(cfg, shape_name)
    kind = specs["kind"]
    params = _abstract_params(cfg)
    p_spec = param_pspecs(cfg, params, mesh, fsdp=fsdp)
    in_sp = input_pspecs(cfg, mesh, specs["batch"])
    ns = lambda s: NamedSharding(mesh, s)
    nst = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))

    with mesh:
        if kind == "train":
            step = make_train_step(cfg)
            opt = jax.eval_shape(lambda: adamw_init(params))
            # mu/nu mirror param shardings; step scalar replicated
            o_spec = type(opt)(step=P(), mu=p_spec, nu=p_spec)
            args = [params, opt, specs["tokens"]]
            in_sh = [nst(p_spec), nst(o_spec), ns(in_sp["tokens"])]
            if "frontend" in specs:
                args.append(specs["frontend"])
                in_sh.append(ns(in_sp["frontend"]))
            out_sh = (nst(p_spec), nst(o_spec), None)
            jitted = jax.jit(step, in_shardings=tuple(in_sh),
                             out_shardings=out_sh)
            lowered = jitted.lower(*args)
        elif kind == "prefill":
            c_spec = cache_pspecs(cfg, specs["cache"], mesh, specs["batch"])
            fn = lambda p, tok, cache, fr=None: prefill(cfg, p, tok, cache, fr)
            args = [params, specs["tokens"], specs["cache"]]
            in_sh = [nst(p_spec), ns(in_sp["tokens"]), nst(c_spec)]
            if "frontend" in specs:
                args.append(specs["frontend"])
                in_sh.append(ns(in_sp["frontend"]))
            out_sh = (None, nst(c_spec))
            lowered = jax.jit(fn, in_shardings=tuple(in_sh),
                              out_shardings=out_sh).lower(*args)
        else:
            c_spec = cache_pspecs(cfg, specs["cache"], mesh, specs["batch"])
            fn = lambda p, cache, tok, t: decode_step(cfg, p, cache, tok, t)
            args = [params, specs["cache"], specs["token"], specs["t"]]
            in_sh = [nst(p_spec), nst(c_spec), ns(in_sp["token"]), ns(P())]
            out_sh = (None, nst(c_spec))
            lowered = jax.jit(fn, in_shardings=tuple(in_sh),
                              out_shardings=out_sh).lower(*args)

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    info = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": mesh.devices.size,
        "fsdp": bool(needs_fsdp(cfg, mesh) if fsdp is None else fsdp),
        "kind": kind, "compile_s": round(compile_s, 2),
        "flops": float(cost.get("flops", 0.0)) if cost else 0.0,
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)) if cost else 0.0,
        "cost_analysis": {k: float(v) for k, v in (cost or {}).items()
                          if isinstance(v, (int, float))},
    }
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                info[attr] = int(v)
    return compiled, info


def run_combo(arch: str, shape_name: str, mesh_kind: str,
              save_hlo: bool = True, force: bool = False) -> Dict[str, Any]:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    tag = f"{arch}__{shape_name}__{mesh_kind}"
    out_json = os.path.join(RESULTS_DIR, tag + ".json")
    if os.path.exists(out_json) and not force:
        with open(out_json) as f:
            return json.load(f)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    compiled, info = lower_one(arch, shape_name, mesh)
    if save_hlo:
        hlo_path = os.path.join(RESULTS_DIR, tag + ".hlo.txt")
        with open(hlo_path, "w") as f:
            f.write(compiled.as_text())
        info["hlo_path"] = hlo_path
    with open(out_json, "w") as f:
        json.dump(info, f, indent=1)
    print(f"[dryrun] {tag}: OK compile={info['compile_s']}s "
          f"flops={info['flops']:.3e} bytes={info['bytes_accessed']:.3e}")
    return info


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-hlo", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            if not shape_applicable(arch, shape):
                print(f"[dryrun] {arch}__{shape}: SKIP (see DESIGN.md)")
                continue
            for mk in meshes:
                try:
                    run_combo(arch, shape, mk, save_hlo=not args.no_hlo,
                              force=args.force)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape, mk, repr(e)))
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("   ", f)
        raise SystemExit(1)
    print("[dryrun] all combinations lowered + compiled.")


if __name__ == "__main__":
    main()
