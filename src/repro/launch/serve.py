"""Serving launcher: `python -m repro.launch.serve --arch <id> ...`

Runs a single-tenant Archipelago serving session with real JAX execution:
calibrates the model (real compile = sandbox setup cost), pre-warms, then
drives Poisson traffic through LBS -> SGS -> workers and reports latency
percentiles and deadline adherence.
"""
import argparse
import random

from ..configs import ARCH_IDS, get_config
from ..core import ClusterConfig
from ..serving import ServedModel, ServingApp, ServingStack
from ..sim.metrics import summarize


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b", choices=ARCH_IDS)
    ap.add_argument("--rps", type=float, default=10.0)
    ap.add_argument("--requests", type=int, default=60)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=4)
    ap.add_argument("--slack", type=float, default=0.5)
    ap.add_argument("--n-sgs", type=int, default=2)
    args = ap.parse_args()

    app = ServingApp(
        dag_id=args.arch,
        models={f"{args.arch}/generate": ServedModel(
            get_config(args.arch, reduced=True),
            prompt_len=args.prompt, gen_len=args.gen)},
        slack=args.slack)
    print(f"[serve] calibrating {args.arch} (real XLA compile)...")
    stack = ServingStack([app], cluster=ClusterConfig(
        n_sgs=args.n_sgs, workers_per_sgs=2, cores_per_worker=2))
    for name, spec in stack.fn_specs.items():
        print(f"  {name}: exec={spec.exec_time*1e3:.1f}ms "
              f"setup={spec.setup_time:.1f}s "
              f"SNE={spec.setup_time/spec.exec_time:.0f}x")
    t = stack.prewarm(args.arch, n_per_fn=4)
    rng = random.Random(0)
    for _ in range(args.requests):
        t += rng.expovariate(args.rps)
        stack.submit_at(t, args.arch)
    m = stack.run(until=t + 10.0)
    print(" ", summarize(args.arch, m))
    print(f"  real executions: {stack.executor.n_executions}")


if __name__ == "__main__":
    main()
