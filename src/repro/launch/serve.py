"""Serving launcher: `python -m repro.launch.serve --arch <id> ...`

Runs a single-tenant Archipelago serving session through the experiment API
with the ``jax`` execution backend: calibrates the model (real XLA compile =
sandbox setup cost), pre-warms, then drives Poisson traffic through
LBS -> SGS -> workers and reports the full ``ExperimentResult`` (latency
percentiles, deadline adherence, cold starts).  ``--backend stub`` replays
the same pipeline with scripted times (no compiles) for smoke testing.
"""
import argparse

from ..configs import ARCH_IDS, get_config
from ..core import ClusterConfig
from ..serving import ServedModel, ServingApp
from ..sim import Experiment, simulate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b", choices=ARCH_IDS)
    ap.add_argument("--rps", type=float, default=10.0)
    ap.add_argument("--requests", type=int, default=60,
                    help="expected request count (duration = requests/rps)")
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=4)
    ap.add_argument("--slack", type=float, default=0.5)
    ap.add_argument("--n-sgs", type=int, default=2)
    ap.add_argument("--backend", default="jax",
                    choices=["jax", "jax-batched", "stub", "stub-batched",
                             "modeled"])
    ap.add_argument("--batch-window", type=float, default=0.005,
                    help="batched backends: coalescing window (sim seconds)")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="batched backends: flush when this many invocations "
                         "of one model have gathered")
    ap.add_argument("--kernels", default="xla",
                    choices=["xla", "pallas", "pallas_interpret"],
                    help="jax backends: which implementation serves the "
                         "model hot spots (attention / decode attention / "
                         "SSD scan) — see docs/KERNELS.md")
    ap.add_argument("--batching", default="windowed",
                    choices=["windowed", "continuous"],
                    help="batched backends: request-window coalescing vs "
                         "step-granular continuous batching "
                         "(docs/SERVING.md)")
    ap.add_argument("--stack", default="archipelago")
    ap.add_argument("--warmup", type=float, default=None,
                    help="steady-state window start (exclude the pre-warm "
                         "transient from the reported stats); default: half "
                         "the duration for the jax backend — real compiles "
                         "take seconds and arrivals start at t=0 — else 0")
    args = ap.parse_args()
    duration = args.requests / args.rps
    warmup = args.warmup
    real_jax = args.backend in ("jax", "jax-batched")
    if warmup is None:
        warmup = duration / 2.0 if real_jax else 0.0
    backend_kwargs = {}
    if args.backend.endswith("-batched"):
        backend_kwargs = dict(batch_window=args.batch_window,
                              max_batch=args.max_batch,
                              batching=args.batching)
    if real_jax:
        backend_kwargs["kernels"] = args.kernels

    app = ServingApp(
        dag_id=args.arch,
        models={f"{args.arch}/generate": ServedModel(
            get_config(args.arch, reduced=True),
            prompt_len=args.prompt, gen_len=args.gen)},
        slack=args.slack)
    exp = Experiment(
        stack=args.stack,
        backend=args.backend,
        backend_kwargs=backend_kwargs,
        workload_factory="serving_apps",
        workload_kwargs=dict(apps=[app], duration=duration,
                             rps=args.rps, prewarm_per_fn=4),
        cluster=ClusterConfig(n_sgs=args.n_sgs, workers_per_sgs=2,
                              cores_per_worker=2),
        warmup=warmup, drain=10.0)
    if real_jax:
        n_compiles = "one executable per batch bucket" \
            if args.backend == "jax-batched" else "real XLA compile"
        print(f"[serve] calibrating {args.arch} ({n_compiles})...")
    r = simulate(exp)
    backend = r.sim.backend
    for name, spec in (getattr(backend, "fn_specs", None) or {}).items():
        print(f"  {name}: exec={spec.exec_time*1e3:.1f}ms "
              f"setup={spec.setup_time:.1f}s "
              f"SNE={spec.setup_time/spec.exec_time:.0f}x")
    lat = r.latency_percentiles
    print(f"  {r.name}: n={r.n_requests} done={r.n_completed} "
          f"p50={(lat['p50'] or 0)*1e3:.1f}ms "
          f"p99={(lat['p99'] or 0)*1e3:.1f}ms "
          f"deadlines_met={(r.deadline_met_frac or 0)*100:.2f}% "
          f"cold_starts={r.cold_start_count}")
    dp = "".join(f" {k}={v}" for k, v in sorted(r.data_plane.items()))
    print(f"  executions: {backend.counters().get('n_executions', 0)} "
          f"({r.backend} backend{dp})")
    bc = r.backend_counters
    if bc.get("n_batches"):
        print(f"  batches: {bc['n_batches']} "
              f"(mean occupancy "
              f"{bc['n_batched_invocations'] / bc['n_batches']:.2f}, "
              f"max {bc['max_batch_occupancy']}, "
              f"padding efficiency "
              f"{bc['n_batched_invocations'] / bc['n_batch_slots']:.2f})")
    if bc.get("n_decode_ticks"):
        print(f"  continuous: {bc['n_prefill_batches']} prefill batches, "
              f"{bc['n_decode_ticks']} decode ticks "
              f"(mean step occupancy "
              f"{bc['n_step_slots'] / bc['n_decode_ticks']:.2f}, "
              f"max {bc['max_batch_occupancy']})")


if __name__ == "__main__":
    main()
