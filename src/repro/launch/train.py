"""Training launcher: `python -m repro.launch.train --arch <id> [...]`.

On this CPU container it trains reduced variants end-to-end; on a real pod
the same entry point shards over the production mesh (--mesh single|multi).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, get_config
from ..models import init_params
from ..train import (DataConfig, Prefetcher, SyntheticLM, adamw_init,
                     checkpoint, make_train_step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true",
                    help="full config (needs a pod); default reduced")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    if cfg.frontend:
        print(f"note: {args.arch} uses a stub {cfg.frontend} frontend; "
              f"training feeds zero frame/patch embeddings")
    print(f"[train] {cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"schedule={cfg.lr_schedule} steps={args.steps}")

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, total_steps=args.steps,
                                      peak_lr=args.lr))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=max(args.seq, cfg.ssm_chunk or 1),
                                  batch_size=args.batch))
    it = Prefetcher(data.iterate())
    frontend = None
    if cfg.frontend:
        frontend = jnp.zeros((args.batch, cfg.n_frontend_tokens,
                              cfg.d_model), cfg.dtype())
    t0 = time.time()
    for step in range(args.steps):
        batch = jnp.asarray(next(it))
        if frontend is not None:
            params, opt, loss = step_fn(params, opt, batch, frontend)
        else:
            params, opt, loss = step_fn(params, opt, batch)
        if step % 20 == 0 or step == args.steps - 1:
            print(f"  step {step:4d} loss {float(loss):.4f} "
                  f"({time.time()-t0:.0f}s)")
        if args.ckpt and (step + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt, step + 1, params, opt)
    it.close()
    print("[train] done")


if __name__ == "__main__":
    main()
