"""Flash-decoding: single-token attention over a long KV cache (Pallas TPU).

TPU adaptation notes:
  * Decode attention is memory-bound (arithmetic intensity ~1 FLOP/byte), so
    the kernel's job is to stream the KV cache HBM->VMEM exactly once at full
    bandwidth while the tiny q tile stays resident.
  * All q-heads of one kv-head group are processed together: the (G, hd)
    query tile rides along for every K/V tile, turning a matrix-vector
    stream into a skinny matmul that still feeds the MXU.
  * The cache-length grid axis is innermost/sequential; the online-softmax
    state (m, l, acc) persists in VMEM scratch across it (the "split-K"
    reduction of GPU flash-decoding becomes a sequential VMEM carry on TPU —
    cross-core splitting happens at the shard_map level instead, via the
    sequence-sharded cache + logsumexp combine in the serving layer).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

f32 = jnp.float32
NEG_INF = -1e30


def _dec_kernel(vlen_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                *, block_k: int, L: int):
    ik = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid_len = vlen_ref[0]
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_k, 1), 0)
    live = (ik * block_k) < valid_len

    @pl.when(live)
    def _compute():
        valid = k_pos < jnp.minimum(valid_len, L)
        q = q_ref[...].astype(f32)                       # (G, hd)
        k = jnp.where(valid, k_ref[...].astype(f32), 0.0)  # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, bk)
        s = jnp.where(valid.reshape(1, -1), s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(-1, keepdims=True)
        v = jnp.where(valid, v_ref[...].astype(f32), 0.0)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[...] = (acc_scr[...] /
                      jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     valid_len: jnp.ndarray, *, block_k: int = 256,
                     interpret: bool = False) -> jnp.ndarray:
    """q: (B,Hq,hd); k/v: (B,L,Hkv,hd); valid_len: (B,) -> (B,Hq,hd).

    Scores are scaled by 1/sqrt(hd); cache uses prefix layout (slots
    [0, valid_len) hold keys)."""
    B, Hq, hd = q.shape
    L, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)

    bk = min(block_k, L)
    nk = pl.cdiv(L, bk)
    qt = (q * scale).reshape(B, Hkv, G, hd).reshape(B * Hkv, G, hd)
    kt = jnp.moveaxis(k, 2, 1).reshape(B * Hkv, L, hd)
    vt = jnp.moveaxis(v, 2, 1).reshape(B * Hkv, L, hd)
    vlen = jnp.repeat(valid_len.astype(jnp.int32), Hkv)    # (B*Hkv,)

    kernel = functools.partial(_dec_kernel, block_k=bk, L=L)
    out = pl.pallas_call(
        kernel,
        grid=(B * Hkv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda h, ik: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((None, G, hd), lambda h, ik: (h, 0, 0)),
            pl.BlockSpec((None, bk, hd), lambda h, ik: (h, ik, 0)),
            pl.BlockSpec((None, bk, hd), lambda h, ik: (h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((None, G, hd), lambda h, ik: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), f32),
            pltpu.VMEM((G, 1), f32),
            pltpu.VMEM((G, hd), f32),
        ],
        interpret=interpret,
    )(vlen, qt, kt, vt)
    return out.reshape(B, Hkv, G, hd).reshape(B, Hq, hd)
