"""Pallas TPU kernels for the data-plane hot spots.

The paper (Archipelago) is a control-plane contribution with no kernel of
its own; these kernels are the compute hot spots of the *workload it
schedules* (model serving): prefill flash attention, flash-decoding over KV
caches, and the Mamba2 SSD scan.

Each kernel has: <name>.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
an oracle in ref.py (pure jnp), and a dispatching wrapper in ops.py.
Validated in interpret mode on CPU; compiled path targets TPU v5e.
"""
from . import ops, ref
from .flash_attention import flash_attention
from .decode_attention import decode_attention
from .ssd_scan import ssd_scan

__all__ = ["ops", "ref", "flash_attention", "decode_attention", "ssd_scan"]
