"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

TPU adaptation notes (vs the Triton/CUDA SSD kernels of the Mamba2 release):
  * One grid program per (batch x head); the chunk axis is the innermost
    *sequential* grid dimension, so the (P, N) inter-chunk state lives in
    VMEM scratch and never round-trips HBM — the GPU implementation's
    separate "state-passing" kernel disappears into the sequential grid.
  * The intra-chunk quadratic term is a (Q,Q) matmul pair, MXU-friendly for
    Q = 64..128; the decay matrix is built in-register from a cumulative sum
    (VPU) rather than precomputed in HBM.
  * All decay math is f32; inputs stream in bf16.

Contract matches ``ref.ssd_scan_ref`` / the sequential oracle:
  x: (B,S,H,P)  dt: (B,S,H) (post-softplus)  A: (H,) negative
  Bm, Cm: (B,S,N)  ->  y: (B,S,H,P), final_state: (B,H,P,N)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

f32 = jnp.float32


def _ssd_kernel(x_ref, dt_ref, da_ref, b_ref, c_ref, s0_ref, y_ref, st_ref,
                state_scr, *, Q: int):
    ic = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ic == 0)
    def _init():
        # seed the inter-chunk carry from the caller's state (decode-time
        # prefill over an existing cache); zeros for a fresh sequence
        state_scr[...] = s0_ref[0].astype(f32)

    x = x_ref[0].astype(f32)           # (Q, P)
    dt = dt_ref[0].astype(f32)         # (Q, 1)
    dA = da_ref[0].astype(f32)         # (Q, 1)  = dt * A[h]  (<= 0)
    Bm = b_ref[0].astype(f32)          # (Q, N)
    Cm = c_ref[0].astype(f32)          # (Q, N)

    cum = jnp.cumsum(dA, axis=0)       # (Q, 1)
    total = cum[Q - 1]                 # (1,)
    xdt = x * dt                       # (Q, P)

    # intra-chunk: y_diag = (L .* (C B^T)) @ xdt, L = exp(segsum(dA))
    seg = cum - cum.T                  # (Q, Q): cum_i - cum_j
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(qi >= kj, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (Q,Q)
    y = jax.lax.dot(L * scores, xdt)   # (Q, P)

    # inter-chunk: y_off = (C .* exp(cum)) @ state_prev^T
    state_prev = state_scr[...]        # (P, N)
    decay_in = jnp.exp(cum)            # (Q, 1)
    y += jax.lax.dot_general(Cm * decay_in, state_prev,
                             (((1,), (1,)), ((), ())))  # (Q, P)
    y_ref[0, :, :] = y.astype(y_ref.dtype)

    # state update: state = state * exp(total) + xdt^T @ (B .* decay_out)
    decay_out = jnp.exp(total[None, :] - cum)           # (Q, 1)
    contrib = jax.lax.dot_general(xdt, Bm * decay_out,
                                  (((0,), (0,)), ((), ())))  # (P, N)
    state_scr[...] = state_prev * jnp.exp(total)[None, :] + contrib

    @pl.when(ic == nc - 1)
    def _emit_state():
        st_ref[0, :, :] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             Bm: jnp.ndarray, Cm: jnp.ndarray, *, chunk: int = 64,
             init_state: jnp.ndarray = None, interpret: bool = False):
    """See module docstring.  S must be a multiple of ``chunk`` (the ops.py
    wrapper pads with dt=0, which provably leaves the state untouched).
    ``init_state`` (B,H,P,N) seeds the recurrence (None = zeros)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, "pad S to a chunk multiple (see ops.ssd)"
    nc = S // chunk

    xt = jnp.moveaxis(x, 2, 1).reshape(B * H, S, P)
    dtt = jnp.moveaxis(dt, 2, 1).reshape(B * H, S, 1)
    dAt = dtt * A.reshape(1, H, 1, 1).repeat(B, 0).reshape(B * H, 1, 1)
    bt = Bm                                             # (B, S, N)
    ct = Cm
    s0 = (jnp.zeros((B * H, P, N), f32) if init_state is None
          else init_state.astype(f32).reshape(B * H, P, N))

    kernel = functools.partial(_ssd_kernel, Q=chunk)
    y, st = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda h, c, H=H: (h // H, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda h, c, H=H: (h // H, c, 0)),
            pl.BlockSpec((1, P, N), lambda h, c: (h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, P, N), lambda h, c: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B * H, P, N), f32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), f32)],
        interpret=interpret,
    )(xt, dtt, dAt, bt, ct, s0)
    y = jnp.moveaxis(y.reshape(B, H, S, P), 1, 2)
    return y, st.reshape(B, H, P, N)
