"""Public kernel entry points with backend dispatch.

``backend``:
  "xla"              pure-jnp reference path (default on CPU; what the
                     dry-run lowers)
  "pallas"           compiled Pallas TPU kernels (TPU targets)
  "pallas_interpret" Pallas kernels executed in interpret mode (CPU
                     validation; used by the kernel test suite)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention as _dec_pallas
from .flash_attention import flash_attention as _fa_pallas
from .ssd_scan import ssd_scan as _ssd_pallas

_BACKEND = "xla"


def set_backend(backend: str) -> None:
    global _BACKEND
    if backend not in ("xla", "pallas", "pallas_interpret"):
        raise ValueError(backend)
    _BACKEND = backend


def get_backend() -> str:
    return _BACKEND


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              backend: Optional[str] = None) -> jnp.ndarray:
    b = backend or _BACKEND
    if b == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _fa_pallas(q, k, v, causal=causal, window=window,
                      interpret=(b == "pallas_interpret"))


def decode_attention(q, k, v, valid_len, *,
                     backend: Optional[str] = None) -> jnp.ndarray:
    b = backend or _BACKEND
    if b == "xla":
        return ref.decode_attention_ref(q, k, v, valid_len)
    return _dec_pallas(q, k, v, valid_len,
                       interpret=(b == "pallas_interpret"))


def ssd(x, dt, A, Bm, Cm, *, chunk: int = 64,
        backend: Optional[str] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b = backend or _BACKEND
    if b == "xla":
        return ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk)
    S = x.shape[1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y, st = _ssd_pallas(x, dt, A, Bm, Cm, chunk=chunk,
                        interpret=(b == "pallas_interpret"))
    return y[:, :S], st
