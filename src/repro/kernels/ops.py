"""Public kernel entry points with backend dispatch.

``backend`` (a :class:`KernelType` or its string value):
  "xla"              pure-jnp reference path (default on CPU; what the
                     dry-run lowers)
  "pallas"           compiled Pallas TPU kernels (TPU targets)
  "pallas_interpret" Pallas kernels executed in interpret mode (CPU
                     validation; used by the kernel test suite and CI)

The model stack (``repro.models``) threads ``ModelConfig.kernels`` into
these entry points, so the choice is a sweepable ``Experiment``
``backend_kwargs`` axis (``kernels="pallas"`` on the jax backends) — see
``docs/KERNELS.md`` for the full dispatch table and the recipe for
registering a new kernel.
"""
from __future__ import annotations

from enum import Enum
from typing import Optional, Tuple, Union

import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention as _dec_pallas
from .flash_attention import flash_attention as _fa_pallas
from .ssd_scan import ssd_scan as _ssd_pallas


class KernelType(Enum):
    """Which implementation services a hot-spot call (mamba-jax idiom)."""

    XLA = "xla"
    PALLAS = "pallas"
    PALLAS_INTERPRET = "pallas_interpret"


def normalize(backend: Union[str, KernelType, None]) -> KernelType:
    """Coerce a user-facing backend choice (string, enum, or None =
    process default) to a :class:`KernelType`, validating the name."""
    if backend is None:
        return KernelType(_BACKEND)
    if isinstance(backend, KernelType):
        return backend
    try:
        return KernelType(backend)
    except ValueError:
        raise ValueError(
            f"unknown kernel backend {backend!r}; choose from "
            f"{[k.value for k in KernelType]}") from None


_BACKEND = "xla"


def set_backend(backend: Union[str, KernelType]) -> None:
    global _BACKEND
    _BACKEND = normalize(backend).value


def get_backend() -> str:
    return _BACKEND


# Dispatch table: hot spot -> {KernelType: implementation}.  The decode-side
# SSM recurrence (``ssd_step``) deliberately maps every backend to the jnp
# reference: at S=1 the update is a handful of memory-bound element-wise ops
# with nothing for a Pallas kernel to fuse beyond what XLA already does.
KERNEL_TABLE = {
    "attention": {
        KernelType.XLA: "ref.flash_attention_ref",
        KernelType.PALLAS: "flash_attention (compiled)",
        KernelType.PALLAS_INTERPRET: "flash_attention (interpret)",
    },
    "decode_attention": {
        KernelType.XLA: "ref.decode_attention_ref",
        KernelType.PALLAS: "decode_attention (compiled)",
        KernelType.PALLAS_INTERPRET: "decode_attention (interpret)",
    },
    "ssd": {
        KernelType.XLA: "ref.ssd_scan_ref",
        KernelType.PALLAS: "ssd_scan (compiled)",
        KernelType.PALLAS_INTERPRET: "ssd_scan (interpret)",
    },
    "ssd_step": {
        KernelType.XLA: "models.layers.ssd_decode_step",
        KernelType.PALLAS: "models.layers.ssd_decode_step (jnp; see above)",
        KernelType.PALLAS_INTERPRET: "models.layers.ssd_decode_step (jnp)",
    },
}


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              backend: Union[str, KernelType, None] = None) -> jnp.ndarray:
    b = normalize(backend)
    if b is KernelType.XLA:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _fa_pallas(q, k, v, causal=causal, window=window,
                      interpret=(b is KernelType.PALLAS_INTERPRET))


def decode_attention(q, k, v, valid_len, *,
                     backend: Union[str, KernelType, None] = None
                     ) -> jnp.ndarray:
    b = normalize(backend)
    if b is KernelType.XLA:
        return ref.decode_attention_ref(q, k, v, valid_len)
    return _dec_pallas(q, k, v, valid_len,
                       interpret=(b is KernelType.PALLAS_INTERPRET))


def ssd(x, dt, A, Bm, Cm, *, chunk: int = 64,
        init_state: Optional[jnp.ndarray] = None,
        backend: Union[str, KernelType, None] = None
        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b = normalize(backend)
    if b is KernelType.XLA:
        return ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk,
                                init_state=init_state)
    S = x.shape[1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y, st = _ssd_pallas(x, dt, A, Bm, Cm, chunk=chunk, init_state=init_state,
                        interpret=(b is KernelType.PALLAS_INTERPRET))
    return y[:, :S], st


def ssd_step(state, x, dt, A, Bm, Cm, *,
             backend: Union[str, KernelType, None] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token SSM recurrence — every backend routes to the jnp
    reference (see KERNEL_TABLE); the entry point exists so call sites
    dispatch uniformly and the choice is recorded in one place."""
    normalize(backend)          # validate even though the impl is shared
    from ..models.layers import ssd_decode_step
    return ssd_decode_step(state, x, dt, A, Bm, Cm)
