"""Flash attention (prefill) as a Pallas TPU kernel.

TPU adaptation notes (vs the CUDA flash-attention algorithm):
  * Tiling targets VMEM (~16MB/core), not shared memory: we stream K/V tiles
    HBM->VMEM via BlockSpec index maps while the (block_q, hd) query tile and
    the f32 accumulator stay resident in VMEM scratch across the k-grid.
  * Online softmax state (m, l) lives in SMEM-sized VMEM scratch; matmul
    tiles are chosen as multiples of the 128x128 MXU face (block_q = block_k
    = 128 by default; hd is padded by the caller if not 128-aligned).
  * The k-grid is the innermost sequential dimension, so the accumulator
    carries across k-steps without HBM round-trips (grid iteration on TPU is
    sequential, unlike CUDA thread blocks).
  * GQA is handled by mapping each q-head to its kv-head in the index maps —
    no K/V duplication in HBM.

Causality/window handled by masking within tiles; fully-masked tiles are
skipped via ``pl.when`` on the tile indices (no wasted MXU work).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

f32 = jnp.float32
NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               block_q: int, block_k: int, sq: int, sk: int,
               causal: bool, window: int, scale: float):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # positions: queries are aligned to the END of the kv sequence
    q_pos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + (sk - sq)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # tile-level skip: is any (q,k) pair in this tile live?
    first_q = iq * block_q + (sk - sq)
    last_q = first_q + block_q - 1
    first_k = ik * block_k
    tile_live = True
    if causal:
        tile_live = first_k <= last_q
    if window > 0:
        tile_live = jnp.logical_and(
            tile_live, (first_q - (first_k + block_k - 1)) < window)

    @pl.when(tile_live)
    def _compute():
        # sanitize K/V padding rows: grid padding may contain garbage/NaN and
        # 0 * NaN = NaN would poison the p @ v accumulation
        valid_k = (ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_k, 1), 0)) < sk
        q = q_ref[...].astype(f32) * scale              # (bq, hd)
        k = jnp.where(valid_k, k_ref[...].astype(f32), 0.0)   # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        mask &= k_pos < sk
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                             # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                          # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                 # (bq, 1)
        l_scr[...] = l_scr[...] * alpha + p.sum(-1, keepdims=True)
        v = jnp.where(valid_k, v_ref[...].astype(f32), 0.0)   # (bk, hd)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B,Sq,Hq,hd), k/v: (B,Sk,Hkv,hd) -> (B,Sq,Hq,hd).

    Queries are aligned to the end of the K sequence (decode-suffix
    convention, matching ``ref.flash_attention_ref``)."""
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)

    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    nq = pl.cdiv(Sq, bq)
    nk = pl.cdiv(Sk, bk)

    # layout: fold heads into the grid; each program handles one (b*h) pair
    qt = jnp.moveaxis(q, 2, 1).reshape(B * Hq, Sq, hd)
    kt = jnp.moveaxis(k, 2, 1).reshape(B * Hkv, Sk, hd)
    vt = jnp.moveaxis(v, 2, 1).reshape(B * Hkv, Sk, hd)

    kernel = functools.partial(_fa_kernel, block_q=bq, block_k=bk,
                               sq=Sq, sk=Sk, causal=causal, window=window,
                               scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((None, bq, hd), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((None, bk, hd), lambda h, iq, ik,
                         G=G: (h // G, ik, 0)),
            pl.BlockSpec((None, bk, hd), lambda h, iq, ik,
                         G=G: (h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, hd), lambda h, iq, ik: (h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, hd), q.dtype),
        # online-softmax state persists in VMEM across the sequential k-grid
        scratch_shapes=[
            pltpu.VMEM((bq, 1), f32),
            pltpu.VMEM((bq, 1), f32),
            pltpu.VMEM((bq, hd), f32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out.reshape(B, Hq, Sq, hd), 1, 2)
