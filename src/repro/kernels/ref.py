"""Pure-jnp oracles for every kernel (the correctness ground truth).

These mirror the contracts of the Pallas kernels exactly; tests sweep shapes
and dtypes asserting allclose between kernel (interpret=True) and oracle.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

f32 = jnp.float32


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True, window: int = 0
                        ) -> jnp.ndarray:
    """q: (B,Sq,Hq,hd), k/v: (B,Sk,Hkv,hd) -> (B,Sq,Hq,hd).  GQA-aware."""
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd).astype(f32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                        k.astype(f32)) / math.sqrt(hd)
    qp = jnp.arange(Sq) + (Sk - Sq)     # align ends (decode-style offset)
    kp = jnp.arange(Sk)
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= qp[:, None] >= kp[None, :]
    if window > 0:
        m &= (qp[:, None] - kp[None, :]) < window
    scores = jnp.where(m[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(f32))
    return out.reshape(B, Sq, Hq, hd).astype(q.dtype)


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         valid_len: jnp.ndarray) -> jnp.ndarray:
    """One-token attention over a KV cache.

    q: (B,Hq,hd); k/v: (B,L,Hkv,hd); valid_len: (B,) number of valid cache
    slots (prefix layout).  Returns (B,Hq,hd)."""
    B, Hq, hd = q.shape
    L, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd).astype(f32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(f32)) / math.sqrt(hd)
    mask = jnp.arange(L)[None, :] < valid_len[:, None]      # (B,L)
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(f32))
    return out.reshape(B, Hq, hd).astype(q.dtype)


def ssd_scan_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                 Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                 init_state: Optional[jnp.ndarray] = None,
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD (Mamba2) — delegates to the model-layer reference.
    x: (B,S,H,P), dt: (B,S,H), A: (H,), Bm/Cm: (B,S,N)."""
    # lazy: models.layers imports kernels.ops (dispatch), which imports this
    # module — a top-level import here would close the cycle
    from ..models.layers import ssd_chunked as _ssd_chunked_ref
    return _ssd_chunked_ref(x, dt, A, Bm, Cm, chunk, init_state=init_state)


def ssd_scan_sequential_ref(x, dt, A, Bm, Cm,
                            init_state=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fully sequential (token-by-token) SSM recurrence — the *independent*
    oracle that validates the chunked math itself."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    s0 = (jnp.zeros((B, H, P, N), f32) if init_state is None
          else init_state.astype(f32))

    def step(state, inp):
        xt, dtt, bt, ct = inp
        dA = jnp.exp(dtt.astype(f32) * A[None, :])           # (B,H)
        dBx = jnp.einsum("bn,bhp,bh->bhpn", bt.astype(f32), xt.astype(f32),
                         dtt.astype(f32))
        state = state * dA[..., None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", state, ct.astype(f32))
        return state, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final
