"""Reproduce the paper's headline macrobenchmark (Fig. 7) at full testbed
scale via the declarative experiment API: 8 SGSs x 8 workers x 20 cores,
Workloads 1 & 2, Archipelago vs the centralized-FIFO-reactive baseline.

    python examples/paper_workload.py [--duration 25]
(works after `pip install -e .` or with PYTHONPATH=src)
"""
import argparse
import os
import sys
from dataclasses import replace

try:
    import repro  # noqa: F401
except ImportError:  # no editable install: fall back to the checkout layout
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "src"))

from repro.core import ClusterConfig
from repro.sim import Experiment, simulate

WARMUP = 5.0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=25.0)
    args = ap.parse_args()
    for name, factory, kw in [
            ("Workload1", "paper_workload_1",
             dict(duration=args.duration, scale=1.3, dags_per_class=2)),
            ("Workload2", "paper_workload_2",
             dict(duration=args.duration, scale=1.0, dags_per_class=2))]:
        base = Experiment(workload_factory=factory, workload_kwargs=kw,
                          cluster=ClusterConfig(), warmup=WARMUP)
        ra = simulate(replace(base, stack="archipelago"))
        rb = simulate(replace(base, stack="fifo"))
        print(f"== {name} ==")
        for tag, r in [("archipelago", ra), ("baseline   ", rb)]:
            lp = r.latency_percentiles
            print(f"  {tag}: n={r.n_requests} done={r.n_completed} "
                  f"p50={(lp['p50'] or 0)*1e3:.1f}ms "
                  f"p99={(lp['p99'] or 0)*1e3:.1f}ms "
                  f"p99.9={(lp['p99.9'] or 0)*1e3:.1f}ms "
                  f"deadlines_met={(r.deadline_met_frac or 0)*100:.2f}% "
                  f"cold_starts={r.cold_start_count}")
        ratio = ((rb.latency_percentiles["p99.9"] or 0)
                 / max(ra.latency_percentiles["p99.9"] or 0, 1e-9))
        print(f"  tail (99.9%) reduction: {ratio:.1f}x   "
              f"deadlines: {(ra.deadline_met_frac or 0)*100:.2f}% vs "
              f"{(rb.deadline_met_frac or 0)*100:.2f}%")


if __name__ == "__main__":
    main()
