"""Reproduce the paper's headline macrobenchmark (Fig. 7) at full testbed
scale: 8 SGSs x 8 workers x 20 cores, Workloads 1 & 2, Archipelago vs the
centralized-FIFO-reactive baseline.

    PYTHONPATH=src python examples/paper_workload.py [--duration 25]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core import ClusterConfig
from repro.sim import (paper_workload_1, paper_workload_2, run_archipelago,
                       run_baseline, summarize)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=25.0)
    args = ap.parse_args()
    cc = ClusterConfig()
    for name, spec in [
            ("Workload1", paper_workload_1(duration=args.duration, scale=1.3,
                                           dags_per_class=2)),
            ("Workload2", paper_workload_2(duration=args.duration, scale=1.0,
                                           dags_per_class=2))]:
        ra = run_archipelago(spec, cluster=cc)
        rb = run_baseline(spec, cluster=cc)
        ma = ra.metrics.after_warmup(5.0)
        mb = rb.metrics.after_warmup(5.0)
        print(f"== {name} ==")
        print(" ", summarize("archipelago", ma))
        print(" ", summarize("baseline   ", mb))
        ratio = mb.latency_pct(99.9) / max(ma.latency_pct(99.9), 1e-9)
        print(f"  tail (99.9%) reduction: {ratio:.1f}x   "
              f"deadlines: {ma.deadline_met_frac()*100:.2f}% vs "
              f"{mb.deadline_met_frac()*100:.2f}%")


if __name__ == "__main__":
    main()
