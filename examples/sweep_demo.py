"""Experiment-API demo + CI smoke: one `simulate` call per registered stack
and a tiny seed x scale `run_sweep` grid on a 4-worker cluster.

    python examples/sweep_demo.py [--quick]
(works after `pip install -e .` or with PYTHONPATH=src; --quick shrinks the
workload to ~2 simulated seconds for CI)
"""
import argparse
import json
import os
import sys
from dataclasses import replace

try:
    import repro  # noqa: F401
except ImportError:  # no editable install: fall back to the checkout layout
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "src"))

from repro.core import ClusterConfig, available_stacks
from repro.sim import Experiment, ExperimentResult, run_sweep, simulate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    dur = 2.0 if args.quick else 8.0

    base = Experiment(
        workload_factory="paper_workload_2",
        workload_kwargs=dict(duration=dur, scale=0.02, dags_per_class=1),
        cluster=ClusterConfig(n_sgs=2, workers_per_sgs=2,
                              cores_per_worker=4),
        warmup=min(1.0, dur / 4), drain=3.0)

    print(f"registered stacks: {', '.join(available_stacks())}")
    for stack in ("archipelago", "fifo", "sparrow", "pull"):
        r = simulate(replace(base, stack=stack))
        lp = r.latency_percentiles
        print(f"  {stack:12s} n={r.n_requests:4d} done={r.n_completed:4d} "
              f"p99={(lp['p99'] or 0)*1e3:7.1f}ms "
              f"deadlines={(r.deadline_met_frac or 0)*100:6.2f}% "
              f"cold={r.cold_start_count}")
        assert r.n_completed > 0, f"stack {stack} completed nothing"
        # JSON round-trip must be lossless
        d = r.to_dict()
        assert ExperimentResult.from_dict(
            json.loads(json.dumps(d))).to_dict() == d

    sweep = run_sweep(base, {"stack": ["archipelago", "fifo"],
                             "seed": [0, 1],
                             "workload_kwargs.scale": [0.02, 0.04]})
    print(f"sweep: {len(sweep)} cells")
    for row in sweep:
        cell, res = row["cell"], row["result"]
        print(f"  {cell}  -> done={res['n_completed']} "
              f"deadlines={res['deadline_met_frac']}")
    assert len(sweep) == 8
    print("OK")


if __name__ == "__main__":
    main()
