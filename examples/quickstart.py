"""Quickstart: serve a small model through the full Archipelago stack
(LBS -> SGS -> workers) via the declarative experiment API, with REAL
jitted JAX execution beneath the sandbox abstraction (backend="jax").

    python examples/quickstart.py
(works after `pip install -e .` or with PYTHONPATH=src)
"""
import os
import sys

try:
    import repro  # noqa: F401
except ImportError:  # no editable install: fall back to the checkout layout
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "src"))

from repro.configs import get_config
from repro.core import ClusterConfig
from repro.serving import ServedModel, ServingApp
from repro.sim import Experiment, simulate


def main() -> None:
    # one tenant app: a chat-style model (reduced minicpm-2b family on CPU)
    app = ServingApp(
        dag_id="chat",
        models={"chat/generate": ServedModel(
            get_config("minicpm-2b", reduced=True),
            prompt_len=32, gen_len=4, batch=2)},
        slack=0.5,
    )
    print("simulating with backend='jax' (calibration compiles the model: "
          "this is the real sandbox setup cost Archipelago hides)...")
    # the serving workload pre-warms sandboxes before traffic (the "DAG
    # upload" step, §3); warmup=5s reports the steady-state window so the
    # cold transient doesn't drown the percentiles
    r = simulate(Experiment(
        stack="archipelago",
        backend="jax",
        workload_factory="serving_apps",
        workload_kwargs=dict(apps=[app], duration=8.0, rps=10.0,
                             prewarm_per_fn=4),
        cluster=ClusterConfig(n_sgs=2, workers_per_sgs=2,
                              cores_per_worker=2),
        warmup=5.0, drain=10.0))
    for name, spec in r.sim.backend.fn_specs.items():
        print(f"  calibrated {name}: exec={spec.exec_time*1e3:.1f}ms "
              f"setup={spec.setup_time:.2f}s "
              f"(SNE={spec.setup_time/spec.exec_time:.0f}x -- the paper's "
              f"T3 regime)")
    print(f"  steady state: n={r.n_requests} done={r.n_completed} "
          f"p50={(r.latency_percentiles['p50'] or 0)*1e3:.1f}ms "
          f"p99={(r.latency_percentiles['p99'] or 0)*1e3:.1f}ms "
          f"deadlines_met={(r.deadline_met_frac or 0)*100:.1f}% "
          f"cold_starts={r.cold_start_count}")
    print(f"real model executions: "
          f"{r.sim.backend.counters()['n_executions']}")
    assert r.n_completed > 0
    assert r.deadline_met_frac > 0.5, "most requests should meet deadline"
    print("OK")


if __name__ == "__main__":
    main()
