"""Quickstart: serve a small model with batched requests through the full
Archipelago stack (LBS -> SGS -> workers), with REAL jitted JAX execution
beneath the sandbox abstraction.

    python examples/quickstart.py
(works after `pip install -e .` or with PYTHONPATH=src)
"""
import os
import random
import sys

try:
    import repro  # noqa: F401
except ImportError:  # no editable install: fall back to the checkout layout
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "src"))

from repro.configs import get_config
from repro.core import ClusterConfig
from repro.serving import ServedModel, ServingApp, ServingStack
from repro.sim.metrics import summarize


def main() -> None:
    # one tenant app: a chat-style model (reduced minicpm-2b family on CPU)
    app = ServingApp(
        dag_id="chat",
        models={"chat/generate": ServedModel(
            get_config("minicpm-2b", reduced=True),
            prompt_len=32, gen_len=4, batch=2)},
        slack=0.5,
    )
    print("building stack (compiles the model: this is the real sandbox "
          "setup cost Archipelago hides)...")
    stack = ServingStack([app], cluster=ClusterConfig(
        n_sgs=2, workers_per_sgs=2, cores_per_worker=2))
    for name, spec in stack.fn_specs.items():
        print(f"  calibrated {name}: exec={spec.exec_time*1e3:.1f}ms "
              f"setup={spec.setup_time:.2f}s "
              f"(SNE={spec.setup_time/spec.exec_time:.0f}x -- the paper's "
              f"T3 regime)")

    # pre-warm sandboxes before traffic (the "DAG upload" step, §3); this
    # is simulated time — it costs no wall clock
    t0 = stack.prewarm("chat", n_per_fn=4)
    rng = random.Random(0)
    t = t0
    n = 60
    for _ in range(n):
        t += rng.expovariate(10.0)     # ~10 requests/s
        stack.submit_at(t, "chat")
    print(f"submitted {n} requests over {t - t0:.1f}s; running...")
    m = stack.run(until=t + 10.0)
    print(summarize("quickstart", m))
    print(f"real model executions: {stack.executor.n_executions}")
    assert m.deadline_met_frac() > 0.5, "most requests should meet deadline"
    print("OK")


if __name__ == "__main__":
    main()
