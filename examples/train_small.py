"""End-to-end training driver: a ~100M-parameter MiniCPM-family model
trained for a few hundred steps on the synthetic Markov-Zipf pipeline with
the WSD schedule, gradient clipping, and checkpointing.

    python examples/train_small.py [--steps 300]
(works after `pip install -e .` or with PYTHONPATH=src)
"""
import argparse
import os
import sys
import time

try:
    import repro  # noqa: F401
except ImportError:  # no editable install: fall back to the checkout layout
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_params
from repro.train import (DataConfig, Prefetcher, SyntheticLM, adamw_init,
                         checkpoint, make_train_step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    # ~100M params: minicpm family scaled (d=768, 10 layers, 32k vocab)
    cfg = get_config("minicpm-2b").with_(
        n_layers=10, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
        d_ff=2048, vocab_size=32000, param_dtype="float32",
        compute_dtype="float32")
    n = cfg.param_count()
    print(f"model: {cfg.name}-small  params={n/1e6:.1f}M  "
          f"schedule={cfg.lr_schedule}")

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, total_steps=args.steps,
                                      peak_lr=1e-3))

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq, batch_size=args.batch))
    it = Prefetcher(data.iterate())

    t0 = time.time()
    first = last = None
    for step in range(args.steps):
        batch = jnp.asarray(next(it))
        params, opt, loss = step_fn(params, opt, batch)
        if step == 0:
            first = float(loss)
        if step % 25 == 0 or step == args.steps - 1:
            last = float(loss)
            tps = args.batch * args.seq * (step + 1) / (time.time() - t0)
            print(f"step {step:4d}  loss {last:7.4f}  tok/s {tps:,.0f}")
    it.close()

    checkpoint.save(args.ckpt, args.steps, params, opt)
    p2, o2 = checkpoint.restore(args.ckpt, args.steps, params, opt)
    assert all((a == b).all() for a, b in
               zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    print(f"checkpoint round-trip OK at {args.ckpt}")
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    assert last < first, "training must reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
