"""Multi-tenant serving: four apps spanning architecture families (dense,
SSM, MoE, VLM pipeline) share one cluster under Archipelago; a two-stage
vision DAG exercises DAG-aware scheduling.  Real JAX execution via the
``jax`` backend — the whole run is one declarative ``Experiment`` through
the same ``simulate`` pipeline as the paper-figure simulations.

    python examples/multitenant_serving.py
(works after `pip install -e .` or with PYTHONPATH=src)
"""
import os
import sys

try:
    import repro  # noqa: F401
except ImportError:  # no editable install: fall back to the checkout layout
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "src"))

from repro.core import ClusterConfig
from repro.serving import multitenant_apps
from repro.sim import Experiment, simulate


def main() -> None:
    apps = multitenant_apps()
    print("calibrating 5 models (real XLA compiles)...")
    r = simulate(Experiment(
        stack="archipelago",
        backend="jax",
        workload_factory="serving_apps",
        workload_kwargs=dict(apps=apps, duration=10.0, rps=3.0,
                             prewarm_per_fn=3),
        cluster=ClusterConfig(n_sgs=3, workers_per_sgs=2,
                              cores_per_worker=2),
        # report past the pre-warm transient (setups measure ~2-3s): the old
        # hand-rolled loop started traffic only after every sandbox was warm
        warmup=4.0, drain=15.0))
    for name, spec in sorted(r.sim.backend.fn_specs.items()):
        print(f"  {name}: exec={spec.exec_time*1e3:.1f}ms "
              f"setup={spec.setup_time:.1f}s")
    for dag_id, cs in sorted(r.per_class.items()):
        print(f"{dag_id}: n={cs.n_requests} done={cs.n_completed} "
              f"p50={(cs.p50 or 0)*1e3:.1f}ms p99={(cs.p99 or 0)*1e3:.1f}ms "
              f"deadlines_met={(cs.deadline_met_frac or 0)*100:.2f}% "
              f"cold_starts={cs.cold_starts}")
    print(f"real executions: {r.sim.backend.counters()['n_executions']}; "
          f"SGSs used: {[s for s in r.sim.lbs.sgss]}")
    assert r.n_completed == r.n_requests
    print("OK")


if __name__ == "__main__":
    main()
