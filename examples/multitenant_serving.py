"""Multi-tenant serving: four apps spanning architecture families (dense,
SSM, MoE, VLM pipeline) share one cluster under Archipelago; a two-stage
vision DAG exercises DAG-aware scheduling.  Real JAX execution.

    python examples/multitenant_serving.py
(works after `pip install -e .` or with PYTHONPATH=src)
"""
import os
import random
import sys

try:
    import repro  # noqa: F401
except ImportError:  # no editable install: fall back to the checkout layout
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "src"))

from repro.configs import get_config
from repro.core import ClusterConfig
from repro.serving import ServedModel, ServingApp, ServingStack
from repro.sim.metrics import summarize


def main() -> None:
    mk = lambda a, **kw: ServedModel(get_config(a, reduced=True), **kw)
    apps = [
        ServingApp("chat", {"chat/gen": mk("minicpm-2b", prompt_len=32,
                                           gen_len=3)}, slack=0.8),
        ServingApp("complete", {"ssm/gen": mk("mamba2-370m", prompt_len=32,
                                              gen_len=2)}, slack=1.2),
        ServingApp("moe", {"moe/gen": mk("mixtral-8x22b", prompt_len=16,
                                         gen_len=2)}, slack=1.2),
        # two-stage pipeline: vision encode (stub embeds) -> caption decode
        ServingApp("caption",
                   {"vlm/embed": mk("phi-3-vision-4.2b", prompt_len=16,
                                    gen_len=1),
                    "vlm/decode": mk("phi3-mini-3.8b", prompt_len=16,
                                     gen_len=2)},
                   edges=(("vlm/embed", "vlm/decode"),), slack=1.5),
    ]
    print("calibrating 5 models (real XLA compiles)...")
    stack = ServingStack(apps, cluster=ClusterConfig(
        n_sgs=3, workers_per_sgs=2, cores_per_worker=2))
    for name, spec in stack.fn_specs.items():
        print(f"  {name}: exec={spec.exec_time*1e3:.1f}ms "
              f"setup={spec.setup_time:.1f}s")

    rng = random.Random(1)
    t = max(stack.prewarm(d, n_per_fn=3)
            for d in ["chat", "complete", "moe", "caption"])
    for _ in range(120):
        t += rng.expovariate(12.0)
        stack.submit_at(t, rng.choice(["chat", "complete", "moe", "caption"]))
    m = stack.run(until=t + 15.0)
    for dag_id, mm in sorted(m.by_class().items()):
        print(summarize(dag_id, mm))
    print(f"real executions: {stack.executor.n_executions}; "
          f"SGSs used: {[s for s in stack.lbs.sgss]}")
    assert len(m.completed) == len(m.requests)
    print("OK")


if __name__ == "__main__":
    main()
