"""Fig. 10: deadline-aware per-DAG scale-out — a 50 ms-slack DAG scales to
more SGSs than a 200 ms-slack DAG under identical arrivals."""
from __future__ import annotations

from repro.core import ClusterConfig
from repro.core.types import DagSpec, FunctionSpec
from repro.sim import Experiment, Sinusoidal, WorkloadSpec, simulate

from .common import emit, record_experiment


def run(duration: float = 20.0) -> None:
    mk = lambda name, slack: DagSpec(
        name, (FunctionSpec(f"{name}/f", 0.1, setup_time=0.25),), (),
        deadline=0.1 + slack)
    tight, loose = mk("tight", 0.05), mk("loose", 0.20)
    proc = lambda: Sinusoidal(110.0, 60.0, 10.0)
    spec = WorkloadSpec([(tight, proc()), (loose, proc())], duration)
    res = simulate(Experiment(
        workload=spec, name="fig10",
        cluster=ClusterConfig(n_sgs=8, workers_per_sgs=3,
                              cores_per_worker=6)))
    record_experiment("fig10", res)
    lbs = res.sim.lbs
    peak_t = max((n for _, d, n in lbs.scale_events if d == "tight"),
                 default=1)
    peak_l = max((n for _, d, n in lbs.scale_events if d == "loose"),
                 default=1)
    emit("fig10_tight_slack_peak_sgs", 0.0, str(peak_t))
    emit("fig10_loose_slack_peak_sgs", 0.0, str(peak_l))
    emit("fig10_deadline_aware", 0.0,
         f"tight({peak_t}) >= loose({peak_l}): {peak_t >= peak_l}")
