"""Shared helpers for paper-figure benchmarks."""
from __future__ import annotations

import time
from typing import Dict, List

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    line = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(line)
    print(line, flush=True)


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
