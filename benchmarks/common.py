"""Shared helpers for paper-figure benchmarks."""
from __future__ import annotations

import os
import sys
import time
from typing import Dict, List

# Allow `python -m benchmarks.run` to work from a checkout without
# PYTHONPATH=src or `pip install -e .` (both of which also work).
try:
    import repro  # noqa: F401
except ImportError:                                     # pragma: no cover
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ROWS: List[str] = []                    # legacy CSV lines (for eyeballs)
RECORDS: List[Dict[str, object]] = []   # structured row per emitted metric
EXPERIMENTS: List[Dict[str, object]] = []  # full ExperimentResult rows


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    line = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(line)
    RECORDS.append({"name": name, "us_per_call": round(float(us_per_call), 1),
                    "derived": derived})
    print(line, flush=True)


def record_experiment(bench: str, result) -> None:
    """Attach a full ``ExperimentResult`` to the ``BENCH_figs.json``
    artifact (``result`` may also be a pre-built dict)."""
    d = result if isinstance(result, dict) else result.to_dict()
    d = dict(d)
    d["bench"] = bench
    EXPERIMENTS.append(d)


def reset() -> None:
    ROWS.clear()
    RECORDS.clear()
    EXPERIMENTS.clear()


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
