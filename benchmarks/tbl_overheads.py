"""§7.4 system overheads: wall-clock microbenchmarks of OUR implementation's
control-plane decisions (paper's Go prototype: LBS route ~190us median, SGS
schedule ~241us, scale-out ~128us, estimation ~879us)."""
from __future__ import annotations

import time

from repro.core import (ClusterConfig, DemandEstimator, Request, SGSConfig)
from repro.core.cluster import build_cluster
from repro.core.types import DagSpec, FunctionSpec
from repro.sim.engine import SimEnv
from repro.sim.metrics import percentile

from .common import emit


def run(n: int = 2000) -> None:
    env = SimEnv()
    cc = ClusterConfig(n_sgs=8, workers_per_sgs=8, cores_per_worker=20)
    lbs = build_cluster(env, cc)
    dags = [DagSpec(f"d{i}",
                    (FunctionSpec(f"d{i}/f", 0.1, setup_time=0.25),), (),
                    deadline=0.3) for i in range(20)]

    # LBS routing decision cost (lottery + state lookup)
    lat = []
    for i in range(n):
        req = Request(dag=dags[i % len(dags)], arrival_time=env.now())
        t0 = time.perf_counter()
        sgs = lbs.select(req, env.now())
        lat.append(time.perf_counter() - t0)
        sgs.submit_request(req)
        env.run_until(env.now() + 0.001)
    emit("tbl_lbs_route_p50", percentile(lat, 50) * 1e6,
         "paper Go prototype: 190us")
    emit("tbl_lbs_route_p99", percentile(lat, 99) * 1e6, "paper: 212us")

    # SGS scheduling decision cost (SRSF pick + worker choice)
    sgs = next(iter(lbs.sgss.values()))
    lat = []
    for i in range(n):
        req = Request(dag=dags[i % len(dags)], arrival_time=env.now())
        t0 = time.perf_counter()
        sgs.submit_request(req)            # enqueue + dispatch decision
        lat.append(time.perf_counter() - t0)
        env.run_until(env.now() + 0.001)
    emit("tbl_sgs_schedule_p50", percentile(lat, 50) * 1e6,
         "paper: 241us")
    emit("tbl_sgs_schedule_p99", percentile(lat, 99) * 1e6, "paper: 342us")

    # estimation decision cost
    est = DemandEstimator()
    for i in range(500):
        est.record_arrival("f", i * 0.002)
    lat = []
    for i in range(n):
        t0 = time.perf_counter()
        est.demand("f", exec_time=0.1, now=1.0 + i * 1e-4)
        lat.append(time.perf_counter() - t0)
    emit("tbl_estimation_p50", percentile(lat, 50) * 1e6, "paper: 879us")
    emit("tbl_estimation_p99", percentile(lat, 99) * 1e6, "paper: 1352us")

    # scale-out decision cost
    lat = []
    for i in range(200):
        st = lbs._state(dags[i % len(dags)], env.now())
        t0 = time.perf_counter()
        lbs._scale_out(st, env.now())
        lat.append(time.perf_counter() - t0)
        if len(st.active) > 1:
            st.active, st.removed = st.active[:1], []
    emit("tbl_scaleout_p50", percentile(lat, 50) * 1e6, "paper: 128us")
    emit("tbl_scaleout_p99", percentile(lat, 99) * 1e6, "paper: 197us")
