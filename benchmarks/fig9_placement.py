"""Fig. 9: even vs packed sandbox placement under a sinusoidal single-DAG
workload (avg 1200 RPS, amplitude 600, 20 s period, scaled)."""
from __future__ import annotations

from dataclasses import replace

from repro.core import ClusterConfig, SGSConfig
from repro.core.types import DagSpec, FunctionSpec
from repro.sim import Experiment, Sinusoidal, WorkloadSpec, simulate

from .common import emit, record_experiment


def run(duration: float = 24.0) -> None:
    fn = FunctionSpec("d/f", exec_time=0.10, mem_mb=128, setup_time=0.3)
    dag = DagSpec("d", (fn,), (), deadline=0.25)
    # peaks push concurrency near capacity: packed placement then schedules
    # on workers without a warm sandbox (paper: ~70% misses at peaks)
    spec = WorkloadSpec([(dag, Sinusoidal(550.0, 280.0, 8.0))], duration)
    base = Experiment(
        workload=spec,
        cluster=ClusterConfig(n_sgs=1, workers_per_sgs=10,
                              cores_per_worker=8),
        warmup=4.0)
    # paper-faithful pair: revival only via the background allocator
    for tag, even in [("even", True), ("packed", False)]:
        r = simulate(replace(base, name=f"fig9_{tag}",
                             sgs=SGSConfig(even_placement=even,
                                           revive_on_dispatch=False)))
        record_experiment("fig9", r)
        emit(f"fig9_{tag}_deadlines_met", 0.0,
             f"{(r.deadline_met_frac or 0)*100:.2f}%")
        emit(f"fig9_{tag}_cold_starts", 0.0, str(r.cold_start_count))
        emit(f"fig9_{tag}_p999", (r.latency_percentiles["p99.9"] or 0) * 1e6)
    # beyond-paper: dispatch-time revival heals the packed pathology
    r = simulate(replace(base, name="fig9_packed_plus_revival",
                         sgs=SGSConfig(even_placement=False,
                                       revive_on_dispatch=True)))
    record_experiment("fig9", r)
    emit("fig9_packed_plus_revival_deadlines_met", 0.0,
         f"{(r.deadline_met_frac or 0)*100:.2f}% (beyond-paper)")
    emit("fig9_packed_plus_revival_cold_starts", 0.0,
         str(r.cold_start_count))
