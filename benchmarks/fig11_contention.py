"""Fig. 11: contention-aware scale-out — a bursty DAG's peaks force a calm
constant-rate DAG to scale out, and it scales back in when contention
passes."""
from __future__ import annotations

from repro.core import ClusterConfig
from repro.core.types import DagSpec, FunctionSpec
from repro.sim import (ConstantRate, Experiment, Sinusoidal, WorkloadSpec,
                       simulate)

from .common import emit, record_experiment


def run(duration: float = 24.0) -> None:
    calm = DagSpec("calm", (FunctionSpec("calm/f", 0.1, setup_time=0.25),),
                   (), deadline=0.22)
    bursty = DagSpec("bursty",
                     (FunctionSpec("bursty/f", 0.1, setup_time=0.25),),
                     (), deadline=0.22)
    spec = WorkloadSpec([(calm, ConstantRate(60.0)),
                         (bursty, Sinusoidal(300.0, 250.0, 12.0))], duration)
    res = simulate(Experiment(
        workload=spec, name="fig11", warmup=4.0,
        cluster=ClusterConfig(n_sgs=5, workers_per_sgs=4,
                              cores_per_worker=4)))
    record_experiment("fig11", res)
    lbs = res.sim.lbs
    ev = [(t, n) for t, d, n in lbs.scale_events if d == "calm"]
    peak = max((n for _, n in ev), default=1)
    final = lbs.n_active("calm")
    emit("fig11_calm_peak_sgs", 0.0, str(peak))
    emit("fig11_calm_final_sgs", 0.0, str(final))
    emit("fig11_scaled_out_under_contention", 0.0, str(peak >= 2))
    emit("fig11_scaled_back_in", 0.0, str(final <= peak))
    for cls, st in sorted(res.per_class.items()):
        emit(f"fig11_{cls}_deadlines_met", 0.0,
             f"{(st.deadline_met_frac or 0)*100:.2f}%")
