"""Fig. 2d: centralized FIFO vs Sparrow-style power-of-two probing at ~70%
cluster CPU utilization — random probing misses warm sandboxes.  Also runs
the registry-only ``pull`` stack (worker-initiated, warm-affinity pulls) as
a beyond-paper comparison point."""
from __future__ import annotations

from dataclasses import replace

from repro.core import ClusterConfig
from repro.sim import Experiment, simulate

from .common import emit, record_experiment


def run(duration: float = 16.0) -> None:
    base = Experiment(
        workload_factory="paper_workload_2",
        workload_kwargs=dict(duration=duration, scale=0.22,
                             dags_per_class=2),
        cluster=ClusterConfig(n_sgs=8, workers_per_sgs=8,
                              cores_per_worker=5),
        warmup=4.0)
    for tag, stack in [("fifo", "fifo"), ("sparrow", "sparrow"),
                       ("pull", "pull")]:
        r = simulate(replace(base, stack=stack, name=f"fig2d_{tag}"))
        record_experiment("fig2d", r)
        emit(f"fig2d_{tag}_p50", (r.latency_percentiles["p50"] or 0) * 1e6)
        emit(f"fig2d_{tag}_p999",
             (r.latency_percentiles["p99.9"] or 0) * 1e6)
        emit(f"fig2d_{tag}_cold_starts", 0.0, str(r.cold_start_count))
