"""Fig. 2d: centralized FIFO vs Sparrow-style power-of-two probing at ~70%
cluster CPU utilization — random probing misses warm sandboxes."""
from __future__ import annotations

from repro.core import ClusterConfig
from repro.sim import paper_workload_2, run_baseline, run_sparrow

from .common import emit


def run(duration: float = 16.0) -> None:
    spec = paper_workload_2(duration=duration, scale=0.22, dags_per_class=2)
    cc = ClusterConfig(n_sgs=8, workers_per_sgs=8, cores_per_worker=5)
    rb = run_baseline(spec, cluster=cc)
    rs = run_sparrow(spec, cluster=cc)
    mb = rb.metrics.after_warmup(4.0)
    ms = rs.metrics.after_warmup(4.0)
    for tag, m in [("fifo", mb), ("sparrow", ms)]:
        emit(f"fig2d_{tag}_p50", m.latency_pct(50) * 1e6)
        emit(f"fig2d_{tag}_p999", m.latency_pct(99.9) * 1e6)
        emit(f"fig2d_{tag}_cold_starts", 0.0, str(m.cold_start_count()))
