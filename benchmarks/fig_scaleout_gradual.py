"""§7.3.2 'Benefits of gradual scale-out': lottery-based gradual ramp vs
instant round-robin scale-out (paper: instant is ~1.5x worse at the tail)."""
from __future__ import annotations

from repro.core import ClusterConfig, LBSConfig
from repro.core.types import DagSpec, FunctionSpec
from repro.sim import Sinusoidal, WorkloadSpec, run_archipelago

from .common import emit


def run(duration: float = 30.0) -> None:
    dag = DagSpec("d", (FunctionSpec("d/f", 0.1, setup_time=0.35),), (),
                  deadline=0.3)
    spec = WorkloadSpec([(dag, Sinusoidal(200.0, 150.0, 15.0))], duration)
    cc = ClusterConfig(n_sgs=5, workers_per_sgs=4, cores_per_worker=6)
    for tag, gradual in [("gradual", True), ("instant", False)]:
        res = run_archipelago(spec, cluster=cc,
                              lbs_cfg=LBSConfig(gradual=gradual))
        m = res.metrics.after_warmup(5.0)
        emit(f"scaleout_{tag}_p999", m.latency_pct(99.9) * 1e6)
        emit(f"scaleout_{tag}_cold_starts", 0.0, str(m.cold_start_count()))
        emit(f"scaleout_{tag}_deadlines_met", 0.0,
             f"{m.deadline_met_frac()*100:.2f}%")
