"""§7.3.2 'Benefits of gradual scale-out': lottery-based gradual ramp vs
instant round-robin scale-out (paper: instant is ~1.5x worse at the tail)."""
from __future__ import annotations

from dataclasses import replace

from repro.core import ClusterConfig, LBSConfig
from repro.core.types import DagSpec, FunctionSpec
from repro.sim import Experiment, Sinusoidal, WorkloadSpec, simulate

from .common import emit, record_experiment


def run(duration: float = 30.0) -> None:
    dag = DagSpec("d", (FunctionSpec("d/f", 0.1, setup_time=0.35),), (),
                  deadline=0.3)
    spec = WorkloadSpec([(dag, Sinusoidal(200.0, 150.0, 15.0))], duration)
    base = Experiment(
        workload=spec, warmup=5.0,
        cluster=ClusterConfig(n_sgs=5, workers_per_sgs=4,
                              cores_per_worker=6))
    for tag, gradual in [("gradual", True), ("instant", False)]:
        r = simulate(replace(base, name=f"scaleout_{tag}",
                             lbs=LBSConfig(gradual=gradual)))
        record_experiment("scaleout", r)
        emit(f"scaleout_{tag}_p999",
             (r.latency_percentiles["p99.9"] or 0) * 1e6)
        emit(f"scaleout_{tag}_cold_starts", 0.0, str(r.cold_start_count))
        emit(f"scaleout_{tag}_deadlines_met", 0.0,
             f"{(r.deadline_met_frac or 0)*100:.2f}%")
