"""§7.3.1 'Benefits of workload-aware hard eviction': fair (demand-aware)
eviction vs LRU with a constant-rate DAG + an on/off DAG and a small
proactive memory pool (to force hard evictions)."""
from __future__ import annotations

from dataclasses import replace

from repro.core import ClusterConfig, SGSConfig
from repro.core.types import DagSpec, FunctionSpec
from repro.sim import (ConstantRate, Experiment, OnOffRate, WorkloadSpec,
                       simulate)

from .common import emit, record_experiment


def run(duration: float = 24.0) -> None:
    f1 = FunctionSpec("steady/f", exec_time=0.1, mem_mb=128, setup_time=0.3)
    f2 = FunctionSpec("onoff/f", exec_time=0.1, mem_mb=128, setup_time=0.3)
    d1 = DagSpec("steady", (f1,), (), deadline=0.3)
    d2 = DagSpec("onoff", (f2,), (), deadline=0.3)
    spec = WorkloadSpec([(d1, ConstantRate(200.0)),
                         (d2, OnOffRate(100.0, on_duration=4.0,
                                        off_duration=4.0))], duration)
    # small pool so that hard eviction actually happens (§7.3.1)
    base = Experiment(
        workload=spec, warmup=4.0,
        cluster=ClusterConfig(n_sgs=1, workers_per_sgs=8,
                              cores_per_worker=8, pool_mem_mb=6 * 128.0))
    for tag, fair in [("fair", True), ("lru", False)]:
        r = simulate(replace(base, name=f"evict_{tag}",
                             sgs=SGSConfig(fair_eviction=fair)))
        record_experiment("eviction", r)
        emit(f"evict_{tag}_p999",
             (r.latency_percentiles["p99.9"] or 0) * 1e6)
        emit(f"evict_{tag}_cold_starts", 0.0, str(r.cold_start_count))
        emit(f"evict_{tag}_deadlines_met", 0.0,
             f"{(r.deadline_met_frac or 0)*100:.2f}%")
