"""Fig. 13: SGS worker-pool size — 20 workers partitioned as 20x1, 10x2,
5x4, 1x20; too-fine partitioning forces constant scale-out and cold
starts.  Implemented as one ``run_sweep`` over the cluster axis."""
from __future__ import annotations

from repro.core import ClusterConfig
from repro.core.types import DagSpec, FunctionSpec
from repro.sim import (Experiment, ExperimentResult, Sinusoidal,
                       WorkloadSpec, run_sweep)

from .common import emit, record_experiment

PARTITIONS = ((20, 1), (10, 2), (5, 4), (1, 20))


def run(duration: float = 20.0) -> None:
    dag = DagSpec("d", (FunctionSpec("d/f", 0.1, setup_time=0.3),), (),
                  deadline=0.3)
    spec = WorkloadSpec([(dag, Sinusoidal(150.0, 100.0, 8.0))], duration)
    base = Experiment(workload=spec, warmup=4.0, name="fig13")
    sweep = run_sweep(base, {
        "cluster": [ClusterConfig(n_sgs=n, workers_per_sgs=w,
                                  cores_per_worker=4)
                    for n, w in PARTITIONS]})
    for (n_sgs, wps), row in zip(PARTITIONS, sweep):
        r = ExperimentResult.from_dict(row["result"])
        record_experiment("fig13", row["result"])
        emit(f"fig13_{n_sgs}sgs_x_{wps}w_p999",
             (r.latency_percentiles["p99.9"] or 0) * 1e6)
        emit(f"fig13_{n_sgs}sgs_x_{wps}w_cold_starts", 0.0,
             str(r.cold_start_count))
        emit(f"fig13_{n_sgs}sgs_x_{wps}w_deadlines_met", 0.0,
             f"{(r.deadline_met_frac or 0)*100:.2f}%")
