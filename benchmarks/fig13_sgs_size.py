"""Fig. 13: SGS worker-pool size — 20 workers partitioned as 20x1, 10x2,
5x4, 1x20; too-fine partitioning forces constant scale-out and cold
starts."""
from __future__ import annotations

from repro.core import ClusterConfig
from repro.core.types import DagSpec, FunctionSpec
from repro.sim import Sinusoidal, WorkloadSpec, run_archipelago

from .common import emit


def run(duration: float = 20.0) -> None:
    dag = DagSpec("d", (FunctionSpec("d/f", 0.1, setup_time=0.3),), (),
                  deadline=0.3)
    spec = WorkloadSpec([(dag, Sinusoidal(150.0, 100.0, 8.0))], duration)
    for n_sgs, wps in [(20, 1), (10, 2), (5, 4), (1, 20)]:
        cc = ClusterConfig(n_sgs=n_sgs, workers_per_sgs=wps,
                           cores_per_worker=4)
        res = run_archipelago(spec, cluster=cc)
        m = res.metrics.after_warmup(4.0)
        emit(f"fig13_{n_sgs}sgs_x_{wps}w_p999", m.latency_pct(99.9) * 1e6)
        emit(f"fig13_{n_sgs}sgs_x_{wps}w_cold_starts", 0.0,
             str(m.cold_start_count()))
        emit(f"fig13_{n_sgs}sgs_x_{wps}w_deadlines_met", 0.0,
             f"{m.deadline_met_frac()*100:.2f}%")
