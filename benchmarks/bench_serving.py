"""Serving benchmark: hardware-in-the-loop ``ExperimentResult`` rows.

Sweeps the multitenant serving app set (dense / SSM / MoE / two-stage VLM
pipeline) over scheduler stacks through ``run_sweep``, with real JAX
execution (``backend="jax"``: one shared backend instance, so the models
calibrate/compile once across all cells) and writes a structured
``BENCH_serving.json``: full per-cell ``ExperimentResult`` rows plus a
flattened per-class view, and — on full (non-smoke) real-JAX runs — two
paired comparisons on identical traffic:

* **batched vs unbatched** (``jax-batched`` vs ``jax``): what window
  coalescing buys over one-model-run-per-invocation;
* **continuous vs windowed** (``batching="continuous"`` vs
  ``"windowed"`` under ``jax-batched``, decode-heavy app): what
  step-granular join/leave buys over request-window coalescing, with the
  measured per-bucket admit/step device times and an analytic TPU roofline
  anchor for the decode step.

    python -m benchmarks.bench_serving [--smoke] \
        [--backend jax|jax-batched|stub|stub-batched] \
        [--kernels xla|pallas|pallas_interpret] \
        [--batching windowed|continuous]

``--kernels`` / ``--batching`` pick the data plane for the main sweep; both
are recorded per row (``data_plane`` in each ``ExperimentResult``, plus
``kernels``/``batching`` columns in ``per_class_rows``).

``--smoke`` runs 1 small model for a short duration and writes
``BENCH_serving.partial.json`` (gitignored) so partial runs never clobber
the tracked artifact — the PR-2 ``--only`` convention.  ``--backend stub``
replays the same pipeline with deterministic scripted times (no compiles);
``stub-batched``/``jax-batched`` route execution through the batching data
plane (``BatchCoalescer``).

Throughput note: the simulator grants every invocation its own abstract
core, so *simulated* completion counts cannot show what batching buys on
one physical device.  The comparison therefore reports
``completed_per_wall_s`` — completed requests per wall-clock second of the
run, i.e. what the actual hardware sustained while the event loop drove it.
Per-invocation ``jax`` pays one full model run per invocation; ``jax-batched``
amortizes weight reads across every batch member, so the same request count
needs a fraction of the device time.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from dataclasses import replace
from pathlib import Path

from .common import timer  # noqa: F401  (also bootstraps sys.path for src/)

from repro.core import (BatchedJaxBackend, ClusterConfig, JaxBackend,
                        StubBackend, StubBatchedBackend)
from repro.configs import get_config
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16
from repro.serving import (ServedModel, ServingApp, multitenant_apps,
                           smoke_apps)
from repro.sim import Experiment, run_sweep, simulate

STACKS = ["archipelago", "fifo", "pull"]

# batched-vs-unbatched comparison knobs: one small model, enough offered
# load that several invocations are in flight per batch window
COMPARE_RPS = 450.0
COMPARE_DURATION = 4.0
COMPARE_WINDOW = 0.008
COMPARE_MAX_BATCH = 8

# continuous-vs-windowed comparison knobs: a decode-heavy function (decode
# steps dominate prefill), offered load within the capacity of ONE
# serialized continuous device chain, arrivals staggered so request-window
# coalescing catches low occupancy while step-level batching stays full
CONT_RPS = 30.0
CONT_DURATION = 4.0
CONT_PROMPT = 16
CONT_GEN = 12


def _make_backend(name: str, batch_window: float = COMPARE_WINDOW,
                  max_batch: int = COMPARE_MAX_BATCH,
                  kernels: str = "xla", batching: str = "windowed"):
    if name == "jax":
        return JaxBackend(kernels=kernels)
    if name == "jax-batched":
        return BatchedJaxBackend(batch_window=batch_window,
                                 max_batch=max_batch,
                                 kernels=kernels, batching=batching)
    if name == "stub":
        return StubBackend(exec_time=0.020, setup_time=1.0)
    if name == "stub-batched":
        return StubBatchedBackend(exec_time=0.020, setup_time=1.0,
                                  batch_window=batch_window,
                                  max_batch=max_batch, batching=batching)
    raise ValueError(name)


def batched_comparison() -> dict:
    """``jax`` vs ``jax-batched`` on identical traffic: same app, same
    arrivals, same cluster — only the data plane differs.  Returns the
    comparison rows plus the headline wall-clock-throughput speedup."""
    apps = smoke_apps()
    base = Experiment(
        stack="archipelago",
        workload_factory="serving_apps",
        workload_kwargs=dict(apps=apps, duration=COMPARE_DURATION,
                             rps=COMPARE_RPS, prewarm_per_fn=4),
        cluster=ClusterConfig(n_sgs=2, workers_per_sgs=2,
                              cores_per_worker=4),
        warmup=1.0, drain=5.0)
    rows = {}
    for name in ("jax", "jax-batched"):
        print(f"[bench_serving] comparison: {name} @ {COMPARE_RPS:.0f} rps "
              f"(real executions)...", flush=True)
        res = simulate(replace(base, backend=_make_backend(name)))
        d = res.to_dict()
        # completed requests per wall second: what the hardware sustained
        d["completed_per_wall_s"] = (
            res.n_completed / res.wall_s if res.wall_s else None)
        rows[name] = d
        extra = ""
        bc = res.backend_counters
        if bc.get("n_batches"):
            extra = (f" batches={bc['n_batches']} "
                     f"mean_occ={bc['n_batched_invocations']/bc['n_batches']:.2f} "
                     f"max_occ={bc['max_batch_occupancy']}")
        print(f"  {name:>12}: done={res.n_completed} wall={res.wall_s:.1f}s "
              f"-> {d['completed_per_wall_s']:.1f} req/wall-s{extra}",
              flush=True)
    speedup = (rows["jax-batched"]["completed_per_wall_s"]
               / rows["jax"]["completed_per_wall_s"])
    print(f"  batched throughput speedup: {speedup:.2f}x", flush=True)
    return {
        "rps": COMPARE_RPS,
        "duration": COMPARE_DURATION,
        "batch_window": COMPARE_WINDOW,
        "max_batch": COMPARE_MAX_BATCH,
        "metric": "completed_per_wall_s (completed requests per wall-clock "
                  "second: real device throughput under the event loop)",
        "results": rows,
        "throughput_speedup": speedup,
    }


def _decode_roofline(cfg, max_batch: int) -> dict:
    """Analytic TPU-v5e bound on one decode step at each batch size.

    A decode step reads every active weight once (bf16: 2 bytes/param) and
    does ~2 FLOPs per active param per batch member, so small batches are
    HBM-bound: the step-time floor is flat in batch size until the compute
    term catches up.  That flat floor is exactly why continuous batching
    pays — B requests share one weight read per token."""
    n = cfg.active_param_count()
    weight_bytes = 2 * n
    per_batch = {}
    b = 1
    while b <= max_batch:
        flops = 2 * n * b
        per_batch[b] = {
            "flops": flops,
            "hbm_bytes": weight_bytes,
            "bound_s": max(flops / PEAK_FLOPS_BF16, weight_bytes / HBM_BW),
            "bound": ("hbm" if weight_bytes / HBM_BW
                      >= flops / PEAK_FLOPS_BF16 else "compute"),
        }
        b *= 2
    return {
        "model": "mamba2-370m (reduced)",
        "active_params": n,
        "peak_flops_bf16": PEAK_FLOPS_BF16,
        "hbm_bw": HBM_BW,
        "note": "per-decode-step lower bound: max(2*N*B/peak_flops, "
                "2*N/hbm_bw); reduced configs are far from saturating a "
                "v5e, so measured step times sit well above bound_s — the "
                "anchor shows the *shape* (flat until compute-bound), which "
                "the measured bucket_step_s medians reproduce",
        "per_batch": per_batch,
    }


def continuous_comparison() -> dict:
    """Windowed vs continuous batching on identical decode-heavy traffic
    (``gen_len`` decode steps dominate prefill).  Windowed coalescing only
    batches requests that arrive inside one window; continuous batching
    lets arrivals join the running batch at token-step boundaries, so the
    device stays occupied at high batch size.  Reports
    ``completed_per_wall_s`` plus the measured per-bucket device times and
    an analytic roofline anchor for the decode step."""
    app = ServingApp("decode", {"ssm/decode": ServedModel(
        get_config("mamba2-370m", reduced=True),
        prompt_len=CONT_PROMPT, gen_len=CONT_GEN)}, slack=2.0)
    base = Experiment(
        stack="archipelago",
        workload_factory="serving_apps",
        workload_kwargs=dict(apps=[app], duration=CONT_DURATION,
                             rps=CONT_RPS, prewarm_per_fn=4),
        cluster=ClusterConfig(n_sgs=2, workers_per_sgs=2,
                              cores_per_worker=4),
        warmup=1.0, drain=8.0)
    rows = {}
    device_times = {}
    for batching in ("windowed", "continuous"):
        print(f"[bench_serving] comparison: jax-batched/{batching} "
              f"@ {CONT_RPS:.0f} rps x {CONT_GEN} decode steps...",
              flush=True)
        be = _make_backend("jax-batched", batching=batching)
        res = simulate(replace(base, backend=be))
        d = res.to_dict()
        d["completed_per_wall_s"] = (
            res.n_completed / res.wall_s if res.wall_s else None)
        rows[batching] = d
        ex = be.executor
        if batching == "continuous":
            device_times["bucket_admit_s"] = {
                b: t for (_, b), t in sorted(ex.bucket_admit_s.items())}
            device_times["bucket_step_s"] = {
                b: t for (_, b), t in sorted(ex.bucket_step_s.items())}
        else:
            device_times["bucket_exec_s"] = {
                b: t for (_, b), t in sorted(ex.bucket_exec_s.items())}
        bc = res.backend_counters
        if batching == "continuous":
            extra = (f" ticks={bc['n_decode_ticks']} "
                     f"mean_step_occ="
                     f"{bc['n_step_slots']/bc['n_decode_ticks']:.2f} "
                     f"max_occ={bc['max_batch_occupancy']}")
        else:
            extra = (f" batches={bc['n_batches']} "
                     f"mean_occ={bc['n_batched_invocations']/bc['n_batches']:.2f} "
                     f"max_occ={bc['max_batch_occupancy']}")
        print(f"  {batching:>12}: done={res.n_completed} "
              f"wall={res.wall_s:.1f}s "
              f"-> {d['completed_per_wall_s']:.1f} req/wall-s{extra}",
              flush=True)
    speedup = (rows["continuous"]["completed_per_wall_s"]
               / rows["windowed"]["completed_per_wall_s"])
    print(f"  continuous throughput speedup: {speedup:.2f}x", flush=True)
    return {
        "rps": CONT_RPS,
        "duration": CONT_DURATION,
        "prompt_len": CONT_PROMPT,
        "gen_len": CONT_GEN,
        "batch_window": COMPARE_WINDOW,
        "max_batch": COMPARE_MAX_BATCH,
        "metric": "completed_per_wall_s (completed requests per wall-clock "
                  "second: real device throughput under the event loop)",
        "results": rows,
        "device_times": device_times,
        "roofline": _decode_roofline(get_config("mamba2-370m", reduced=True),
                                     COMPARE_MAX_BATCH),
        "throughput_speedup": speedup,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="1 small model, short duration, partial artifact")
    ap.add_argument("--backend", default="jax",
                    choices=["jax", "jax-batched", "stub", "stub-batched"])
    ap.add_argument("--no-compare", action="store_true",
                    help="skip the jax-batched vs jax comparison (it runs "
                         "on full --backend jax runs by default)")
    ap.add_argument("--batch-window", type=float, default=COMPARE_WINDOW,
                    help="batched backends, main sweep only: coalescing "
                         "window in sim seconds (the comparison always uses "
                         "the pinned COMPARE_* constants)")
    ap.add_argument("--max-batch", type=int, default=COMPARE_MAX_BATCH,
                    help="batched backends, main sweep only: size-triggered "
                         "flush threshold")
    ap.add_argument("--kernels", default="xla",
                    choices=["xla", "pallas", "pallas_interpret"],
                    help="jax backends, main sweep only: serving-model "
                         "hot-spot implementation (docs/KERNELS.md); "
                         "recorded per row in data_plane")
    ap.add_argument("--batching", default="windowed",
                    choices=["windowed", "continuous"],
                    help="batched backends, main sweep only: request-window "
                         "coalescing vs step-granular continuous batching "
                         "(docs/SERVING.md); recorded per row in data_plane")
    ap.add_argument("--workers", type=int, default=1,
                    help="run sweep cells in N worker processes "
                         "(repro.sim.run_sweep(workers=N)).  Requires "
                         "picklable cells: works with the stub backends; "
                         "a shared live JAX backend falls back to "
                         "sequential with a warning (cells would not share "
                         "one calibration anyway)")
    ap.add_argument("--out", default="",
                    help="JSON artifact path (default: BENCH_serving.json "
                         "at the repo root, or BENCH_serving.partial.json "
                         "with --smoke)")
    args = ap.parse_args()

    apps = smoke_apps() if args.smoke else multitenant_apps()
    backend = _make_backend(args.backend, args.batch_window, args.max_batch,
                            kernels=args.kernels, batching=args.batching)
    if args.backend.startswith("jax"):
        # one instance shared across every sweep cell: calibrate once
        n_models = len({id(m) for a in apps for m in a.models.values()})
        per = ("one executable per batch bucket"
               if args.backend == "jax-batched" else "real XLA compiles")
        print(f"[bench_serving] calibrating {n_models} model(s) ({per})...",
              flush=True)

    duration = 3.0 if args.smoke else 12.0
    base = Experiment(
        backend=backend,
        workload_factory="serving_apps",
        workload_kwargs=dict(apps=apps, duration=duration, rps=6.0,
                             prewarm_per_fn=3),
        cluster=ClusterConfig(n_sgs=2 if args.smoke else 3,
                              workers_per_sgs=2, cores_per_worker=2),
        warmup=0.0 if args.smoke else 4.0,
        drain=10.0)
    stacks = STACKS[:2] if args.smoke else STACKS

    t0 = time.time()
    sweep = run_sweep(base, {"stack": stacks}, workers=args.workers)
    per_class_rows = []
    for row in sweep:
        res = row["result"]
        print(f"  {row['cell']['stack']:>12}: n={res['n_requests']} "
              f"done={res['n_completed']} "
              f"p99={(res['latency_percentiles']['p99'] or 0)*1e3:.1f}ms "
              f"deadlines_met={(res['deadline_met_frac'] or 0)*100:.1f}% "
              f"cold_starts={res['cold_start_count']} "
              f"batches={res['backend_counters'].get('n_batches', 0)}",
              flush=True)
        dp = res.get("data_plane", {})
        for cls, stats in sorted(res["per_class"].items()):
            per_class_rows.append(dict(stats, **row["cell"],
                                       dag_class=cls,
                                       backend=res["backend"],
                                       kernels=dp.get("kernels", "none"),
                                       batching=dp.get("batching", "none")))

    comparison = None
    cont_comparison = None
    if args.backend == "jax" and not args.smoke and not args.no_compare:
        comparison = batched_comparison()
        cont_comparison = continuous_comparison()

    calibration = {
        name: {"exec_time": spec.exec_time, "setup_time": spec.setup_time}
        for name, spec in (getattr(backend, "fn_specs", None) or {}).items()}
    executions = backend.counters().get("n_executions", 0)
    if args.workers > 1:
        # parallel cells executed in worker processes: the shared instance
        # here never ran, so total executions come from the per-cell deltas
        executions = sum(
            row["result"]["backend_counters"].get("n_executions", 0)
            for row in sweep.rows)
    repo_root = Path(__file__).resolve().parent.parent
    default_name = ("BENCH_serving.partial.json" if args.smoke
                    else "BENCH_serving.json")
    out_path = Path(args.out) if args.out else repo_root / default_name
    payload = {
        "schema": 2,
        "bench": "serving",
        "smoke": bool(args.smoke),
        "backend": backend.name,
        "python": sys.version.split()[0],
        "calibration": calibration,
        "executions": executions,
        "wall_s": round(time.time() - t0, 2),
        "data_plane": backend.data_plane(),
        "sweep": sweep.to_dict(),          # full ExperimentResult rows
        "per_class_rows": per_class_rows,  # flattened per-class view
        "batched_comparison": comparison,  # jax-batched vs jax (full runs)
        "continuous_comparison": cont_comparison,  # continuous vs windowed
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path} ({len(sweep)} cells, "
          f"{len(per_class_rows)} per-class rows, "
          f"{payload['executions']} executions)")


if __name__ == "__main__":
    main()
