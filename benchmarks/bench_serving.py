"""Serving benchmark: hardware-in-the-loop ``ExperimentResult`` rows.

Sweeps the multitenant serving app set (dense / SSM / MoE / two-stage VLM
pipeline) over scheduler stacks through ``run_sweep``, with real JAX
execution (``backend="jax"``: one shared backend instance, so the models
calibrate/compile once across all cells) and writes a structured
``BENCH_serving.json``: full per-cell ``ExperimentResult`` rows plus a
flattened per-class view.

    python -m benchmarks.bench_serving [--smoke] [--backend jax|stub]

``--smoke`` runs 1 small model for a short duration and writes
``BENCH_serving.partial.json`` (gitignored) so partial runs never clobber
the tracked artifact — the PR-2 ``--only`` convention.  ``--backend stub``
replays the same pipeline with deterministic scripted times (no compiles).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from .common import timer  # noqa: F401  (also bootstraps sys.path for src/)

from repro.core import ClusterConfig, JaxBackend, StubBackend
from repro.serving import multitenant_apps, smoke_apps
from repro.sim import Experiment, run_sweep

STACKS = ["archipelago", "fifo", "pull"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="1 small model, short duration, partial artifact")
    ap.add_argument("--backend", default="jax", choices=["jax", "stub"])
    ap.add_argument("--out", default="",
                    help="JSON artifact path (default: BENCH_serving.json "
                         "at the repo root, or BENCH_serving.partial.json "
                         "with --smoke)")
    args = ap.parse_args()

    apps = smoke_apps() if args.smoke else multitenant_apps()
    if args.backend == "jax":
        # one instance shared across every sweep cell: calibrate once
        backend = JaxBackend()
        n_models = len({id(m) for a in apps for m in a.models.values()})
        print(f"[bench_serving] calibrating {n_models} model(s) "
              f"(real XLA compiles)...", flush=True)
    else:
        backend = StubBackend(exec_time=0.020, setup_time=1.0)

    duration = 3.0 if args.smoke else 12.0
    base = Experiment(
        backend=backend,
        workload_factory="serving_apps",
        workload_kwargs=dict(apps=apps, duration=duration, rps=6.0,
                             prewarm_per_fn=3),
        cluster=ClusterConfig(n_sgs=2 if args.smoke else 3,
                              workers_per_sgs=2, cores_per_worker=2),
        warmup=0.0 if args.smoke else 4.0,
        drain=10.0)
    stacks = STACKS[:2] if args.smoke else STACKS

    t0 = time.time()
    sweep = run_sweep(base, {"stack": stacks})
    per_class_rows = []
    for row in sweep:
        res = row["result"]
        print(f"  {row['cell']['stack']:>12}: n={res['n_requests']} "
              f"done={res['n_completed']} "
              f"p99={(res['latency_percentiles']['p99'] or 0)*1e3:.1f}ms "
              f"deadlines_met={(res['deadline_met_frac'] or 0)*100:.1f}% "
              f"cold_starts={res['cold_start_count']}", flush=True)
        for cls, stats in sorted(res["per_class"].items()):
            per_class_rows.append(dict(stats, **row["cell"],
                                       dag_class=cls,
                                       backend=res["backend"]))

    calibration = {
        name: {"exec_time": spec.exec_time, "setup_time": spec.setup_time}
        for name, spec in (getattr(backend, "fn_specs", None) or {}).items()}
    repo_root = Path(__file__).resolve().parent.parent
    default_name = ("BENCH_serving.partial.json" if args.smoke
                    else "BENCH_serving.json")
    out_path = Path(args.out) if args.out else repo_root / default_name
    payload = {
        "schema": 1,
        "bench": "serving",
        "smoke": bool(args.smoke),
        "backend": backend.name,
        "python": sys.version.split()[0],
        "calibration": calibration,
        "executions": backend.counters().get("n_executions", 0),
        "wall_s": round(time.time() - t0, 2),
        "sweep": sweep.to_dict(),          # full ExperimentResult rows
        "per_class_rows": per_class_rows,  # flattened per-class view
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path} ({len(sweep)} cells, "
          f"{len(per_class_rows)} per-class rows, "
          f"{payload['executions']} executions)")


if __name__ == "__main__":
    main()
