"""Deliverable (g): per-(arch x shape) roofline terms from the compiled
dry-run (reads results/roofline/*.json; run `python -m repro.launch.roofline`
first — benchmarks.run invokes it automatically if the table is missing)."""
from __future__ import annotations

import json
import os

from .common import emit

TABLE = os.path.join(os.path.dirname(__file__), "..", "results", "roofline",
                     "table.json")


def run() -> None:
    if not os.path.exists(TABLE):
        emit("roofline_table", 0.0, "missing - run repro.launch.roofline")
        return
    with open(TABLE) as f:
        rows = json.load(f)
    for r in rows:
        emit(f"roofline_{r['arch']}_{r['shape']}_compute",
             r["compute_s"] * 1e6)
        emit(f"roofline_{r['arch']}_{r['shape']}_memory",
             r["memory_s"] * 1e6)
        emit(f"roofline_{r['arch']}_{r['shape']}_collective",
             r["collective_s"] * 1e6,
             f"dom={r['bottleneck']};useful={r['useful_ratio']*100:.1f}%")
