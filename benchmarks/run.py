"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Run:
    PYTHONPATH=src python -m benchmarks.run [--only fig7,...] [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from .common import ROWS, emit


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--quick", action="store_true",
                    help="shorter durations (CI smoke)")
    args = ap.parse_args()

    from . import (fig2d_sparrow, fig7_macro, fig8b_estimation,
                   fig9_placement, fig10_deadline_scaling, fig11_contention,
                   fig12_sot, fig13_sgs_size, fig_eviction, fig_fault,
                   fig_scaleout_gradual, roofline_table, tbl_overheads)

    benches = {
        "fig2d": lambda: fig2d_sparrow.run(8.0 if args.quick else 16.0),
        "fig7": lambda: fig7_macro.run(12.0 if args.quick else 25.0),
        "fig8b": lambda: fig8b_estimation.run(12.0 if args.quick else 20.0),
        "fig9": lambda: fig9_placement.run(12.0 if args.quick else 24.0),
        "eviction": lambda: fig_eviction.run(12.0 if args.quick else 24.0),
        "fig10": lambda: fig10_deadline_scaling.run(
            12.0 if args.quick else 20.0),
        "fig11": lambda: fig11_contention.run(12.0 if args.quick else 24.0),
        "fig12": lambda: fig12_sot.run(10.0 if args.quick else 16.0),
        "fig13": lambda: fig13_sgs_size.run(10.0 if args.quick else 20.0),
        "scaleout": lambda: fig_scaleout_gradual.run(
            14.0 if args.quick else 30.0),
        "fault": lambda: fig_fault.run(12.0 if args.quick else 20.0),
        "overheads": lambda: tbl_overheads.run(500 if args.quick else 2000),
        "roofline": roofline_table.run,
    }
    only = [s for s in args.only.split(",") if s]
    failures = 0
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            fn()
            emit(f"_bench_{name}_wall", (time.time() - t0) * 1e6, "ok")
        except Exception:
            traceback.print_exc()
            emit(f"_bench_{name}_wall", (time.time() - t0) * 1e6, "FAILED")
            failures += 1
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
