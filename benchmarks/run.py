"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes a machine-readable
``BENCH_figs.json`` (one structured row per emitted metric, plus full
``ExperimentResult`` rows for every simulated experiment).  Run:
    python -m benchmarks.run [--only fig7,...] [--quick] [--workers N]
(``PYTHONPATH=src`` is no longer required but still works.)

``--workers N`` farms whole figure benchmarks to a spawn-context process
pool (every bench is an independent fixed-seed simulation, so results are
identical to sequential execution); rows are merged back in canonical
bench order, child stdout interleaves.
"""
from __future__ import annotations

import argparse
import importlib
import json
import sys
import time
import traceback
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from .common import EXPERIMENTS, RECORDS, ROWS, emit, reset

# name -> (module, quick arg, full arg); None args = call run() bare
BENCH_SPECS: Dict[str, Tuple[str, Optional[float], Optional[float]]] = {
    "fig2d": ("fig2d_sparrow", 8.0, 16.0),
    "fig7": ("fig7_macro", 12.0, 25.0),
    "fig8b": ("fig8b_estimation", 12.0, 20.0),
    "fig9": ("fig9_placement", 12.0, 24.0),
    "eviction": ("fig_eviction", 12.0, 24.0),
    "fig10": ("fig10_deadline_scaling", 12.0, 20.0),
    "fig11": ("fig11_contention", 12.0, 24.0),
    "fig12": ("fig12_sot", 10.0, 16.0),
    "fig13": ("fig13_sgs_size", 10.0, 20.0),
    "scaleout": ("fig_scaleout_gradual", 14.0, 30.0),
    "fault": ("fig_fault", 12.0, 20.0),
    "scenarios": ("bench_scenarios", 6.0, 20.0),
    "overheads": ("tbl_overheads", 500, 2000),
    "roofline": ("roofline_table", None, None),
}


def _bench_call(name: str, quick: bool) -> None:
    mod_name, qarg, farg = BENCH_SPECS[name]
    mod = importlib.import_module(f".{mod_name}", package=__package__)
    if qarg is None:
        mod.run()
    else:
        mod.run(qarg if quick else farg)


def _bench_worker(arg: Tuple[str, bool]
                  ) -> Tuple[str, int, List[str], list, list]:
    """Process-pool entry point: run one figure bench in a fresh process
    and ship its emitted rows back to the parent."""
    name, quick = arg
    reset()
    failures = 0
    t0 = time.time()
    try:
        _bench_call(name, quick)
        emit(f"_bench_{name}_wall", (time.time() - t0) * 1e6, "ok")
    except Exception:
        traceback.print_exc()
        emit(f"_bench_{name}_wall", (time.time() - t0) * 1e6, "FAILED")
        failures = 1
    return name, failures, list(ROWS), list(RECORDS), list(EXPERIMENTS)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--quick", action="store_true",
                    help="shorter durations (CI smoke)")
    ap.add_argument("--workers", type=int, default=1,
                    help="run figure benchmarks in N worker processes "
                         "(results identical to sequential; child output "
                         "interleaves)")
    ap.add_argument("--out", default="",
                    help="JSON artifact path (default: BENCH_figs.json at "
                         "the repo root, or BENCH_figs.partial.json when "
                         "--only selects a subset, so partial runs never "
                         "clobber the full artifact)")
    args = ap.parse_args()
    reset()     # in-process reruns must not accumulate rows

    only = [s for s in args.only.split(",") if s]
    unknown = [s for s in only if s not in BENCH_SPECS]
    if unknown:
        sys.exit(f"unknown bench name(s): {', '.join(unknown)}")
    selected = [n for n in BENCH_SPECS if not only or n in only]
    failures = 0
    print("name,us_per_call,derived")
    if args.workers > 1 and len(selected) > 1:
        import multiprocessing
        ctx = multiprocessing.get_context("spawn")
        with ctx.Pool(min(args.workers, len(selected))) as pool:
            results = pool.map(_bench_worker,
                               [(n, args.quick) for n in selected])
        # merge in canonical bench order (pool.map preserves input order)
        for _name, fail, rows, records, experiments in results:
            failures += fail
            ROWS.extend(rows)
            RECORDS.extend(records)
            EXPERIMENTS.extend(experiments)
    else:
        for name in selected:
            t0 = time.time()
            try:
                _bench_call(name, args.quick)
                emit(f"_bench_{name}_wall", (time.time() - t0) * 1e6, "ok")
            except Exception:
                traceback.print_exc()
                emit(f"_bench_{name}_wall", (time.time() - t0) * 1e6,
                     "FAILED")
                failures += 1

    repo_root = Path(__file__).resolve().parent.parent
    default_name = "BENCH_figs.partial.json" if only else "BENCH_figs.json"
    out_path = Path(args.out) if args.out else repo_root / default_name
    payload = {
        "schema": 1,
        "bench": "figs",
        "quick": bool(args.quick),
        "only": only,
        "python": sys.version.split()[0],
        "rows": RECORDS,               # one structured row per emit()
        "experiments": EXPERIMENTS,    # ExperimentResult.to_dict() rows
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path} ({len(RECORDS)} rows, "
          f"{len(EXPERIMENTS)} experiments; {len(ROWS)} CSV lines above)")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
