"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes a machine-readable
``BENCH_figs.json`` (one structured row per emitted metric, plus full
``ExperimentResult`` rows for every simulated experiment).  Run:
    python -m benchmarks.run [--only fig7,...] [--quick]
(``PYTHONPATH=src`` is no longer required but still works.)
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

from .common import EXPERIMENTS, RECORDS, ROWS, emit, reset


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--quick", action="store_true",
                    help="shorter durations (CI smoke)")
    ap.add_argument("--out", default="",
                    help="JSON artifact path (default: BENCH_figs.json at "
                         "the repo root, or BENCH_figs.partial.json when "
                         "--only selects a subset, so partial runs never "
                         "clobber the full artifact)")
    args = ap.parse_args()
    reset()     # in-process reruns must not accumulate rows

    from . import (fig2d_sparrow, fig7_macro, fig8b_estimation,
                   fig9_placement, fig10_deadline_scaling, fig11_contention,
                   fig12_sot, fig13_sgs_size, fig_eviction, fig_fault,
                   fig_scaleout_gradual, roofline_table, tbl_overheads)

    benches = {
        "fig2d": lambda: fig2d_sparrow.run(8.0 if args.quick else 16.0),
        "fig7": lambda: fig7_macro.run(12.0 if args.quick else 25.0),
        "fig8b": lambda: fig8b_estimation.run(12.0 if args.quick else 20.0),
        "fig9": lambda: fig9_placement.run(12.0 if args.quick else 24.0),
        "eviction": lambda: fig_eviction.run(12.0 if args.quick else 24.0),
        "fig10": lambda: fig10_deadline_scaling.run(
            12.0 if args.quick else 20.0),
        "fig11": lambda: fig11_contention.run(12.0 if args.quick else 24.0),
        "fig12": lambda: fig12_sot.run(10.0 if args.quick else 16.0),
        "fig13": lambda: fig13_sgs_size.run(10.0 if args.quick else 20.0),
        "scaleout": lambda: fig_scaleout_gradual.run(
            14.0 if args.quick else 30.0),
        "fault": lambda: fig_fault.run(12.0 if args.quick else 20.0),
        "overheads": lambda: tbl_overheads.run(500 if args.quick else 2000),
        "roofline": roofline_table.run,
    }
    only = [s for s in args.only.split(",") if s]
    failures = 0
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            fn()
            emit(f"_bench_{name}_wall", (time.time() - t0) * 1e6, "ok")
        except Exception:
            traceback.print_exc()
            emit(f"_bench_{name}_wall", (time.time() - t0) * 1e6, "FAILED")
            failures += 1

    repo_root = Path(__file__).resolve().parent.parent
    default_name = "BENCH_figs.partial.json" if only else "BENCH_figs.json"
    out_path = Path(args.out) if args.out else repo_root / default_name
    payload = {
        "schema": 1,
        "bench": "figs",
        "quick": bool(args.quick),
        "only": only,
        "python": sys.version.split()[0],
        "rows": RECORDS,               # one structured row per emit()
        "experiments": EXPERIMENTS,    # ExperimentResult.to_dict() rows
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path} ({len(RECORDS)} rows, "
          f"{len(EXPERIMENTS)} experiments; {len(ROWS)} CSV lines above)")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
