"""§6.1 fault tolerance: worker fail-stop mid-run — deadline adherence
before/after, and whether the queuing-delay signal drives recovery
scale-out.  Fault injection rides ``simulate``'s ``timed_calls`` hook;
control-plane decision costs are zeroed to match the original direct-route
driver."""
from __future__ import annotations

from repro.core import ClusterConfig
from repro.core.fault import fail_worker
from repro.core.types import DagSpec, FunctionSpec
from repro.sim import ConstantRate, Experiment, Metrics, WorkloadSpec, simulate

from .common import emit, record_experiment


def run(duration: float = 20.0) -> None:
    dag = DagSpec("d", (FunctionSpec("d/f", 0.08, setup_time=0.25),), (),
                  deadline=0.33)
    spec = WorkloadSpec([(dag, ConstantRate(80.0))], duration)
    t_fail = duration / 3.0

    def inject(env, stack):
        home = stack.lbs.sgss[stack.lbs.ring.lookup("d")]
        for w in list(home.workers[:2]):
            fail_worker(home, w.worker_id)

    res = simulate(
        Experiment(workload=spec, name="fault", drain=3.0,
                   cluster=ClusterConfig(n_sgs=3, workers_per_sgs=3,
                                         cores_per_worker=4),
                   lb_cost=0.0, sgs_cost=0.0, params={"n_lbs": 1}),
        timed_calls=[(t_fail, inject)])
    record_experiment("fault", res)

    metrics = res.sim.metrics
    pre = Metrics(requests=[r for r in metrics.requests
                            if 2.0 <= r.arrival_time < t_fail])
    post = Metrics(requests=[r for r in metrics.requests
                             if r.arrival_time >= t_fail + 2.0])
    emit("fault_pre_failure_deadlines_met", 0.0,
         f"{pre.deadline_met_frac()*100:.2f}%")
    emit("fault_post_failure_deadlines_met", 0.0,
         f"{post.deadline_met_frac()*100:.2f}%")
    emit("fault_all_requests_completed", 0.0,
         str(len(metrics.completed) == len(metrics.requests)))
    emit("fault_recovery_scale_out", 0.0,
         f"n_active={res.sim.lbs.n_active('d')} (>=2 expected)")
