"""§6.1 fault tolerance: worker fail-stop mid-run — deadline adherence
before/after, and whether the queuing-delay signal drives recovery
scale-out.  Injection is a declarative ``FaultPlan`` (docs/FAULTS.md); the
home-SGS targeting is a custom ``@register_fault`` handler (the registry
recipe); pre/post windows are zero-copy ``Metrics.window`` views.
Control-plane decision costs are zeroed to match the original direct-route
driver."""
from __future__ import annotations

from repro.core import ClusterConfig
from repro.core.fault import FaultEvent, FaultPlan, fail_worker, register_fault
from repro.core.types import DagSpec, FunctionSpec
from repro.sim import ConstantRate, Experiment, WorkloadSpec, simulate

from .common import emit, record_experiment


@register_fault("home_worker_crash")
def _home_worker_crash(ctx, dag_id: str = "d", k: int = 2) -> None:
    """Kill ``k`` workers of the SGS the ring homes ``dag_id`` on — the
    worst-case blast radius for a single-DAG workload (a random crash would
    usually hit an idle rack)."""
    lbs = ctx.stack.lbs
    home = lbs.sgss[lbs.ring.lookup(dag_id)]
    n_retry = 0
    killed = []
    for w in list(home.workers[:k]):
        n_retry += fail_worker(home, w.worker_id)
        killed.append(w.worker_id)
    ctx.injector.n_retries += n_retry
    ctx.record("home_worker_crash", sgs=home.sgs_id, killed=killed,
               n_retry=n_retry)


def run(duration: float = 20.0) -> None:
    dag = DagSpec("d", (FunctionSpec("d/f", 0.08, setup_time=0.25),), (),
                  deadline=0.33)
    spec = WorkloadSpec([(dag, ConstantRate(80.0))], duration)
    t_fail = duration / 3.0

    plan = FaultPlan(events=(FaultEvent("home_worker_crash", at=t_fail,
                                        kwargs=(("dag_id", "d"), ("k", 2))),),
                     name="home_crash")
    res = simulate(
        Experiment(workload=spec, name="fault", drain=3.0,
                   cluster=ClusterConfig(n_sgs=3, workers_per_sgs=3,
                                         cores_per_worker=4),
                   lb_cost=0.0, sgs_cost=0.0, params={"n_lbs": 1},
                   faults=plan))
    record_experiment("fault", res)

    metrics = res.sim.metrics
    pre = metrics.window(2.0, t_fail)
    post = metrics.window(t_fail + 2.0, float("inf"))
    emit("fault_pre_failure_deadlines_met", 0.0,
         f"{pre.deadline_met_frac()*100:.2f}%")
    emit("fault_post_failure_deadlines_met", 0.0,
         f"{post.deadline_met_frac()*100:.2f}%")
    emit("fault_all_requests_completed", 0.0,
         str(metrics.n_completed == metrics.n_requests))
    emit("fault_n_retries", 0.0, str(res.n_retries))
    rec = res.recovery["events"][0]
    emit("fault_time_to_recovery", 0.0,
         f"{rec['recovery_s']}s (baseline met={rec['baseline_met']})")
    emit("fault_recovery_scale_out", 0.0,
         f"n_active={res.sim.lbs.n_active('d')} (>=2 expected)")
