"""§6.1 fault tolerance: worker fail-stop mid-run — deadline adherence
before/after, and whether the queuing-delay signal drives recovery
scale-out."""
from __future__ import annotations

from repro.core import ClusterConfig, Request
from repro.core.cluster import build_cluster
from repro.core.fault import fail_worker
from repro.core.types import DagSpec, FunctionSpec
from repro.sim import ConstantRate, WorkloadSpec
from repro.sim.engine import SimEnv
from repro.sim.metrics import Metrics

from .common import emit


def run(duration: float = 20.0) -> None:
    env = SimEnv()
    cc = ClusterConfig(n_sgs=3, workers_per_sgs=3, cores_per_worker=4)
    lbs = build_cluster(env, cc)
    dag = DagSpec("d", (FunctionSpec("d/f", 0.08, setup_time=0.25),), (),
                  deadline=0.33)
    metrics = Metrics()
    spec = WorkloadSpec([(dag, ConstantRate(80.0))], duration)
    for t, d in spec.generate(0):
        def fire(t=t, d=d):
            req = Request(dag=d, arrival_time=env.now())
            metrics.requests.append(req)
            lbs.route(req, env.now())
        env.call_at(t, fire)
    env.every(0.05, lambda: lbs.check_scaling(env.now()), until=duration)

    home = lbs.sgss[lbs.ring.lookup("d")]
    t_fail = duration / 3.0

    def inject():
        for w in list(home.workers[:2]):
            fail_worker(home, w.worker_id)

    env.call_at(t_fail, inject)
    env.run_until(duration + 3.0)

    pre = Metrics(requests=[r for r in metrics.requests
                            if 2.0 <= r.arrival_time < t_fail])
    post = Metrics(requests=[r for r in metrics.requests
                             if r.arrival_time >= t_fail + 2.0])
    emit("fault_pre_failure_deadlines_met", 0.0,
         f"{pre.deadline_met_frac()*100:.2f}%")
    emit("fault_post_failure_deadlines_met", 0.0,
         f"{post.deadline_met_frac()*100:.2f}%")
    emit("fault_all_requests_completed", 0.0,
         str(len(metrics.completed) == len(metrics.requests)))
    emit("fault_recovery_scale_out", 0.0,
         f"n_active={lbs.n_active('d')} (>=2 expected)")
