"""Simulator-throughput benchmark: the perf trajectory every PR is judged by.

Three tracked tiers:

* ``std`` — ``paper_workload_1``/``paper_workload_2`` at several scales on
  a 200-worker cluster (8 SGSs x 25 workers — one rack per SGS, §4.1).
  These are the historical trajectory scenarios (names unchanged since
  PR 1, so successive entries stay comparable).
* ``xl`` — the scale-out tier: 2,000 workers (80 SGSs x 25, one rack per
  SGS), 80 tenants, and >= 1 million simulated requests per run (~3.5 M
  discrete events).  This is the scale the flat metrics plane (PR 5)
  exists for: request accounting is append-only numpy columns, so the
  simulator's working set stays bounded by in-flight requests rather than
  the full request history.
* ``xxl`` — the sharded-core tier (PR 8): 20,000 workers (800 SGSs x 25),
  800 tenants, >= 10 million requests per run, executed through
  ``Experiment.shards`` (``repro.sim.shard``: SGS islands in separate
  processes, epoch-synchronized at LBS decision boundaries).  Run it
  explicitly with ``--tier xxl`` — it is deliberately not part of
  ``--tier all`` (a full run is minutes even on a many-core box).

Sharded scenarios report per-shard event counts, epoch count, and the
coordinator's cumulative barrier-wait time alongside the usual columns,
and the payload records ``host_cpus`` — events/sec for a sharded run is
only meaningful relative to the cores it actually had.

Reported per scenario: wall time, ``events/sec`` (discrete events through
the engine), ``requests/sec``, deadline-met fraction, and peak RSS.  The
cyclic GC is disabled around the timed region (simulation allocations are
refcount-managed; gen-2 scans over millions of live objects are allocator
noise, not simulator cost) — collection runs between scenarios.

Results are written to ``BENCH_sim_throughput.json`` at the repo root so
successive PRs can track the trajectory.  ``--min-events-per-s`` turns the
run into a regression gate (CI uses it with a conservative floor).
``--profile`` wraps each timed region in cProfile (coordinator process
only for sharded runs), dumps ``BENCH_profile_<name>.pstats`` next to the
output file, and prints the top-25 cumulative entries.

Run:
    python benchmarks/bench_sim_throughput.py [--quick]
                                              [--tier std|xl|xxl|all]
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import resource
import sys
import time
from pathlib import Path

try:
    import repro  # noqa: F401
except ImportError:                                     # pragma: no cover
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.autoscale import AutoscaleConfig, scaling_summary
from repro.core.cluster import ClusterConfig
from repro.sim.experiment import Experiment, simulate

# 200 workers: 8 rack-sized SGS pools of 25 machines (§4.1, §7.1 scaled up)
CLUSTERS = {
    "std": dict(n_sgs=8, workers_per_sgs=25, cores_per_worker=20,
                pool_mem_mb=65536.0),
    # 2,000 workers: 80 rack-sized SGS pools of 25 machines
    "xl": dict(n_sgs=80, workers_per_sgs=25, cores_per_worker=20,
               pool_mem_mb=65536.0),
    # 20,000 workers: 800 rack-sized SGS pools of 25 machines (sharded core)
    "xxl": dict(n_sgs=800, workers_per_sgs=25, cores_per_worker=20,
                pool_mem_mb=65536.0),
}

# Pre-refactor throughput on the same scenarios/machine class (seed scheduler
# + identical stable-hash workloads, measured 2026-07-30).  Kept as recorded
# history: the headline acceptance for PR 1 was >=10x on wl1_scale1.0.
BASELINE_BEFORE = {
    "wl1_scale1.0": {"wall_s": 24.465, "events_per_s": 10838,
                     "n_events": 265143},
    "wl1_scale0.25": {"wall_s": 3.765, "events_per_s": 18117,
                      "n_events": 68216},
    "wl2_scale1.0": {"wall_s": 35.672, "events_per_s": 7541,
                     "n_events": 269013},
}

# The LBS is "a scalable service" (§5): at the xl tier's ~26 k rps the
# default 4 replicas (190 us per decision ~ 21 k rps capacity) would
# themselves saturate.  The replica pool is elastic now (core.autoscale):
# the controller observes decision-clock utilization and sizes the tier
# itself — no hand-tuned n_lbs — exactly as the paper argues the LBS
# should scale with the cluster.
XL_AUTOSCALE = AutoscaleConfig()

# (name, workload factory, workload kwargs, experiment params[, shards])
# per tier; std names are the PR-1 trajectory keys and must not change.
# The optional 5th element routes the run through the sharded core.
SCENARIOS = {
    "std": [
        ("wl1_scale0.25", "paper_workload_1",
         dict(duration=30.0, scale=0.25), {}),
        ("wl1_scale1.0", "paper_workload_1",
         dict(duration=30.0, scale=1.0), {}),
        ("wl2_scale1.0", "paper_workload_2",
         dict(duration=30.0, scale=1.0), {}),
    ],
    # 80 tenants at ~26 k rps aggregate for 40 s -> ~1.02 M requests
    # (~3.5 M events) per run; dags_per_class scales tenant count so the
    # consistent-hash LBS tier actually spreads load over the 80 SGSs
    "xl": [
        ("xl_wl1_scale10", "paper_workload_1",
         dict(duration=40.0, scale=10.0, dags_per_class=20), {}),
        ("xl_wl2_scale10", "paper_workload_2",
         dict(duration=40.0, scale=10.0, dags_per_class=20), {}),
        # the same xl_wl1 cell through the sharded core: decision-identical
        # rows, SGS islands advancing in 4 processes
        ("xl_wl1_scale10_sh4", "paper_workload_1",
         dict(duration=40.0, scale=10.0, dags_per_class=20), {}, 4),
    ],
    # 800 tenants at ~260 k rps aggregate for 40 s -> >= 10 M requests
    # (~35 M events): only tractable through the sharded core
    "xxl": [
        ("xxl_wl1_scale100_sh8", "paper_workload_1",
         dict(duration=40.0, scale=100.0, dags_per_class=200), {}, 8),
    ],
}

QUICK_SCENARIOS = {
    "std": [
        ("wl1_quick", "paper_workload_1", dict(duration=5.0, scale=0.1), {}),
        ("wl2_quick", "paper_workload_2", dict(duration=5.0, scale=0.1), {}),
    ],
    # trimmed 2,000-worker cells: full cluster + tenant fan-out, short
    # trace; the sharded twin keeps the epoch protocol under the CI floor
    "xl": [
        ("xl_wl1_quick", "paper_workload_1",
         dict(duration=4.0, scale=2.0, dags_per_class=20), {}),
        ("xl_wl1_quick_sh2", "paper_workload_1",
         dict(duration=4.0, scale=2.0, dags_per_class=20), {}, 2),
    ],
    # trimmed 20,000-worker sharded cell
    "xxl": [
        ("xxl_wl1_quick_sh4", "paper_workload_1",
         dict(duration=2.0, scale=10.0, dags_per_class=200), {}, 4),
    ],
}


def run_one(name: str, tier: str, factory: str, kw: dict, params: dict,
            repeats: int = 1, autoscale: AutoscaleConfig = None,
            shards: int = None, profile_dir: Path = None) -> dict:
    cluster = ClusterConfig(**CLUSTERS[tier])
    # timeit-style best-of-N: on a noisy shared machine the minimum wall
    # time is the informative statistic (every run does identical
    # deterministic work; anything above the minimum is interference)
    wall = float("inf")
    res = None
    prof = None
    for _ in range(max(1, repeats)):
        res = None      # free the previous repeat before timing the next
        gc.collect()
        gc.disable()    # see module docstring: timed region is GC-free
        if profile_dir is not None:
            import cProfile
            prof = cProfile.Profile()
        try:
            t0 = time.perf_counter()
            if prof is not None:
                prof.enable()
            res = simulate(Experiment(stack="archipelago",
                                      workload_factory=factory,
                                      workload_kwargs=kw, name=name,
                                      cluster=cluster, params=dict(params),
                                      autoscale=autoscale, shards=shards,
                                      seed=0))
            if prof is not None:
                prof.disable()
            wall = min(wall, time.perf_counter() - t0)
        finally:
            gc.enable()
    row = {
        "tier": tier,
        "params": params,
        "repeats": max(1, repeats),
        "wall_s": round(wall, 3),
        "n_events": res.n_events,
        "events_per_s": round(res.n_events / wall, 1),
        "n_requests": res.n_requests_total,
        "n_completed": res.n_completed,
        "requests_per_s": round(res.n_requests_total / wall, 1),
        "deadline_met_frac": round(res.deadline_met_frac, 5)
        if res.deadline_met_frac is not None else None,
        "peak_rss_mb": round(
            resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0, 1),
    }
    # accounting integrity: every request must be either completed or still
    # in flight at the horizon — a nonzero count means rows were dropped
    # (the sharded merge is the path this guards)
    try:
        n_pending = len(res.sim.metrics._cols.pending)
    except AttributeError:
        n_pending = None
    if n_pending is not None:
        row["n_pending"] = n_pending
        row["lost_requests"] = (res.n_requests_total - res.n_completed
                                - n_pending)
    shard_stats = getattr(res.sim, "shard_stats", None) if res.sim else None
    if shard_stats is not None:
        row["shards"] = shard_stats["shards"]
        row["parent_events"] = shard_stats["parent_events"]
        row["shard_events"] = shard_stats["shard_events"]
        row["n_epochs"] = shard_stats["n_epochs"]
        row["barrier_wait_s"] = shard_stats["barrier_wait_s"]
    if autoscale is not None:
        row["autoscale"] = autoscale.to_dict()
        row["scaling"] = scaling_summary(res.scaling_events)
    before = BASELINE_BEFORE.get(name)
    if before:
        row["speedup_vs_before"] = round(
            row["events_per_s"] / before["events_per_s"], 2)
    if prof is not None:
        import pstats
        ppath = profile_dir / f"BENCH_profile_{name}.pstats"
        st = pstats.Stats(prof)
        st.dump_stats(str(ppath))
        print(f"-- profile ({name}): top 25 by cumulative time "
              f"-> {ppath}")
        st.sort_stats("cumulative").print_stats(25)
    print(f"{name}: {row['wall_s']}s  {row['events_per_s']:.0f} ev/s  "
          f"{row['requests_per_s']:.0f} req/s  "
          f"n={row['n_requests']} rss={row['peak_rss_mb']}MB"
          + (f"  shards={row['shards']} epochs={row['n_epochs']} "
             f"barrier_wait={row['barrier_wait_s']}s"
             if shard_stats is not None else "")
          + (f"  ({row['speedup_vs_before']}x vs pre-refactor)"
             if before else ""),
          flush=True)
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small scenarios only (CI smoke); writes to "
                         "BENCH_sim_throughput.quick.json so the tracked "
                         "full-run trajectory is never clobbered")
    ap.add_argument("--tier", choices=["std", "xl", "xxl", "all"],
                    default="all",
                    help="which cluster tier(s) to run (default: all = "
                         "std+xl; xxl only runs when named explicitly; "
                         "--quick defaults to std unless --tier is given)")
    ap.add_argument("--profile", action="store_true",
                    help="cProfile each scenario's timed region (the "
                         "coordinator process only for sharded runs), dump "
                         "BENCH_profile_<name>.pstats next to the output "
                         "file, and print the top-25 cumulative entries; "
                         "forces repeats=1 (profiling skews timing)")
    ap.add_argument("--min-events-per-s", type=float, default=0.0,
                    help="regression floor: exit 1 if any scenario falls "
                         "below this events/sec (CI gate)")
    ap.add_argument("--repeats", type=int, default=0,
                    help="timed repetitions per scenario, reporting the "
                         "best (timeit convention; identical deterministic "
                         "work per repeat).  Default: 2 for full runs, 1 "
                         "for --quick")
    ap.add_argument("--out", default="",
                    help="output path (default: BENCH_sim_throughput.json "
                         "at the repo root, or *.quick.json with --quick)")
    args = ap.parse_args()

    repo_root = Path(__file__).resolve().parent.parent
    default_name = ("BENCH_sim_throughput.quick.json" if args.quick
                    else "BENCH_sim_throughput.json")
    out_path = Path(args.out) if args.out else (repo_root / default_name)

    # --quick without an explicit tier historically means the std smoke
    tiers = ["std", "xl"] if args.tier == "all" else [args.tier]
    if args.quick and args.tier == "all":
        tiers = ["std"]
    table = QUICK_SCENARIOS if args.quick else SCENARIOS
    repeats = args.repeats if args.repeats > 0 else (1 if args.quick else 2)
    if args.profile:
        repeats = 1
    runs = {}
    for tier in tiers:
        for entry in table[tier]:
            name, make, kw, params = entry[:4]
            shards = entry[4] if len(entry) > 4 else None
            runs[name] = run_one(
                name, tier, make, kw, params, repeats=repeats,
                # the xl/xxl routing tiers size themselves (no hand-tuned
                # n_lbs)
                autoscale=XL_AUTOSCALE if tier in ("xl", "xxl") else None,
                shards=shards,
                profile_dir=out_path.parent if args.profile else None)

    payload = {
        "schema": 3,
        "bench": "sim_throughput",
        "quick": bool(args.quick),
        "tiers": tiers,
        "clusters": {t: CLUSTERS[t] for t in tiers},
        # legacy (schema 1) alias for the std cluster shape
        "cluster": CLUSTERS["std"],
        "python": sys.version.split()[0],
        # sharded events/sec only means anything relative to the cores the
        # run actually had — record the host honestly
        "host_cpus": os.cpu_count(),
        "baseline_before": BASELINE_BEFORE,
        "runs": runs,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path}")

    if args.min_events_per_s > 0:
        slow = {n: r["events_per_s"] for n, r in runs.items()
                if r["events_per_s"] < args.min_events_per_s}
        if slow:
            print(f"REGRESSION: below the {args.min_events_per_s:.0f} ev/s "
                  f"floor: {slow}", file=sys.stderr)
            sys.exit(1)
        print(f"floor check passed: all >= {args.min_events_per_s:.0f} ev/s")


if __name__ == "__main__":
    main()
