"""Simulator-throughput benchmark: the perf trajectory every PR is judged by.

Runs ``paper_workload_1``/``paper_workload_2`` through the experiment API's
``simulate`` (stack="archipelago") at several scales on a 200-worker cluster
(8 SGSs x 25 workers — one rack per SGS, §4.1) and reports events/sec,
requests/sec, wall time and peak RSS.  Writes ``BENCH_sim_throughput.json``
at the repo root so successive PRs can track the trajectory.

The ``baseline_before`` numbers are the pre-index-refactor scheduler (PR 1
seed: linear worker/sandbox scans, per-sandbox placement re-sorts) measured
on this same harness's scenarios; they are the denominator for the reported
speedups.

Run:
    python benchmarks/bench_sim_throughput.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import sys
import time
from pathlib import Path

try:
    import repro  # noqa: F401
except ImportError:                                     # pragma: no cover
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.cluster import ClusterConfig
from repro.sim.experiment import Experiment, simulate

# 200 workers: 8 rack-sized SGS pools of 25 machines (§4.1, §7.1 scaled up)
CLUSTER = dict(n_sgs=8, workers_per_sgs=25, cores_per_worker=20,
               pool_mem_mb=65536.0)

# Pre-refactor throughput on the same scenarios/machine class (seed scheduler
# + identical stable-hash workloads, measured 2026-07-30).  Kept as recorded
# history: the headline acceptance for PR 1 was >=10x on wl1_scale1.0.
BASELINE_BEFORE = {
    "wl1_scale1.0": {"wall_s": 24.465, "events_per_s": 10838,
                     "n_events": 265143},
    "wl1_scale0.25": {"wall_s": 3.765, "events_per_s": 18117,
                      "n_events": 68216},
    "wl2_scale1.0": {"wall_s": 35.672, "events_per_s": 7541,
                     "n_events": 269013},
}

SCENARIOS = [
    ("wl1_scale0.25", "paper_workload_1", dict(duration=30.0, scale=0.25)),
    ("wl1_scale1.0", "paper_workload_1", dict(duration=30.0, scale=1.0)),
    ("wl2_scale1.0", "paper_workload_2", dict(duration=30.0, scale=1.0)),
]

QUICK_SCENARIOS = [
    ("wl1_quick", "paper_workload_1", dict(duration=5.0, scale=0.1)),
    ("wl2_quick", "paper_workload_2", dict(duration=5.0, scale=0.1)),
]


def run_one(name: str, factory: str, kw: dict) -> dict:
    t0 = time.perf_counter()
    res = simulate(Experiment(stack="archipelago", workload_factory=factory,
                              workload_kwargs=kw, name=name,
                              cluster=ClusterConfig(**CLUSTER), seed=0))
    wall = time.perf_counter() - t0
    m = res.sim.metrics
    row = {
        "wall_s": round(wall, 3),
        "n_events": res.n_events,
        "events_per_s": round(res.n_events / wall, 1),
        "n_requests": len(m.requests),
        "n_completed": len(m.completed),
        "requests_per_s": round(len(m.requests) / wall, 1),
        "deadline_met_frac": round(m.deadline_met_frac(), 5),
        "peak_rss_mb": round(
            resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0, 1),
    }
    before = BASELINE_BEFORE.get(name)
    if before:
        row["speedup_vs_before"] = round(
            row["events_per_s"] / before["events_per_s"], 2)
    print(f"{name}: {row['wall_s']}s  {row['events_per_s']:.0f} ev/s  "
          f"{row['requests_per_s']:.0f} req/s"
          + (f"  ({row['speedup_vs_before']}x vs pre-refactor)"
             if before else ""),
          flush=True)
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small scenarios only (CI smoke); writes to "
                         "BENCH_sim_throughput.quick.json so the tracked "
                         "full-run trajectory is never clobbered")
    ap.add_argument("--out", default="",
                    help="output path (default: BENCH_sim_throughput.json "
                         "at the repo root, or *.quick.json with --quick)")
    args = ap.parse_args()

    repo_root = Path(__file__).resolve().parent.parent
    default_name = ("BENCH_sim_throughput.quick.json" if args.quick
                    else "BENCH_sim_throughput.json")
    out_path = Path(args.out) if args.out else (repo_root / default_name)

    scenarios = QUICK_SCENARIOS if args.quick else SCENARIOS
    runs = {name: run_one(name, make, kw) for name, make, kw in scenarios}

    payload = {
        "schema": 1,
        "bench": "sim_throughput",
        "quick": bool(args.quick),
        "cluster": CLUSTER,
        "python": sys.version.split()[0],
        "baseline_before": BASELINE_BEFORE,
        "runs": runs,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
