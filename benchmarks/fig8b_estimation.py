"""Fig. 8b: proactive allocation vs the ideal sandbox count for a sinusoidal
C2-like DAG — how closely the estimator tracks true demand.  Uses
``simulate``'s periodic-hook support instead of a hand-rolled pump loop."""
from __future__ import annotations

from repro.core import ClusterConfig
from repro.core.types import DagSpec, FunctionSpec
from repro.sim import Experiment, Sinusoidal, WorkloadSpec, simulate

from .common import emit, record_experiment


def run(duration: float = 20.0) -> None:
    fn = FunctionSpec("c2/f", exec_time=0.15, mem_mb=128, setup_time=0.25)
    dag = DagSpec("c2", (fn,), (), deadline=0.55)
    proc = Sinusoidal(400.0, 200.0, 10.0)
    spec = WorkloadSpec([(dag, proc)], duration)
    exp = Experiment(stack="archipelago", workload=spec,
                     cluster=ClusterConfig(n_sgs=2, workers_per_sgs=8,
                                           cores_per_worker=20),
                     name="fig8b")

    # sample allocated vs ideal at 1 s boundaries, in-loop
    samples = []

    def sample(env, stack):
        alloc = sum(s.proactive_sandbox_count("c2")
                    for s in stack.lbs.sgss.values())
        ideal = proc.rate(env.now()) * fn.exec_time      # Little's law
        samples.append((env.now(), alloc, ideal))

    res = simulate(exp, hooks=[(1.0, sample)])
    record_experiment("fig8b", res)

    steady = [s for s in samples if 5.0 <= s[0] <= duration]
    over = [(a - i) / max(i, 1.0) for _, a, i in steady]
    emit("fig8b_worst_overalloc", 0.0,
         f"{max(over)*100:.1f}% (paper: 37.4% worst case)")
    emit("fig8b_mean_overalloc", 0.0, f"{sum(over)/len(over)*100:.1f}%")
    shortfall = sum(1 for _, a, i in steady if a < i)
    emit("fig8b_underalloc_samples", 0.0, f"{shortfall}/{len(steady)}")
