"""Fig. 8b: proactive allocation vs the ideal sandbox count for a sinusoidal
C2-like DAG — how closely the estimator tracks true demand."""
from __future__ import annotations

from repro.core import ClusterConfig, Request
from repro.core.cluster import build_cluster
from repro.core.types import DagSpec, FunctionSpec
from repro.sim import Sinusoidal, WorkloadSpec
from repro.sim.engine import SimEnv
from repro.sim.runner import run_archipelago

from .common import emit


def run(duration: float = 20.0) -> None:
    fn = FunctionSpec("c2/f", exec_time=0.15, mem_mb=128, setup_time=0.25)
    dag = DagSpec("c2", (fn,), (), deadline=0.55)
    proc = Sinusoidal(400.0, 200.0, 10.0)
    spec = WorkloadSpec([(dag, proc)], duration)
    cc = ClusterConfig(n_sgs=2, workers_per_sgs=8, cores_per_worker=20)
    res = run_archipelago(spec, cluster=cc)

    # sample allocated vs ideal at 1s boundaries (post-hoc from final state
    # we re-run with sampling)
    env = SimEnv()
    from repro.sim.runner import _ServiceClock, LB_DECISION_COST, \
        SGS_DECISION_COST
    lbs = build_cluster(env, cc)
    lb_c, sgs_c = _ServiceClock(), {s: _ServiceClock() for s in lbs.sgss}
    from repro.sim.metrics import Metrics
    metrics = Metrics()
    for t, d in spec.generate(0):
        def fire(t=t, d=d):
            req = Request(dag=d, arrival_time=env.now())
            metrics.requests.append(req)
            tr = lb_c.acquire(env.now(), LB_DECISION_COST)
            sgs = lbs.select(req, env.now())
            ts = sgs_c[sgs.sgs_id].acquire(tr, SGS_DECISION_COST)
            env.call_at(ts, lambda: sgs.submit_request(req))
        env.call_at(t, fire)
    env.every(0.05, lambda: lbs.check_scaling(env.now()), until=duration)

    samples = []

    def sample():
        alloc = sum(s.proactive_sandbox_count("c2")
                    for s in lbs.sgss.values())
        ideal = proc.rate(env.now()) * fn.exec_time      # Little's law
        samples.append((env.now(), alloc, ideal))

    env.every(1.0, sample, until=duration)
    env.run_until(duration + 2.0)

    steady = [s for s in samples if s[0] >= 5.0]
    over = [(a - i) / max(i, 1.0) for _, a, i in steady]
    emit("fig8b_worst_overalloc", 0.0,
         f"{max(over)*100:.1f}% (paper: 37.4% worst case)")
    emit("fig8b_mean_overalloc", 0.0, f"{sum(over)/len(over)*100:.1f}%")
    shortfall = sum(1 for _, a, i in steady if a < i)
    emit("fig8b_underalloc_samples", 0.0, f"{shortfall}/{len(steady)}")
