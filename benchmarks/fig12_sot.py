"""Fig. 12: scale-out threshold (SOT) sensitivity — cold starts rise as SOT
falls; queuing delay (and tail latency) rises as SOT grows.  Implemented as
one ``run_sweep`` over the SOT axis."""
from __future__ import annotations

from repro.core import ClusterConfig, LBSConfig
from repro.sim import Experiment, ExperimentResult, run_sweep

from .common import emit, record_experiment

SOTS = (0.05, 0.1, 0.3, 0.6, 1.2)


def run(duration: float = 16.0) -> None:
    base = Experiment(
        workload_factory="paper_workload_2",
        workload_kwargs=dict(duration=duration, scale=0.25,
                             dags_per_class=2),
        cluster=ClusterConfig(n_sgs=8, workers_per_sgs=8,
                              cores_per_worker=5),
        warmup=4.0, name="fig12")
    sweep = run_sweep(base, {
        "lbs": [LBSConfig(scale_out_threshold=sot,
                          scale_in_threshold=sot / 6.0) for sot in SOTS]})
    for sot, row in zip(SOTS, sweep):
        r = ExperimentResult.from_dict(row["result"])
        record_experiment("fig12", row["result"])
        emit(f"fig12_sot{sot}_cold_starts", 0.0, str(r.cold_start_count))
        emit(f"fig12_sot{sot}_p999",
             (r.latency_percentiles["p99.9"] or 0) * 1e6)
        emit(f"fig12_sot{sot}_deadlines_met", 0.0,
             f"{(r.deadline_met_frac or 0)*100:.2f}%")
