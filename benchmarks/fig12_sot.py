"""Fig. 12: scale-out threshold (SOT) sensitivity — cold starts rise as SOT
falls; queuing delay (and tail latency) rises as SOT grows."""
from __future__ import annotations

from repro.core import ClusterConfig, LBSConfig
from repro.sim import paper_workload_2, run_archipelago

from .common import emit


def run(duration: float = 16.0) -> None:
    spec = paper_workload_2(duration=duration, scale=0.25, dags_per_class=2)
    cc = ClusterConfig(n_sgs=8, workers_per_sgs=8, cores_per_worker=5)
    for sot in (0.05, 0.1, 0.3, 0.6, 1.2):
        res = run_archipelago(
            spec, cluster=cc,
            lbs_cfg=LBSConfig(scale_out_threshold=sot,
                              scale_in_threshold=sot / 6.0))
        m = res.metrics.after_warmup(4.0)
        emit(f"fig12_sot{sot}_cold_starts", 0.0, str(m.cold_start_count()))
        emit(f"fig12_sot{sot}_p999", m.latency_pct(99.9) * 1e6)
        emit(f"fig12_sot{sot}_deadlines_met", 0.0,
             f"{m.deadline_met_frac()*100:.2f}%")
