"""Fig. 7 (+Fig. 8a): macrobenchmarks — E2E latency and % deadlines met for
Workloads 1 and 2, Archipelago vs the centralized-FIFO-reactive baseline."""
from __future__ import annotations

from dataclasses import replace

from repro.core import ClusterConfig
from repro.sim import Experiment, simulate

from .common import emit, record_experiment

WARMUP = 5.0


def run(duration: float = 25.0) -> None:
    cc = ClusterConfig()        # 8 SGS x 8 workers x 20 cores (paper §7.1)
    for wname, factory, kw in [
            ("w1", "paper_workload_1",
             dict(duration=duration, scale=1.3, dags_per_class=2)),
            ("w2", "paper_workload_2",
             dict(duration=duration, scale=1.0, dags_per_class=2))]:
        base = Experiment(workload_factory=factory, workload_kwargs=kw,
                          cluster=cc, warmup=WARMUP)
        ra = simulate(replace(base, stack="archipelago",
                              name=f"fig7_{wname}_arch"))
        rb = simulate(replace(base, stack="fifo", name=f"fig7_{wname}_base"))
        for tag, r in [("arch", ra), ("base", rb)]:
            record_experiment("fig7", r)
            lp = r.latency_percentiles
            emit(f"fig7_{wname}_{tag}_p50", (lp["p50"] or 0) * 1e6)
            emit(f"fig7_{wname}_{tag}_p99", (lp["p99"] or 0) * 1e6)
            emit(f"fig7_{wname}_{tag}_p999", (lp["p99.9"] or 0) * 1e6)
            emit(f"fig7_{wname}_{tag}_deadlines_met", 0.0,
                 f"{(r.deadline_met_frac or 0)*100:.2f}%")
            emit(f"fig7_{wname}_{tag}_cold_starts", 0.0,
                 str(r.cold_start_count))
        ratio = ((rb.latency_percentiles["p99.9"] or 0)
                 / max(ra.latency_percentiles["p99.9"] or 0, 1e-9))
        emit(f"fig7_{wname}_tail_reduction", 0.0, f"{ratio:.2f}x")
        # Fig. 8a: queuing delay distribution (steady-state samples)
        emit(f"fig8a_{wname}_qdelay_p999_arch",
             (ra.queuing_percentiles["p99.9"] or 0) * 1e6)
        emit(f"fig8a_{wname}_qdelay_p999_base",
             (rb.queuing_percentiles["p99.9"] or 0) * 1e6)
        # per-class deadline breakdown (Fig. 7b/7d)
        for cls, st in sorted(ra.per_class.items()):
            emit(f"fig7_{wname}_arch_{cls}_deadlines_met", 0.0,
                 f"{(st.deadline_met_frac or 0)*100:.2f}%")
