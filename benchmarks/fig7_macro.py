"""Fig. 7 (+Fig. 8a): macrobenchmarks — E2E latency and % deadlines met for
Workloads 1 and 2, Archipelago vs the centralized-FIFO-reactive baseline."""
from __future__ import annotations

from repro.core import ClusterConfig
from repro.sim import (paper_workload_1, paper_workload_2, run_archipelago,
                       run_baseline)
from repro.sim.metrics import percentile

from .common import emit

WARMUP = 5.0


def run(duration: float = 25.0) -> None:
    cc = ClusterConfig()        # 8 SGS x 8 workers x 20 cores (paper §7.1)
    for wname, spec in [
            ("w1", paper_workload_1(duration=duration, scale=1.3,
                                    dags_per_class=2)),
            ("w2", paper_workload_2(duration=duration, scale=1.0,
                                    dags_per_class=2))]:
        ra = run_archipelago(spec, cluster=cc)
        rb = run_baseline(spec, cluster=cc)
        ma = ra.metrics.after_warmup(WARMUP)
        mb = rb.metrics.after_warmup(WARMUP)
        for tag, m in [("arch", ma), ("base", mb)]:
            emit(f"fig7_{wname}_{tag}_p50", m.latency_pct(50) * 1e6)
            emit(f"fig7_{wname}_{tag}_p99", m.latency_pct(99) * 1e6)
            emit(f"fig7_{wname}_{tag}_p999", m.latency_pct(99.9) * 1e6)
            emit(f"fig7_{wname}_{tag}_deadlines_met", 0.0,
                 f"{m.deadline_met_frac()*100:.2f}%")
            emit(f"fig7_{wname}_{tag}_cold_starts", 0.0,
                 str(m.cold_start_count()))
        ratio = mb.latency_pct(99.9) / max(ma.latency_pct(99.9), 1e-9)
        emit(f"fig7_{wname}_tail_reduction", 0.0, f"{ratio:.2f}x")
        # Fig. 8a: queuing delay distribution
        qa = ra.metrics.queuing_delays
        qb = rb.metrics.queuing_delays
        emit(f"fig8a_{wname}_qdelay_p999_arch",
             percentile(qa, 99.9) * 1e6)
        emit(f"fig8a_{wname}_qdelay_p999_base",
             percentile(qb, 99.9) * 1e6)
        # per-class deadline breakdown (Fig. 7b/7d)
        for cls, m in sorted(ma.by_class().items()):
            emit(f"fig7_{wname}_arch_{cls}_deadlines_met", 0.0,
                 f"{m.deadline_met_frac()*100:.2f}%")
