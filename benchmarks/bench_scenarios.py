"""Traffic-scenario benchmark: realistic arrival shapes x scheduler stacks
(docs/SCENARIOS.md).

Two tracked tiers, mirroring ``bench_sim_throughput`` / ``bench_faults``:

* ``std`` — the scenario matrix on the 200-worker cluster (8 SGSs x 25):
  every built-in traffic shape (steady / diurnal / flash_crowd /
  tenant_churn / zipf_mix) x scheduler stacks (archipelago / sparrow /
  pull).  ``traffic`` is a literal ``run_sweep`` axis — each cell is one
  registered scenario applied to ``paper_workload_1``.
* ``xl`` — 2,000 workers (80 SGSs x 25), 80+ tenants, >= 1 M simulated
  requests per cell, under the two scenarios that actually stress the
  control plane: a flash crowd (burst routing load) and tenant churn
  (DAGs joining/leaving the consistent-hash ring mid-run).  The LBS
  replica pool is elastic (``Experiment.autoscale``) — no hand-tuned
  ``n_lbs`` anywhere in this file.

Reported per cell: deadline-met fraction, end-to-end latency percentiles
(the CDF the paper plots), completion accounting (completed == arrivals),
and the control-plane scaling digest (LBS replica peak/final, SGS per-DAG
scale events) from ``ExperimentResult.scaling_events``.

Results go to ``BENCH_scenarios.json`` at the repo root (tracked);
``--smoke`` runs trimmed std cells only and writes
``BENCH_scenarios.partial.json`` (gitignored) so CI never clobbers the
tracked matrix.

Run:
    python -m benchmarks.bench_scenarios [--smoke] [--tier std|xl|all]
                                         [--workers N]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path
from typing import Dict, List

try:
    import repro  # noqa: F401
except ImportError:                                     # pragma: no cover
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.autoscale import AutoscaleConfig, scaling_summary
from repro.core.cluster import ClusterConfig
from repro.sim.experiment import Experiment, run_sweep, simulate

CLUSTERS = {
    "std": dict(n_sgs=8, workers_per_sgs=25, cores_per_worker=20,
                pool_mem_mb=65536.0),
    # 2,000 workers: 80 rack-sized SGS pools of 25 machines
    "xl": dict(n_sgs=80, workers_per_sgs=25, cores_per_worker=20,
               pool_mem_mb=65536.0),
}

STACKS = ["archipelago", "sparrow", "pull"]
TRAFFICS = ["steady", "diurnal", "flash_crowd", "tenant_churn", "zipf_mix"]

# the xl routing tier sizes itself from observed decision-clock load
XL_AUTOSCALE = AutoscaleConfig()

# the two xl cells: the shapes that exercise the elastic control plane
XL_TRAFFICS = ["flash_crowd", "tenant_churn"]


def _cell_row(tier: str, stack: str, traffic: str, rd: Dict,
              wall_s: float) -> Dict:
    """Compact tracked row: deadline adherence + latency CDF + accounting
    + the control-plane scaling digest."""
    return {
        "tier": tier,
        "stack": stack,
        "traffic": traffic,
        "wall_s": round(wall_s, 3),
        "n_requests": rd["n_requests_total"],
        "n_completed_total": rd["n_completed_total"],
        "all_completed": rd["n_completed_total"] == rd["n_requests_total"],
        "deadline_met_frac": rd["deadline_met_frac"],
        "latency_percentiles": rd["latency_percentiles"],
        "scaling": scaling_summary(rd["scaling_events"]),
    }


def run_std(duration: float, scale: float, workers: int,
            stacks: List[str] = None,
            traffics: List[str] = None) -> Dict[str, Dict]:
    stacks = stacks or STACKS
    traffics = traffics or TRAFFICS
    base = Experiment(workload_factory="paper_workload_1",
                      workload_kwargs=dict(duration=duration, scale=scale),
                      cluster=ClusterConfig(**CLUSTERS["std"]),
                      drain=5.0, seed=0)
    t0 = time.perf_counter()
    sweep = run_sweep(base, {"stack": stacks, "traffic": traffics},
                      workers=workers)
    wall = time.perf_counter() - t0
    rows: Dict[str, Dict] = {}
    per_cell = wall / max(1, len(sweep))
    for row in sweep:
        stack = row["cell"]["stack"]
        traffic = row["cell"]["traffic"]
        r = row["result"]
        rd = {"n_requests_total": r["n_requests_total"],
              "n_completed_total": r["n_completed"],
              "deadline_met_frac": r["deadline_met_frac"],
              "latency_percentiles": r["latency_percentiles"],
              "scaling_events": r["scaling_events"]}
        name = f"std_{stack}_{traffic}"
        rows[name] = _cell_row("std", stack, traffic, rd, per_cell)
        print(f"{name}: met={rd['deadline_met_frac']} "
              f"p99={rd['latency_percentiles']['p99']} "
              f"completed={rd['n_completed_total']}/"
              f"{rd['n_requests_total']}", flush=True)
    return rows


def run_xl(duration: float, scale: float) -> Dict[str, Dict]:
    rows: Dict[str, Dict] = {}
    for traffic in XL_TRAFFICS:
        exp = Experiment(stack="archipelago",
                         workload_factory="paper_workload_1",
                         workload_kwargs=dict(duration=duration, scale=scale,
                                              dags_per_class=20),
                         cluster=ClusterConfig(**CLUSTERS["xl"]),
                         autoscale=XL_AUTOSCALE, traffic=traffic,
                         drain=5.0, seed=0)
        t0 = time.perf_counter()
        res = simulate(exp)
        wall = time.perf_counter() - t0
        rd = {"n_requests_total": res.n_requests_total,
              "n_completed_total": res.n_completed,
              "deadline_met_frac": res.deadline_met_frac,
              "latency_percentiles": res.to_dict()["latency_percentiles"],
              "scaling_events": res.scaling_events}
        name = f"xl_{traffic}"
        row = _cell_row("xl", "archipelago", traffic, rd, wall)
        row["autoscale"] = XL_AUTOSCALE.to_dict()
        rows[name] = row
        s = row["scaling"]
        print(f"{name}: {row['wall_s']}s met={row['deadline_met_frac']} "
              f"completed={row['n_completed_total']}/{row['n_requests']} "
              f"lbs_peak={s.get('lbs_peak_replicas')} "
              f"sgs_outs={s.get('sgs_scale_outs')}", flush=True)
    return rows


def run(duration: float = 20.0) -> None:
    """``benchmarks.run`` entry point: the std matrix at reduced width,
    emitted as figure rows (full matrices live in BENCH_scenarios.json)."""
    from .common import emit
    rows = run_std(duration=duration, scale=0.5, workers=1,
                   stacks=["archipelago", "sparrow"],
                   traffics=["steady", "flash_crowd", "tenant_churn"])
    for name, r in rows.items():
        emit(f"scenarios_{r['stack']}_{r['traffic']}_met", 0.0,
             f"{r['deadline_met_frac']*100:.2f}% "
             f"(p99={r['latency_percentiles']['p99']})")
    emit("scenarios_all_completed", 0.0,
         str(all(r["all_completed"] for r in rows.values())))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="trimmed std matrix only (CI); writes "
                         "BENCH_scenarios.partial.json so the tracked "
                         "full-run file is never clobbered")
    ap.add_argument("--tier", choices=["std", "xl", "all"], default="all")
    ap.add_argument("--workers", type=int, default=4,
                    help="run_sweep process-pool width for the std matrix "
                         "(rows are byte-identical at any width)")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    repo_root = Path(__file__).resolve().parent.parent
    default_name = ("BENCH_scenarios.partial.json" if args.smoke
                    else "BENCH_scenarios.json")
    out_path = Path(args.out) if args.out else (repo_root / default_name)

    tiers = ["std", "xl"] if args.tier == "all" else [args.tier]
    if args.smoke and args.tier == "all":
        tiers = ["std"]

    runs: Dict[str, Dict] = {}
    if "std" in tiers:
        if args.smoke:
            runs.update(run_std(duration=6.0, scale=0.25,
                                workers=args.workers))
        else:
            runs.update(run_std(duration=20.0, scale=1.0,
                                workers=args.workers))
    if "xl" in tiers:
        if args.smoke:
            runs.update(run_xl(duration=4.0, scale=2.0))
        else:
            runs.update(run_xl(duration=40.0, scale=10.0))

    payload = {
        "schema": 1,
        "bench": "scenarios",
        "smoke": bool(args.smoke),
        "tiers": tiers,
        "clusters": {t: CLUSTERS[t] for t in tiers},
        "stacks": STACKS,
        "traffics": TRAFFICS,
        "python": sys.version.split()[0],
        "runs": runs,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path}")

    # hard accounting gate: no scenario may lose a request
    lost = {n: r for n, r in runs.items() if not r["all_completed"]}
    if lost:
        print(f"ACCOUNTING FAILURE: incomplete requests in {sorted(lost)}",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
