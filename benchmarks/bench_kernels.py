"""Kernel microbenchmark: Pallas vs XLA on the serving hot spots.

For each dispatch-table op (``attention``, ``decode_attention``, ``ssd``)
at serving-representative shapes, measures median device time per backend
(``xla`` = jnp reference, ``pallas`` = compiled kernel), records analytic
FLOPs / HBM bytes and the TPU-v5e roofline bound
(``max(flops/peak_flops, bytes/hbm_bw)``), and runs an interpret-mode
parity check (``pallas_interpret`` vs ``xla`` max abs error) so the
artifact itself witnesses numerical agreement.

Compiled Pallas only lowers on TPU/GPU; on a CPU host the ``pallas_s``
column is ``null`` (interpret mode is an emulation path — timing it would
be meaningless) while the parity check and the ``xla`` timings still run,
so the artifact stays reproducible everywhere.

    python -m benchmarks.bench_kernels [--smoke] [--reps N] [--out PATH]

Writes ``BENCH_kernels.json`` at the repo root; ``--smoke`` runs the small
shape subset with fewer reps and writes ``BENCH_kernels.partial.json``
(gitignored) so partial runs never clobber the tracked artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from .common import timer  # noqa: F401  (bootstraps sys.path for src/)

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

DTYPE = jnp.bfloat16
BYTES = 2                     # bf16

# (B, S, Hq, Hkv, hd, causal, window); smoke keeps the first of each op
ATTN_SHAPES = [
    (1, 512, 8, 4, 64, True, 0),
    (1, 1024, 8, 4, 64, True, 0),
    (2, 512, 8, 8, 64, True, 128),
]
# (B, L, Hq, Hkv, hd) — one-token decode over a KV cache (continuous-
# batching step shape: B in-flight requests share one dispatch)
DEC_SHAPES = [
    (8, 512, 8, 4, 64),
    (16, 1024, 8, 4, 64),
]
# (B, S, H, P, N) — Mamba2 SSD chunked scan, chunk=64
SSD_SHAPES = [
    (1, 512, 8, 64, 64),
    (2, 1024, 8, 64, 64),
]


def _attn_cost(B, S, Hq, Hkv, hd, causal, window):
    """QK^T + AV are each 2*B*S*S*Hq*hd FLOPs; causal masking halves the
    useful work.  Bytes: q/k/v read + o written once (flash kernels never
    materialize the S x S score matrix in HBM)."""
    flops = 4 * B * S * S * Hq * hd * (0.5 if causal else 1.0)
    bytes_ = BYTES * (B * S * Hq * hd * 2 + B * S * Hkv * hd * 2)
    return flops, bytes_


def _dec_cost(B, L, Hq, Hkv, hd):
    flops = 4 * B * L * Hq * hd
    bytes_ = BYTES * (B * L * Hkv * hd * 2 + B * Hq * hd * 2)
    return flops, bytes_


def _ssd_cost(B, S, H, P, N):
    """Dominant terms per token: state update (dt*B outer-product accumulate,
    2*H*P*N), output contraction C.state (2*H*P*N), plus the intra-chunk
    quadratic term amortized to ~2*H*P*chunk -> fold into a 6x multiplier."""
    flops = 6 * B * S * H * P * N
    bytes_ = BYTES * (B * S * (H * P * 2 + H + 2 * N))
    return flops, bytes_


def _rand(key, shape):
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(DTYPE)


def _cases(smoke: bool):
    """Yield (op, label, make_args(), (flops, bytes)) rows."""
    k = jax.random.PRNGKey(0)
    attn = ATTN_SHAPES[:1] if smoke else ATTN_SHAPES
    dec = DEC_SHAPES[:1] if smoke else DEC_SHAPES
    ssd = SSD_SHAPES[:1] if smoke else SSD_SHAPES
    for B, S, Hq, Hkv, hd, causal, window in attn:
        ks = jax.random.split(k, 3)
        args = (_rand(ks[0], (B, S, Hq, hd)), _rand(ks[1], (B, S, Hkv, hd)),
                _rand(ks[2], (B, S, Hkv, hd)))
        kw = dict(causal=causal, window=window)
        yield ("attention", f"attn_B{B}_S{S}_H{Hq}/{Hkv}_d{hd}"
               + (f"_w{window}" if window else ""),
               args, kw, _attn_cost(B, S, Hq, Hkv, hd, causal, window))
    for B, L, Hq, Hkv, hd in dec:
        ks = jax.random.split(k, 3)
        args = (_rand(ks[0], (B, Hq, hd)), _rand(ks[1], (B, L, Hkv, hd)),
                _rand(ks[2], (B, L, Hkv, hd)),
                jnp.full((B,), L, jnp.int32))
        yield ("decode_attention", f"dec_B{B}_L{L}_H{Hq}/{Hkv}_d{hd}",
               args, {}, _dec_cost(B, L, Hq, Hkv, hd))
    for B, S, H, P, N in ssd:
        ks = jax.random.split(k, 5)
        args = (_rand(ks[0], (B, S, H, P)),
                jax.nn.softplus(_rand(ks[1], (B, S, H)).astype(jnp.float32)),
                -jnp.exp(jax.random.normal(ks[2], (H,))),
                _rand(ks[3], (B, S, N)), _rand(ks[4], (B, S, N)))
        yield ("ssd", f"ssd_B{B}_S{S}_H{H}_P{P}_N{N}",
               args, dict(chunk=64), _ssd_cost(B, S, H, P, N))


def _median_time(fn, args, kw, reps: int) -> float:
    call = jax.jit(lambda *a: fn(*a, **kw))
    jax.block_until_ready(call(*args))          # compile outside the clock
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(call(*args))
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def _max_err(a, b) -> float:
    fa = jax.tree_util.tree_map(lambda x: jnp.asarray(x, jnp.float32), a)
    fb = jax.tree_util.tree_map(lambda x: jnp.asarray(x, jnp.float32), b)
    errs = jax.tree_util.tree_map(
        lambda x, y: float(jnp.max(jnp.abs(x - y))), fa, fb)
    return max(jax.tree_util.tree_leaves(errs))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="first shape per op, fewer reps, partial artifact")
    ap.add_argument("--reps", type=int, default=0,
                    help="timed repetitions per cell (default 20, smoke 5)")
    ap.add_argument("--out", default="",
                    help="JSON artifact path (default: BENCH_kernels.json "
                         "at the repo root, or BENCH_kernels.partial.json "
                         "with --smoke)")
    args = ap.parse_args()
    reps = args.reps or (5 if args.smoke else 20)

    platform = jax.devices()[0].platform
    compiled_ok = platform in ("tpu", "gpu")
    if not compiled_ok:
        print(f"[bench_kernels] platform={platform}: compiled Pallas "
              f"cannot lower here; pallas_s will be null (interpret "
              f"parity + xla timings still run)", flush=True)

    t0 = time.time()
    rows = []
    for op_name, label, op_args, op_kw, (flops, bytes_) in _cases(args.smoke):
        fn = getattr(ops, op_name)
        bound_s = max(flops / PEAK_FLOPS_BF16, bytes_ / HBM_BW)
        row = {
            "op": op_name, "case": label, "dtype": "bfloat16",
            "flops": flops, "hbm_bytes": bytes_,
            "roofline_bound_s": bound_s,
            "roofline_bound": ("hbm" if bytes_ / HBM_BW
                               >= flops / PEAK_FLOPS_BF16 else "compute"),
        }
        backends = ("xla", "pallas") if compiled_ok else ("xla",)
        for backend in backends:
            t = _median_time(fn, op_args, dict(op_kw, backend=backend), reps)
            row[f"{backend}_s"] = t
            row[f"{backend}_vs_bound"] = t / bound_s
        if compiled_ok:
            row["pallas_speedup"] = row["xla_s"] / row["pallas_s"]
        else:
            row["pallas_s"] = row["pallas_vs_bound"] = None
            row["pallas_speedup"] = None
        # interpret parity: the artifact itself witnesses agreement
        row["interpret_max_abs_err"] = _max_err(
            fn(*op_args, **dict(op_kw, backend="pallas_interpret")),
            fn(*op_args, **dict(op_kw, backend="xla")))
        rows.append(row)
        pal = (f"pallas={row['pallas_s']*1e3:.2f}ms "
               f"({row['pallas_speedup']:.2f}x, " if compiled_ok
               else "pallas=n/a (")
        print(f"  {label:>28}: xla={row['xla_s']*1e3:.2f}ms {pal}"
              f"bound={bound_s*1e6:.0f}us {row['roofline_bound']}-bound, "
              f"interp_err={row['interpret_max_abs_err']:.2e})", flush=True)

    repo_root = Path(__file__).resolve().parent.parent
    default_name = ("BENCH_kernels.partial.json" if args.smoke
                    else "BENCH_kernels.json")
    out_path = Path(args.out) if args.out else repo_root / default_name
    payload = {
        "schema": 1,
        "bench": "kernels",
        "smoke": bool(args.smoke),
        "python": sys.version.split()[0],
        "jax": jax.__version__,
        "device": jax.devices()[0].platform,
        "reps": reps,
        "peak_flops_bf16": PEAK_FLOPS_BF16,
        "hbm_bw": HBM_BW,
        "metric": "median wall seconds per dispatch (block_until_ready), "
                  "vs analytic roofline bound max(flops/peak, bytes/bw)",
        "rows": rows,
        "wall_s": round(time.time() - t0, 2),
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
