"""Behavioral fingerprint of ``run_archipelago`` for refactor equivalence.

Runs a matrix of small fixed-seed simulations covering every scheduler
ablation (even/packed placement, fair/LRU eviction, revive-on-dispatch,
proactive off) under eviction pressure, and reduces each run to a
fingerprint: summary counters plus a SHA-256 over the exact per-request
timeline (float bits via ``float.hex``).

Golden provenance — read before trusting or regenerating
--------------------------------------------------------
``tests/data/golden_equivalence.json`` was captured (PR 1) from the
**pre-index-refactor scan-based scheduler** carrying only this PR's two
*intentional* behavior changes, applied verbatim to the seed tree:

1. stable per-tenant workload seeding (``zlib.crc32`` instead of the
   process-salted builtin ``hash`` in ``paper_workload_1``), and
2. the reactive-allocation bugfix (public ``reactive_allocate`` that refuses
   to overcommit + fall-back-to-another-worker in ``SemiGlobalScheduler._start``),

i.e. the reference is "seed decisions modulo the sanctioned bugfix".  The
indexed scheduler was verified to match these goldens bit-for-bit, which is
what certifies the *index refactor itself* as decision-preserving.  Running
this harness against the raw seed tree (without patch 2) diverges on configs
whose pools saturate — that divergence IS the overcommit bugfix, not index
drift.  Capture procedure: stash the working tree, apply patches 1+2 to the
seed sources, run ``--write``, restore.

Regenerate (only when another *intentional* behavior change is made, from a
reference tree carrying the same change):
    PYTHONPATH=src python benchmarks/equivalence_fingerprint.py \
        --write tests/data/golden_equivalence.json
"""
from __future__ import annotations

import argparse
import hashlib
import inspect
import json
from typing import Dict

from repro.core.cluster import ClusterConfig
from repro.core.sgs import SGSConfig
from repro.sim.runner import run_archipelago
from repro.sim.workload import paper_workload_1, paper_workload_2


def _hex(x) -> str:
    return "none" if x is None else float(x).hex()


CONFIGS: Dict[str, dict] = {
    # moderate load, default policies, tight pool -> soft+hard evictions
    "wl1_even_fair": dict(
        workload=("wl1", dict(duration=5.0, scale=0.02, dags_per_class=2,
                              seed=7)),
        cluster=dict(n_sgs=2, workers_per_sgs=3, cores_per_worker=4,
                     pool_mem_mb=1024.0),
        sgs=dict(), seed=3),
    # sinusoidal load, very tight pool + few cores -> queueing, hard evictions
    "wl2_tight_pool": dict(
        workload=("wl2", dict(duration=5.0, scale=0.03, dags_per_class=2,
                              seed=11)),
        cluster=dict(n_sgs=3, workers_per_sgs=2, cores_per_worker=2,
                     pool_mem_mb=512.0),
        sgs=dict(), seed=5),
    # packed-placement + LRU-eviction ablation (Fig. 9 / §7.3.1 paths)
    "wl1_packed_lru": dict(
        workload=("wl1", dict(duration=4.0, scale=0.02, dags_per_class=2,
                              seed=7)),
        cluster=dict(n_sgs=2, workers_per_sgs=3, cores_per_worker=4,
                     pool_mem_mb=1024.0),
        sgs=dict(even_placement=False, fair_eviction=False), seed=9),
    # paper-faithful reactive path (no revive-on-dispatch)
    "wl1_no_revive": dict(
        workload=("wl1", dict(duration=4.0, scale=0.02, dags_per_class=2,
                              seed=7)),
        cluster=dict(n_sgs=2, workers_per_sgs=3, cores_per_worker=4,
                     pool_mem_mb=768.0),
        sgs=dict(revive_on_dispatch=False), seed=4),
    # proactive allocation disabled: pure reactive cold-start path
    "wl2_no_proactive": dict(
        workload=("wl2", dict(duration=4.0, scale=0.02, dags_per_class=2,
                              seed=11)),
        cluster=dict(n_sgs=2, workers_per_sgs=2, cores_per_worker=4,
                     pool_mem_mb=1024.0),
        sgs=dict(proactive=False), seed=6),
}


def fingerprint_one(name: str) -> dict:
    cfg = CONFIGS[name]
    kind, wkw = cfg["workload"]
    spec = (paper_workload_1 if kind == "wl1" else paper_workload_2)(**wkw)
    kwargs = {}
    # post-refactor runners accept a workload method; the golden was captured
    # on seed code whose only generator was the legacy dt-loop
    if "workload_method" in inspect.signature(run_archipelago).parameters:
        kwargs["workload_method"] = "legacy"
    res = run_archipelago(spec, cluster=ClusterConfig(**cfg["cluster"]),
                          sgs_cfg=SGSConfig(**cfg["sgs"]), seed=cfg["seed"],
                          **kwargs)
    m = res.metrics
    h = hashlib.sha256()
    for r in m.requests:
        h.update((f"{_hex(r.arrival_time)}|{_hex(r.completion_time)}|"
                  f"{r.n_cold_starts}|{r.sgs_id}|"
                  f"{_hex(r.total_queuing_delay)}\n").encode())
    sgss = [res.lbs.sgss[k] for k in sorted(res.lbs.sgss)]
    return {
        "n_requests": len(m.requests),
        "n_completed": len(m.completed),
        "cold_starts": [s.n_cold_starts for s in sgss],
        "warm_hits": [s.n_warm_hits for s in sgss],
        "allocations": [s.sandboxes.n_allocations for s in sgss],
        "soft_evictions": [s.sandboxes.n_soft_evictions for s in sgss],
        "hard_evictions": [s.sandboxes.n_hard_evictions for s in sgss],
        "revivals": [s.sandboxes.n_revivals for s in sgss],
        "n_events": res.env.n_events,
        "timeline_sha256": h.hexdigest(),
    }


def compute_all() -> Dict[str, dict]:
    return {name: fingerprint_one(name) for name in CONFIGS}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", default="", help="write golden JSON here")
    args = ap.parse_args()
    out = compute_all()
    text = json.dumps(out, indent=2, sort_keys=True)
    if args.write:
        with open(args.write, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.write}")
    else:
        print(text)


if __name__ == "__main__":
    main()
