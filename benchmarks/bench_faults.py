"""Chaos benchmark: fault plans as a sweep axis (docs/FAULTS.md).

Two tracked tiers, mirroring ``bench_sim_throughput``:

* ``std`` — a chaos sweep on the 200-worker cluster (8 SGSs x 25): crash
  storms, sustained Poisson crash rates, and SGS fail-stop x scheduler
  stacks (archipelago / fifo / sparrow).  ``faults`` is a literal
  ``run_sweep`` axis — each cell is one ``FaultPlan``.  The std tier also
  carries the **time-to-recovery scoreboard** (archipelago / sparrow /
  pull under IDENTICAL correlated + gray plans: rack_power, az_outage,
  cascading_crash, slow_worker, flaky_network, memory_pressure → payload
  ``"scoreboard"``: plan -> stack -> ttr_s) and a **hedged-retry
  ablation** (``params["hedge_timeout"]`` on/off under slow_worker,
  reporting ``n_hedges`` and ``tail_reduction_p99.9``).
* ``xl`` — one 2,000-worker (80 SGSs x 25) cell under a composite plan
  firing every original fault shape at staggered times (crash storm at
  T/4, SGS fail-stop at 2T/4, mass eviction at 3T/4, a control-plane
  stall between), reporting deadline-met and per-fault time-to-recovery.

Reported per cell: deadline-met fraction, completion accounting
(completed == arrivals — retries re-drive every lost execution), the
``Metrics.accounting()`` request ledger (lost == duplicates == 0 is a
hard exit gate), retry count, and the windowed recovery report (baseline
deadline-met, worst post-fault window, time until back within tolerance —
``Metrics.window`` zero-copy views; see docs/FAULTS.md "Recovery
metrics" and "Benchmarks & CI" for ttr_s semantics).

Results go to ``BENCH_faults.json`` at the repo root (tracked); ``--smoke``
runs trimmed std cells only and writes ``BENCH_faults.partial.json``
(gitignored) so CI never clobbers the tracked trajectory.

Run:
    python -m benchmarks.bench_faults [--smoke] [--tier std|xl|all]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path
from typing import Dict, Optional

try:
    import repro  # noqa: F401
except ImportError:                                     # pragma: no cover
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.autoscale import AutoscaleConfig, scaling_summary
from repro.core.cluster import ClusterConfig
from repro.core.fault import (FaultPlan, az_outage, cascading_crash,
                              control_plane_delay, flaky_network,
                              mass_eviction, memory_pressure, rack_power,
                              sgs_failstop, slow_worker, worker_crash)
from repro.sim.experiment import Experiment, run_sweep, simulate

CLUSTERS = {
    "std": dict(n_sgs=8, workers_per_sgs=25, cores_per_worker=20,
                pool_mem_mb=65536.0),
    # 2,000 workers: 80 rack-sized SGS pools of 25 machines
    "xl": dict(n_sgs=80, workers_per_sgs=25, cores_per_worker=20,
               pool_mem_mb=65536.0),
}

# see bench_sim_throughput: the routing tier sizes itself under load
# (core.autoscale) instead of a hand-tuned n_lbs
XL_AUTOSCALE = AutoscaleConfig()

STACKS = ["archipelago", "fifo", "sparrow"]

# the recovery scoreboard compares the paper's stack against the two
# decentralized baselines under IDENTICAL seeded plans (docs/FAULTS.md
# "Recovery scoreboard")
SCOREBOARD_STACKS = ["archipelago", "sparrow", "pull"]

# straggler-mitigation knob for the hedged ablation: duplicate an
# invocation once it runs 1.5x over its expected execution time
HEDGE_TIMEOUT = 1.5


def std_plans(duration: float) -> Dict[str, Optional[FaultPlan]]:
    """The std-tier chaos axis: one plan per fault shape plus the no-fault
    baseline every chaos cell is compared against."""
    t1 = round(duration / 3.0, 3)
    return {
        "none": None,
        # 10 workers (5% of the pool) fail-stop at once
        "crash_storm": FaultPlan(
            events=(worker_crash(k=10, at=t1),), seed=0, name="crash_storm"),
        # sustained attrition: ~1 crash every 2 s for the whole run
        "crash_rate": FaultPlan(
            events=(worker_crash(k=1, rate=0.5, start=1.0),), seed=0,
            name="crash_rate"),
        # one scheduler process dies; replacement restores from the store
        # (recorded-but-skipped on the flat baseline stacks)
        "sgs_failstop": FaultPlan(
            events=(sgs_failstop(at=t1),), seed=0, name="sgs_failstop"),
    }


def gray_plans(duration: float) -> Dict[str, FaultPlan]:
    """The gray-failure scoreboard axis: topology-correlated crashes plus
    degraded-mode (non-fail-stop) shapes, all seeded so every stack sees
    the identical schedule (docs/FAULTS.md "Gray failures")."""
    t1 = round(duration / 3.0, 3)
    return {
        # correlated: one rack (= one SGS pool, 25 workers) loses power
        "rack_power": FaultPlan(
            events=(rack_power(at=t1),), seed=0, name="rack_power"),
        # correlated: a whole availability zone (racks_per_az racks) goes
        "az_outage": FaultPlan(
            events=(az_outage(at=t1),), seed=0, name="az_outage"),
        # correlated: seeded branching-process crash cascade
        "cascading_crash": FaultPlan(
            events=(cascading_crash(at=t1, p=0.6, k0=2),), seed=0,
            name="cascading_crash"),
        # degraded: stragglers — 8 workers run 16x slow (not fail-stop)
        "slow_worker": FaultPlan(
            events=(slow_worker(at=t1, k=8, factor=16.0),), seed=0,
            name="slow_worker"),
        # degraded: seeded jitter on the LBS<->SGS control-plane clocks
        "flaky_network": FaultPlan(
            events=(flaky_network(rate=2.0, jitter=0.02, start=1.0,
                                  end=duration),), seed=0,
            name="flaky_network"),
        # degraded: pool memory shrinks 60% for 2 s -> real eviction storm
        "memory_pressure": FaultPlan(
            events=(memory_pressure(at=t1, frac=0.6, duration=2.0),),
            seed=0, name="memory_pressure"),
    }


def _ttr(recovery: Dict) -> Optional[float]:
    """Scoreboard time-to-recovery for one run: the worst per-fault
    recovery time; 0.0 when no fault dipped past tolerance; None when any
    fault never recovered within the horizon."""
    if recovery.get("n_unrecovered"):
        return None
    m = recovery.get("max_recovery_s")
    return 0.0 if m is None else m


def xl_plan(duration: float) -> FaultPlan:
    """Every built-in fault shape, staggered so each recovery window is
    attributable to one fault."""
    q = duration / 4.0
    return FaultPlan(
        events=(worker_crash(k=20, at=round(q, 3)),
                sgs_failstop(at=round(2 * q, 3)),
                control_plane_delay(at=round(2.5 * q, 3), stall=0.05),
                mass_eviction(at=round(3 * q, 3), frac=0.5)),
        seed=0, name="composite")


def _cell_row(name: str, tier: str, stack: str, plan_label: str,
              rd: Dict, wall_s: float) -> Dict:
    """Compact tracked row: accounting + recovery, not the full result."""
    acct = rd.get("accounting", {})
    row = {
        "tier": tier,
        "stack": stack,
        "plan": plan_label,
        "wall_s": round(wall_s, 3),
        "n_requests": rd["n_requests_total"],
        "n_completed_total": rd["n_completed_total"],
        "all_completed": rd["n_completed_total"] == rd["n_requests_total"],
        "deadline_met_frac": rd["deadline_met_frac"],
        "n_retries": rd["n_retries"],
        "fault_events": rd["fault_events"],
        "recovery": rd["recovery"],
    }
    if acct:
        row["accounting"] = acct
        row["accounting_ok"] = (acct["lost"] == 0
                                and acct["duplicate_completions"] == 0)
    return row


def _result_rd(r: Dict) -> Dict:
    """The compact per-cell view `_cell_row` consumes, from a result dict."""
    return {"n_requests_total": r["n_requests_total"],
            "n_completed_total": r["n_completed"],
            "deadline_met_frac": r["deadline_met_frac"],
            "n_retries": r["n_retries"],
            "fault_events": r["fault_events"],
            "recovery": r["recovery"],
            "accounting": r.get("accounting", {})}


def run_scoreboard(duration: float, scale: float, workers: int
                   ) -> Dict[str, Dict]:
    """The time-to-recovery scoreboard: every SCOREBOARD stack under the
    identical seeded gray plans (correlated + degraded shapes).  The drain
    is long enough for 16x-slowed stragglers to finish, so zero-lost
    accounting is a hard expectation, not an aspiration."""
    plans = gray_plans(duration)
    base = Experiment(workload_factory="paper_workload_1",
                      workload_kwargs=dict(duration=duration, scale=scale),
                      cluster=ClusterConfig(**CLUSTERS["std"]),
                      drain=40.0, seed=0)
    t0 = time.perf_counter()
    sweep = run_sweep(base, {"stack": SCOREBOARD_STACKS,
                             "faults": list(plans.values())},
                      workers=workers)
    wall = time.perf_counter() - t0
    labels = list(plans)
    rows: Dict[str, Dict] = {}
    per_cell = wall / max(1, len(sweep))
    for row in sweep:
        stack = row["cell"]["stack"]
        label = labels[list(plans.values()).index(row["cell"]["faults"])]
        r = row["result"]
        name = f"score_{stack}_{label}"
        cell = _cell_row(name, "std", stack, label, _result_rd(r), per_cell)
        cell["ttr_s"] = _ttr(r["recovery"])
        cell["p99"] = r["latency_percentiles"]["p99"]
        rows[name] = cell
        print(f"{name}: ttr={cell['ttr_s']} met={cell['deadline_met_frac']} "
              f"retries={cell['n_retries']} acct_ok={cell['accounting_ok']}",
              flush=True)
    return rows


def run_hedge_ablation(duration: float, scale: float) -> Dict[str, Dict]:
    """Hedged-retry on/off under the slow_worker plan (archipelago only:
    the hedge lives in the SGS).  The tail above the workload's own heavy
    band is where stragglers land, so the headline comparison is p99.9/max,
    with p99 reported alongside."""
    plan = gray_plans(duration)["slow_worker"]
    rows: Dict[str, Dict] = {}
    for label, params in (("off", {}),
                          ("on", {"hedge_timeout": HEDGE_TIMEOUT})):
        exp = Experiment(stack="archipelago",
                         workload_factory="paper_workload_1",
                         workload_kwargs=dict(duration=duration,
                                              scale=scale),
                         cluster=ClusterConfig(**CLUSTERS["std"]),
                         drain=40.0, seed=0, faults=plan, params=params)
        t0 = time.perf_counter()
        res = simulate(exp)
        wall = time.perf_counter() - t0
        name = f"hedge_{label}_slow_worker"
        cell = _cell_row(name, "std", "archipelago", "slow_worker",
                         _result_rd(res.to_dict()), wall)
        cell["hedge_timeout"] = params.get("hedge_timeout")
        cell["n_hedges"] = res.n_hedges
        cell["p99"] = res.latency_percentiles["p99"]
        cell["p99.9"] = res.latency_percentiles["p99.9"]
        rows[name] = cell
        print(f"{name}: p99={cell['p99']} p99.9={cell['p99.9']} "
              f"hedges={cell['n_hedges']} acct_ok={cell['accounting_ok']}",
              flush=True)
    off = rows["hedge_off_slow_worker"]
    on = rows["hedge_on_slow_worker"]
    if on["p99.9"] is not None and off["p99.9"] is not None:
        on["tail_reduction_p99.9"] = round(off["p99.9"] - on["p99.9"], 6)
    return rows


def run_gray_smoke(duration: float, scale: float) -> Dict[str, Dict]:
    """CI gray cells under the *stub* backend (the real-execution code
    path, scripted times): one correlated-fault cell and one
    slow_worker+hedging cell, both gated on the accounting invariant."""
    cells = (
        ("smoke_stub_rack_power",
         dict(faults=gray_plans(duration)["rack_power"])),
        ("smoke_stub_slow_worker_hedged",
         dict(faults=gray_plans(duration)["slow_worker"],
              params={"hedge_timeout": HEDGE_TIMEOUT})),
    )
    rows: Dict[str, Dict] = {}
    for name, kw in cells:
        exp = Experiment(stack="archipelago", backend="stub",
                         workload_factory="paper_workload_1",
                         workload_kwargs=dict(duration=duration,
                                              scale=scale),
                         cluster=ClusterConfig(**CLUSTERS["std"]),
                         drain=40.0, seed=0, **kw)
        t0 = time.perf_counter()
        res = simulate(exp)
        wall = time.perf_counter() - t0
        cell = _cell_row(name, "std", "archipelago",
                         kw["faults"].name, _result_rd(res.to_dict()), wall)
        cell["backend"] = "stub"
        cell["n_hedges"] = res.n_hedges
        rows[name] = cell
        print(f"{name}: met={cell['deadline_met_frac']} "
              f"retries={cell['n_retries']} hedges={cell['n_hedges']} "
              f"acct_ok={cell['accounting_ok']}", flush=True)
    return rows


def run_std(duration: float, scale: float, workers: int) -> Dict[str, Dict]:
    plans = std_plans(duration)
    base = Experiment(workload_factory="paper_workload_1",
                      workload_kwargs=dict(duration=duration, scale=scale),
                      cluster=ClusterConfig(**CLUSTERS["std"]),
                      drain=5.0, seed=0)
    t0 = time.perf_counter()
    sweep = run_sweep(base, {"stack": STACKS,
                             "faults": list(plans.values())},
                      workers=workers)
    wall = time.perf_counter() - t0
    labels = list(plans)
    rows: Dict[str, Dict] = {}
    per_cell = wall / max(1, len(sweep))
    for row in sweep:
        stack = row["cell"]["stack"]
        plan = row["cell"]["faults"]
        label = labels[list(plans.values()).index(plan)]
        r = row["result"]
        # full-trace accounting: every arrival must complete (the window
        # metrics in `recovery` are where the dip shows up)
        rd = _result_rd(r)
        name = f"std_{stack}_{label}"
        rows[name] = _cell_row(name, "std", stack, label, rd, per_cell)
        print(f"{name}: met={rd['deadline_met_frac']} "
              f"retries={rd['n_retries']} "
              f"completed={rd['n_completed_total']}/"
              f"{rd['n_requests_total']}", flush=True)
    return rows


def run_xl(duration: float, scale: float) -> Dict[str, Dict]:
    plan = xl_plan(duration)
    exp = Experiment(stack="archipelago",
                     workload_factory="paper_workload_1",
                     workload_kwargs=dict(duration=duration, scale=scale,
                                          dags_per_class=20),
                     cluster=ClusterConfig(**CLUSTERS["xl"]),
                     autoscale=XL_AUTOSCALE, drain=5.0, seed=0,
                     faults=plan)
    t0 = time.perf_counter()
    res = simulate(exp)
    wall = time.perf_counter() - t0
    rd = _result_rd(res.to_dict())
    name = "xl_composite_chaos"
    row = _cell_row(name, "xl", "archipelago", plan.name, rd, wall)
    row["autoscale"] = XL_AUTOSCALE.to_dict()
    row["scaling"] = scaling_summary(res.scaling_events)
    print(f"{name}: {row['wall_s']}s met={row['deadline_met_frac']} "
          f"retries={row['n_retries']} "
          f"completed={row['n_completed_total']}/{row['n_requests']}",
          flush=True)
    for ev in res.recovery.get("events", []):
        print(f"  {ev['kind']}@{ev['t']}: "
              f"recovery_s={ev.get('recovery_s')} "
              f"dip={ev.get('dip_met')}", flush=True)
    return {name: row}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="trimmed std cells only (CI); writes "
                         "BENCH_faults.partial.json so the tracked "
                         "full-run file is never clobbered")
    ap.add_argument("--tier", choices=["std", "xl", "all"], default="all")
    ap.add_argument("--workers", type=int, default=4,
                    help="run_sweep process-pool width for the std sweep "
                         "(rows are byte-identical at any width)")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    repo_root = Path(__file__).resolve().parent.parent
    default_name = ("BENCH_faults.partial.json" if args.smoke
                    else "BENCH_faults.json")
    out_path = Path(args.out) if args.out else (repo_root / default_name)

    tiers = ["std", "xl"] if args.tier == "all" else [args.tier]
    if args.smoke and args.tier == "all":
        tiers = ["std"]

    runs: Dict[str, Dict] = {}
    if "std" in tiers:
        if args.smoke:
            runs.update(run_std(duration=6.0, scale=0.25,
                                workers=args.workers))
            runs.update(run_scoreboard(duration=6.0, scale=0.25,
                                       workers=args.workers))
            runs.update(run_gray_smoke(duration=6.0, scale=0.25))
        else:
            runs.update(run_std(duration=20.0, scale=1.0,
                                workers=args.workers))
            runs.update(run_scoreboard(duration=20.0, scale=1.0,
                                       workers=args.workers))
            runs.update(run_hedge_ablation(duration=20.0, scale=1.0))
    if "xl" in tiers:
        if args.smoke:
            runs.update(run_xl(duration=4.0, scale=2.0))
        else:
            runs.update(run_xl(duration=40.0, scale=10.0))

    # compact per-stack time-to-recovery scoreboard: plan -> stack -> TTR
    # (identical seeded plans per stack; see docs/FAULTS.md)
    scoreboard: Dict[str, Dict[str, Optional[float]]] = {}
    for r in runs.values():
        if "ttr_s" in r:
            scoreboard.setdefault(r["plan"], {})[r["stack"]] = r["ttr_s"]

    payload = {
        "schema": 2,
        "bench": "faults",
        "smoke": bool(args.smoke),
        "tiers": tiers,
        "clusters": {t: CLUSTERS[t] for t in tiers},
        "python": sys.version.split()[0],
        "scoreboard": scoreboard,
        "runs": runs,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path}")

    # hard accounting gates: chaos must never lose a request, and the
    # invariant completed + lost + pending == arrivals must hold with
    # lost == 0 and no duplicate completions in every cell that carries
    # full accounting
    lost = {n: r for n, r in runs.items() if not r["all_completed"]}
    if lost:
        print(f"ACCOUNTING FAILURE: incomplete requests in {sorted(lost)}",
              file=sys.stderr)
        sys.exit(1)
    bad = {n: r["accounting"] for n, r in runs.items()
           if "accounting_ok" in r and not r["accounting_ok"]}
    if bad:
        print(f"ACCOUNTING INVARIANT FAILURE: {bad}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
