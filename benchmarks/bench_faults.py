"""Chaos benchmark: fault plans as a sweep axis (docs/FAULTS.md).

Two tracked tiers, mirroring ``bench_sim_throughput``:

* ``std`` — a chaos sweep on the 200-worker cluster (8 SGSs x 25): crash
  storms, sustained Poisson crash rates, and SGS fail-stop x scheduler
  stacks (archipelago / fifo / sparrow).  ``faults`` is a literal
  ``run_sweep`` axis — each cell is one ``FaultPlan``.
* ``xl`` — one 2,000-worker (80 SGSs x 25) cell under a composite plan
  firing every built-in fault shape at staggered times (crash storm at
  T/4, SGS fail-stop at 2T/4, mass eviction at 3T/4, a control-plane
  stall between), reporting deadline-met and per-fault time-to-recovery.

Reported per cell: deadline-met fraction, completion accounting
(completed == arrivals — retries re-drive every lost execution), retry
count, and the windowed recovery report (baseline deadline-met, worst
post-fault window, time until back within tolerance — ``Metrics.window``
zero-copy views; see docs/FAULTS.md "Recovery metrics").

Results go to ``BENCH_faults.json`` at the repo root (tracked); ``--smoke``
runs trimmed std cells only and writes ``BENCH_faults.partial.json``
(gitignored) so CI never clobbers the tracked trajectory.

Run:
    python -m benchmarks.bench_faults [--smoke] [--tier std|xl|all]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path
from typing import Dict, Optional

try:
    import repro  # noqa: F401
except ImportError:                                     # pragma: no cover
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.autoscale import AutoscaleConfig, scaling_summary
from repro.core.cluster import ClusterConfig
from repro.core.fault import (FaultPlan, control_plane_delay, mass_eviction,
                              sgs_failstop, worker_crash)
from repro.sim.experiment import Experiment, run_sweep, simulate

CLUSTERS = {
    "std": dict(n_sgs=8, workers_per_sgs=25, cores_per_worker=20,
                pool_mem_mb=65536.0),
    # 2,000 workers: 80 rack-sized SGS pools of 25 machines
    "xl": dict(n_sgs=80, workers_per_sgs=25, cores_per_worker=20,
               pool_mem_mb=65536.0),
}

# see bench_sim_throughput: the routing tier sizes itself under load
# (core.autoscale) instead of a hand-tuned n_lbs
XL_AUTOSCALE = AutoscaleConfig()

STACKS = ["archipelago", "fifo", "sparrow"]


def std_plans(duration: float) -> Dict[str, Optional[FaultPlan]]:
    """The std-tier chaos axis: one plan per fault shape plus the no-fault
    baseline every chaos cell is compared against."""
    t1 = round(duration / 3.0, 3)
    return {
        "none": None,
        # 10 workers (5% of the pool) fail-stop at once
        "crash_storm": FaultPlan(
            events=(worker_crash(k=10, at=t1),), seed=0, name="crash_storm"),
        # sustained attrition: ~1 crash every 2 s for the whole run
        "crash_rate": FaultPlan(
            events=(worker_crash(k=1, rate=0.5, start=1.0),), seed=0,
            name="crash_rate"),
        # one scheduler process dies; replacement restores from the store
        # (recorded-but-skipped on the flat baseline stacks)
        "sgs_failstop": FaultPlan(
            events=(sgs_failstop(at=t1),), seed=0, name="sgs_failstop"),
    }


def xl_plan(duration: float) -> FaultPlan:
    """Every built-in fault shape, staggered so each recovery window is
    attributable to one fault."""
    q = duration / 4.0
    return FaultPlan(
        events=(worker_crash(k=20, at=round(q, 3)),
                sgs_failstop(at=round(2 * q, 3)),
                control_plane_delay(at=round(2.5 * q, 3), stall=0.05),
                mass_eviction(at=round(3 * q, 3), frac=0.5)),
        seed=0, name="composite")


def _cell_row(name: str, tier: str, stack: str, plan_label: str,
              rd: Dict, wall_s: float) -> Dict:
    """Compact tracked row: accounting + recovery, not the full result."""
    return {
        "tier": tier,
        "stack": stack,
        "plan": plan_label,
        "wall_s": round(wall_s, 3),
        "n_requests": rd["n_requests_total"],
        "n_completed_total": rd["n_completed_total"],
        "all_completed": rd["n_completed_total"] == rd["n_requests_total"],
        "deadline_met_frac": rd["deadline_met_frac"],
        "n_retries": rd["n_retries"],
        "fault_events": rd["fault_events"],
        "recovery": rd["recovery"],
    }


def run_std(duration: float, scale: float, workers: int) -> Dict[str, Dict]:
    plans = std_plans(duration)
    base = Experiment(workload_factory="paper_workload_1",
                      workload_kwargs=dict(duration=duration, scale=scale),
                      cluster=ClusterConfig(**CLUSTERS["std"]),
                      drain=5.0, seed=0)
    t0 = time.perf_counter()
    sweep = run_sweep(base, {"stack": STACKS,
                             "faults": list(plans.values())},
                      workers=workers)
    wall = time.perf_counter() - t0
    labels = list(plans)
    rows: Dict[str, Dict] = {}
    per_cell = wall / max(1, len(sweep))
    for row in sweep:
        stack = row["cell"]["stack"]
        plan = row["cell"]["faults"]
        label = labels[list(plans.values()).index(plan)]
        r = row["result"]
        # full-trace accounting: every arrival must complete (the window
        # metrics in `recovery` are where the dip shows up)
        rd = {"n_requests_total": r["n_requests_total"],
              "n_completed_total": r["n_completed"],
              "deadline_met_frac": r["deadline_met_frac"],
              "n_retries": r["n_retries"],
              "fault_events": r["fault_events"],
              "recovery": r["recovery"]}
        name = f"std_{stack}_{label}"
        rows[name] = _cell_row(name, "std", stack, label, rd, per_cell)
        print(f"{name}: met={rd['deadline_met_frac']} "
              f"retries={rd['n_retries']} "
              f"completed={rd['n_completed_total']}/"
              f"{rd['n_requests_total']}", flush=True)
    return rows


def run_xl(duration: float, scale: float) -> Dict[str, Dict]:
    plan = xl_plan(duration)
    exp = Experiment(stack="archipelago",
                     workload_factory="paper_workload_1",
                     workload_kwargs=dict(duration=duration, scale=scale,
                                          dags_per_class=20),
                     cluster=ClusterConfig(**CLUSTERS["xl"]),
                     autoscale=XL_AUTOSCALE, drain=5.0, seed=0,
                     faults=plan)
    t0 = time.perf_counter()
    res = simulate(exp)
    wall = time.perf_counter() - t0
    rd = {"n_requests_total": res.n_requests_total,
          "n_completed_total": res.n_completed,
          "deadline_met_frac": res.deadline_met_frac,
          "n_retries": res.n_retries,
          "fault_events": res.fault_events,
          "recovery": res.recovery}
    name = "xl_composite_chaos"
    row = _cell_row(name, "xl", "archipelago", plan.name, rd, wall)
    row["autoscale"] = XL_AUTOSCALE.to_dict()
    row["scaling"] = scaling_summary(res.scaling_events)
    print(f"{name}: {row['wall_s']}s met={row['deadline_met_frac']} "
          f"retries={row['n_retries']} "
          f"completed={row['n_completed_total']}/{row['n_requests']}",
          flush=True)
    for ev in res.recovery.get("events", []):
        print(f"  {ev['kind']}@{ev['t']}: "
              f"recovery_s={ev.get('recovery_s')} "
              f"dip={ev.get('dip_met')}", flush=True)
    return {name: row}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="trimmed std cells only (CI); writes "
                         "BENCH_faults.partial.json so the tracked "
                         "full-run file is never clobbered")
    ap.add_argument("--tier", choices=["std", "xl", "all"], default="all")
    ap.add_argument("--workers", type=int, default=4,
                    help="run_sweep process-pool width for the std sweep "
                         "(rows are byte-identical at any width)")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    repo_root = Path(__file__).resolve().parent.parent
    default_name = ("BENCH_faults.partial.json" if args.smoke
                    else "BENCH_faults.json")
    out_path = Path(args.out) if args.out else (repo_root / default_name)

    tiers = ["std", "xl"] if args.tier == "all" else [args.tier]
    if args.smoke and args.tier == "all":
        tiers = ["std"]

    runs: Dict[str, Dict] = {}
    if "std" in tiers:
        if args.smoke:
            runs.update(run_std(duration=6.0, scale=0.25,
                                workers=args.workers))
        else:
            runs.update(run_std(duration=20.0, scale=1.0,
                                workers=args.workers))
    if "xl" in tiers:
        if args.smoke:
            runs.update(run_xl(duration=4.0, scale=2.0))
        else:
            runs.update(run_xl(duration=40.0, scale=10.0))

    payload = {
        "schema": 1,
        "bench": "faults",
        "smoke": bool(args.smoke),
        "tiers": tiers,
        "clusters": {t: CLUSTERS[t] for t in tiers},
        "python": sys.version.split()[0],
        "runs": runs,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path}")

    # hard accounting gate: chaos must never lose a request
    lost = {n: r for n, r in runs.items() if not r["all_completed"]}
    if lost:
        print(f"ACCOUNTING FAILURE: incomplete requests in {sorted(lost)}",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
