"""Unit tests for the roofline toolchain's HLO parsing (no 512-device mesh
needed — those paths are covered by the launch sweeps themselves)."""
import pytest

from repro.configs import get_config
from repro.launch.roofline import (_shape_bytes, model_flops,
                                   parse_collective_bytes)


def test_shape_bytes_basic():
    assert _shape_bytes("f32[4,8]{1,0}") == 4 * 8 * 4
    assert _shape_bytes("bf16[2,3,5]") == 2 * 3 * 5 * 2
    assert _shape_bytes("pred[7]") == 7
    assert _shape_bytes("(f32[4], bf16[8,2])") == 16 + 32
    assert _shape_bytes("s32[]") == 0 or _shape_bytes("s32[]") == 4  # scalar


def test_parse_collectives_ring_factors():
    hlo = """
HloModule test
ENTRY main {
  %p0 = bf16[16,128]{1,0} parameter(0)
  %ag = bf16[256,128]{1,0} all-gather(%p0), replica_groups=[1,16]<=[16], dimensions={0}
  %ar = bf16[256,128]{1,0} all-reduce(%ag), replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = bf16[16,128]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  ROOT %t = (bf16[256,128]{1,0}) tuple(%ar)
}
"""
    out = parse_collective_bytes(hlo, default_group=16)
    ag_result = 256 * 128 * 2
    assert out["all-gather"] == pytest.approx((15 / 16) * ag_result)
    ar_operand = 256 * 128 * 2
    assert out["all-reduce"] == pytest.approx(2 * (3 / 4) * ar_operand)
    assert out["collective-permute"] == pytest.approx(16 * 128 * 2)
    assert out["all-to-all"] == 0.0


def test_parse_collectives_ignores_non_collectives():
    hlo = "%x = f32[8]{0} add(%a, %b)\n%y = f32[8]{0} dot(%x, %x)\n"
    out = parse_collective_bytes(hlo, default_group=4)
    assert sum(out.values()) == 0.0


def test_model_flops_moe_counts_active_only():
    dense = get_config("minitron-8b")
    moe = get_config("mixtral-8x22b")
    # mixtral total params >> active params; model_flops must use active
    assert moe.param_count() > 2.5 * moe.active_param_count()
    f_train = model_flops(moe, "train_4k")
    assert f_train == pytest.approx(
        6.0 * moe.active_param_count() * 256 * 4096)
    # decode: one token per sequence
    assert model_flops(dense, "decode_32k") == pytest.approx(
        2.0 * dense.param_count() * 128)


def test_param_count_magnitudes():
    """Sanity: analytic parameter counts are in each card's ballpark."""
    expect = {"minicpm-2b": (2.0e9, 3.3e9),
              "phi3-mini-3.8b": (3.3e9, 4.4e9),
              "minitron-8b": (7.0e9, 10.0e9),
              "mamba2-370m": (0.3e9, 0.5e9),
              "mixtral-8x22b": (120e9, 150e9),
              "gemma3-1b": (0.8e9, 1.6e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo},{hi}]"
