"""Flat (column-recording) metrics plane (PR 5): the simulate pump records
into numpy columns, yet every statistic and the compatibility ``requests``
view must match the legacy per-object accounting bit-for-bit."""
import math

import numpy as np
import pytest

from repro.core.types import DagSpec, FunctionSpec, Request
from repro.sim import Experiment, Metrics, simulate
from repro.sim.metrics import summarize


def _run(stack="archipelago", warmup=0.0, **wl):
    wl = dict(dict(duration=2.5, scale=0.04, dags_per_class=2), **wl)
    return simulate(Experiment(stack=stack, workload_factory="paper_workload_1",
                               workload_kwargs=wl, warmup=warmup, drain=4.0))


def _legacy_copy(m):
    """Rebuild a legacy object-mode Metrics from the flat one's
    compatibility view."""
    return Metrics(requests=list(m.requests),
                   queuing_delays=list(m.queuing_delays),
                   queuing_delay_times=list(m.queuing_delay_times))


@pytest.mark.parametrize("stack", ["archipelago", "fifo", "sparrow", "pull"])
def test_simulate_uses_flat_mode_for_every_builtin_stack(stack):
    res = _run(stack=stack)
    assert res.sim.metrics.is_flat
    assert res.n_completed > 0


def test_flat_statistics_match_legacy_object_scan():
    m = _run().sim.metrics
    leg = _legacy_copy(m)
    assert m.n_requests == len(leg.requests)
    assert m.n_completed == len(leg.completed)
    assert list(m.sorted_latencies()) == leg.sorted_latencies()
    assert m.latency_pct(99) == leg.latency_pct(99)
    assert m.deadline_met_frac() == leg.deadline_met_frac()
    assert m.cold_start_count() == leg.cold_start_count()
    assert m.cold_start_frac() == leg.cold_start_frac()
    assert summarize("x", m) == summarize("x", leg)


def test_flat_after_warmup_matches_legacy_filtering():
    m = _run(warmup=0.0).sim.metrics
    w = m.after_warmup(1.0)
    leg = _legacy_copy(m).after_warmup(1.0)
    assert w.is_flat                        # zero-copy view, same columns
    assert w._cols is m._cols
    assert w.n_requests == len(leg.requests)
    assert w.n_completed == len(leg.completed)
    assert list(w.sorted_latencies()) == leg.sorted_latencies()
    assert w.deadline_met_frac() == leg.deadline_met_frac()
    assert list(w.queuing_delays) == leg.queuing_delays
    assert list(w.queuing_delay_times) == leg.queuing_delay_times
    assert all(t >= 1.0 for t in w.queuing_delay_times)


def test_flat_by_class_matches_legacy_views():
    m = _run().sim.metrics
    flat_cls = m.by_class()
    leg_cls = _legacy_copy(m).by_class()
    assert set(flat_cls) == set(leg_cls)
    for name in flat_cls:
        f, l = flat_cls[name], leg_cls[name]
        assert f.n_requests == len(l.requests)
        assert f.n_completed == len(l.completed)
        assert list(f.sorted_latencies()) == l.sorted_latencies()
        assert f.cold_start_count() == l.cold_start_count()


def test_compatibility_requests_view_is_bit_identical():
    """Materialized Request objects must carry the exact recorded floats
    (the equivalence fingerprints hash float bits off this view)."""
    m = _run().sim.metrics
    reqs = m.requests
    arr = m._cols.arrival
    assert len(reqs) == len(arr)
    for i, r in enumerate(reqs):
        assert isinstance(r, Request)
        assert r.arrival_time == arr[i]     # exact float round-trip
        assert r.completion_time is None or isinstance(r.completion_time,
                                                       float)
    # arrival order is non-decreasing (the column is the sorted trace)
    ts = [r.arrival_time for r in reqs]
    assert ts == sorted(ts)


def test_incomplete_requests_stay_live_and_exact():
    """Requests still in flight at the end of the run come back as the
    actual live objects (accurate partial state), and completed rows free
    their objects."""
    dag = DagSpec("slow-0", (FunctionSpec("slow-0/f", 5.0),), (),
                  deadline=10.0)
    from repro.sim.workload import ConstantRate, WorkloadSpec
    spec = WorkloadSpec([(dag, ConstantRate(2.0))], duration=1.0)
    res = simulate(Experiment(workload=spec, drain=0.5))  # exec outlives run
    m = res.sim.metrics
    assert m.n_completed == 0
    assert len(m._cols.pending) == m.n_requests > 0
    for r in m.requests:
        assert r.completion_time is None
        assert math.isnan(np.float64("nan")) or True
    assert math.isnan(m.deadline_met_frac())


def test_completed_requests_release_objects():
    res = _run()
    m = res.sim.metrics
    assert len(m._cols.pending) == 0        # everything drained
    assert m.n_completed == m.n_requests


def test_legacy_constructor_unchanged():
    """Direct Metrics construction (tests, fig_fault) keeps full object-mode
    semantics including post-append mutation visibility."""
    dag = DagSpec("d-0", (FunctionSpec("d-0/f", 0.1),), (), deadline=1.0)
    m = Metrics()
    assert not m.is_flat
    r = Request(dag=dag, arrival_time=0.0)
    m.requests.append(r)
    assert m.n_completed == 0
    r.completion_time = 0.2
    assert m.n_completed == 1
    assert m.latency_pct(50) == pytest.approx(0.2)
