"""The O(1)-index scheduler must be decision-identical to the legacy scans.

``tests/data/golden_equivalence.json`` holds fingerprints (per-request
timeline SHA-256 + scheduler counters) captured from the pre-refactor
scan-based scheduler — carrying this PR's two sanctioned behavior changes
(stable workload seeding + the reactive-allocation overcommit bugfix; see
``benchmarks/equivalence_fingerprint.py`` for the exact provenance and
capture procedure) — on fixed-seed workloads covering every ablation:
even/packed placement, fair/LRU eviction, revive-on-dispatch on/off, and
proactive allocation off.  Any drift in placement order, eviction victims,
lazy WARM promotion, or queue tie-breaking shows up as a hash mismatch.

Regenerate only for *intentional* behavior changes, from a reference tree
carrying the same change:
    PYTHONPATH=src python benchmarks/equivalence_fingerprint.py \
        --write tests/data/golden_equivalence.json
"""
import json
import pathlib
import sys

import pytest

BENCH_DIR = pathlib.Path(__file__).resolve().parent.parent
GOLDEN = pathlib.Path(__file__).resolve().parent / "data" / \
    "golden_equivalence.json"

sys.path.insert(0, str(BENCH_DIR))

from benchmarks.equivalence_fingerprint import CONFIGS, fingerprint_one  # noqa: E402


@pytest.fixture(scope="module")
def golden():
    return json.loads(GOLDEN.read_text())


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_scheduler_matches_pre_refactor_golden(name, golden):
    got = fingerprint_one(name)
    want = golden[name]
    # compare counters first for a readable diff, then the exact timeline
    for key in ("n_requests", "n_completed", "cold_starts", "warm_hits",
                "allocations", "soft_evictions", "hard_evictions",
                "revivals", "n_events"):
        assert got[key] == want[key], f"{name}: {key} diverged"
    assert got["timeline_sha256"] == want["timeline_sha256"], (
        f"{name}: counters match but the per-request timeline diverged")
