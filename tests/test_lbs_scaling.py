"""LBS unit tests: lottery, scaling metric, gradual scale-out/in, hotspot
damping."""
import pytest

from repro.core import ClusterConfig, LBSConfig, Request, SGSConfig
from repro.core.cluster import build_cluster
from repro.core.types import DagSpec, FunctionSpec
from repro.sim.engine import SimEnv


def _stack(n_sgs=4, lbs_cfg=None):
    env = SimEnv()
    lbs = build_cluster(env, ClusterConfig(n_sgs=n_sgs, workers_per_sgs=2,
                                           cores_per_worker=4),
                        lbs_cfg=lbs_cfg)
    dag = DagSpec("d", (FunctionSpec("d/f", 0.1, setup_time=0.2),), (),
                  deadline=0.3)
    return env, lbs, dag


def test_initial_sgs_via_consistent_hashing():
    env, lbs, dag = _stack()
    req = Request(dag=dag, arrival_time=0.0)
    sgs = lbs.select(req, 0.0)
    assert sgs.sgs_id == lbs.ring.lookup("d")
    # all requests for the DAG go to the single active SGS initially
    for _ in range(10):
        assert lbs.select(Request(dag=dag, arrival_time=0.0),
                          0.0).sgs_id == sgs.sgs_id


def test_scaling_metric_normalized_by_slack():
    env, lbs, dag = _stack()
    st = lbs._state(dag, 0.0)
    sid = st.active[0]
    st.sandbox_count[sid] = 10
    st.qdelay_ewma[sid] = 0.06                 # 60ms queuing delay
    metric = lbs.scaling_metric(st)
    assert metric == pytest.approx(0.06 / dag.slack)
    assert metric > 0.29                       # would trigger SOT=0.3 ~ now


def test_scale_out_adds_ring_successor_and_preallocates():
    env, lbs, dag = _stack()
    st = lbs._state(dag, 0.0)
    first = st.active[0]
    assert lbs._scale_out(st, 0.0)
    assert len(st.active) == 2
    succ = lbs.ring.successors("d")
    assert st.active[1] == next(s for s in succ if s != first)
    # gradual ramp: the new SGS received a preallocation demand
    new_sgs = lbs.sgss[st.active[1]]
    assert any(new_sgs.sandboxes.demand_map.values())


def test_scale_in_moves_last_added_to_removed():
    env, lbs, dag = _stack()
    st = lbs._state(dag, 0.0)
    lbs._scale_out(st, 0.0)
    last = st.active[-1]
    lbs._scale_in(st, 1.0)
    assert last in st.removed and last not in st.active


def test_hotspot_damping_shifts_lottery():
    env, lbs, dag = _stack()
    st = lbs._state(dag, 0.0)
    lbs._scale_out(st, 0.0)
    a, b = st.active
    st.sandbox_count[a] = 50
    st.sandbox_count[b] = 50
    st.qdelay_ewma[a] = 10 * dag.slack       # a is a severe hotspot
    st.qdelay_ewma[b] = 0.0
    picks = [lbs._lottery(st) for _ in range(400)]
    assert picks.count(b) > picks.count(a) * 3


def test_instant_mode_round_robins():
    env, lbs, dag = _stack(lbs_cfg=LBSConfig(gradual=False))
    st = lbs._state(dag, 0.0)
    lbs._scale_out(st, 0.0)
    picks = {lbs._lottery(st) for _ in range(100)}
    assert picks == set(st.active)


def test_scale_in_patience_prevents_oscillation():
    env, lbs, dag = _stack(lbs_cfg=LBSConfig(scale_in_patience=3,
                                             decision_interval=0.1))
    st = lbs._state(dag, 0.0)
    lbs._scale_out(st, 0.0)
    st.qdelay_samples = {s: 99 for s in st.active}
    # metric ~ 0 (no queuing): needs 3 consecutive decisions to scale in
    lbs.check_scaling(1.0)
    assert len(st.active) == 2
    st.qdelay_samples = {s: 99 for s in st.active}
    lbs.check_scaling(2.0)
    assert len(st.active) == 2
    st.qdelay_samples = {s: 99 for s in st.active}
    lbs.check_scaling(3.0)
    assert len(st.active) == 1


def test_sim_engine_ordering_and_every():
    env = SimEnv()
    seen = []
    env.call_at(2.0, lambda: seen.append("b"))
    env.call_at(1.0, lambda: seen.append("a"))
    env.call_at(1.0, lambda: seen.append("a2"))     # FIFO on ties
    env.every(1.0, lambda: seen.append("t"), until=3.5)
    env.run_until(4.0)
    assert seen == ["a", "a2", "t", "b", "t", "t"]
    assert env.now() == 4.0
