"""Kernel dispatch in the serving models (PR 9): ``ModelConfig.kernels``
routes the hot spots (attention, decode attention over KV caches, the SSD
scan) through ``repro.kernels.ops``.  Parity: the Pallas path
(interpret=True on CPU) must agree with the pure-jnp reference on forward,
prefill, and decode — including the ragged continuous-batching decode step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.models import (ModelConfig, decode_step, decode_step_ragged,
                          forward, init_cache, init_params, prefill)

KEY = jax.random.PRNGKey(0)


def _dense(**kw):
    base = dict(name="t-dense", arch_type="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                compute_dtype="float32", param_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def _ssm(**kw):
    base = dict(name="t-ssm", arch_type="ssm", n_layers=2, d_model=128,
                n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=256,
                ssm_state=16, compute_dtype="float32", param_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


CFGS = [_dense(), _dense(sliding_window=4, name="t-swa"), _ssm()]


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
def test_forward_parity_xla_vs_pallas_interpret(cfg):
    p = init_params(cfg, KEY)
    S = 64 if cfg.arch_type == "ssm" else 8      # ssm pads S to the chunk
    toks = jax.random.randint(KEY, (2, S), 0, cfg.vocab_size)
    lx, _ = forward(cfg, p, toks)
    lp, _ = forward(cfg.with_(kernels="pallas_interpret"), p, toks)
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lp),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
def test_prefill_decode_parity_xla_vs_pallas_interpret(cfg):
    p = init_params(cfg, KEY)
    S = 64 if cfg.arch_type == "ssm" else 8
    toks = jax.random.randint(KEY, (2, S), 0, cfg.vocab_size)
    outs = {}
    for kern in ("xla", "pallas_interpret"):
        c = cfg.with_(kernels=kern)
        lg, cache = prefill(c, p, toks, init_cache(c, 2, S + 4))
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        l1, _ = decode_step(c, p, cache, tok, jnp.int32(S))
        outs[kern] = (lg, l1)
    for a, b in zip(outs["xla"], outs["pallas_interpret"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
@pytest.mark.parametrize("kern", ["xla", "pallas_interpret"])
def test_ragged_uniform_t_matches_decode_step(cfg, kern):
    """decode_step_ragged with a uniform position vector IS decode_step."""
    c = cfg.with_(kernels=kern)
    p = init_params(c, KEY)
    S = 64 if c.arch_type == "ssm" else 8
    toks = jax.random.randint(KEY, (2, S), 0, c.vocab_size)
    lg, cache = prefill(c, p, toks, init_cache(c, 2, S + 4))
    tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    l1, c1 = decode_step(c, p, cache, tok, jnp.int32(S))
    l2, c2 = decode_step_ragged(c, p, cache, tok,
                                jnp.full((2,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=0)
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=0)


@pytest.mark.parametrize("cfg", [_dense(), _dense(sliding_window=4)],
                         ids=["full", "windowed"])
@pytest.mark.parametrize("kern", ["xla", "pallas_interpret"])
def test_ragged_rows_match_independent_sequences(cfg, kern):
    """A ragged batch at different depths must compute, row for row, what
    each row computes alone at its own position (the continuous-batching
    correctness property)."""
    c = cfg.with_(kernels=kern)
    p = init_params(c, KEY)
    max_len = 12
    prompts = [6, 9]                      # row depths differ
    cache = init_cache(c, 2, max_len)
    # fill each row's cache by prefilling it alone and scattering in
    toks = {n: jax.random.randint(jax.random.PRNGKey(n), (1, n), 0,
                                  c.vocab_size) for n in prompts}
    row_caches, row_toks = [], []
    for n in prompts:
        lg1, c1 = prefill(c, p, toks[n], init_cache(c, 1, max_len))
        row_caches.append(c1)
        row_toks.append(jnp.argmax(lg1, axis=-1).astype(jnp.int32))
    cache = jax.tree.map(
        lambda s, r0, r1: s.at[:, 0:1].set(r0.astype(s.dtype))
                           .at[:, 1:2].set(r1.astype(s.dtype)),
        cache, row_caches[0], row_caches[1])
    tok = jnp.concatenate(row_toks)
    t = jnp.asarray(prompts, jnp.int32)
    lr, cr = decode_step_ragged(c, p, cache, tok, t)
    for i, n in enumerate(prompts):
        li, ci = decode_step(c, p, row_caches[i], row_toks[i], jnp.int32(n))
        np.testing.assert_allclose(np.asarray(lr[i:i + 1]), np.asarray(li),
                                   rtol=2e-5, atol=2e-5)
        got = jax.tree.map(lambda s: s[:, i:i + 1], cr)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ci)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-5, atol=2e-5)


def test_kernel_type_normalization_and_validation():
    assert ops.normalize(None) == ops.KernelType.XLA
    assert ops.normalize("pallas") == ops.KernelType.PALLAS
    assert ops.normalize(ops.KernelType.PALLAS_INTERPRET) \
        == ops.KernelType.PALLAS_INTERPRET
    with pytest.raises(ValueError, match="unknown kernel backend"):
        ops.normalize("triton")
    prev = ops.get_backend()
    try:
        ops.set_backend("pallas_interpret")
        assert ops.normalize(None) == ops.KernelType.PALLAS_INTERPRET
    finally:
        ops.set_backend(prev)


def test_kernel_table_covers_every_backend():
    for spot, impls in ops.KERNEL_TABLE.items():
        assert set(impls) == set(ops.KernelType), spot
