"""Traffic-scenario subsystem: registry semantics, spec round-trips,
scenario determinism, sweep-axis integration, and the traffic x faults
cross-axis (docs/SCENARIOS.md)."""
import json
import math

import pytest

from repro.core import FaultPlan, sgs_failstop
from repro.core.cluster import ClusterConfig
from repro.sim import (Experiment, TrafficSpec, apply_traffic,
                       available_traffic, get_traffic, paper_workload_1,
                       register_traffic, run_sweep, scenario, simulate)
from repro.sim.traffic import _TRAFFIC
from repro.sim.workload import (BurstRate, DiurnalRate, ScaledRate,
                                WindowedRate, WorkloadSpec)


def _exp(**kw):
    base = dict(
        stack="archipelago",
        workload_factory="paper_workload_1",
        workload_kwargs={"duration": 4.0, "scale": 0.03, "dags_per_class": 1},
        cluster=ClusterConfig(n_sgs=2, workers_per_sgs=3),
        drain=3.0, seed=11)
    base.update(kw)
    return Experiment(**base)


def _spec(n_per_class=2, duration=8.0):
    return paper_workload_1(duration=duration, scale=0.05,
                            dags_per_class=n_per_class)


# -- registry ----------------------------------------------------------------


def test_builtins_registered():
    assert {"steady", "diurnal", "flash_crowd", "tenant_churn",
            "zipf_mix"} <= set(available_traffic())


def test_unknown_scenario_lists_registered_names():
    with pytest.raises(ValueError) as ei:
        get_traffic("flash_mob")
    msg = str(ei.value)
    assert "flash_mob" in msg
    for name in available_traffic():
        assert name in msg


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        register_traffic("diurnal")(lambda spec, rng: spec)


def test_alias_duplicate_leaves_no_partial_registration():
    before = dict(_TRAFFIC)
    with pytest.raises(ValueError):
        # first alias is fresh, second collides: nothing may be inserted
        register_traffic("totally_new_shape", "diurnal")(
            lambda spec, rng: spec)
    assert _TRAFFIC == before


# -- TrafficSpec -------------------------------------------------------------


def test_trafficspec_roundtrip_and_hashable():
    ts = scenario("flash_crowd", seed=3, amplify=4.0, frac=0.5)
    d = json.loads(json.dumps(ts.to_dict()))
    assert TrafficSpec.from_dict(d) == ts
    assert hash(ts) == hash(TrafficSpec.from_dict(d))


def test_apply_traffic_accepts_bare_string():
    spec = _spec()
    out = apply_traffic(spec, "steady")
    assert [d.dag_id for d, _ in out.tenants] == \
        [d.dag_id for d, _ in spec.tenants]


# -- scenario shapes ---------------------------------------------------------


def test_diurnal_wraps_every_tenant():
    out = apply_traffic(_spec(), "diurnal")
    assert all(isinstance(p, DiurnalRate) for _, p in out.tenants)
    # period defaults to the run duration
    assert all(p.period == out.duration for _, p in out.tenants)


def test_flash_crowd_amplifies_seeded_fraction():
    spec = _spec(n_per_class=3)
    out = apply_traffic(spec, scenario("flash_crowd", frac=0.5, seed=1))
    hot = [p for _, p in out.tenants if isinstance(p, BurstRate)]
    assert len(hot) == round(0.5 * len(spec.tenants))
    b = hot[0]
    mid = b.at + 0.5 * b.duration
    assert b.rate(mid) > b.base.rate(mid)          # amplified inside
    assert b.rate(b.at - 0.1) == b.base.rate(b.at - 0.1)  # untouched outside


def test_tenant_churn_adds_fresh_ids_and_windows():
    spec = _spec(n_per_class=3)
    out = apply_traffic(spec, "tenant_churn")
    old_ids = {d.dag_id for d, _ in spec.tenants}
    new_ids = {d.dag_id for d, _ in out.tenants} - old_ids
    assert new_ids and all("join" in i for i in new_ids)
    joiners = [p for d, p in out.tenants if d.dag_id in new_ids]
    assert all(isinstance(p, WindowedRate) and p.start > 0.0
               for p in joiners)
    leavers = [p for d, p in out.tenants
               if d.dag_id in old_ids and isinstance(p, WindowedRate)]
    assert leavers and all(p.end is not None and p.end < out.duration
                           for p in leavers)


def test_zipf_mix_preserves_mean_factor():
    spec = _spec(n_per_class=3)
    out = apply_traffic(spec, scenario("zipf_mix", s=1.3))
    factors = [p.factor for _, p in out.tenants]
    assert all(isinstance(p, ScaledRate) for _, p in out.tenants)
    assert math.isclose(sum(factors) / len(factors), 1.0, rel_tol=1e-9)
    assert max(factors) / min(factors) > 2.0       # actually skewed


def test_scenario_seed_is_deterministic_and_independent():
    spec = _spec(n_per_class=3)
    pick = lambda seed: {d.dag_id for d, p in apply_traffic(
        spec, scenario("flash_crowd", seed=seed)).tenants
        if isinstance(p, BurstRate)}
    assert pick(5) == pick(5)
    assert any(pick(s) != pick(5) for s in range(6, 16))


# -- Experiment integration --------------------------------------------------


def test_traffic_none_is_decision_identical():
    a = simulate(_exp()).detach_sim().to_dict()
    b = simulate(_exp(traffic=None)).detach_sim().to_dict()
    a.pop("wall_s"), b.pop("wall_s")
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_steady_matches_traffic_none():
    a = simulate(_exp()).detach_sim().to_dict()
    b = simulate(_exp(traffic="steady")).detach_sim().to_dict()
    for d in (a, b):            # labels differ by design ("+steady" suffix)
        d.pop("wall_s"), d.pop("name")
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_label_carries_scenario():
    assert simulate(_exp(traffic="diurnal")).name.endswith("+diurnal")


def test_traffic_axis_parallel_rows_byte_identical():
    axes = {"traffic": [None, "diurnal",
                        scenario("flash_crowd", amplify=4.0)]}
    seq = run_sweep(_exp(), axes, workers=1)
    par = run_sweep(_exp(), axes, workers=2)

    def canon(rs):
        d = rs.to_dict()
        for r in d["rows"]:
            r["result"].pop("wall_s", None)
        return json.dumps(d, sort_keys=True)

    assert canon(seq) == canon(par)


def test_every_builtin_scenario_simulates_cleanly():
    for name in available_traffic():
        r = simulate(_exp(traffic=name))
        assert r.n_completed == r.n_requests, name
        assert r.n_requests > 0, name


# -- cross-axis: traffic x faults --------------------------------------------


def test_flash_crowd_with_sgs_failstop_loses_nothing():
    exp = _exp(traffic="flash_crowd",
               faults=FaultPlan(events=(sgs_failstop(at=2.0),)))
    r = simulate(exp)
    assert r.n_requests > 0
    assert r.n_completed == r.n_requests
    assert r.recovery and r.recovery["events"]
    assert r.recovery["events"][0]["kind"] == "sgs_failstop"


# -- params validation (satellite 1) -----------------------------------------


def test_unknown_param_rejected_with_known_names():
    with pytest.raises(ValueError) as ei:
        simulate(_exp(params={"n_lb": 4}))
    msg = str(ei.value)
    assert "n_lb" in msg and "n_lbs" in msg and "archipelago" in msg


def test_unknown_param_rejected_per_stack():
    with pytest.raises(ValueError, match="probes"):
        simulate(_exp(stack="sparrow", params={"n_lbs": 4}))


def test_known_params_still_accepted():
    r = simulate(_exp(params={"n_lbs": 2}))
    assert r.n_completed == r.n_requests


if __name__ == "__main__":
    import sys
    sys.exit(pytest.main([__file__, "-q"]))
