"""Determinism + workload-generation regressions for the indexed scheduler.

Guards the O(1) index refactor against iteration-order drift (sets/heaps
feeding decisions), the reactive-allocation bugfix, and the vectorized
arrival generator.
"""
import math

import numpy as np
import pytest

from repro.core.cluster import ClusterConfig
from repro.core.sandbox import SandboxManager, Worker
from repro.core.sgs import SGSConfig
from repro.core.types import FunctionSpec, SandboxState
from repro.sim.runner import run_archipelago
from repro.sim.workload import (PoissonResampled, Sinusoidal, WorkloadSpec,
                                paper_workload_1, paper_workload_2)


def _run(make, seed, method="numpy"):
    spec = make(duration=4.0, scale=0.02, dags_per_class=2)
    res = run_archipelago(
        spec,
        cluster=ClusterConfig(n_sgs=2, workers_per_sgs=3,
                              cores_per_worker=4, pool_mem_mb=1024.0),
        seed=seed, workload_method=method)
    m = res.metrics
    sgss = [res.lbs.sgss[k] for k in sorted(res.lbs.sgss)]
    timeline = [(r.arrival_time, r.completion_time, r.n_cold_starts,
                 r.sgs_id) for r in m.requests]
    counters = {
        "cold": [s.n_cold_starts for s in sgss],
        "warm": [s.n_warm_hits for s in sgss],
        "soft": [s.sandboxes.n_soft_evictions for s in sgss],
        "hard": [s.sandboxes.n_hard_evictions for s in sgss],
        "revive": [s.sandboxes.n_revivals for s in sgss],
        "events": res.env.n_events,
    }
    return timeline, counters


@pytest.mark.parametrize("make", [paper_workload_1, paper_workload_2])
def test_same_seed_runs_are_identical(make):
    """Two runs with one seed: identical per-request completion times and
    identical cold-start/warm-hit/eviction counters (guards the index
    refactor against set/heap iteration-order leaking into decisions)."""
    t1, c1 = _run(make, seed=3)
    t2, c2 = _run(make, seed=3)
    assert t1 == t2
    assert c1 == c2


def test_different_seeds_differ():
    t1, _ = _run(paper_workload_1, seed=3)
    t2, _ = _run(paper_workload_1, seed=4)
    assert t1 != t2


def test_workload_generation_is_cross_seed_deterministic():
    """The numpy generator must be a pure function of (spec, seed) — no
    process-salted hashing (the legacy tenant seeding used builtin hash())."""
    s1 = paper_workload_1(duration=10.0, scale=0.5)
    s2 = paper_workload_1(duration=10.0, scale=0.5)
    t1, i1, _ = s1.generate_arrays(7)
    t2, i2, _ = s2.generate_arrays(7)
    assert np.array_equal(t1, t2)
    assert np.array_equal(i1, i2)


def test_vectorized_arrivals_match_rate_function():
    """Thinning sampler sanity: realized counts within a few sigma of the
    integrated rate, arrivals sorted and in-range."""
    proc = Sinusoidal(avg=200.0, amplitude=150.0, period=7.0, phase=1.0)
    rng = np.random.default_rng(0)
    ts = proc.generate_np(50.0, rng)
    assert np.all(np.diff(ts) >= 0)
    assert ts.min() >= 0.0 and ts.max() <= 50.0
    expected = 200.0 * 50.0 + 150.0 * sum(
        math.sin(2 * math.pi * t / 7.0 + 1.0) for t in
        np.linspace(0, 50, 20000)) * 50.0 / 20000
    assert abs(len(ts) - expected) < 5 * math.sqrt(expected)


def test_vectorized_resampled_matches_scalar_rate():
    proc = PoissonResampled((100.0, 300.0), seed=5)
    ts = np.linspace(0.0, 20.0, 500)
    vec = proc.rate_array(ts)
    scalar = [proc.rate(float(t)) for t in ts]
    assert np.allclose(vec, scalar)
    assert proc.max_rate(20.0) >= max(scalar) - 1e-12


def test_legacy_and_numpy_arrivals_agree_statistically():
    spec = paper_workload_2(duration=10.0, scale=0.2)
    n_legacy = len(spec.generate(3, method="legacy"))
    n_numpy = len(spec.generate(3, method="numpy"))
    assert n_legacy > 100
    # same arrival process, different samplers: counts agree within ~5 sigma
    assert abs(n_legacy - n_numpy) < 5 * math.sqrt(max(n_legacy, n_numpy))


# -- reactive-allocation bugfix regression ----------------------------------


def test_reactive_allocate_refuses_overcommit():
    """When every resident sandbox is BUSY or protected, the reactive path
    must return None (previously it appended anyway, overcommitting the
    worker's proactive pool)."""
    w = Worker(worker_id=0, cores=4, pool_mem_mb=2 * 128.0)
    mgr = SandboxManager(workers=[w])
    f1 = FunctionSpec("f1", 0.1, mem_mb=128)
    mgr.set_demand(f1, 2, now=0.0)
    for s in w.sandboxes:
        s.state = SandboxState.BUSY
    f2 = FunctionSpec("f2", 0.1, mem_mb=128)
    assert mgr.reactive_allocate(w, f2, now=0.0) is None
    assert w.used_pool_mem <= w.pool_mem_mb + 1e-9


def test_reactive_allocate_evicts_surplus_then_fits():
    w = Worker(worker_id=0, cores=4, pool_mem_mb=2 * 128.0)
    mgr = SandboxManager(workers=[w])
    f1 = FunctionSpec("f1", 0.1, mem_mb=128)
    mgr.set_demand(f1, 2, now=0.0)          # fills the pool, all WARM-able
    f2 = FunctionSpec("f2", 0.1, mem_mb=128)
    sbx = mgr.reactive_allocate(w, f2, now=0.0)
    assert sbx is not None and sbx.state == SandboxState.BUSY
    assert mgr.n_hard_evictions >= 1
    assert w.used_pool_mem <= w.pool_mem_mb + 1e-9


def test_cold_start_falls_back_to_another_worker():
    """If the chosen worker cannot host (all its evictables protected), the
    dispatch must fall back to another free-core worker with pool space
    instead of requeueing forever (starvation regression)."""
    from repro.core.sgs import SemiGlobalScheduler
    from repro.core.types import DagSpec, Request
    from repro.sim.engine import SimEnv

    env = SimEnv()
    w0 = Worker(worker_id=0, cores=2, pool_mem_mb=128.0)
    w1 = Worker(worker_id=1, cores=2, pool_mem_mb=4096.0)
    sgs = SemiGlobalScheduler(0, [w0, w1], env,
                              SGSConfig(proactive=False))
    g = FunctionSpec("g", 0.1, mem_mb=128)
    sgs.sandboxes.set_demand(g, 1, now=0.0)     # lands on w0, fills its pool
    assert w0.schedulable_count("g") == 1
    sgs.sandboxes.demand_map["g"] = 5           # now under-provisioned ->
    #                                             protected from hard evict
    f = FunctionSpec("f", 0.05, mem_mb=128)
    dag = DagSpec("d", (f,), (), deadline=1.0)
    sgs.submit_request(Request(dag=dag, arrival_time=0.0))
    env.run_until(2.0)
    assert len(sgs.completed_requests) == 1     # served via w1's pool
    assert w0.schedulable_count("g") == 1       # protected sandbox survived
    assert w1.schedulable_count("f") == 1
    assert w0.used_pool_mem <= w0.pool_mem_mb + 1e-9


def test_dispatch_requeues_when_no_worker_can_host():
    """End-to-end: an overloaded tiny pool must never exceed pool memory
    (the old overcommit path violated this under pressure)."""
    spec = WorkloadSpec(
        tenants=paper_workload_1(duration=3.0, scale=0.015).tenants,
        duration=3.0)
    res = run_archipelago(
        spec,
        cluster=ClusterConfig(n_sgs=2, workers_per_sgs=2,
                              cores_per_worker=2, pool_mem_mb=384.0),
        sgs_cfg=SGSConfig(), seed=1)
    for sgs in res.lbs.sgss.values():
        for w in sgs.workers:
            assert w.used_pool_mem <= w.pool_mem_mb + 1e-9
