"""Continuous batching (PR 9): ContinuousBatcher join/leave semantics and
determinism, the scripted stub twin under every stack, the
order-independent batch seed, and a tiny real-JAX continuous run."""
import json

import pytest

from repro.core import ClusterConfig, ContinuousBatcher, StubBatchedBackend
from repro.core.types import DagSpec, FunctionSpec, Invocation, Request
from repro.sim import Experiment, ExperimentResult, simulate
from repro.sim.engine import SimEnv

SMALL = ClusterConfig(n_sgs=2, workers_per_sgs=2, cores_per_worker=4,
                      pool_mem_mb=2048.0)


def _inv(fn_name="f", exec_time=0.1):
    dag = DagSpec("d", (FunctionSpec(fn_name, exec_time),), ())
    req = Request(dag=dag, arrival_time=0.0)
    return Invocation(request=req, fn=dag.fn(fn_name), ready_time=0.0)


def _batcher(env, admit_s=0.04, step_s=0.01, steps=3, max_batch=4):
    trace = []

    def admit(fn, invs, slots):
        trace.append(("admit", fn, [i.inv_id for i in invs], list(slots)))
        return admit_s

    def step(fn, slots):
        trace.append(("step", fn, list(slots)))
        return step_s

    cb = ContinuousBatcher(env, admit, step, lambda fn: steps,
                           max_batch=max_batch)
    return cb, trace


# -- ContinuousBatcher unit semantics ----------------------------------------


def test_same_instant_submits_join_one_prefill_in_inv_id_order():
    env = SimEnv()
    cb, trace = _batcher(env)
    done = []
    invs = [_inv() for _ in range(3)]
    # submit in REVERSE inv_id order: admission must re-sort
    for inv in reversed(invs):
        cb.submit(inv, lambda s, i=inv: done.append((env.now(), i.inv_id, s)))
    env.run()
    admits = [e for e in trace if e[0] == "admit"]
    assert admits == [("admit", "f", [i.inv_id for i in invs], [0, 1, 2])]
    # 3 joiners x 3 steps: ticks at 0, .05, .06; all leave at .07
    assert [e for e in trace if e[0] == "step"] \
        == [("step", "f", [0, 1, 2])] * 3
    assert done == [(pytest.approx(0.07), i.inv_id, pytest.approx(0.07))
                    for i in invs]
    assert cb.counters() == {"n_prefill_batches": 1, "n_joins": 3,
                             "n_decode_ticks": 3, "n_step_slots": 9,
                             "max_batch_occupancy": 3,
                             "n_dropped_invocations": 0}


def test_late_arrival_joins_running_batch_and_leaves_independently():
    env = SimEnv()
    cb, trace = _batcher(env, admit_s=0.04, step_s=0.01, steps=3)
    done = []
    a, b = _inv(), _inv()
    cb.submit(a, lambda s: done.append(("a", env.now())))
    # arrives mid-generation: joins at the next tick boundary, decodes
    # alongside a, finishes its own 3 steps later
    env.call_after(0.055, lambda: cb.submit(
        b, lambda s: done.append(("b", env.now()))))
    env.run()
    admits = [e for e in trace if e[0] == "admit"]
    assert len(admits) == 2 and admits[1][3] == [1]    # b gets slot 1
    # ticks: t=0 (admit a + step), t=.05 (step), t=.06 (admit b + step —
    # a's LAST step shares the tick with b's prefill, so a completes at
    # .06 + .04 + .01 = .11); b then steps alone at .11 and .12 -> .13
    assert done[0] == ("a", pytest.approx(0.11))
    assert done[1] == ("b", pytest.approx(0.13))
    # the shared tick ran both slots
    assert ("step", "f", [0, 1]) in trace


def test_freed_slot_is_reused_by_the_next_joiner():
    env = SimEnv()
    cb, trace = _batcher(env, steps=1, max_batch=2)
    invs = [_inv() for _ in range(4)]
    for inv in invs:
        cb.submit(inv, lambda s: None)
    env.run()
    admits = [e for e in trace if e[0] == "admit"]
    # capacity 2: two waves of two, each reusing slots {0,1}
    assert [a[2] for a in admits] == [[invs[0].inv_id, invs[1].inv_id],
                                      [invs[2].inv_id, invs[3].inv_id]]
    assert [a[3] for a in admits] == [[0, 1], [0, 1]]


def test_cold_delay_defers_enrollment():
    env = SimEnv()
    cb, trace = _batcher(env, steps=1)
    warm, cold = _inv(), _inv()
    cb.submit(warm, lambda s: None)
    cb.submit(cold, lambda s: None, 0.5)     # sandbox setup: joins at 0.5
    env.run()
    admits = [e for e in trace if e[0] == "admit"]
    assert [a[2] for a in admits] == [[warm.inv_id], [cold.inv_id]]


def test_zero_step_requests_complete_at_admission():
    env = SimEnv()
    cb, _ = _batcher(env, admit_s=0.04, steps=0)
    done = []
    cb.submit(_inv(), lambda s: done.append(env.now()))
    env.run()
    assert done == [pytest.approx(0.04)]


def test_batcher_validates_max_batch():
    with pytest.raises(ValueError, match="max_batch"):
        ContinuousBatcher(SimEnv(), lambda f, i, s: 0.0, lambda f, s: 0.0,
                          lambda f: 1, max_batch=0)


# -- the stub twin under the experiment API ----------------------------------


def _stub_exp(stack="archipelago", **kw):
    base = dict(stack=stack, backend="stub-batched",
                backend_kwargs=dict(exec_time=0.02, batching="continuous",
                                    max_batch=4, n_steps=3),
                workload_factory="paper_workload_1",
                workload_kwargs=dict(duration=3.0, scale=0.02,
                                     dags_per_class=1),
                cluster=SMALL, warmup=1.0, drain=3.0)
    base.update(kw)
    return Experiment(**base)


def test_stub_continuous_runs_under_every_stack_and_is_reproducible():
    from repro.core import available_stacks
    for name in available_stacks():
        a = simulate(_stub_exp(stack=name))
        assert a.n_completed > 0
        assert a.data_plane == {"kernels": "none", "batching": "continuous"}
        assert a.backend_counters["n_joins"] > 0
        assert a.backend_counters["n_decode_ticks"] > 0
        b = simulate(_stub_exp(stack=name))
        da, db = a.to_dict(), b.to_dict()
        da.pop("wall_s"), db.pop("wall_s")
        assert da == db, f"continuous run not reproducible under {name!r}"


def test_stub_continuous_counters_round_trip_through_json():
    res = simulate(_stub_exp())
    back = ExperimentResult.from_dict(json.loads(json.dumps(res.to_dict())))
    assert back.backend_counters == res.backend_counters
    assert back.data_plane == res.data_plane


def test_stub_lone_request_costs_exec_time_under_both_disciplines():
    """The scripted continuous twin splits exec_time into prefill + steps;
    an uncontended request must still take exactly exec_time end to end, so
    windowed and continuous stub latencies are directly comparable."""
    rows = {}
    for batching in ("windowed", "continuous"):
        # batch_window=0 so an uncontended windowed request flushes
        # immediately (no window wait to skew the comparison)
        exp = _stub_exp(backend_kwargs=dict(
            exec_time=0.02, batching=batching, max_batch=4, n_steps=3,
            batch_window=0.0),
            workload_kwargs=dict(duration=2.0, scale=0.002,
                                 dags_per_class=1))
        rows[batching] = simulate(exp)
    for r in rows.values():
        assert r.n_completed == r.n_requests
    assert rows["continuous"].latency_percentiles["p50"] == pytest.approx(
        rows["windowed"].latency_percentiles["p50"], rel=1e-6)


def test_stub_batched_validates_batching_choice():
    with pytest.raises(ValueError, match="batching"):
        StubBatchedBackend(batching="dynamic")


# -- order-independent batch seed --------------------------------------------


def test_batch_seed_is_order_independent_and_set_sensitive():
    jax = pytest.importorskip("jax")  # noqa: F841  (executor imports jax)
    from repro.serving.executor import batch_seed
    assert batch_seed([3, 1, 2]) == batch_seed([2, 3, 1])
    assert batch_seed([1]) != batch_seed([2])
    assert batch_seed([1, 2]) != batch_seed([1, 3])


def test_run_batch_seed_ignores_coalescing_order():
    """Regression: run_batch seeded from invs[0].inv_id made the executed
    work depend on gather order.  The member SET must determine the seed."""
    pytest.importorskip("jax")
    from repro.serving.executor import BatchingJaxExecutor, batch_seed

    class _FakeInstance:
        def __init__(self):
            self.seeds = []

        def run(self, seed=0):
            self.seeds.append(seed)
            return 0.001

    ex = BatchingJaxExecutor({}, max_batch=4)
    fake = _FakeInstance()
    ex._instances[("f", 4)] = fake
    invs = [_inv("f") for _ in range(3)]
    ex.run_batch("f", invs)
    ex.run_batch("f", list(reversed(invs)))
    assert fake.seeds[0] == fake.seeds[1] \
        == batch_seed(i.inv_id for i in invs)


# -- real JAX continuous serving ---------------------------------------------


def test_jax_continuous_serves_a_tiny_app_end_to_end():
    pytest.importorskip("jax")
    from dataclasses import replace
    from repro.core import BatchedJaxBackend
    from repro.serving import smoke_apps

    base = Experiment(
        stack="archipelago",
        workload_factory="serving_apps",
        workload_kwargs=dict(apps=smoke_apps(), duration=1.0, rps=4.0,
                             prewarm_per_fn=2),
        cluster=SMALL, warmup=0.2, drain=120.0)
    be = BatchedJaxBackend(max_batch=4, batching="continuous")
    res = simulate(replace(base, backend=be))
    assert res.n_completed == res.n_requests > 0
    assert res.data_plane == {"kernels": "xla", "batching": "continuous"}
    assert res.backend_counters["n_joins"] >= res.n_requests
    assert res.backend_counters["n_decode_ticks"] > 0
