"""Hypothesis property tests on system invariants."""
import math

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (ConsistentHashRing, DagSpec, FunctionSpec,
                        SandboxManager, Worker, poisson_ppf)
from repro.core.estimator import _poisson_cdf


# -- Poisson inverse CDF -----------------------------------------------------


@given(p=st.floats(0.5, 0.9999), lam=st.floats(0.0, 300.0))
@settings(max_examples=200, deadline=None)
def test_ppf_is_inverse_cdf(p, lam):
    n = poisson_ppf(p, lam)
    assert _poisson_cdf(lam, n) >= p - 1e-12
    if n > 0:
        assert _poisson_cdf(lam, n - 1) < p + 1e-12


@given(lam=st.floats(0.0, 100.0), p1=st.floats(0.5, 0.99),
       dp=st.floats(0.0, 0.009))
@settings(max_examples=100, deadline=None)
def test_ppf_monotone_in_p(lam, p1, dp):
    assert poisson_ppf(p1 + dp, lam) >= poisson_ppf(p1, lam)


@given(p=st.floats(0.5, 0.999), lam=st.floats(0.0, 100.0),
       dl=st.floats(0.0, 10.0))
@settings(max_examples=100, deadline=None)
def test_ppf_monotone_in_lambda(p, lam, dl):
    assert poisson_ppf(p, lam + dl) >= poisson_ppf(p, lam)


# -- even placement invariant (§4.3.2) ---------------------------------------


@given(n_workers=st.integers(1, 12), demand=st.integers(0, 40))
@settings(max_examples=80, deadline=None)
def test_even_placement_max_min_gap(n_workers, demand):
    ws = [Worker(worker_id=i, cores=4, pool_mem_mb=1e6)
          for i in range(n_workers)]
    mgr = SandboxManager(workers=ws)
    f = FunctionSpec("f", 0.1, mem_mb=128)
    mgr.set_demand(f, demand, now=0.0)
    counts = mgr.counts_per_worker("f")
    assert sum(counts) == demand
    assert max(counts) - min(counts) <= 1


@given(n_workers=st.integers(1, 8),
       seq=st.lists(st.integers(0, 30), min_size=1, max_size=12))
@settings(max_examples=60, deadline=None)
def test_placement_balance_under_demand_sequence(n_workers, seq):
    """After any sequence of demand changes, schedulable sandboxes stay
    balanced and never exceed demand."""
    ws = [Worker(worker_id=i, cores=4, pool_mem_mb=1e6)
          for i in range(n_workers)]
    mgr = SandboxManager(workers=ws)
    f = FunctionSpec("f", 0.1, mem_mb=128)
    t = 0.0
    for d in seq:
        mgr.set_demand(f, d, now=t)
        t += 0.1
        counts = mgr.counts_per_worker("f")
        assert sum(counts) == d
        assert max(counts) - min(counts) <= 1


# -- memory safety ------------------------------------------------------------


@given(demands=st.lists(st.tuples(st.integers(0, 20),
                                  st.sampled_from([64.0, 128.0, 256.0])),
                        min_size=1, max_size=6))
@settings(max_examples=60, deadline=None)
def test_pool_memory_never_exceeded(demands):
    ws = [Worker(worker_id=i, cores=4, pool_mem_mb=1024.0) for i in range(3)]
    mgr = SandboxManager(workers=ws)
    for i, (d, mem) in enumerate(demands):
        f = FunctionSpec(f"f{i}", 0.1, mem_mb=mem)
        mgr.set_demand(f, d, now=0.1 * i)
    for w in ws:
        assert w.used_pool_mem <= w.pool_mem_mb + 1e-9


# -- consistent hashing -------------------------------------------------------


@given(ids=st.lists(st.integers(0, 1000), min_size=2, max_size=20,
                    unique=True),
       key=st.text(min_size=1, max_size=30))
@settings(max_examples=80, deadline=None)
def test_ring_lookup_stable_and_member(ids, key):
    ring = ConsistentHashRing(ids)
    owner = ring.lookup(key)
    assert owner in ids
    assert ring.lookup(key) == owner
    succ = ring.successors(key)
    assert sorted(succ) == sorted(ids)


@given(ids=st.lists(st.integers(0, 100), min_size=3, max_size=12,
                    unique=True))
@settings(max_examples=40, deadline=None)
def test_ring_removal_only_moves_affected_keys(ids):
    """Consistent hashing property: removing one node only remaps keys that
    belonged to it."""
    ring_a = ConsistentHashRing(ids)
    removed = ids[0]
    ring_b = ConsistentHashRing(ids[1:])
    for i in range(50):
        key = f"dag-{i}"
        a = ring_a.lookup(key)
        if a != removed:
            assert ring_b.lookup(key) == a


@given(ids=st.lists(st.integers(0, 1000), min_size=2, max_size=16,
                    unique=True),
       new_id=st.integers(1001, 2000))
@settings(max_examples=40, deadline=None)
def test_ring_add_node_moves_bounded_fraction(ids, new_id):
    """Incremental re-sharding: adding one node may only steal keys for
    itself — no key moves between pre-existing nodes — and the stolen
    share stays near 1/n (within generous concentration slack)."""
    ring = ConsistentHashRing(ids)
    keys = [f"dag-{i}" for i in range(400)]
    before = {k: ring.lookup(k) for k in keys}
    ring.add_node(new_id)
    moved = 0
    for k in keys:
        after = ring.lookup(k)
        if after != before[k]:
            assert after == new_id
            moved += 1
    # expected share is 1/(n+1); vnode placement is random-ish, so allow 4x
    assert moved / len(keys) <= 4.0 / (len(ids) + 1)
    assert sorted(ring.ids()) == sorted(ids + [new_id])


@given(ids=st.lists(st.integers(0, 1000), min_size=3, max_size=16,
                    unique=True), data=st.data())
@settings(max_examples=40, deadline=None)
def test_ring_remove_node_moves_only_its_keys(ids, data):
    """In-place removal: only keys owned by the removed node remap, and the
    mutated ring is indistinguishable from one built without that id."""
    victim = data.draw(st.sampled_from(ids))
    ring = ConsistentHashRing(ids)
    keys = [f"dag-{i}" for i in range(400)]
    before = {k: ring.lookup(k) for k in keys}
    ring.remove_node(victim)
    rebuilt = ConsistentHashRing([i for i in ids if i != victim])
    for k in keys:
        after = ring.lookup(k)
        if before[k] != victim:
            assert after == before[k]
        assert after == rebuilt.lookup(k)


@given(ids=st.lists(st.integers(0, 1000), min_size=2, max_size=12,
                    unique=True),
       new_id=st.integers(1001, 2000), key=st.text(min_size=1, max_size=20))
@settings(max_examples=40, deadline=None)
def test_ring_successors_duplicate_free_after_resharding(ids, new_id, key):
    ring = ConsistentHashRing(ids)
    ring.add_node(new_id)
    ring.remove_node(ids[0])
    succ = ring.successors(key)
    assert len(succ) == len(set(succ))
    assert sorted(succ) == sorted(ring.ids())


def test_ring_empty_and_remove_to_empty_raise():
    with pytest.raises(ValueError, match="at least one SGS id"):
        ConsistentHashRing([])
    ring = ConsistentHashRing([7])
    with pytest.raises(ValueError, match="at least one SGS id"):
        ring.remove_node(7)
    with pytest.raises(ValueError, match="unknown SGS id"):
        ring.remove_node(99)
    ring.add_node(8)
    ring.remove_node(7)          # fine once a second id exists
    assert ring.ids() == [8]


# -- DAG / slack --------------------------------------------------------------


# -- fault-plan request accounting (docs/FAULTS.md) ---------------------------


@st.composite
def _fault_event(draw):
    """One seeded fault event with drawn-but-valid parameters, spanning the
    fail-stop, correlated, and gray (degraded-mode) shapes."""
    from repro.core import fault as f
    t = draw(st.floats(0.5, 3.0))
    kind = draw(st.sampled_from(
        ["worker_crash", "rack_power", "az_outage", "cascading_crash",
         "slow_worker", "flaky_network", "memory_pressure",
         "mass_eviction"]))
    if kind == "worker_crash":
        return f.worker_crash(k=draw(st.integers(1, 3)), at=t)
    if kind == "rack_power":
        return f.rack_power(at=t)
    if kind == "az_outage":
        return f.az_outage(at=t)
    if kind == "cascading_crash":
        return f.cascading_crash(at=t, p=draw(st.floats(0.0, 1.0)),
                                 k0=draw(st.integers(1, 2)), max_kills=4)
    if kind == "slow_worker":
        return f.slow_worker(at=t, k=draw(st.integers(1, 2)),
                             factor=draw(st.floats(1.5, 8.0)))
    if kind == "flaky_network":
        return f.flaky_network(at=t, jitter=draw(st.floats(0.001, 0.1)))
    if kind == "memory_pressure":
        return f.memory_pressure(at=t, frac=draw(st.floats(0.1, 1.0)),
                                 duration=draw(st.floats(0.2, 2.0)))
    return f.mass_eviction(at=t, frac=draw(st.floats(0.1, 1.0)))


@given(events=st.lists(_fault_event(), min_size=1, max_size=3),
       plan_seed=st.integers(0, 2**16),
       stack=st.sampled_from(["archipelago", "fifo", "sparrow", "pull"]),
       hedge=st.booleans())
@settings(max_examples=12, deadline=None)
def test_fault_plan_accounting_invariant(events, plan_seed, stack, hedge):
    """For ANY seeded FaultPlan, under every registered stack:
    completed + pending == arrivals, nothing lost, nothing completed twice
    (deterministic twin: tests/test_fault_plan.py::
    test_gray_plans_keep_every_request_accounted_under_every_stack)."""
    from repro.core import ClusterConfig
    from repro.core.fault import FaultPlan
    from repro.sim import Experiment, simulate
    params = ({"hedge_timeout": 1.5}
              if hedge and stack == "archipelago" else {})
    res = simulate(Experiment(
        stack=stack, workload_factory="paper_workload_1",
        workload_kwargs=dict(duration=3.0, scale=0.03, dags_per_class=1),
        cluster=ClusterConfig(n_sgs=2, workers_per_sgs=3,
                              cores_per_worker=4, pool_mem_mb=2048.0),
        drain=10.0, params=params,
        faults=FaultPlan(events=tuple(events), seed=plan_seed)))
    acc = res.accounting
    assert acc["lost"] == 0
    assert acc["duplicate_completions"] == 0
    assert acc["completed"] + acc["pending"] == acc["arrivals"]
    assert acc["completed"] == acc["unique_completed"]


@given(times=st.lists(st.floats(0.01, 2.0), min_size=1, max_size=6),
       slack=st.floats(0.0, 5.0))
@settings(max_examples=60, deadline=None)
def test_chain_critical_path_is_sum(times, slack):
    fns = tuple(FunctionSpec(f"f{i}", t) for i, t in enumerate(times))
    edges = tuple((f"f{i}", f"f{i+1}") for i in range(len(times) - 1))
    dag = DagSpec("chain", fns, edges, deadline=sum(times) + slack)
    assert abs(dag.critical_path_time() - sum(times)) < 1e-9
    assert abs(dag.slack - slack) < 1e-6
    # remaining critical path decreases along the chain
    rcps = [dag.remaining_critical_path(f"f{i}") for i in range(len(times))]
    assert all(a >= b - 1e-12 for a, b in zip(rcps, rcps[1:]))
