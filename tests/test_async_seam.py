"""The asynchronous execution seam (PR 4): submit/done contract, the
deterministic CompletionQueue, BatchCoalescer window/bucket semantics, the
stub-batched backend under every stack, batch-occupancy counters in
ExperimentResult, and the modeled fast path staying untouched."""
import json

import pytest

from repro.core import (BatchCoalescer, ClusterConfig, CompletionQueue,
                        ConsistentHashRing, ExecutionBackend,
                        StubBatchedBackend, available_stacks,
                        register_backend)
from repro.core.backends import pow2_bucket, served_model_key
from repro.core.types import DagSpec, FunctionSpec, Invocation, Request
from repro.sim import Experiment, ExperimentResult, simulate
from repro.sim.engine import SimEnv

SMALL = ClusterConfig(n_sgs=2, workers_per_sgs=2, cores_per_worker=4,
                      pool_mem_mb=2048.0)


def _tiny_exp(**kw):
    base = dict(workload_factory="paper_workload_1",
                workload_kwargs=dict(duration=3.0, scale=0.02,
                                     dags_per_class=1),
                cluster=SMALL, warmup=1.0, drain=3.0)
    base.update(kw)
    return Experiment(**base)


def _inv(fn_name="f", exec_time=0.1):
    dag = DagSpec("d", (FunctionSpec(fn_name, exec_time),), ())
    req = Request(dag=dag, arrival_time=0.0)
    return Invocation(request=req, fn=dag.fn(fn_name), ready_time=0.0)


def _cmp_dict(res):
    d = res.to_dict()
    d.pop("wall_s")
    return d


# -- CompletionQueue ----------------------------------------------------------


def test_completion_queue_ties_fire_in_inv_id_order():
    env = SimEnv()
    fired = []
    cq = CompletionQueue(env)
    hi, lo = _inv(), _inv()
    assert hi.inv_id < lo.inv_id
    # scheduled in REVERSE inv_id order, both due at t=0.5
    cq.schedule(lo, 0.5, lambda s: fired.append(("lo", s)))
    cq.schedule(hi, 0.5, lambda s: fired.append(("hi", s)))
    env.run()
    assert fired == [("hi", 0.5), ("lo", 0.5)]


def test_completion_queue_delay_offsets_fire_time():
    env = SimEnv()
    fired = []
    cq = CompletionQueue(env)
    cq.schedule(_inv(), 0.2, lambda s: fired.append((env.now(), s)),
                delay=0.3)
    env.run()
    assert fired == [(0.5, 0.2)]       # done(exec_s) at now + delay + exec_s


# -- BatchCoalescer -----------------------------------------------------------


def _coalescer(env, runtimes, **kw):
    batches = []

    def run_batch(fn_name, invs):
        batches.append((fn_name, [i.inv_id for i in invs]))
        return runtimes

    return BatchCoalescer(env, run_batch, **kw), batches


def test_coalescer_window_flush_batches_concurrent_submits():
    env = SimEnv()
    co, batches = _coalescer(env, 0.1, batch_window=0.01, max_batch=8)
    done = []
    invs = [_inv() for _ in range(3)]
    for inv in invs:
        co.submit(inv, lambda s, i=inv: done.append((env.now(), i.inv_id)))
    env.run()
    assert len(batches) == 1                   # one padded batch of 3
    assert batches[0][1] == [i.inv_id for i in invs]
    # all complete at window + shared runtime, in inv_id order
    assert done == [(pytest.approx(0.11), i.inv_id) for i in invs]
    assert co.counters() == {"n_batches": 1, "n_batched_invocations": 3,
                             "n_batch_slots": 4, "max_batch_occupancy": 3,
                             "n_dropped_invocations": 0}


def test_coalescer_size_flush_preempts_window():
    env = SimEnv()
    co, batches = _coalescer(env, 0.1, batch_window=10.0, max_batch=2)
    done = []
    for _ in range(5):
        co.submit(_inv(), lambda s: done.append(env.now()))
    env.run()
    # 2+2 flush immediately at max_batch; the trailing 1 waits the window
    assert [len(ids) for _, ids in batches] == [2, 2, 1]
    assert done[:4] == [pytest.approx(0.1)] * 4
    assert done[4] == pytest.approx(10.1)
    c = co.counters()
    assert c["n_batches"] == 3 and c["n_batched_invocations"] == 5
    assert c["max_batch_occupancy"] == 2


def test_coalescer_separates_functions_and_defers_cold_setup():
    env = SimEnv()
    co, batches = _coalescer(env, 0.1, batch_window=0.01, max_batch=8)
    a, b = _inv("a"), _inv("b")
    cold = _inv("a")
    co.submit(a, lambda s: None)
    co.submit(b, lambda s: None)
    co.submit(cold, lambda s: None, 0.5)       # setup: enrolls at t=0.5
    env.run()
    assert batches == [("a", [a.inv_id]), ("b", [b.inv_id]),
                       ("a", [cold.inv_id])]


def test_coalescer_validates_knobs():
    env = SimEnv()
    with pytest.raises(ValueError, match="max_batch"):
        BatchCoalescer(env, lambda n, i: 0.1, max_batch=0)
    with pytest.raises(ValueError, match="batch_window"):
        BatchCoalescer(env, lambda n, i: 0.1, batch_window=-1.0)


def test_pow2_bucket():
    assert [pow2_bucket(k) for k in (1, 2, 3, 4, 5, 8, 9)] \
        == [1, 2, 4, 4, 8, 8, 16]


# -- the async seam under the experiment API ---------------------------------


def test_stub_completions_are_reproducible_under_every_stack():
    for name in available_stacks():
        a = _cmp_dict(simulate(_tiny_exp(stack=name, backend="stub")))
        b = _cmp_dict(simulate(_tiny_exp(stack=name, backend="stub")))
        assert a == b, f"stub run not reproducible under stack {name!r}"


def test_stub_batched_runs_under_every_stack_and_is_reproducible():
    for name in available_stacks():
        a = simulate(_tiny_exp(stack=name, backend="stub-batched"))
        assert a.n_completed > 0
        assert a.backend == "stub-batched"
        assert a.backend_counters["n_batches"] > 0
        assert a.backend_counters["n_batched_invocations"] \
            >= a.backend_counters["n_batches"]
        b = simulate(_tiny_exp(stack=name, backend="stub-batched"))
        assert _cmp_dict(a) == _cmp_dict(b), \
            f"batched run not reproducible under stack {name!r}"


def test_batches_actually_form_under_load():
    """At an offered load with many concurrent in-flight invocations the
    coalescer must gather real batches (occupancy > 1), and perfect
    batching (batch_cost=0) must beat per-invocation stub throughput."""
    exp = _tiny_exp(backend="stub-batched",
                    backend_kwargs=dict(exec_time=0.2, batch_window=0.02,
                                        max_batch=8),
                    workload_kwargs=dict(duration=3.0, scale=0.2,
                                         dags_per_class=1))
    res = simulate(exp)
    bc = res.backend_counters
    assert bc["max_batch_occupancy"] > 1
    assert bc["n_batched_invocations"] > bc["n_batches"]
    assert bc["n_batch_slots"] >= bc["n_batched_invocations"]
    # occupancy counters round-trip through JSON with the result
    back = ExperimentResult.from_dict(json.loads(json.dumps(res.to_dict())))
    assert back.backend_counters == bc


def test_modeled_backend_keeps_the_fast_path_untouched():
    """The modeled backend must leave both data-plane hooks unset so
    schedulers take the exact pre-seam fast path (the equivalence goldens
    pin the resulting decisions; see tests/test_equivalence.py)."""
    res = simulate(_tiny_exp())
    backend = res.sim.backend
    assert backend.name == "modeled"
    assert backend.submit is None and backend.execute is None
    sgss = res.sim.lbs.sgss.values()
    assert all(s.backend_submit is None and s.execute is None for s in sgss)
    assert res.backend_counters == {}


def test_legacy_execute_only_backend_is_adapted_to_submit():
    @register_backend("test-legacy-sync")
    class LegacySync(ExecutionBackend):
        def build(self, exp, spec):
            self.execute = lambda inv: inv.fn.exec_time
            return spec

    res = simulate(_tiny_exp(backend="test-legacy-sync"))
    backend = res.sim.backend
    assert backend.submit is not None          # bind() wrapped the hook
    assert res.n_completed > 0
    # the adapter preserves modeled timing exactly
    m = _cmp_dict(simulate(_tiny_exp()))
    s = _cmp_dict(res)
    for d in (m, s):
        d.pop("backend"), d.pop("name"), d.pop("backend_counters")
    assert m == s


# -- satellite regressions ----------------------------------------------------


def test_served_model_key_is_content_based():
    """Regression for the id()-keyed calibration cache: a garbage-collected
    ServedModel's id can be reused, false-hitting the cache.  The key must
    depend on model content only."""
    pytest.importorskip("jax")
    from repro.serving import ServedModel
    from repro.configs import get_config

    cfg = get_config("mamba2-370m", reduced=True)
    a = {"f": ServedModel(cfg, prompt_len=16, gen_len=2)}
    same = {"f": ServedModel(cfg, prompt_len=16, gen_len=2)}
    assert served_model_key(a) == served_model_key(same)   # ids differ
    assert served_model_key(a) != served_model_key(
        {"f": ServedModel(cfg, prompt_len=32, gen_len=2)})
    assert served_model_key(a) != served_model_key(
        {"f": ServedModel(cfg, prompt_len=16, gen_len=2, batch=4)})
    assert served_model_key(a) != served_model_key(
        {"g": ServedModel(cfg, prompt_len=16, gen_len=2)})
    other = get_config("gemma3-1b", reduced=True)
    assert served_model_key(a) != served_model_key(
        {"f": ServedModel(other, prompt_len=16, gen_len=2)})


def test_hash_ring_rejects_empty_id_list():
    with pytest.raises(ValueError, match="at least one SGS id"):
        ConsistentHashRing([])
