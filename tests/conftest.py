import os

# Smoke tests and benches must see the host's real (single) CPU device —
# only launch/dryrun.py forces 512 placeholder devices.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
