import os
import sys

# Smoke tests and benches must see the host's real (single) CPU device —
# only launch/dryrun.py forces 512 placeholder devices.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Let `python -m pytest` work from a bare checkout: prefer an installed
# `repro` (pip install -e .) or PYTHONPATH=src, else fall back to src/.
try:
    import repro  # noqa: F401
except ImportError:                                     # pragma: no cover
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
