"""Elastic control plane: LBS replica autoscaler control law, typed
scaling events, and end-to-end integration via ``Experiment.autoscale``
(docs/SCENARIOS.md)."""
import json

import pytest

from repro.core import (AutoscaleConfig, LBSReplicaAutoscaler, ScalingEvent,
                        scaling_summary)
from repro.core.cluster import ClusterConfig
from repro.core.stacks import _ServiceClock
from repro.sim import Experiment, ExperimentResult, run_sweep, simulate


CFG = AutoscaleConfig(min_replicas=1, max_replicas=8, interval=0.1,
                      target_utilization=0.6, scale_in_utilization=0.25,
                      cooldown=0.2, scale_in_patience=2)


def _scaler(n=1, cfg=CFG, lb_cost=190e-6):
    clocks = [_ServiceClock() for _ in range(n)]
    return LBSReplicaAutoscaler(clocks, lb_cost, cfg,
                                make_clock=_ServiceClock), clocks


def _drive(scaler, now, n_routed):
    scaler.n_routed = n_routed
    scaler.tick(now)


# -- control law -------------------------------------------------------------


def test_scale_out_to_target_sizing():
    scaler, clocks = _scaler(n=1)
    # 4000 decisions in 0.1s on 1 clock at 190us each: util = 7.6
    _drive(scaler, now=0.1, n_routed=4000)
    # ceil(1 * 7.6 / 0.6) = 13, clamped to max_replicas=8
    assert len(clocks) == 8
    (ev,) = scaler.events
    assert ev.action == "scale_out" and ev.component == "lbs"
    assert ev.n_before == 1 and ev.n_after == 8
    assert ev.metric == pytest.approx(7.6)


def test_fresh_replicas_start_idle_at_now():
    scaler, clocks = _scaler(n=1)
    clocks[0].busy_until = 5.0
    _drive(scaler, now=0.1, n_routed=4000)
    assert all(c.busy_until == 0.1 for c in clocks[1:])


def test_backlog_alone_triggers_scale_out():
    scaler, clocks = _scaler(n=2)
    clocks[0].busy_until = 1.0          # 0.9s of formed queue
    _drive(scaler, now=0.1, n_routed=0)  # zero utilization
    assert len(clocks) == 3
    assert scaler.events[0].detail["backlog_s"] == pytest.approx(0.9)


def test_scale_in_needs_patience_and_cooldown():
    scaler, clocks = _scaler(n=4)
    # quiet window 1: patience not yet met -> no change
    _drive(scaler, now=0.1, n_routed=0)
    assert len(clocks) == 4
    # quiet window 2: patience met -> retire exactly one
    _drive(scaler, now=0.2, n_routed=0)
    assert len(clocks) == 3
    assert scaler.events[-1].action == "scale_in"
    # patience resets after an action: quiet window 1 of the next round
    _drive(scaler, now=0.3, n_routed=0)
    assert len(clocks) == 3
    _drive(scaler, now=0.4, n_routed=0)
    assert len(clocks) == 2


def test_scale_in_retires_most_idle_clock():
    scaler, clocks = _scaler(n=3)
    clocks[0].busy_until = -1.0
    clocks[1].busy_until = -5.0         # most idle
    clocks[2].busy_until = -2.0
    keep = (clocks[0], clocks[2])
    _drive(scaler, now=10.0, n_routed=0)
    _drive(scaler, now=11.0, n_routed=0)
    assert tuple(clocks) == keep


def test_busy_window_resets_patience():
    scaler, clocks = _scaler(n=3)
    _drive(scaler, now=0.1, n_routed=0)            # quiet 1
    _drive(scaler, now=0.2, n_routed=800)          # busy (util ~0.5): reset
    _drive(scaler, now=0.3, n_routed=0)            # quiet 1 again
    assert len(clocks) == 3
    _drive(scaler, now=0.4, n_routed=0)            # quiet 2: shrink
    assert len(clocks) == 2


def test_never_below_min_or_above_max():
    scaler, clocks = _scaler(n=1)
    for i in range(20):
        _drive(scaler, now=0.1 * (i + 1), n_routed=10000)
    assert len(clocks) == CFG.max_replicas
    scaler2, clocks2 = _scaler(n=CFG.min_replicas)
    for i in range(20):
        _drive(scaler2, now=0.1 * (i + 1), n_routed=0)
    assert len(clocks2) == CFG.min_replicas


# -- ring re-sharding (deterministic complement to test_properties.py) -------


def test_ring_resharding_deterministic():
    from repro.core import ConsistentHashRing
    ids = [0, 1, 2, 3]
    ring = ConsistentHashRing(ids)
    keys = [f"dag-{i}" for i in range(300)]
    before = {k: ring.lookup(k) for k in keys}
    ring.add_node(9)
    moved = [k for k in keys if ring.lookup(k) != before[k]]
    assert moved and all(ring.lookup(k) == 9 for k in moved)
    assert len(moved) / len(keys) <= 4.0 / 5.0
    ring.remove_node(9)
    assert all(ring.lookup(k) == before[k] for k in keys)
    succ = ring.successors("dag-0")
    assert sorted(succ) == ids and len(succ) == len(set(succ))
    with pytest.raises(ValueError, match="unknown SGS id"):
        ring.remove_node(42)


# -- events / config serialization -------------------------------------------


def test_config_and_event_roundtrip():
    cfg = AutoscaleConfig(max_replicas=32, interval=0.05)
    assert AutoscaleConfig.from_dict(
        json.loads(json.dumps(cfg.to_dict()))) == cfg
    ev = ScalingEvent(t=1.5, component="lbs", action="scale_out",
                      n_before=2, n_after=4, metric=0.9,
                      detail={"backlog_s": 0.2})
    assert ScalingEvent.from_dict(
        json.loads(json.dumps(ev.to_dict()))) == ev


def test_scaling_summary_digest():
    events = [
        {"component": "lbs", "action": "scale_out", "n_after": 6},
        {"component": "lbs", "action": "scale_in", "n_after": 5},
        {"component": "sgs", "action": "scale_out", "n_after": 2},
    ]
    s = scaling_summary(events)
    assert s["n_events"] == 3
    assert s["lbs_scale_outs"] == 1 and s["lbs_scale_ins"] == 1
    assert s["sgs_scale_outs"] == 1 and s["sgs_scale_ins"] == 0
    assert s["lbs_peak_replicas"] == 6 and s["lbs_final_replicas"] == 5


# -- Experiment integration --------------------------------------------------


def _exp(**kw):
    base = dict(
        stack="archipelago",
        workload_factory="paper_workload_1",
        workload_kwargs={"duration": 4.0, "scale": 0.05, "dags_per_class": 2},
        cluster=ClusterConfig(n_sgs=2, workers_per_sgs=4),
        drain=3.0, seed=11)
    base.update(kw)
    return Experiment(**base)


def test_autoscale_none_is_decision_identical():
    a = simulate(_exp()).detach_sim().to_dict()
    b = simulate(_exp(autoscale=None)).detach_sim().to_dict()
    a.pop("wall_s"), b.pop("wall_s")
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_autoscaled_run_completes_and_records_events():
    # tiny pool + aggressive target so the toy load actually forces growth
    cfg = AutoscaleConfig(min_replicas=1, max_replicas=16, interval=0.05,
                          target_utilization=0.005,
                          scale_in_utilization=0.0001)
    r = simulate(_exp(traffic="flash_crowd", autoscale=cfg))
    assert r.n_completed == r.n_requests
    lbs = [e for e in r.scaling_events if e["component"] == "lbs"]
    assert lbs and any(e["action"] == "scale_out" for e in lbs)
    assert scaling_summary(r.scaling_events)["lbs_peak_replicas"] > 1
    # events survive the lossless result round-trip
    rt = ExperimentResult.from_dict(json.loads(json.dumps(r.to_dict())))
    assert rt.scaling_events == r.scaling_events


def test_events_are_time_ordered_and_typed():
    cfg = AutoscaleConfig(min_replicas=1, max_replicas=16, interval=0.05,
                          target_utilization=0.005)
    r = simulate(_exp(autoscale=cfg))
    ts = [e["t"] for e in r.scaling_events]
    assert ts == sorted(ts)
    for e in r.scaling_events:
        assert e["component"] in ("lbs", "sgs")
        assert e["action"] in ("scale_out", "scale_in")
        assert e["n_after"] != e["n_before"]


def test_sgs_scaling_log_mirrors_legacy_channel():
    # heavy enough that per-DAG SGS scale-out fires; the typed log must
    # mirror the legacy (t, dag_id, n_active) tuples one-for-one
    r = simulate(_exp(workload_kwargs={"duration": 4.0, "scale": 0.3,
                                       "dags_per_class": 2}))
    lbs_obj = r.sim.lbs
    assert lbs_obj is not None
    legacy = lbs_obj.scale_events
    typed = lbs_obj.scaling_log
    assert len(legacy) == len(typed)
    for (t, dag_id, n_active), ev in zip(legacy, typed):
        assert ev.t == pytest.approx(t, abs=1e-6)
        assert ev.detail["dag_id"] == dag_id
        assert ev.n_after == n_active


def test_autoscale_is_sweepable_axis():
    cfg = AutoscaleConfig(min_replicas=1, max_replicas=4, interval=0.05)
    rs = run_sweep(_exp(), {"autoscale": [None, cfg]}, workers=1)
    assert len(rs.rows) == 2
    d = rs.to_dict()          # AutoscaleConfig serializes via to_dict
    assert d["rows"][1]["cell"]["autoscale"]["max_replicas"] == 4
    json.dumps(d)


def test_autoscale_dotted_override():
    cfg = AutoscaleConfig(min_replicas=1, max_replicas=4, interval=0.05)
    rs = run_sweep(_exp(autoscale=cfg),
                   {"autoscale.max_replicas": [2, 6]}, workers=1)
    assert [r["cell"]["autoscale.max_replicas"] for r in rs.rows] == [2, 6]


if __name__ == "__main__":
    import sys
    sys.exit(pytest.main([__file__, "-q"]))
