"""End-to-end behaviour tests for the Archipelago system (scaled-down
versions of the paper's experiments; the full-scale runs live in
benchmarks/)."""
import random

import pytest

from repro.core import ClusterConfig, LBSConfig, SGSConfig
from repro.core.types import DagSpec, FunctionSpec
from repro.sim import (ConstantRate, OnOffRate, Sinusoidal, WorkloadSpec,
                       paper_workload_1, paper_workload_2, run_archipelago,
                       run_baseline, run_sparrow)

CC = ClusterConfig(n_sgs=4, workers_per_sgs=4, cores_per_worker=8,
                   pool_mem_mb=65536.0)


def _single_fn_dag(dag_id, exec_time=0.08, slack=0.15, setup=0.25):
    return DagSpec(dag_id,
                   (FunctionSpec(f"{dag_id}/f", exec_time,
                                 setup_time=setup),),
                   (), deadline=exec_time + slack)


def test_archipelago_meets_deadlines_steady_state():
    spec = paper_workload_2(duration=15.0, scale=0.08, dags_per_class=1)
    res = run_archipelago(spec, cluster=CC)
    m = res.metrics.after_warmup(5.0)
    assert m.deadline_met_frac() > 0.95
    assert len(m.completed) == len(m.requests)


def test_archipelago_beats_baseline_under_load():
    """At cluster-scale RPS the centralized baseline's single scheduler
    saturates (§2.4); Archipelago's partitioned SGSs do not."""
    spec = paper_workload_1(duration=12.0, scale=1.3, dags_per_class=2)
    full = ClusterConfig()      # 8 SGSs x 8 workers x 20 cores
    ra = run_archipelago(spec, cluster=full)
    rb = run_baseline(spec, cluster=full)
    ma = ra.metrics.after_warmup(4.0)
    mb = rb.metrics.after_warmup(4.0)
    assert ma.deadline_met_frac() > 0.97
    assert ma.deadline_met_frac() > mb.deadline_met_frac() + 0.2
    assert mb.latency_pct(99.9) > ma.latency_pct(99.9)


def test_proactive_allocation_reduces_cold_starts():
    dag = _single_fn_dag("d", exec_time=0.05, setup=0.3)
    spec = WorkloadSpec([(dag, ConstantRate(100.0))], duration=10.0)
    on = run_archipelago(spec, cluster=CC,
                         sgs_cfg=SGSConfig(proactive=True))
    off = run_archipelago(spec, cluster=CC,
                          sgs_cfg=SGSConfig(proactive=False))
    m_on = on.metrics.after_warmup(3.0)
    m_off = off.metrics.after_warmup(3.0)
    assert m_on.cold_start_count() <= m_off.cold_start_count()
    assert m_on.deadline_met_frac() >= m_off.deadline_met_frac()
    # steady state: proactive allocation leaves essentially no cold starts
    assert m_on.cold_start_frac() < 0.02


def test_even_beats_packed_placement():
    """Fig. 9: packed placement misses deadlines at load peaks."""
    dag = _single_fn_dag("d", exec_time=0.1, slack=0.12, setup=0.3)
    spec = WorkloadSpec([(dag, Sinusoidal(120.0, 60.0, 8.0))], duration=16.0)
    cc = ClusterConfig(n_sgs=1, workers_per_sgs=10, cores_per_worker=4)
    even = run_archipelago(spec, cluster=cc,
                           sgs_cfg=SGSConfig(even_placement=True))
    packed = run_archipelago(spec, cluster=cc,
                             sgs_cfg=SGSConfig(even_placement=False))
    me = even.metrics.after_warmup(4.0)
    mp = packed.metrics.after_warmup(4.0)
    assert me.deadline_met_frac() >= mp.deadline_met_frac()
    assert me.cold_start_count() <= mp.cold_start_count()


def test_scale_out_under_contention():
    """Fig. 11: a constant-rate DAG scales out when a bursty DAG contends."""
    calm = _single_fn_dag("calm", exec_time=0.1, slack=0.1)
    bursty = _single_fn_dag("bursty", exec_time=0.1, slack=0.1)
    cc = ClusterConfig(n_sgs=5, workers_per_sgs=4, cores_per_worker=4)
    spec = WorkloadSpec([(calm, ConstantRate(60.0)),
                         (bursty, Sinusoidal(250.0, 200.0, 8.0))],
                        duration=16.0)
    res = run_archipelago(spec, cluster=cc)
    assert res.lbs.n_active("bursty") >= 2 or res.lbs.n_active("calm") >= 2
    m = res.metrics.after_warmup(4.0)
    assert m.deadline_met_frac() > 0.85


def test_sparrow_random_probing_worse_than_archipelago():
    """Fig. 2d flavor: power-of-two probing misses warm sandboxes."""
    spec = paper_workload_2(duration=12.0, scale=0.08, dags_per_class=1)
    ra = run_archipelago(spec, cluster=CC)
    rs = run_sparrow(spec, cluster=CC)
    ma = ra.metrics.after_warmup(4.0)
    ms = rs.metrics.after_warmup(4.0)
    assert ma.cold_start_count() < ms.cold_start_count()


def test_all_requests_complete_and_conserve():
    """No request is lost or double-completed by the scheduling machinery."""
    spec = paper_workload_1(duration=6.0, scale=0.1, dags_per_class=1)
    res = run_archipelago(spec, cluster=CC, drain=20.0)
    m = res.metrics
    assert len(m.completed) == len(m.requests)
    for r in m.completed:
        assert r.completion_time >= r.arrival_time
        assert r.e2e_latency >= r.dag.critical_path_time() - 1e-9


def test_deadline_aware_scaling_favors_tight_slack():
    """Fig. 10: the lower-slack DAG scales out to at least as many SGSs."""
    tight = _single_fn_dag("tight", exec_time=0.1, slack=0.05)
    loose = _single_fn_dag("loose", exec_time=0.1, slack=0.60)
    cc = ClusterConfig(n_sgs=6, workers_per_sgs=2, cores_per_worker=4)
    spec = WorkloadSpec([(tight, Sinusoidal(150.0, 100.0, 8.0)),
                         (loose, Sinusoidal(150.0, 100.0, 8.0))],
                        duration=14.0)
    res = run_archipelago(spec, cluster=cc)
    assert res.lbs.n_active("tight") >= res.lbs.n_active("loose")
