"""End-to-end behaviour tests for the Archipelago system (scaled-down
versions of the paper's experiments; the full-scale runs live in
benchmarks/).  All drivers go through the declarative experiment API."""
from dataclasses import replace

from repro.core import ClusterConfig, SGSConfig
from repro.core.types import DagSpec, FunctionSpec
from repro.sim import (ConstantRate, Experiment, Sinusoidal, WorkloadSpec,
                       simulate)

CC = ClusterConfig(n_sgs=4, workers_per_sgs=4, cores_per_worker=8,
                   pool_mem_mb=65536.0)


def _single_fn_dag(dag_id, exec_time=0.08, slack=0.15, setup=0.25):
    return DagSpec(dag_id,
                   (FunctionSpec(f"{dag_id}/f", exec_time,
                                 setup_time=setup),),
                   (), deadline=exec_time + slack)


def test_archipelago_meets_deadlines_steady_state():
    res = simulate(Experiment(
        stack="archipelago", workload_factory="paper_workload_2",
        workload_kwargs=dict(duration=15.0, scale=0.08, dags_per_class=1),
        cluster=CC, warmup=5.0))
    assert res.deadline_met_frac > 0.95
    assert res.n_completed == res.n_requests


def test_archipelago_beats_baseline_under_load():
    """At cluster-scale RPS the centralized baseline's single scheduler
    saturates (§2.4); Archipelago's partitioned SGSs do not."""
    base = Experiment(
        workload_factory="paper_workload_1",
        workload_kwargs=dict(duration=12.0, scale=1.3, dags_per_class=2),
        cluster=ClusterConfig(),    # 8 SGSs x 8 workers x 20 cores
        warmup=4.0)
    ra = simulate(replace(base, stack="archipelago"))
    rb = simulate(replace(base, stack="fifo"))
    assert ra.deadline_met_frac > 0.97
    assert ra.deadline_met_frac > rb.deadline_met_frac + 0.2
    assert (rb.latency_percentiles["p99.9"]
            > ra.latency_percentiles["p99.9"])


def test_proactive_allocation_reduces_cold_starts():
    dag = _single_fn_dag("d", exec_time=0.05, setup=0.3)
    spec = WorkloadSpec([(dag, ConstantRate(100.0))], duration=10.0)
    base = Experiment(stack="archipelago", workload=spec, cluster=CC,
                      warmup=3.0)
    on = simulate(replace(base, sgs=SGSConfig(proactive=True)))
    off = simulate(replace(base, sgs=SGSConfig(proactive=False)))
    assert on.cold_start_count <= off.cold_start_count
    assert on.deadline_met_frac >= off.deadline_met_frac
    # steady state: proactive allocation leaves essentially no cold starts
    assert on.cold_start_frac < 0.02


def test_even_beats_packed_placement():
    """Fig. 9: packed placement misses deadlines at load peaks."""
    dag = _single_fn_dag("d", exec_time=0.1, slack=0.12, setup=0.3)
    spec = WorkloadSpec([(dag, Sinusoidal(120.0, 60.0, 8.0))], duration=16.0)
    base = Experiment(
        workload=spec, warmup=4.0,
        cluster=ClusterConfig(n_sgs=1, workers_per_sgs=10,
                              cores_per_worker=4))
    even = simulate(replace(base, sgs=SGSConfig(even_placement=True)))
    packed = simulate(replace(base, sgs=SGSConfig(even_placement=False)))
    assert even.deadline_met_frac >= packed.deadline_met_frac
    assert even.cold_start_count <= packed.cold_start_count


def test_scale_out_under_contention():
    """Fig. 11: a constant-rate DAG scales out when a bursty DAG contends."""
    calm = _single_fn_dag("calm", exec_time=0.1, slack=0.1)
    bursty = _single_fn_dag("bursty", exec_time=0.1, slack=0.1)
    spec = WorkloadSpec([(calm, ConstantRate(60.0)),
                         (bursty, Sinusoidal(250.0, 200.0, 8.0))],
                        duration=16.0)
    res = simulate(Experiment(
        workload=spec, warmup=4.0,
        cluster=ClusterConfig(n_sgs=5, workers_per_sgs=4,
                              cores_per_worker=4)))
    lbs = res.sim.lbs
    assert lbs.n_active("bursty") >= 2 or lbs.n_active("calm") >= 2
    assert res.deadline_met_frac > 0.85


def test_sparrow_random_probing_worse_than_archipelago():
    """Fig. 2d flavor: power-of-two probing misses warm sandboxes."""
    base = Experiment(
        workload_factory="paper_workload_2",
        workload_kwargs=dict(duration=12.0, scale=0.08, dags_per_class=1),
        cluster=CC, warmup=4.0)
    ra = simulate(replace(base, stack="archipelago"))
    rs = simulate(replace(base, stack="sparrow"))
    assert ra.cold_start_count < rs.cold_start_count


def test_all_requests_complete_and_conserve():
    """No request is lost or double-completed by the scheduling machinery."""
    res = simulate(Experiment(
        stack="archipelago", workload_factory="paper_workload_1",
        workload_kwargs=dict(duration=6.0, scale=0.1, dags_per_class=1),
        cluster=CC, drain=20.0))
    m = res.sim.metrics
    assert len(m.completed) == len(m.requests)
    for r in m.completed:
        assert r.completion_time >= r.arrival_time
        assert r.e2e_latency >= r.dag.critical_path_time() - 1e-9


def test_deadline_aware_scaling_favors_tight_slack():
    """Fig. 10: the lower-slack DAG scales out to at least as many SGSs."""
    tight = _single_fn_dag("tight", exec_time=0.1, slack=0.05)
    loose = _single_fn_dag("loose", exec_time=0.1, slack=0.60)
    spec = WorkloadSpec([(tight, Sinusoidal(150.0, 100.0, 8.0)),
                         (loose, Sinusoidal(150.0, 100.0, 8.0))],
                        duration=14.0)
    res = simulate(Experiment(
        workload=spec,
        cluster=ClusterConfig(n_sgs=6, workers_per_sgs=2,
                              cores_per_worker=4)))
    lbs = res.sim.lbs
    assert lbs.n_active("tight") >= lbs.n_active("loose")
