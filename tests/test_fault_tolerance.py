"""Fault-tolerance tests (§6.1): worker fail-stop, SGS/LB state recovery,
async-seam crash safety, and end-to-end SGS failover (docs/FAULTS.md)."""
import pytest

from repro.core import (BatchCoalescer, ClusterConfig, ContinuousBatcher,
                        Request, SGSConfig, SemiGlobalScheduler, Worker)
from repro.core.cluster import build_cluster
from repro.core.fault import (FaultPlan, StateStore, checkpoint_lbs,
                              checkpoint_sgs, fail_sgs, fail_worker,
                              restore_lbs, restore_sgs, sgs_failstop,
                              slow_worker, worker_crash)
from repro.core.types import DagSpec, FunctionSpec
from repro.sim import ConstantRate, Experiment, WorkloadSpec, simulate
from repro.sim.engine import SimEnv


def _dag(dag_id="d", exec_time=0.1, slack=0.3):
    return DagSpec(dag_id,
                   (FunctionSpec(f"{dag_id}/f", exec_time, setup_time=0.2),),
                   (), deadline=exec_time + slack)


def test_worker_failure_retries_inflight():
    env = SimEnv()
    workers = [Worker(worker_id=i, cores=2, pool_mem_mb=4096)
               for i in range(3)]
    sgs = SemiGlobalScheduler(0, workers, env)
    dag = _dag()
    reqs = [Request(dag=dag, arrival_time=0.0) for _ in range(4)]
    for r in reqs:
        sgs.submit_request(r)
    env.run_until(0.05)                 # executions in flight (exec 0.1s)
    victim = next(w for w in sgs.workers if w.busy_cores > 0)
    n_retry = fail_worker(sgs, victim.worker_id)
    assert n_retry > 0
    assert victim not in sgs.workers
    env.run_until(5.0)
    # every request still completes exactly once
    assert all(r.completion_time is not None for r in reqs)
    assert len(sgs.completed_requests) == len(reqs)


def test_worker_failure_under_load_recovers_deadlines():
    """Lost capacity shows up as queuing delay; the LBS scales the DAG out
    (the paper's §6.1 argument); steady state recovers."""
    env = SimEnv()
    cc = ClusterConfig(n_sgs=3, workers_per_sgs=3, cores_per_worker=4)
    lbs = build_cluster(env, cc)
    dag = _dag(exec_time=0.08, slack=0.25)
    from repro.sim.metrics import Metrics
    metrics = Metrics()
    spec = WorkloadSpec([(dag, ConstantRate(80.0))], 12.0)
    for t, d in spec.generate(0):
        def fire(t=t, d=d):
            req = Request(dag=d, arrival_time=env.now())
            metrics.requests.append(req)
            lbs.route(req, env.now())
        env.call_at(t, fire)
    env.every(0.05, lambda: lbs.check_scaling(env.now()), until=12.0)

    # at t=4s, kill 2 of the home SGS's 3 workers
    home = lbs.sgss[lbs.ring.lookup("d")]

    def inject():
        ids = [w.worker_id for w in home.workers[:2]]
        for wid in ids:
            fail_worker(home, wid)

    env.call_at(4.0, inject)
    env.run_until(14.0)
    m = metrics.after_warmup(6.0)       # post-failure steady state
    assert m.deadline_met_frac() > 0.9
    assert len(m.completed) == len(m.requests)
    # capacity loss forced a scale-out
    assert lbs.n_active("d") >= 2


def test_sgs_state_recovery_from_store():
    env = SimEnv()
    workers = [Worker(worker_id=i, cores=2, pool_mem_mb=4096)
               for i in range(2)]
    sgs = SemiGlobalScheduler(0, workers, env)
    dag = _dag()
    for _ in range(5):
        sgs.submit_request(Request(dag=dag, arrival_time=env.now()))
    env.run_until(1.0)                  # estimator ticks, demand set
    store = StateStore()
    checkpoint_sgs(sgs, store)
    assert store.n_writes >= 3

    # fresh instance (same id, fresh pool) restores and re-allocates
    w2 = [Worker(worker_id=10 + i, cores=2, pool_mem_mb=4096)
          for i in range(2)]
    sgs2 = SemiGlobalScheduler(0, w2, env)
    restore_sgs(sgs2, store, env.now())
    assert dag.dag_id in sgs2._dags
    old_demand = sgs.sandboxes.demand_map.get("d/f", 0)
    if old_demand > 0:
        assert sgs2.sandboxes.total_sandboxes("d/f") == old_demand


def test_async_backend_completion_on_dead_worker_is_dropped():
    """Satellite regression: under the async execution seam a completion
    scheduled via ``submit()`` on a worker that later dies must neither
    mutate scheduler/worker state nor double-complete the retried
    invocation (guarded by the inflight registration)."""
    env = SimEnv()
    workers = [Worker(worker_id=i, cores=2, pool_mem_mb=4096)
               for i in range(3)]

    def submit(inv, done, setup=0.0):       # async seam: completion later
        env.call_after(setup + 0.1, done, 0.1)

    sgs = SemiGlobalScheduler(0, workers, env, backend_submit=submit)
    dag = _dag()
    reqs = [Request(dag=dag, arrival_time=0.0) for _ in range(4)]
    for r in reqs:
        sgs.submit_request(r)
    env.run_until(0.05)                     # all executions in flight
    victim = next(w for w in sgs.workers if w.busy_cores > 0)
    busy_at_death = victim.busy_cores
    assert fail_worker(sgs, victim.worker_id) > 0
    env.run_until(5.0)                      # stale done()s fire en route
    # stale completions for the dead worker were dropped, not applied
    assert victim.busy_cores == busy_at_death
    # every request completed exactly once through the retries
    assert len(sgs.completed_requests) == len(reqs)
    assert all(r.completion_time is not None for r in reqs)
    assert all(w.busy_cores == 0 for w in sgs.workers)
    assert sgs._free_cores == sum(w.cores for w in sgs.workers)


def test_stub_backend_crash_storm_accounting():
    """Same regression end-to-end: the ``stub`` backend (the real-execution
    code path) under a crash storm keeps all requests accounted for and the
    core ledgers consistent."""
    res = simulate(Experiment(
        stack="archipelago", backend="stub",
        workload_factory="paper_workload_1",
        workload_kwargs=dict(duration=4.0, scale=0.03, dags_per_class=1),
        cluster=ClusterConfig(n_sgs=2, workers_per_sgs=3,
                              cores_per_worker=4, pool_mem_mb=2048.0),
        drain=6.0,
        faults=FaultPlan(events=(worker_crash(k=1, at=1.0),
                                 worker_crash(k=1, at=2.0)), seed=4)))
    assert res.n_retries >= 0 and len(res.fault_events) == 2
    m = res.sim.metrics
    assert m.n_completed == m.n_requests
    for sgs in res.sim.lbs.sgss.values():
        assert all(w.busy_cores == 0 for w in sgs.workers)


def test_lbs_mapping_recovery_from_store():
    env = SimEnv()
    cc = ClusterConfig(n_sgs=4, workers_per_sgs=2, cores_per_worker=4)
    lbs = build_cluster(env, cc)
    dag = _dag()
    st = lbs._state(dag, 0.0)
    lbs._scale_out(st, 0.0)
    store = StateStore()
    checkpoint_lbs(lbs, store)

    lbs2 = build_cluster(env, cc)
    st2 = lbs2._state(dag, 0.0)         # re-register the DAG
    restore_lbs(lbs2, store, 0.0)
    assert lbs2._dag_state["d"].active == st.active


def test_restore_lbs_drops_mappings_to_dead_sgss():
    env = SimEnv()
    big = build_cluster(env, ClusterConfig(n_sgs=4, workers_per_sgs=2,
                                           cores_per_worker=4))
    dag = _dag()
    st = big._state(dag, 0.0)
    for _ in range(3):
        big._scale_out(st, 0.0)
    store = StateStore()
    checkpoint_lbs(big, store)
    assert len(st.active) >= 3

    # the replacement cluster only has SGSs 0 and 1: mappings to the dead
    # ids must be filtered, not restored blind
    small = build_cluster(env, ClusterConfig(n_sgs=2, workers_per_sgs=2,
                                             cores_per_worker=4))
    small._state(dag, 0.0)
    restore_lbs(small, store, 0.0)
    st2 = small._dag_state["d"]
    assert set(st2.active) <= set(small.sgss)
    assert set(st2.removed) <= set(small.sgss)


def test_checkpoint_restore_round_trip_reproduces_soft_state():
    """Property-style round-trip: checkpoint → fresh SGS (same pool shape)
    → restore reproduces demand targets, fn specs and the DAG registry,
    and holds the demand as a floor so the fresh estimator cannot
    immediately soft-evict the restored pool."""
    env = SimEnv()
    workers = [Worker(worker_id=i, cores=4, pool_mem_mb=4096)
               for i in range(3)]
    sgs = SemiGlobalScheduler(0, workers, env)
    dags = [_dag(f"d{i}", exec_time=0.05 * (i + 1)) for i in range(3)]
    for t in range(6):
        for d in dags:
            env.call_at(0.2 * t, lambda d=d: sgs.submit_request(
                Request(dag=d, arrival_time=env.now())))
    env.run_until(2.0)                  # estimator ticks, demand set
    store = StateStore()
    checkpoint_sgs(sgs, store)

    w2 = [Worker(worker_id=10 + i, cores=4, pool_mem_mb=4096)
          for i in range(3)]
    sgs2 = SemiGlobalScheduler(0, w2, env)
    restore_sgs(sgs2, store, env.now())
    assert sgs2._dags == sgs._dags
    assert sgs2.sandboxes.fn_specs == sgs.sandboxes.fn_specs
    for fn, d in sgs.sandboxes.demand_map.items():
        assert sgs2.sandboxes.demand_map.get(fn) == d
        if d > 0:
            assert sgs2.sandboxes.total_sandboxes(fn) == d
            floor, expiry = sgs2._demand_floor[fn]
            assert floor == d and expiry > env.now()


# -- end-to-end SGS failover (§6.1) ------------------------------------------


def _failover_exp(**kw):
    base = dict(stack="archipelago", workload_factory="paper_workload_1",
                workload_kwargs=dict(duration=8.0, scale=0.05,
                                     dags_per_class=2),
                cluster=ClusterConfig(n_sgs=3, workers_per_sgs=4,
                                      cores_per_worker=8,
                                      pool_mem_mb=8192.0),
                drain=5.0, seed=1)
    base.update(kw)
    return Experiment(**base)


def test_sgs_failstop_end_to_end_failover():
    """Acceptance: kill an SGS mid-run; the replacement restores from the
    StateStore, the LBS re-routes, all pre-failure requests complete, and
    post-recovery deadline-met stays within 5 points of the no-fault run."""
    t_fail = 4.0
    healthy = simulate(_failover_exp())
    chaos = simulate(_failover_exp(faults=FaultPlan(
        events=(sgs_failstop(at=t_fail),), seed=0)))

    ev = chaos.fault_events[0]
    assert ev["kind"] == "sgs_failstop" and ev["restored"]
    sid = ev["sgs"]
    lbs = chaos.sim.lbs
    replacement = lbs.sgss[sid]
    assert replacement._successor is None       # live instance
    # the ring still routes this id — to the replacement object
    assert replacement is not None and replacement.sgs_id == sid

    # every request (pre- and post-failure) completes
    m = chaos.sim.metrics
    assert m.n_completed == m.n_requests == healthy.sim.metrics.n_requests
    pre = m.window(0.0, t_fail)
    assert pre.n_completed == pre.n_requests

    # post-recovery deadline-met within 5 points of the no-fault run
    after = m.window(t_fail + 1.0, float("inf")).deadline_met_frac()
    baseline = healthy.sim.metrics.window(
        t_fail + 1.0, float("inf")).deadline_met_frac()
    assert after == pytest.approx(baseline, abs=0.05)
    # the recovery report covers the event
    assert chaos.recovery["events"][0]["kind"] == "sgs_failstop"


def test_fail_sgs_requeues_and_forwards_completions():
    """Direct fail_sgs: queued work is retried on the replacement and
    in-flight completions on surviving workers forward through the dead
    instance's successor pointer."""
    env = SimEnv()
    lbs = build_cluster(env, ClusterConfig(n_sgs=2, workers_per_sgs=2,
                                           cores_per_worker=2))
    dag = _dag(exec_time=0.2)
    sid = lbs.ring.lookup("d")
    home = lbs.sgss[sid]
    reqs = [Request(dag=dag, arrival_time=0.0) for _ in range(10)]
    for r in reqs:
        env.call_at(0.0, lambda r=r: lbs.route(r, env.now()))
    env.run_until(0.05)             # 4 cores busy, 6 invocations queued
    assert home._queue
    store = StateStore()
    checkpoint_sgs(home, store)
    checkpoint_lbs(lbs, store)

    replacement, n_retry = fail_sgs(lbs, sid, store, env)
    assert replacement is not None and n_retry > 0
    assert lbs.sgss[sid] is replacement
    assert home._successor is replacement
    # unknown ids are a no-op (killing the *replacement* again is allowed —
    # repeated fail-stops of the same rack's scheduler are a valid plan)
    assert fail_sgs(lbs, 999, store, env) == (None, 0)

    env.run_until(10.0)
    assert all(r.completion_time is not None for r in reqs)
    # completions (including pre-failure in-flight ones) landed once each
    assert len(replacement.completed_requests) == len(reqs)
    assert all(w.busy_cores == 0 for w in replacement.workers)


# -- dead-member release in the batched data planes (satellite) ---------------


def _batch_inv(exec_time=0.1):
    from repro.core.types import DagSpec, FunctionSpec, Invocation
    dag = DagSpec("d", (FunctionSpec("d/f", exec_time),), ())
    req = Request(dag=dag, arrival_time=0.0)
    return Invocation(request=req, fn=dag.fn("d/f"), ready_time=0.0)


def test_coalescer_drop_removes_pending_and_tombstones_cold_members():
    env = SimEnv()
    flushed = []

    def run_batch(fn, invs):
        flushed.append([i.inv_id for i in invs])
        return 0.01

    co = BatchCoalescer(env, run_batch, batch_window=0.05, max_batch=8)
    done = []
    invs = [_batch_inv() for _ in range(3)]
    for inv in invs:
        co.submit(inv, lambda s, i=inv: done.append(i.inv_id))
    cold = _batch_inv()
    co.submit(cold, lambda s: done.append(cold.inv_id), 0.5)  # in setup
    env.run_until(0.01)                  # window open, nothing flushed
    co.drop([invs[1].inv_id, cold.inv_id])
    env.run()
    # the dropped pending member left the window; the cold member's
    # deferred enrollment consumed its tombstone instead of joining
    assert flushed == [[invs[0].inv_id, invs[2].inv_id]]
    assert sorted(done) == sorted([invs[0].inv_id, invs[2].inv_id])
    assert co.counters()["n_dropped_invocations"] == 2


def test_continuous_batcher_drop_frees_slot_and_fires_release_hook():
    env = SimEnv()
    released = []

    cb = ContinuousBatcher(env, lambda fn, invs, slots: 0.04,
                           lambda fn, slots: 0.01, lambda fn: 50,
                           max_batch=2,
                           release=lambda fn, slots: released.append(
                               (fn, list(slots))))
    done = []
    a, b = _batch_inv(), _batch_inv()
    cb.submit(a, lambda s: done.append("a"))
    cb.submit(b, lambda s: done.append("b"))
    late = _batch_inv()
    env.call_after(0.10, lambda: cb.submit(late,
                                           lambda s: done.append("late")))
    env.run_until(0.08)                  # both decoding, batch is full
    cb.drop([a.inv_id])                  # a's worker died mid-generation
    env.run_until(0.30)
    # a never completes (the scheduler retries it elsewhere); its slot was
    # zeroed via the release hook and handed to the late joiner
    assert "a" not in done and "late" not in done  # late still decoding
    assert released == [("d/f", [0])]
    assert cb.counters()["n_dropped_invocations"] == 1
    assert cb.counters()["max_batch_occupancy"] == 2
    cb.drop([b.inv_id, late.inv_id])
    env.run()
    assert done == []
    assert cb.counters()["n_dropped_invocations"] == 3


def _batched_crash_exp(batching, **backend_kw):
    kw = dict(exec_time=0.05, batching=batching, max_batch=4)
    kw.update(backend_kw)
    return Experiment(
        stack="archipelago", backend="stub-batched", backend_kwargs=kw,
        workload_factory="paper_workload_1",
        workload_kwargs=dict(duration=4.0, scale=0.03, dags_per_class=1),
        cluster=ClusterConfig(n_sgs=2, workers_per_sgs=3,
                              cores_per_worker=4, pool_mem_mb=2048.0),
        drain=8.0,
        faults=FaultPlan(events=(worker_crash(k=2, at=1.0),
                                 worker_crash(k=2, at=2.0)), seed=1))


@pytest.mark.parametrize("batching,extra", [
    ("windowed", {"batch_window": 0.2}),
    ("continuous", {"n_steps": 6}),
])
def test_worker_crash_mid_batch_drops_members_cleanly(batching, extra):
    """Satellite regression: a worker crash while its invocations sit in a
    windowed batch / continuous slot slab must drop exactly those members
    — retried cleanly, no CompletionQueue corruption, counters coherent."""
    res = simulate(_batched_crash_exp(batching, **extra))
    assert res.n_retries > 0
    # the crash reached the data plane: members were released, not leaked
    assert res.backend_counters["n_dropped_invocations"] > 0
    acc = res.accounting
    assert acc["lost"] == 0 and acc["duplicate_completions"] == 0
    assert acc["completed"] == acc["arrivals"]
    for sgs in res.sim.lbs.sgss.values():
        assert all(w.busy_cores == 0 for w in sgs.workers)
        assert sgs._free_cores == sum(w.cores for w in sgs.workers)
    if batching == "continuous":
        assert res.backend_counters["n_joins"] > 0
        assert res.backend_counters["n_decode_ticks"] > 0


# -- hedged retries under gray failure (mitigation layer) ---------------------


def _slow_exp(**kw):
    base = dict(stack="archipelago", workload_factory="paper_workload_1",
                workload_kwargs=dict(duration=6.0, scale=0.05,
                                     dags_per_class=2),
                cluster=ClusterConfig(n_sgs=2, workers_per_sgs=4,
                                      cores_per_worker=4,
                                      pool_mem_mb=4096.0),
                drain=30.0, seed=0,
                faults=FaultPlan(events=(slow_worker(at=0.5, k=3,
                                                     factor=16.0),),
                                 seed=7))
    base.update(kw)
    return Experiment(**base)


def test_hedged_retry_trims_the_slow_worker_tail():
    plain = simulate(_slow_exp())
    hedged = simulate(_slow_exp(params={"hedge_timeout": 1.5}))
    assert plain.n_hedges == 0
    assert hedged.n_hedges > 0
    # speculative copies cut the gray-straggler tail
    assert hedged.sim.metrics.sorted_latencies()[-1] \
        < plain.sim.metrics.sorted_latencies()[-1]
    # duplicate completions are suppressed: first copy wins, exactly once
    for res in (plain, hedged):
        acc = res.accounting
        assert acc["lost"] == 0 and acc["duplicate_completions"] == 0
        assert acc["completed"] == acc["arrivals"]
    # n_hedges survives the JSON round-trip
    from repro.sim import ExperimentResult
    import json as _json
    back = ExperimentResult.from_dict(
        _json.loads(_json.dumps(hedged.to_dict())))
    assert back.n_hedges == hedged.n_hedges
    assert back.accounting == hedged.accounting


def test_hedge_timeout_never_fires_on_healthy_workers():
    """On the modeled path a healthy dispatch completes at exactly
    setup + exec, strictly before the 1.5× hedge deadline: a faultless
    hedged run does the same work as an unhedged one."""
    off = simulate(_slow_exp(faults=None))
    on = simulate(_slow_exp(faults=None, params={"hedge_timeout": 1.5}))
    assert on.n_hedges == 0
    assert on.latency_percentiles == off.latency_percentiles
    assert on.accounting == off.accounting


def test_hedge_params_rejected_on_stacks_without_the_sgs_layer():
    with pytest.raises(ValueError, match="hedge_timeout"):
        simulate(_slow_exp(stack="fifo", params={"hedge_timeout": 1.5}))
