"""Fault-tolerance tests (§6.1): worker fail-stop, SGS/LB state recovery."""
import pytest

from repro.core import (ClusterConfig, Request, SGSConfig,
                        SemiGlobalScheduler, Worker)
from repro.core.cluster import build_cluster
from repro.core.fault import (StateStore, checkpoint_lbs, checkpoint_sgs,
                              fail_worker, restore_lbs, restore_sgs)
from repro.core.types import DagSpec, FunctionSpec
from repro.sim import ConstantRate, WorkloadSpec
from repro.sim.engine import SimEnv


def _dag(dag_id="d", exec_time=0.1, slack=0.3):
    return DagSpec(dag_id,
                   (FunctionSpec(f"{dag_id}/f", exec_time, setup_time=0.2),),
                   (), deadline=exec_time + slack)


def test_worker_failure_retries_inflight():
    env = SimEnv()
    workers = [Worker(worker_id=i, cores=2, pool_mem_mb=4096)
               for i in range(3)]
    sgs = SemiGlobalScheduler(0, workers, env)
    dag = _dag()
    reqs = [Request(dag=dag, arrival_time=0.0) for _ in range(4)]
    for r in reqs:
        sgs.submit_request(r)
    env.run_until(0.05)                 # executions in flight (exec 0.1s)
    victim = next(w for w in sgs.workers if w.busy_cores > 0)
    n_retry = fail_worker(sgs, victim.worker_id)
    assert n_retry > 0
    assert victim not in sgs.workers
    env.run_until(5.0)
    # every request still completes exactly once
    assert all(r.completion_time is not None for r in reqs)
    assert len(sgs.completed_requests) == len(reqs)


def test_worker_failure_under_load_recovers_deadlines():
    """Lost capacity shows up as queuing delay; the LBS scales the DAG out
    (the paper's §6.1 argument); steady state recovers."""
    env = SimEnv()
    cc = ClusterConfig(n_sgs=3, workers_per_sgs=3, cores_per_worker=4)
    lbs = build_cluster(env, cc)
    dag = _dag(exec_time=0.08, slack=0.25)
    from repro.sim.metrics import Metrics
    metrics = Metrics()
    spec = WorkloadSpec([(dag, ConstantRate(80.0))], 12.0)
    for t, d in spec.generate(0):
        def fire(t=t, d=d):
            req = Request(dag=d, arrival_time=env.now())
            metrics.requests.append(req)
            lbs.route(req, env.now())
        env.call_at(t, fire)
    env.every(0.05, lambda: lbs.check_scaling(env.now()), until=12.0)

    # at t=4s, kill 2 of the home SGS's 3 workers
    home = lbs.sgss[lbs.ring.lookup("d")]

    def inject():
        ids = [w.worker_id for w in home.workers[:2]]
        for wid in ids:
            fail_worker(home, wid)

    env.call_at(4.0, inject)
    env.run_until(14.0)
    m = metrics.after_warmup(6.0)       # post-failure steady state
    assert m.deadline_met_frac() > 0.9
    assert len(m.completed) == len(m.requests)
    # capacity loss forced a scale-out
    assert lbs.n_active("d") >= 2


def test_sgs_state_recovery_from_store():
    env = SimEnv()
    workers = [Worker(worker_id=i, cores=2, pool_mem_mb=4096)
               for i in range(2)]
    sgs = SemiGlobalScheduler(0, workers, env)
    dag = _dag()
    for _ in range(5):
        sgs.submit_request(Request(dag=dag, arrival_time=env.now()))
    env.run_until(1.0)                  # estimator ticks, demand set
    store = StateStore()
    checkpoint_sgs(sgs, store)
    assert store.n_writes >= 3

    # fresh instance (same id, fresh pool) restores and re-allocates
    w2 = [Worker(worker_id=10 + i, cores=2, pool_mem_mb=4096)
          for i in range(2)]
    sgs2 = SemiGlobalScheduler(0, w2, env)
    restore_sgs(sgs2, store, env.now())
    assert dag.dag_id in sgs2._dags
    old_demand = sgs.sandboxes.demand_map.get("d/f", 0)
    if old_demand > 0:
        assert sgs2.sandboxes.total_sandboxes("d/f") == old_demand


def test_lbs_mapping_recovery_from_store():
    env = SimEnv()
    cc = ClusterConfig(n_sgs=4, workers_per_sgs=2, cores_per_worker=4)
    lbs = build_cluster(env, cc)
    dag = _dag()
    st = lbs._state(dag, 0.0)
    lbs._scale_out(st, 0.0)
    store = StateStore()
    checkpoint_lbs(lbs, store)

    lbs2 = build_cluster(env, cc)
    st2 = lbs2._state(dag, 0.0)         # re-register the DAG
    restore_lbs(lbs2, store, 0.0)
    assert lbs2._dag_state["d"].active == st.active
