"""Vectorized/scalar parity for every ArrivalProcess.

``generate_np`` (Lewis-Shedler thinning) evaluates ``rate_array`` while the
legacy ``generate`` dt-loop evaluates scalar ``rate`` — the two samplers
agree only if the two rate views are pointwise identical and ``max_rate``
really dominates.  Covers the original shapes and the traffic-scenario
modulators (ScaledRate/DiurnalRate/BurstRate/WindowedRate), nested."""
import math
import random

import numpy as np
import pytest

from repro.sim.workload import (BurstRate, ConstantRate, DiurnalRate,
                                OnOffRate, PoissonResampled, ScaledRate,
                                Sinusoidal, WindowedRate)

T_END = 12.0

PROCS = [
    ("constant", ConstantRate(rps=40.0)),
    ("sinusoidal", Sinusoidal(avg=30.0, amplitude=12.0, period=5.0,
                              phase=0.7)),
    ("onoff", OnOffRate(rps=50.0, on_duration=1.5, off_duration=0.75)),
    ("poisson_resampled", PoissonResampled(rps_range=(10.0, 60.0),
                                           resample_every=0.8, seed=3)),
    ("scaled", ScaledRate(ConstantRate(rps=40.0), factor=1.7)),
    ("diurnal", DiurnalRate(Sinusoidal(avg=30.0, amplitude=10.0, period=4.0),
                            period=T_END, depth=0.6)),
    ("burst_square", BurstRate(ConstantRate(rps=25.0), at=4.0, duration=2.0,
                               amplify=6.0)),
    ("burst_ramped", BurstRate(OnOffRate(rps=40.0, on_duration=2.0,
                                         off_duration=1.0),
                               at=3.0, duration=4.0, amplify=5.0, ramp=0.8)),
    ("windowed", WindowedRate(ConstantRate(rps=35.0), start=2.0, end=9.0)),
    ("windowed_open", WindowedRate(ConstantRate(rps=35.0), start=4.0)),
    ("nested", DiurnalRate(BurstRate(ScaledRate(
        PoissonResampled(rps_range=(20.0, 50.0), resample_every=1.0, seed=9),
        factor=0.8), at=5.0, duration=3.0, amplify=4.0, ramp=0.5),
        period=T_END, depth=0.4)),
]


@pytest.mark.parametrize("name,proc", PROCS, ids=[n for n, _ in PROCS])
def test_rate_array_matches_scalar_rate_pointwise(name, proc):
    rng = np.random.default_rng(17)
    ts = np.sort(rng.uniform(0.0, T_END, 3000))
    # deliberately include envelope edges and bin boundaries
    edges = np.array([0.0, 2.0, 3.0, 4.0, 5.0, 8.0, 9.0, 4.0 + 1e-12,
                      T_END - 1e-9])
    ts = np.concatenate([ts, edges])
    vec = proc.rate_array(ts)
    scalar = np.array([proc.rate(float(t)) for t in ts])
    np.testing.assert_allclose(vec, scalar, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("name,proc", PROCS, ids=[n for n, _ in PROCS])
def test_max_rate_dominates_rate(name, proc):
    rng = np.random.default_rng(23)
    ts = rng.uniform(0.0, T_END, 2000)
    lam_max = proc.max_rate(T_END)
    assert float(np.max(proc.rate_array(ts))) <= lam_max + 1e-9


def test_thinning_matches_legacy_on_burst_shape():
    """Statistical pin of the vectorized thinning sampler against the legacy
    dt-loop on a traffic-scenario shape (same rule as
    test_determinism.py's pin on the original shapes)."""
    proc = BurstRate(ConstantRate(rps=60.0), at=10.0, duration=8.0,
                     amplify=5.0, ramp=1.5)
    t_end = 30.0
    n_legacy = len(proc.generate(t_end, random.Random(5)))
    n_numpy = len(proc.generate_np(t_end, np.random.default_rng(5)))
    assert n_legacy > 0 and n_numpy > 0
    assert abs(n_legacy - n_numpy) < 5 * math.sqrt(max(n_legacy, n_numpy))
    # arrivals respect the envelope: the burst window is denser than outside
    ts = proc.generate_np(t_end, np.random.default_rng(7))
    in_burst = np.sum((ts >= 10.0) & (ts < 18.0)) / 8.0
    outside = np.sum((ts < 10.0) | (ts >= 18.0)) / 22.0
    assert in_burst > 2.0 * outside


def test_windowed_rate_emits_nothing_outside_window():
    proc = WindowedRate(ConstantRate(rps=80.0), start=3.0, end=7.0)
    ts = proc.generate_np(12.0, np.random.default_rng(11))
    assert len(ts) > 0
    assert float(ts.min()) >= 3.0 and float(ts.max()) < 7.0


if __name__ == "__main__":
    import sys
    sys.exit(pytest.main([__file__, "-q"]))
