"""The execution-backend seam: registry errors, stub-under-every-stack,
scripted times surfacing in metrics, modeled-backend identity, the workload
registry, and full ExperimentResult reporting for real-execution runs
(the ServingStack.run() regression: the old private pump loop collected
only queuing delays — no per-class stats, no n_events, no warmup window)."""
import json
from dataclasses import replace

import pytest

from repro.core import (ClusterConfig, ExecutionBackend, StubBackend,
                        available_backends, available_stacks, get_backend,
                        register_backend)
from repro.core.backends import respec_dag
from repro.core.types import DagSpec, FunctionSpec
from repro.serving.engine import ServingApp, serving_workload
from repro.sim import (Experiment, ExperimentResult, available_workloads,
                       register_workload, run_sweep, simulate)

SMALL = ClusterConfig(n_sgs=2, workers_per_sgs=2, cores_per_worker=4,
                      pool_mem_mb=2048.0)


def _tiny_exp(**kw):
    base = dict(workload_factory="paper_workload_1",
                workload_kwargs=dict(duration=3.0, scale=0.02,
                                     dags_per_class=1),
                cluster=SMALL, warmup=1.0, drain=3.0)
    base.update(kw)
    return Experiment(**base)


def _serving_exp(**kw):
    apps = [ServingApp("chat", {"chat/gen": None}, slack=0.5),
            ServingApp("caption", {"vlm/embed": None, "vlm/decode": None},
                       edges=(("vlm/embed", "vlm/decode"),), slack=1.0)]
    base = dict(stack="archipelago", backend="stub",
                backend_kwargs=dict(exec_time=0.05, setup_time=0.4),
                workload_factory="serving_apps",
                workload_kwargs=dict(apps=apps, duration=6.0, rps=8.0,
                                     prewarm_per_fn=2),
                cluster=SMALL, warmup=1.0, drain=5.0)
    base.update(kw)
    return Experiment(**base)


# -- registry ----------------------------------------------------------------


def test_builtin_backends_registered():
    names = available_backends()
    for name in ("modeled", "stub", "jax"):
        assert name in names


def test_unknown_backend_error_lists_registered():
    with pytest.raises(ValueError) as ei:
        simulate(_tiny_exp(backend="no-such-backend"))
    msg = str(ei.value)
    for name in ("modeled", "stub", "jax"):
        assert name in msg


def test_duplicate_backend_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        register_backend("stub")(object)


def test_backend_instance_passes_through():
    backend = StubBackend(exec_time=0.03)
    res = simulate(_tiny_exp(backend=backend))
    assert res.backend == "stub"
    assert res.sim.backend is backend
    assert backend.n_executions > 0


def test_backend_kwargs_rejected_with_instance():
    with pytest.raises(ValueError, match="backend_kwargs"):
        simulate(_tiny_exp(backend=StubBackend(),
                           backend_kwargs=dict(exec_time=0.1)))


# -- the backend seam under every stack --------------------------------------


def test_stub_backend_runs_under_every_registered_stack():
    """The data plane is orthogonal to the control plane: any registered
    stack drives real-execution code paths through ``simulate`` and reports
    a full ExperimentResult."""
    seen = set()
    for name in available_stacks():
        res = simulate(_tiny_exp(stack=name, backend="stub"))
        assert res.backend == "stub"
        assert res.n_completed > 0
        assert res.n_events > 0
        assert res.per_class                      # per-class stats populated
        assert res.sim.backend.counters()["n_executions"] > 0
        seen.add(name)
    assert {"archipelago", "fifo", "baseline", "sparrow", "pull"} <= seen


def test_stub_without_scripts_is_decision_identical_to_modeled():
    for stack in ("archipelago", "fifo", "sparrow", "pull"):
        m = simulate(_tiny_exp(stack=stack)).to_dict()
        s = simulate(_tiny_exp(stack=stack, backend="stub")).to_dict()
        for d in (m, s):
            # wall_s varies per run; backend/name/backend_counters identify
            # the backend by design — everything else must match exactly
            d.pop("wall_s"), d.pop("backend"), d.pop("name")
            d.pop("backend_counters")
        assert m == s


def test_modeled_backend_is_default_and_explicit_form_identical():
    a = simulate(_tiny_exp()).to_dict()
    b = simulate(_tiny_exp(backend="modeled")).to_dict()
    a.pop("wall_s"), b.pop("wall_s")
    assert a == b
    assert b["backend"] == "modeled"


def test_scripted_times_surface_in_metrics():
    """Scripted setup/exec times must show up in cold-start latency and the
    percentiles — the seam feeds scheduling real numbers, not fn defaults."""
    dag = DagSpec("d", (FunctionSpec("d/f", 0.001),), (), deadline=1.0)
    from repro.sim import ConstantRate, WorkloadSpec
    spec = WorkloadSpec([(dag, ConstantRate(5.0))], duration=2.0)
    res = simulate(Experiment(
        workload=spec, cluster=SMALL, backend="stub",
        backend_kwargs=dict(exec_time=0.080, setup_time=0.500)))
    assert res.cold_start_count >= 1
    lats = res.sim.metrics.latencies()
    # the first (cold) request pays scripted setup + exec
    assert max(lats) >= 0.58
    # every request pays at least the scripted exec time
    assert min(lats) >= 0.08
    assert res.latency_percentiles["p50"] >= 0.08


def test_stub_per_fn_scripting():
    res = simulate(_serving_exp(backend_kwargs=dict(
        exec_time={"chat/gen": 0.2, "vlm/embed": 0.01, "vlm/decode": 0.01},
        setup_time=0.1)))
    chat = res.per_class["chat"]
    caption = res.per_class["caption"]
    assert chat.p50 >= 0.2
    assert caption.p50 < 0.2


def test_backend_is_a_sweep_axis():
    sweep = run_sweep(_tiny_exp(), {"backend": ["modeled", "stub"]})
    assert len(sweep) == 2
    assert [r["result"]["backend"] for r in sweep] == ["modeled", "stub"]
    keys = {frozenset(r["result"].keys()) for r in sweep}
    assert len(keys) == 1              # stable row schema across backends


def test_backend_kwargs_is_a_sweep_axis():
    sweep = run_sweep(_tiny_exp(backend="stub"),
                      {"backend_kwargs.exec_time": [0.05, 0.1]})
    p50s = [r["result"]["latency_percentiles"]["p50"] for r in sweep]
    assert p50s[0] >= 0.05
    assert p50s[1] >= 0.1
    assert p50s[1] > p50s[0]


def test_jax_backend_requires_served_models():
    with pytest.raises(ValueError, match="served"):
        simulate(_tiny_exp(backend="jax"))


# -- workload registry (register_workload) -----------------------------------


def test_workload_registry_lists_and_rejects_duplicates():
    assert "paper_workload_1" in available_workloads()
    with pytest.raises(ValueError, match="already registered"):
        register_workload("paper_workload_1")(lambda: None)


def test_serving_apps_factory_registered():
    assert "serving_apps" in available_workloads()


def test_unknown_workload_error_lists_known():
    with pytest.raises(ValueError) as ei:
        simulate(_tiny_exp(workload_factory="not_a_workload"))
    msg = str(ei.value)
    assert "paper_workload_1" in msg and "serving_apps" in msg


# -- serving workloads through the unified path ------------------------------


def test_serving_run_reports_full_experiment_result():
    """Regression for the old ServingStack.run(): the unified path must
    report per-class stats, event counts, queuing percentiles and the
    steady-state window for real-execution (stub) runs."""
    res = simulate(_serving_exp())
    assert res.n_completed == res.n_requests > 0
    assert res.n_events > 0
    assert set(res.per_class) == {"chat", "caption"}
    assert res.queuing_percentiles["p50"] is not None
    assert res.deadline_met_frac is not None
    assert res.n_requests <= res.n_requests_total       # warmup filtering
    assert res.n_requests == sum(
        1 for r in res.sim.metrics.requests if r.arrival_time >= 1.0)
    d = res.to_dict()
    back = ExperimentResult.from_dict(json.loads(json.dumps(d)))
    assert back.to_dict() == d
    assert back.backend == "stub"


def test_serving_deadlines_derive_from_critical_path():
    """The old engine built DagSpecs with the dead `deadline=0.0 or 1.0`
    expression and constructed every DAG twice; ``with_deadline`` derives
    the deadline from the DAG's (possibly re-specced) critical path once."""
    app = ServingApp("caption", {"vlm/embed": None, "vlm/decode": None},
                     edges=(("vlm/embed", "vlm/decode"),), slack=1.0)
    dag = app.dag()
    assert dag.deadline == pytest.approx(dag.critical_path_time() + 1.0)
    assert dag.deadline != 1.0          # the old dead expression's value
    # re-speccing with scripted times re-derives the deadline
    new = respec_dag(dag, {
        "vlm/embed": FunctionSpec("vlm/embed", 0.2),
        "vlm/decode": FunctionSpec("vlm/decode", 0.3)}, slack=1.0)
    assert new.critical_path_time() == pytest.approx(0.5)
    assert new.deadline == pytest.approx(1.5)


def test_with_deadline_validation():
    dag = DagSpec("d", (FunctionSpec("d/f", 0.1),), (), deadline=1.0)
    assert dag.with_deadline(2.0).deadline == 2.0
    assert dag.with_deadline(slack=0.5).deadline == pytest.approx(0.6)
    with pytest.raises(ValueError, match="exactly one"):
        dag.with_deadline()
    with pytest.raises(ValueError, match="exactly one"):
        dag.with_deadline(2.0, slack=0.5)


def test_prewarm_pre_pump_reduces_cold_starts():
    warm = simulate(_serving_exp())
    cold = simulate(_serving_exp(workload_kwargs=dict(
        apps=[ServingApp("chat", {"chat/gen": None}, slack=0.5),
              ServingApp("caption", {"vlm/embed": None, "vlm/decode": None},
                         edges=(("vlm/embed", "vlm/decode"),), slack=1.0)],
        duration=6.0, rps=8.0, prewarm_per_fn=0)))
    assert warm.sim.metrics.cold_start_count() \
        < cold.sim.metrics.cold_start_count()


def test_serving_workload_under_baseline_stack():
    """Reactive baselines ignore prewarm (no proactive allocation) but the
    serving workload still runs and reports through the same pipeline."""
    res = simulate(_serving_exp(stack="fifo"))
    assert res.n_completed == res.n_requests > 0
    assert res.sim.metrics.cold_start_count() > 0   # no prewarm possible


def test_serving_workload_rejects_duplicate_fn_names():
    apps = [ServingApp("a", {"f": None}), ServingApp("b", {"f": None})]
    with pytest.raises(ValueError, match="more than one app"):
        serving_workload(apps, duration=1.0)


def test_serving_workload_rejects_duplicate_dag_ids():
    apps = [ServingApp("a", {"f": None}), ServingApp("a", {"g": None})]
    with pytest.raises(ValueError, match="duplicate dag_id"):
        serving_workload(apps, duration=1.0)


def test_serving_workload_validates_rps_and_arrivals_keys():
    apps = [ServingApp("a", {"f": None}), ServingApp("b", {"g": None})]
    with pytest.raises(ValueError, match="unknown dag_id"):
        serving_workload(apps, duration=1.0, rps={"typo": 5.0, "a": 1.0,
                                                  "b": 1.0})
    with pytest.raises(ValueError, match="must cover every app"):
        serving_workload(apps, duration=1.0, rps={"a": 5.0})
    with pytest.raises(ValueError, match="unknown dag_id"):
        from repro.sim import ConstantRate
        serving_workload(apps, duration=1.0,
                         arrivals={"typo": ConstantRate(1.0)})
    # a partial rps mapping is fine when arrivals covers the rest
    from repro.sim import ConstantRate
    spec = serving_workload(apps, duration=1.0, rps={"a": 5.0},
                            arrivals={"b": ConstantRate(2.0)})
    assert len(spec.tenants) == 2
    # but the same dag_id in both is ambiguous
    with pytest.raises(ValueError, match="both"):
        serving_workload(apps, duration=1.0, rps={"a": 5.0, "b": 1.0},
                         arrivals={"b": ConstantRate(2.0)})


def test_stub_rejects_unknown_scripted_fn_names():
    with pytest.raises(ValueError, match="unknown function"):
        simulate(_serving_exp(backend_kwargs=dict(
            exec_time={"chat/gn": 0.2})))       # typo for chat/gen


def test_custom_backend_registration():
    @register_backend("test-doubling")
    class DoublingBackend(ExecutionBackend):
        """Every invocation takes twice its modeled time."""

        def build(self, exp, spec):
            self.execute = lambda inv: 2.0 * inv.fn.exec_time
            return spec

    fast = simulate(_tiny_exp())
    slow = simulate(_tiny_exp(backend="test-doubling"))
    assert slow.latency_percentiles["p50"] \
        > fast.latency_percentiles["p50"]
