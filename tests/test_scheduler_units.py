"""Unit tests for the paper's core mechanisms."""
import math

import pytest

from repro.core import (ConsistentHashRing, DagSpec, DemandEstimator,
                        FunctionSpec, Request, SandboxManager, SandboxState,
                        Worker, poisson_ppf)
from repro.core.types import Invocation


# ---------------------------------------------------------------------------
# DAG / slack (§4.2)
# ---------------------------------------------------------------------------


def _diamond(deadline=2.0):
    fns = tuple(FunctionSpec(n, t) for n, t in
                [("a", 0.1), ("b", 0.3), ("c", 0.2), ("d", 0.1)])
    edges = (("a", "b"), ("a", "c"), ("b", "d"), ("c", "d"))
    return DagSpec("diamond", fns, edges, deadline)


def test_critical_path():
    d = _diamond()
    assert d.critical_path_time() == pytest.approx(0.1 + 0.3 + 0.1)
    assert d.remaining_critical_path("c") == pytest.approx(0.2 + 0.1)
    assert d.remaining_critical_path("d") == pytest.approx(0.1)
    assert d.slack == pytest.approx(2.0 - 0.5)


def test_dag_cycle_rejected():
    fns = (FunctionSpec("a", 0.1), FunctionSpec("b", 0.1))
    with pytest.raises(ValueError):
        DagSpec("cyc", fns, (("a", "b"), ("b", "a")), 1.0)


def test_srsf_priority_ordering():
    """Least remaining slack first; ties by least remaining work (§4.2)."""
    d_tight = DagSpec("t", (FunctionSpec("t/f", 0.10),), (), deadline=0.15)
    d_loose = DagSpec("l", (FunctionSpec("l/f", 0.10),), (), deadline=0.90)
    rt = Request(dag=d_tight, arrival_time=0.0)
    rl = Request(dag=d_loose, arrival_time=0.0)
    it = Invocation(request=rt, fn=d_tight.fn("t/f"), ready_time=0.0)
    il = Invocation(request=rl, fn=d_loose.fn("l/f"), ready_time=0.0)
    assert it.priority_key() < il.priority_key()
    assert it.remaining_slack(0.0) == pytest.approx(0.05)
    assert il.remaining_slack(0.0) == pytest.approx(0.80)


# ---------------------------------------------------------------------------
# Poisson demand estimation (§4.3.1)
# ---------------------------------------------------------------------------


def test_poisson_ppf_basics():
    assert poisson_ppf(0.99, 0.0) == 0
    assert poisson_ppf(0.5, 1.0) == 1
    # known value: Poisson(10) 99th percentile = 18
    assert poisson_ppf(0.99, 10.0) == 18
    # large-lambda branch stays consistent with the exact walk
    for lam in (60.0, 123.4, 400.0):
        n = poisson_ppf(0.99, lam)
        from repro.core.estimator import _poisson_cdf
        assert _poisson_cdf(lam, n) >= 0.99
        assert _poisson_cdf(lam, n - 1) < 0.99


def test_demand_tracks_rate():
    est = DemandEstimator(sla=0.99, interval=0.1)
    # 50 rps for 2 seconds
    t = 0.0
    while t < 2.0:
        est.record_arrival("f", t)
        t += 0.02
    rate = est.rate("f", 2.0)
    assert 30 <= rate <= 60
    d = est.demand("f", exec_time=0.2, now=2.0)
    # Little's law: ~10 concurrent; 99th pct of Poisson(10) = 18
    assert 12 <= d <= 25


def test_estimator_decays_when_idle():
    est = DemandEstimator(sla=0.99, interval=0.1, alpha=0.5)
    for i in range(100):
        est.record_arrival("f", i * 0.01)
    busy = est.rate("f", 1.0)
    idle = est.rate("f", 5.0)
    assert idle < busy * 0.01


# ---------------------------------------------------------------------------
# Sandbox placement / eviction (§4.3.2, §4.3.3)
# ---------------------------------------------------------------------------


def _mgr(n_workers=4, mem=1024.0, placement="even"):
    ws = [Worker(worker_id=i, cores=4, pool_mem_mb=mem)
          for i in range(n_workers)]
    return SandboxManager(workers=ws, placement=placement), ws


def test_even_placement_balance():
    mgr, ws = _mgr()
    f = FunctionSpec("f", 0.1, mem_mb=128)
    mgr.set_demand(f, 10, now=0.0)
    counts = mgr.counts_per_worker("f")
    assert sum(counts) == 10
    assert max(counts) - min(counts) <= 1    # the even-placement invariant


def test_packed_placement_fills_one_worker_first():
    mgr, ws = _mgr(mem=16 * 128.0, placement="packed")
    f = FunctionSpec("f", 0.1, mem_mb=128)
    mgr.set_demand(f, 10, now=0.0)
    counts = mgr.counts_per_worker("f")
    assert max(counts) == 10 and sum(counts) == 10


def test_soft_eviction_from_max_worker_and_revival():
    mgr, ws = _mgr()
    f = FunctionSpec("f", 0.1, mem_mb=128)
    mgr.set_demand(f, 8, now=0.0)
    mgr.set_demand(f, 4, now=0.2)
    assert mgr.n_soft_evictions == 4
    counts = mgr.counts_per_worker("f")
    assert max(counts) - min(counts) <= 1    # still balanced after eviction
    # revival is free: demand rises again, no new allocations
    alloc_before = mgr.n_allocations
    mgr.set_demand(f, 8, now=0.4)
    assert mgr.n_allocations == alloc_before
    assert mgr.n_revivals == 4


def test_hard_eviction_protects_underprovisioned():
    mgr, ws = _mgr(n_workers=1, mem=4 * 128.0)
    f1 = FunctionSpec("f1", 0.1, mem_mb=128)
    f2 = FunctionSpec("f2", 0.1, mem_mb=128)
    mgr.set_demand(f1, 2, now=0.0)       # f1 at its estimate
    mgr.set_demand(f2, 6, now=0.0)       # f2 under-provisioned (pool full)
    # f2 got only the remaining 2 slots; f1 (at estimate) was evictable
    assert mgr.total_sandboxes("f2") >= 2
    # f1 must never be evicted below... f1's surplus is 0 => evictable;
    # but a function far BELOW estimate is protected:
    assert mgr.total_sandboxes("f1") + mgr.total_sandboxes("f2") <= 4


def test_busy_sandboxes_never_hard_evicted():
    mgr, ws = _mgr(n_workers=1, mem=2 * 128.0)
    f1 = FunctionSpec("f1", 0.1, mem_mb=128)
    mgr.set_demand(f1, 2, now=0.0)
    for s in ws[0].sandboxes:
        s.state = SandboxState.BUSY
    f2 = FunctionSpec("f2", 0.1, mem_mb=128)
    mgr.set_demand(f2, 2, now=0.0)
    assert mgr.total_sandboxes("f1") == 2   # untouched
    assert mgr.total_sandboxes("f2") == 0   # could not fit


# ---------------------------------------------------------------------------
# Consistent hashing (§5.2.2)
# ---------------------------------------------------------------------------


def test_ring_deterministic_and_covers():
    ring = ConsistentHashRing(list(range(8)))
    assert ring.lookup("dag-1") == ring.lookup("dag-1")
    owners = {ring.lookup(f"dag-{i}") for i in range(200)}
    assert len(owners) >= 6      # spread across most SGSs


def test_ring_successors_rotation():
    ring = ConsistentHashRing(list(range(4)))
    succ = ring.successors("dag-x")
    assert sorted(succ) == [0, 1, 2, 3]
    assert succ[0] == ring.lookup("dag-x")
