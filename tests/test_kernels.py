"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import (decode_attention_ref, flash_attention_ref,
                               ssd_scan_ref, ssd_scan_sequential_ref)
from repro.kernels.ssd_scan import ssd_scan

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=3e-5, atol=3e-5)


FA_CASES = [
    # B, Sq, Sk, Hq, Hkv, hd, causal, window
    (2, 128, 128, 4, 2, 64, True, 0),
    (1, 256, 256, 4, 1, 128, True, 0),
    (2, 64, 192, 4, 4, 64, True, 0),       # q aligned to kv suffix
    (1, 256, 256, 8, 2, 64, True, 64),     # sliding window
    (1, 96, 96, 2, 2, 32, False, 0),       # ragged, bidirectional
    (2, 100, 228, 6, 3, 64, True, 100),    # ragged + window + GQA
    # edge shapes (PR 9): decode-suffix q, window wider than the cache,
    # single-token windowed decode, sequences smaller than one block
    (2, 1, 128, 4, 2, 64, True, 0),        # Sq=1: flash as decode suffix
    (1, 64, 64, 4, 2, 64, True, 128),      # window > Sk: full-causal limit
    (2, 1, 96, 6, 3, 64, True, 32),        # Sq=1 + window + GQA + ragged Sk
    (1, 17, 17, 2, 1, 32, True, 8),        # S < block, ragged + window
]


@pytest.mark.parametrize("case", FA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_oracle(case, dtype):
    B, Sq, Sk, Hq, Hkv, hd, causal, window = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, hd), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


DEC_CASES = [
    (2, 512, 8, 2, 64, 128),
    (1, 1000, 4, 4, 128, 256),
    (3, 256, 4, 1, 32, 64),
    (2, 300, 6, 3, 64, 128),
    # edge shapes (PR 9): cache smaller than one block, MHA limit
    (2, 33, 4, 2, 64, 128),                # L < block_k, ragged
    (1, 64, 1, 1, 32, 64),                 # single-head MHA
]


@pytest.mark.parametrize("fill", ["one", "full"])
def test_decode_attention_valid_len_extremes(fill):
    """valid_len at both ends of the legal range: 1 (only the first cache
    slot attends) and L (the whole cache attends)."""
    B, L, Hq, Hkv, hd = 2, 128, 4, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, hd))
    k = jax.random.normal(ks[1], (B, L, Hkv, hd))
    v = jax.random.normal(ks[2], (B, L, Hkv, hd))
    vlen = jnp.full((B,), 1 if fill == "one" else L, jnp.int32)
    out = decode_attention(q, k, v, vlen, block_k=64, interpret=True)
    ref = decode_attention_ref(q, k, v, vlen)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("case", DEC_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_vs_oracle(case, dtype):
    B, L, Hq, Hkv, hd, bk = case
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, Hq, hd), dtype)
    k = jax.random.normal(ks[1], (B, L, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, L, Hkv, hd), dtype)
    vlen = jax.random.randint(ks[3], (B,), 1, L + 1)
    out = decode_attention(q, k, v, vlen, block_k=bk, interpret=True)
    ref = decode_attention_ref(q, k, v, vlen)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


SSD_CASES = [
    (2, 128, 4, 64, 32, 64),
    (1, 64, 2, 32, 16, 16),
    (2, 256, 3, 64, 64, 64),
    (1, 192, 2, 32, 128, 64),
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_vs_oracles(case, dtype):
    B, S, H, P, N, chunk = case
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N), dtype)
    Cm = jax.random.normal(ks[4], (B, S, N), dtype)
    y, st = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, sr = ssd_scan_ref(x, dt, A, Bm, Cm, chunk)
    # bf16 inputs: long accumulation chains differ in summation order
    tol = dict(rtol=6e-2, atol=6e-2) if dtype == jnp.bfloat16 else _tol(dtype)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-4)
    if dtype == jnp.float32:
        # the chunked math itself vs an independent sequential recurrence
        ys, ss = ssd_scan_sequential_ref(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ys),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st), np.asarray(ss),
                                   rtol=1e-4, atol=1e-4)


def test_ssd_scan_init_state_parity():
    """The kernel's seeded inter-chunk carry (decode-time prefill over an
    existing cache) must match both oracles given the same init_state."""
    B, S, H, P, N, chunk = 2, 128, 2, 32, 16, 64
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    s0 = jax.random.normal(ks[5], (B, H, P, N))
    y, st = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, init_state=s0,
                     interpret=True)
    yr, sr = ssd_scan_ref(x, dt, A, Bm, Cm, chunk, init_state=s0)
    ys, ss = ssd_scan_sequential_ref(x, dt, A, Bm, Cm, init_state=s0)
    for got, ref in ((y, yr), (y, ys), (st, sr), (st, ss)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


def test_ops_dispatch_backends():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 64))
    k = jax.random.normal(ks[1], (1, 64, 2, 64))
    v = jax.random.normal(ks[2], (1, 64, 2, 64))
    a = ops.attention(q, k, v, backend="xla")
    b = ops.attention(q, k, v, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=3e-5, atol=3e-5)


def test_ops_ssd_pads_ragged_seq():
    ks = jax.random.split(KEY, 5)
    B, S, H, P, N = 1, 100, 2, 32, 16     # S not a chunk multiple
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y1, s1 = ops.ssd(x, dt, A, Bm, Cm, chunk=64, backend="pallas_interpret")
    y2, s2 = ops.ssd(x, dt, A, Bm, Cm, chunk=64, backend="xla")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)
