"""Per-architecture smoke tests: reduced variants (2 layers, d_model<=512,
<=4 experts) run one forward + one train step on CPU; output shapes and
no-NaN asserted.  Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (decode_step, forward, init_cache, init_params,
                          prefill)
from repro.train import adamw_init
from repro.train.steps import make_train_step

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=32):
    S = max(S, cfg.ssm_chunk or 0)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    frontend = None
    if cfg.frontend:
        frontend = jax.random.normal(
            KEY, (B, cfg.n_frontend_tokens, cfg.d_model), cfg.dtype())
    return tokens, frontend


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_limits(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.n_layers <= 4
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch, reduced=True)
    params = init_params(cfg, KEY)
    tokens, frontend = _inputs(cfg)
    logits, aux = forward(cfg, params, tokens, frontend)
    B, S = tokens.shape
    extra = (cfg.n_frontend_tokens
             if cfg.frontend and cfg.arch_type != "encdec" else 0)
    assert logits.shape == (B, S + extra, cfg.vocab_padded)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert not bool(jnp.isnan(aux).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_config(arch, reduced=True)
    params = init_params(cfg, KEY)
    opt = adamw_init(params)
    step = make_train_step(cfg, total_steps=10)
    tokens, frontend = _inputs(cfg)
    params2, opt2, loss = step(params, opt, tokens, frontend)
    assert not bool(jnp.isnan(loss).any()), f"{arch}: NaN loss"
    assert float(loss) > 0.0
    assert int(opt2.step) == 1
    # parameters actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_shapes(arch):
    cfg = get_config(arch, reduced=True)
    params = init_params(cfg, KEY)
    tokens, frontend = _inputs(cfg)
    B, S = tokens.shape
    cache = init_cache(cfg, B, S + 8)
    logits, cache = prefill(cfg, params, tokens, cache, frontend)
    assert logits.shape == (B, 1, cfg.vocab_padded)
    lg2, cache = decode_step(cfg, params, cache, tokens[:, :1],
                             jnp.int32(S))
    assert lg2.shape == (B, 1, cfg.vocab_padded)
    assert not bool(jnp.isnan(lg2.astype(jnp.float32)).any())
