"""Declarative chaos plans (docs/FAULTS.md): the @register_fault registry,
seeded occurrence expansion, the sweepable ``faults=`` axis, zero-fault
pay-for-what-you-use, and Metrics.window recovery views."""
import json

import pytest

from repro.core import ClusterConfig
from repro.core.fault import (FaultEvent, FaultInjector, FaultPlan,
                              available_faults, az_outage, cascading_crash,
                              control_plane_delay, flaky_network, get_fault,
                              mass_eviction, memory_pressure, rack_power,
                              register_fault, sgs_failstop, slow_worker,
                              worker_crash)
from repro.sim import Experiment, run_sweep, simulate

SMALL = ClusterConfig(n_sgs=2, workers_per_sgs=3, cores_per_worker=4,
                      pool_mem_mb=2048.0)


def _exp(**kw):
    base = dict(workload_factory="paper_workload_1",
                workload_kwargs=dict(duration=4.0, scale=0.03,
                                     dags_per_class=1),
                cluster=SMALL, drain=3.0)
    base.update(kw)
    return Experiment(**base)


def _crash_plan(**kw):
    kw.setdefault("at", 1.5)
    return FaultPlan(events=(worker_crash(k=1, **kw),), seed=3)


# -- registry ----------------------------------------------------------------


def test_builtin_faults_registered():
    assert {"worker_crash", "sgs_failstop", "mass_eviction",
            "control_plane_delay", "rack_power", "az_outage",
            "cascading_crash", "slow_worker", "flaky_network",
            "memory_pressure"} <= set(available_faults())


def test_unknown_fault_error_lists_registered():
    with pytest.raises(ValueError, match="worker_crash"):
        get_fault("nope")


def test_duplicate_fault_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        register_fault("worker_crash")(lambda ctx: None)


def test_custom_fault_runs_through_plan():
    fired = []

    @register_fault("test_noop_fault")
    def _noop(ctx, tag="x"):
        fired.append((ctx.env.now(), tag))
        ctx.record("test_noop_fault", tag=tag)

    try:
        plan = FaultPlan(events=(FaultEvent("test_noop_fault", at=1.0,
                                            kwargs=(("tag", "y"),)),))
        res = simulate(_exp(faults=plan))
        assert fired == [(1.0, "y")]
        assert res.fault_events == [{"kind": "test_noop_fault", "t": 1.0,
                                     "tag": "y"}]
    finally:
        from repro.core import fault as fault_mod
        del fault_mod._FAULTS["test_noop_fault"]


# -- event constructors / plan serialization ---------------------------------


def test_worker_crash_needs_exactly_one_schedule():
    with pytest.raises(ValueError, match="at= / rate="):
        worker_crash(k=1)
    with pytest.raises(ValueError, match="at= / rate="):
        worker_crash(k=1, at=1.0, rate=2.0)


def test_gray_fault_constructors_validate():
    with pytest.raises(ValueError, match="at= / rate="):
        cascading_crash()
    with pytest.raises(ValueError, match="at= / rate="):
        cascading_crash(at=1.0, rate=0.5)
    with pytest.raises(ValueError, match=r"p=1.5 must be in \[0, 1\]"):
        cascading_crash(at=1.0, p=1.5)
    with pytest.raises(ValueError, match="at= / rate="):
        slow_worker()
    with pytest.raises(ValueError, match="factor=0.0 must be > 0"):
        slow_worker(at=1.0, factor=0.0)
    with pytest.raises(ValueError, match="at= / rate="):
        flaky_network()
    with pytest.raises(ValueError, match="jitter=0.0 must be > 0"):
        flaky_network(at=1.0, jitter=0.0)
    with pytest.raises(ValueError, match=r"frac=0.0 must be in \(0, 1\]"):
        memory_pressure(at=1.0, frac=0.0)
    with pytest.raises(ValueError, match="duration=0.0 must be > 0"):
        memory_pressure(at=1.0, duration=0.0)


def test_gray_fault_plan_json_round_trip():
    plan = FaultPlan(events=(rack_power(at=1.0, rack=2, spare_racks=1),
                             az_outage(at=2.0),
                             cascading_crash(rate=0.5, p=0.7, k0=2,
                                             max_kills=6),
                             slow_worker(at=3.0, k=2, factor=8.0,
                                         duration=1.5),
                             flaky_network(rate=2.0, jitter=0.01),
                             memory_pressure(at=4.0, frac=0.25)),
                     seed=13, name="gray")
    back = FaultPlan.from_dict(json.loads(json.dumps(plan.to_dict())))
    assert back == plan and back.label() == "gray"


def test_fault_plan_json_round_trip():
    plan = FaultPlan(events=(worker_crash(k=2, rate=0.5, start=1.0, end=9.0),
                             sgs_failstop(at=3.0, sgs=1),
                             mass_eviction(at=4.0, frac=0.25),
                             control_plane_delay(at=5.0, stall=0.1,
                                                 target="lbs")),
                     seed=11, name="storm", checkpoint_interval=0.5)
    back = FaultPlan.from_dict(json.loads(json.dumps(plan.to_dict())))
    assert back == plan
    assert back.label() == "storm"
    assert FaultPlan(events=(sgs_failstop(at=1.0),)).label() == "sgs_failstop"


def test_occurrence_expansion_is_seeded_and_bounded():
    ev = worker_crash(k=1, rate=2.0, start=1.0, end=6.0)
    a = FaultInjector(FaultPlan(seed=5)).occurrences(ev, horizon=10.0)
    b = FaultInjector(FaultPlan(seed=5)).occurrences(ev, horizon=10.0)
    c = FaultInjector(FaultPlan(seed=6)).occurrences(ev, horizon=10.0)
    assert a == b                       # same seed, same Poisson draws
    assert a != c
    assert all(1.0 < t < 6.0 for t in a)
    # one-shot events fire verbatim; rate events clamp to the horizon
    assert FaultInjector(FaultPlan()).occurrences(
        worker_crash(at=2.5), horizon=10.0) == [2.5]
    late = worker_crash(k=1, rate=2.0, start=1.0)
    assert all(t < 3.0 for t in
               FaultInjector(FaultPlan(seed=5)).occurrences(late, 3.0))


# -- pay-for-what-you-use ----------------------------------------------------


def test_empty_plan_is_decision_identical_to_no_plan():
    r_none = simulate(_exp())
    r_empty = simulate(_exp(faults=FaultPlan()))
    a = r_none.detach_sim().to_dict()
    b = r_empty.detach_sim().to_dict()
    a.pop("wall_s"), b.pop("wall_s")
    assert a == b
    assert b["fault_events"] == [] and b["n_retries"] == 0
    assert b["recovery"] == {}


# -- the sweepable axis ------------------------------------------------------


def test_faults_is_a_sweep_axis_with_serializable_cells():
    sweep = run_sweep(_exp(), {"faults": [None, _crash_plan()],
                               "seed": [0, 1]})
    assert len(sweep) == 4
    # chaos cells report events, zero-fault cells report none
    for row in sweep:
        has_plan = row["cell"]["faults"] is not None
        assert bool(row["result"]["fault_events"]) == has_plan
    # FaultPlan cell values serialize through their own to_dict
    d = json.loads(json.dumps(sweep.to_dict()))
    assert d["rows"][2]["cell"]["faults"]["events"][0]["kind"] == \
        "worker_crash"


def test_chaos_sweep_rows_byte_identical_across_workers():
    """Identical seeds + identical FaultPlan give byte-identical rows
    whether cells run sequentially or in a spawn pool (satellite: chaos
    determinism under run_sweep(workers=N))."""
    axes = {"faults": [_crash_plan(), FaultPlan(
        events=(worker_crash(k=1, rate=1.0),), seed=9)],
        "seed": [0, 1]}
    seq = run_sweep(_exp(), axes, workers=1)
    par = run_sweep(_exp(), axes, workers=2)

    def strip(rows):
        out = []
        for r in rows:
            d = json.loads(json.dumps({"cell": {k: getattr(v, "to_dict",
                                                           lambda: v)()
                                                for k, v in r["cell"].items()},
                                       "result": dict(r["result"])}))
            d["result"].pop("wall_s")
            out.append(d)
        return out

    assert json.dumps(strip(seq.rows)) == json.dumps(strip(par.rows))


# -- built-in fault shapes through simulate ----------------------------------


def test_worker_crash_rate_all_requests_accounted_for():
    """Nonzero fault rate on every stack: no hangs, every arrival either
    completes (retries re-drive lost executions) — completed == arrivals."""
    plan = FaultPlan(events=(worker_crash(k=1, rate=1.0),), seed=2)
    for stack in ("archipelago", "fifo", "sparrow"):
        res = simulate(_exp(stack=stack, faults=plan, drain=6.0))
        assert res.fault_events, stack
        m = res.sim.metrics
        assert m.n_completed == m.n_requests, stack


def test_worker_crash_never_kills_last_worker():
    tiny = ClusterConfig(n_sgs=1, workers_per_sgs=2, cores_per_worker=4,
                         pool_mem_mb=2048.0)
    plan = FaultPlan(events=(worker_crash(k=8, at=1.0),), seed=0)
    res = simulate(_exp(cluster=tiny, faults=plan))
    killed = res.fault_events[0]["killed"]
    assert len(killed) == 1             # spare=1 leaves one worker alive
    (sgs,) = res.sim.lbs.sgss.values()
    assert len(sgs.workers) == 1
    # the survivor keeps completing on half capacity (no hang, no crash)
    assert res.sim.metrics.n_completed > 0


def test_mass_eviction_triggers_cold_boot_storm():
    no_fault = simulate(_exp())
    storm = simulate(_exp(faults=FaultPlan(
        events=(mass_eviction(at=2.0, frac=1.0),))))
    ev = storm.fault_events[0]
    assert ev["kind"] == "mass_eviction" and ev["n_evicted"] > 0
    # re-building the evicted pool costs extra setups
    assert storm.cold_start_count >= no_fault.cold_start_count
    assert storm.sim.metrics.n_completed == storm.sim.metrics.n_requests


def test_control_plane_delay_stalls_decisions():
    base = dict(lb_cost=2e-4, sgs_cost=2e-4)
    calm = simulate(_exp(**base))
    spiky = simulate(_exp(faults=FaultPlan(
        events=(control_plane_delay(at=1.0, stall=0.5),)), **base))
    assert spiky.fault_events[0]["n_clocks"] > 0
    assert spiky.queuing_percentiles["p99"] >= calm.queuing_percentiles["p99"]
    assert spiky.sim.metrics.n_completed == spiky.sim.metrics.n_requests


def test_sgs_failstop_skips_flat_stacks():
    res = simulate(_exp(stack="fifo", faults=FaultPlan(
        events=(sgs_failstop(at=1.0),))))
    assert res.fault_events[0].get("skipped") is True
    assert res.n_retries == 0


# -- correlated fault shapes (worker → rack → AZ topology) -------------------

# 4 racks (one per SGS pool, §4.1) grouped into 2 AZs of 2 racks each
TOPO = ClusterConfig(n_sgs=4, workers_per_sgs=3, cores_per_worker=4,
                     pool_mem_mb=2048.0, racks_per_az=2)


def _live_worker_ids(res):
    return {w.worker_id for s in res.sim.lbs.sgss.values()
            for w in s.workers}


def test_cluster_config_topology_arithmetic():
    assert (TOPO.n_workers, TOPO.n_racks, TOPO.n_azs) == (12, 4, 2)
    assert [TOPO.rack_of(w) for w in (0, 3, 6, 9)] == [0, 1, 2, 3]
    assert [TOPO.az_of(w) for w in (0, 3, 6, 9)] == [0, 0, 1, 1]
    assert list(TOPO.rack_workers(2)) == [6, 7, 8]
    assert list(TOPO.az_racks(1)) == [2, 3]


def test_rack_power_kills_one_whole_pool_and_evacuates():
    plan = FaultPlan(events=(rack_power(at=1.5),), seed=1)
    res = simulate(_exp(cluster=TOPO, faults=plan, drain=6.0))
    ev = res.fault_events[0]
    assert ev["kind"] == "rack_power" and ev["n_killed"] == 3
    # one entire rack (== one SGS pool) is gone; 3 racks survive
    assert len(_live_worker_ids(res)) == 9
    assert res.n_retries == ev["n_retry"]
    m = res.sim.metrics
    assert m.n_completed == m.n_requests
    assert res.accounting["lost"] == 0


def test_az_outage_kills_racks_per_az_racks_together():
    plan = FaultPlan(events=(az_outage(at=1.5),), seed=1)
    res = simulate(_exp(cluster=TOPO, faults=plan, drain=6.0))
    ev = res.fault_events[0]
    assert ev["kind"] == "az_outage"
    assert len(ev["racks"]) == TOPO.racks_per_az and ev["n_killed"] == 6
    # the zone's racks are correlated: both die at the same instant
    assert len(_live_worker_ids(res)) == 6
    m = res.sim.metrics
    assert m.n_completed == m.n_requests
    assert res.accounting["lost"] == 0


def test_rack_power_spares_the_last_rack():
    lone = ClusterConfig(n_sgs=1, workers_per_sgs=3, cores_per_worker=4,
                         pool_mem_mb=2048.0)
    res = simulate(_exp(cluster=lone, faults=FaultPlan(
        events=(rack_power(at=1.0),))))
    assert res.fault_events[0].get("skipped") is True
    assert res.sim.metrics.n_completed == res.sim.metrics.n_requests


def test_cascading_crash_branching_is_seeded_and_bounded():
    # p=0: the cascade never propagates — exactly k0 seed crashes
    none = simulate(_exp(faults=FaultPlan(
        events=(cascading_crash(at=1.0, p=0.0, k0=2),), seed=5), drain=6.0))
    assert len(none.fault_events[0]["killed"]) == 2
    # p=1: every crash propagates — bounded by max_kills
    full = simulate(_exp(faults=FaultPlan(
        events=(cascading_crash(at=1.0, p=1.0, k0=1, max_kills=3),),
        seed=5), drain=6.0))
    assert len(full.fault_events[0]["killed"]) == 3
    # identical plan + seed replays the identical cascade (victims included)
    again = simulate(_exp(faults=FaultPlan(
        events=(cascading_crash(at=1.0, p=1.0, k0=1, max_kills=3),),
        seed=5), drain=6.0))
    assert again.fault_events == full.fault_events
    for res in (none, full):
        assert res.sim.metrics.n_completed == res.sim.metrics.n_requests


# -- degraded-mode (gray failure) shapes -------------------------------------


def test_slow_worker_degrades_tail_without_killing_anything():
    calm = simulate(_exp(drain=20.0))
    slow = simulate(_exp(faults=FaultPlan(
        events=(slow_worker(at=0.5, k=2, factor=4.0),), seed=2),
        drain=20.0))
    ev = slow.fault_events[0]
    assert ev["kind"] == "slow_worker" and len(ev["slowed"]) == 2
    # gray: no worker dies, no retries fire — the work just runs slower
    assert slow.n_retries == 0
    assert len(_live_worker_ids(slow)) == SMALL.n_workers
    assert slow.sim.metrics.sorted_latencies()[-1] \
        > calm.sim.metrics.sorted_latencies()[-1]
    assert slow.sim.metrics.n_completed == slow.sim.metrics.n_requests


def test_slow_worker_duration_restores_full_speed():
    res = simulate(_exp(faults=FaultPlan(
        events=(slow_worker(at=1.0, k=2, factor=8.0, duration=0.5),),
        seed=2), drain=20.0))
    assert len(res.fault_events[0]["slowed"]) == 2
    for s in res.sim.lbs.sgss.values():
        assert s._slow == {}
    assert res.sim.metrics.n_completed == res.sim.metrics.n_requests


def test_flaky_network_jitters_control_plane_clocks():
    res = simulate(_exp(faults=FaultPlan(
        events=(flaky_network(rate=3.0, jitter=0.05, start=0.5),), seed=4),
        drain=6.0))
    assert res.fault_events
    for ev in res.fault_events:
        assert ev["kind"] == "flaky_network" and ev["n_clocks"] > 0
        assert 0.0 <= ev["total_stall"] < 0.05 * ev["n_clocks"]
    assert res.sim.metrics.n_completed == res.sim.metrics.n_requests


def test_memory_pressure_evicts_then_restores_pool_capacity():
    res = simulate(_exp(faults=FaultPlan(
        events=(memory_pressure(at=2.5, frac=1.0, duration=1.0),), seed=0),
        drain=6.0))
    ev = res.fault_events[0]
    assert ev["kind"] == "memory_pressure" and ev["n_workers"] > 0
    assert ev["n_evicted"] > 0          # a real eviction storm fired
    # capacity restored after `duration`; demand targets rebuilt the pool
    for s in res.sim.lbs.sgss.values():
        for w in s.workers:
            assert w.pool_mem_mb == pytest.approx(SMALL.pool_mem_mb)
            assert w.used_pool_mem <= w.pool_mem_mb + 1e-9
    assert res.sim.metrics.n_completed == res.sim.metrics.n_requests


def test_gray_plans_keep_every_request_accounted_under_every_stack():
    """No-hypothesis twin of tests/test_properties.py::
    test_fault_plan_accounting_invariant: a fixed matrix of correlated and
    gray plans never loses or double-completes a request on any stack."""
    from repro.core import available_stacks
    plans = [
        FaultPlan(events=(rack_power(at=1.0),), seed=0, name="rack"),
        FaultPlan(events=(cascading_crash(at=1.0, p=0.8, k0=2),), seed=1,
                  name="cascade"),
        FaultPlan(events=(slow_worker(at=0.5, k=2, factor=4.0),
                          flaky_network(rate=2.0, jitter=0.02)), seed=2,
                  name="gray"),
        FaultPlan(events=(memory_pressure(at=1.5, frac=0.5),
                          worker_crash(k=1, rate=0.5)), seed=3,
                  name="pressure"),
    ]
    for stack in available_stacks():
        for plan in plans:
            res = simulate(_exp(stack=stack, faults=plan, drain=20.0))
            acc = res.accounting
            assert acc["lost"] == 0, (stack, plan.name)
            assert acc["duplicate_completions"] == 0, (stack, plan.name)
            assert acc["completed"] + acc["pending"] == acc["arrivals"], \
                (stack, plan.name)


# -- sharded-core interlock ---------------------------------------------------


def test_shards_reject_fault_plans_and_hedging_with_clear_errors():
    """docs/PERF.md: fault plans and hedged retries are sequential-only;
    the shard validator must say so rather than silently diverge."""
    with pytest.raises(ValueError, match="does not support fault plans yet"):
        simulate(_exp(faults=_crash_plan(), shards=2))
    with pytest.raises(ValueError,
                       match="does not support hedged retries"):
        simulate(_exp(params={"hedge_timeout": 1.5}, shards=2))


# -- Metrics.window ----------------------------------------------------------


def test_metrics_window_partitions_flat_trace():
    res = simulate(_exp())
    m = res.sim.metrics
    full = m.window(0.0, float("inf"))
    assert (full.n_requests, full.n_completed) == \
        (m.n_requests, m.n_completed)
    assert full.deadline_met_frac() == m.deadline_met_frac()
    edges = [0.0, 1.0, 2.5, 4.0, float("inf")]
    parts = [m.window(a, b) for a, b in zip(edges, edges[1:])]
    assert sum(p.n_requests for p in parts) == m.n_requests
    assert sum(p.n_completed for p in parts) == m.n_completed
    arr = m._cols.arrival
    for (a, b), p in zip(zip(edges, edges[1:]), parts):
        assert p.n_requests == int(((arr >= a) & (arr < b)).sum())
        assert all(a <= t < b for t in p.queuing_delay_times)


def test_metrics_window_legacy_object_mode():
    from repro.core.types import Request
    from repro.sim import Metrics
    from repro.sim.metrics import percentile  # noqa: F401  (import check)
    dag = None
    from repro.core.types import DagSpec, FunctionSpec
    dag = DagSpec("d", (FunctionSpec("d/f", 0.1),), (), deadline=1.0)

    def req(arrival, completion):
        r = Request(dag=dag, arrival_time=arrival)
        r.completion_time = completion
        return r

    m = Metrics(requests=[req(0.5, 0.7), req(1.5, 1.9), req(3.0, None)],
                queuing_delays=[0.1, 0.2, 0.3],
                queuing_delay_times=[0.6, 1.6, 3.1])
    w = m.window(1.0, 3.0)
    assert [r.arrival_time for r in w.requests] == [1.5]
    assert w.queuing_delays == [0.2]
    assert w.n_completed == 1


def test_metrics_window_composes_with_warmup():
    res = simulate(_exp(warmup=1.0))
    m = res.sim.metrics
    a = m.after_warmup(1.0).window(0.0, 3.0)
    b = m.window(1.0, 3.0)
    assert a.n_requests == b.n_requests
    assert a.n_completed == b.n_completed
