"""The declarative experiment API: registry errors, JSON round-trips,
shim/simulate decision identity, sweeps, and registry-only extensibility."""
import json
from dataclasses import replace

import pytest

from repro.core import ClusterConfig, available_stacks, register_stack
from repro.core.stacks import FlatWorkerStack, PullScheduler
from repro.sim import (ConstantRate, Experiment, ExperimentResult,
                       WorkloadSpec, run_sweep, simulate)
from repro.sim.runner import run_baseline, run_sparrow
from repro.sim.workload import paper_workload_1

SMALL = ClusterConfig(n_sgs=2, workers_per_sgs=2, cores_per_worker=4,
                      pool_mem_mb=2048.0)


def _tiny_exp(**kw):
    base = dict(workload_factory="paper_workload_1",
                workload_kwargs=dict(duration=3.0, scale=0.02,
                                     dags_per_class=1),
                cluster=SMALL, warmup=1.0, drain=3.0)
    base.update(kw)
    return Experiment(**base)


def _timeline(sim):
    return [(r.arrival_time, r.completion_time, r.n_cold_starts)
            for r in sim.metrics.requests]


# -- registry ----------------------------------------------------------------


def test_unknown_stack_error_lists_registered():
    with pytest.raises(ValueError) as ei:
        simulate(_tiny_exp(stack="no-such-stack"))
    msg = str(ei.value)
    for name in ("archipelago", "fifo", "sparrow", "pull"):
        assert name in msg


def test_builtin_stacks_registered():
    names = available_stacks()
    for name in ("archipelago", "baseline", "fifo", "sparrow", "pull"):
        assert name in names


def test_register_custom_stack_runs_through_generic_loop():
    """A scheduler added purely via @register_stack needs no driver edits."""

    @register_stack("test-greedy")
    class GreedyStack(FlatWorkerStack):
        def make_scheduler(self, workers, env, exp):
            return PullScheduler(workers, env, scan_limit=4)

    res = simulate(_tiny_exp(stack="test-greedy"))
    assert res.stack == "test-greedy"
    assert res.n_completed > 0


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        register_stack("fifo")(object)


# -- pull stack (the new registry-only scheduler) ----------------------------


def test_pull_stack_completes_and_reuses_sandboxes():
    res = simulate(_tiny_exp(stack="pull"))
    assert res.n_completed == res.n_requests
    assert res.warm_hits > 0


def test_pull_stack_warm_affinity_beats_fifo_on_cold_starts():
    """Many DAG types on few cores: warm-affinity pulls should not reuse
    fewer sandboxes than strict-FIFO worker choice."""
    base = _tiny_exp(workload_kwargs=dict(duration=6.0, scale=0.05,
                                          dags_per_class=2))
    fifo = simulate(replace(base, stack="fifo"))
    pull = simulate(replace(base, stack="pull"))
    assert pull.n_completed > 0
    assert pull.cold_start_count <= fifo.cold_start_count * 1.5 + 5


# -- shims stay decision-identical to the generic loop -----------------------


def test_run_baseline_shim_matches_simulate():
    spec = paper_workload_1(duration=3.0, scale=0.02, dags_per_class=1)
    old = run_baseline(spec, cluster=SMALL, seed=2)
    new = simulate(Experiment(stack="fifo", workload=spec, cluster=SMALL,
                              seed=2)).sim
    assert _timeline(old) == _timeline(new)


def test_run_sparrow_shim_matches_simulate():
    spec = paper_workload_1(duration=3.0, scale=0.02, dags_per_class=1)
    old = run_sparrow(spec, cluster=SMALL, seed=2, probes=2)
    new = simulate(Experiment(stack="sparrow", workload=spec, cluster=SMALL,
                              seed=2, params={"probes": 2})).sim
    assert _timeline(old) == _timeline(new)


# -- results -----------------------------------------------------------------


def test_result_json_round_trip_is_lossless():
    res = simulate(_tiny_exp())
    d = res.to_dict()
    assert "sim" not in d
    back = ExperimentResult.from_dict(json.loads(json.dumps(d)))
    assert back.to_dict() == d
    assert back.sim is None
    # dataclass equality ignores sim (compare=False)
    assert back == res


def test_chaos_result_fields_round_trip_losslessly():
    """fault_events / n_retries / recovery survive the JSON round-trip and
    default to empty on fault-free runs."""
    from repro.core.fault import FaultPlan, worker_crash

    plain = simulate(_tiny_exp())
    assert plain.fault_events == [] and plain.n_retries == 0
    assert plain.recovery == {}

    res = simulate(_tiny_exp(faults=FaultPlan(
        events=(worker_crash(k=1, at=1.0),), seed=2)))
    assert res.fault_events and res.fault_events[0]["kind"] == "worker_crash"
    assert isinstance(res.n_retries, int)
    assert res.recovery["events"][0]["kind"] == "worker_crash"
    d = res.detach_sim().to_dict()
    back = ExperimentResult.from_dict(json.loads(json.dumps(d)))
    assert back.to_dict() == d
    assert back.fault_events == res.fault_events
    assert back.recovery == res.recovery


def test_result_handles_zero_completions():
    dag_spec = WorkloadSpec([], duration=1.0)
    res = simulate(Experiment(workload=dag_spec, cluster=SMALL))
    assert res.n_requests == 0
    assert res.deadline_met_frac is None
    assert res.latency_percentiles["p99"] is None
    d = res.to_dict()
    assert ExperimentResult.from_dict(
        json.loads(json.dumps(d))).to_dict() == d


def test_result_reports_steady_state_window():
    res = simulate(_tiny_exp(warmup=1.5))
    assert res.warmup == 1.5
    assert res.n_requests <= res.n_requests_total
    m = res.sim.metrics
    assert res.n_requests == sum(1 for r in m.requests
                                 if r.arrival_time >= 1.5)


# -- sweeps ------------------------------------------------------------------


def test_run_sweep_grid_shape_and_schema():
    sweep = run_sweep(_tiny_exp(), {"stack": ["archipelago", "fifo"],
                                    "seed": [0, 1]})
    assert len(sweep) == 4
    cells = [row["cell"] for row in sweep]
    assert cells == [{"stack": "archipelago", "seed": 0},
                     {"stack": "archipelago", "seed": 1},
                     {"stack": "fifo", "seed": 0},
                     {"stack": "fifo", "seed": 1}]
    keys = {frozenset(row["result"].keys()) for row in sweep}
    assert len(keys) == 1        # stable row schema across cells


def test_run_sweep_cells_deterministic_and_order_independent():
    """Each (seed, config) cell is a pure function of its Experiment: the
    same cell re-simulated standalone, in reverse order, matches the sweep
    row bit-for-bit (modulo wall time)."""
    base = _tiny_exp()
    axes = {"seed": [0, 1], "workload_kwargs.scale": [0.02, 0.03]}
    sweep = run_sweep(base, axes)
    for row in reversed(sweep.rows):
        cell = row["cell"]
        exp = replace(base, seed=cell["seed"],
                      workload_kwargs=dict(base.workload_kwargs,
                                           scale=cell["workload_kwargs.scale"]))
        again = simulate(exp).to_dict()
        want = dict(row["result"])
        again.pop("wall_s")
        want.pop("wall_s")
        assert again == want


def test_run_sweep_nested_config_axes():
    sweep = run_sweep(_tiny_exp(cluster=None),
                      {"cluster.n_sgs": [1, 2], "sgs.proactive": [True]})
    assert len(sweep) == 2
    for row, n in zip(sweep, [1, 2]):
        assert row["cell"]["cluster.n_sgs"] == n
        assert row["result"]["n_completed"] > 0


def test_run_sweep_rejects_unknown_axis():
    with pytest.raises(ValueError, match="cannot sweep|unknown"):
        run_sweep(_tiny_exp(), {"nonsense.axis": [1]})


def test_workload_factory_by_name_validated():
    with pytest.raises(ValueError, match="paper_workload_1"):
        simulate(_tiny_exp(workload_factory="not_a_workload"))
    with pytest.raises(ValueError, match="workload"):
        simulate(Experiment(stack="fifo"))


def test_experiment_with_constant_rate_workload_object():
    from repro.core.types import DagSpec, FunctionSpec
    dag = DagSpec("d", (FunctionSpec("d/f", 0.05),), (), deadline=0.5)
    spec = WorkloadSpec([(dag, ConstantRate(20.0))], duration=2.0)
    res = simulate(Experiment(workload=spec, cluster=SMALL))
    assert res.n_completed == res.n_requests > 0
